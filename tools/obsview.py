"""Pretty-print or diff manifest-stamped run JSONs, and render the
local perf history.

Every ``benchmarks/run.py --json`` output (and anything written through
``benchmarks.common.save_json``) carries a ``repro.obs.report``
manifest. This tool renders one run — provenance header plus a flat
metric table — diffs two runs metric-by-metric, or plots the per-metric
trajectory accumulated in ``results/history.jsonl`` (one flattened row
appended per ``save_json`` call), so the perf trend is visible between
checked-in baseline updates.

Usage:
  python tools/obsview.py results/BENCH_fleet.json
  python tools/obsview.py --diff old.json new.json [--threshold 0.05]
      [--fail-on-move]                  # exit 1 if anything moved
  python tools/obsview.py --history [results/history.jsonl]
      [--name BENCH_fleet] [--filter steps_per_s] [--last 12]
  python tools/obsview.py --timeline run.json
      # render windowed learning-curve series + SLO attainment tables

Flattening and the relative-diff rule are shared with the
``tools/benchgate.py`` regression gate via ``repro.obs.report``. A
plain diff still exits 0 (information, not a gate); ``--fail-on-move``
turns the threshold into an exit code for scripting.
"""
import argparse
import json
import numbers
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.obs.report import flatten, is_number, rel_diff  # noqa: E402
from repro.obs.timeline import window_series  # noqa: E402


def load(path) -> dict:
    with open(path) as f:
        return json.load(f)


def manifest_lines(payload: dict):
    m = payload.get("manifest")
    if not m:
        return ["  (no manifest)"]
    git = m.get("git") or {}
    sha = git.get("sha") or "?"
    dirty = "+dirty" if git.get("dirty") else ""
    lines = [
        f"  git      {sha[:12]}{dirty} ({git.get('branch', '?')})",
        f"  created  {m.get('created_utc', '?')}",
        f"  jax      {m.get('jax_version', '?')} on "
        f"{m.get('backend', '?')} x{m.get('device_count', '?')}",
        f"  python   {m.get('python', '?')}",
    ]
    if m.get("mesh_shape"):
        lines.append(f"  mesh     {m['mesh_shape']}")
    if m.get("config_hash"):
        lines.append(f"  config   {m['config_hash']}")
    if m.get("wall_seconds") is not None:
        lines.append(f"  wall     {float(m['wall_seconds']):.1f}s")
    return lines


def fmt(v) -> str:
    if isinstance(v, bool) or not isinstance(v, numbers.Real):
        return str(v)
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return f"{v:.6g}"


def show(path: str) -> None:
    payload = load(path)
    print(path)
    for line in manifest_lines(payload):
        print(line)
    print()
    flat = flatten(payload)
    if not flat:
        print("  (no metrics)")
        return
    width = max(len(k) for k in flat)
    for k in sorted(flat):
        print(f"  {k:<{width}}  {fmt(flat[k])}")


def diff(path_a: str, path_b: str, threshold: float) -> int:
    """Print the metric-by-metric diff; returns the moved count so
    ``--fail-on-move`` can turn it into an exit code."""
    a, b = load(path_a), load(path_b)
    fa, fb = flatten(a), flatten(b)
    print(f"--- {path_a}")
    for line in manifest_lines(a):
        print(line)
    print(f"+++ {path_b}")
    for line in manifest_lines(b):
        print(line)
    print()
    keys = sorted(set(fa) | set(fb))
    width = max(len(k) for k in keys) if keys else 0
    moved = 0
    for k in keys:
        va, vb = fa.get(k), fb.get(k)
        if va == vb:
            continue
        if is_number(va) and is_number(vb):
            rel = rel_diff(va, vb)
            mark = " <-- " if abs(rel) >= threshold else "     "
            print(f"  {k:<{width}}  {fmt(va):>14} -> {fmt(vb):>14} "
                  f"({rel:+.1%}){mark}")
            moved += abs(rel) >= threshold
        else:
            print(f"  {k:<{width}}  {fmt(va):>14} -> {fmt(vb):>14}")
            moved += 1
    print(f"\n{moved} metric(s) moved >= {threshold:.0%} "
          f"(of {len(keys)} compared)")
    return moved


def _walk_dicts(obj, path=()):
    """Yield every nested dict with its dotted path (lists descended)."""
    if isinstance(obj, dict):
        yield path, obj
        for k, v in obj.items():
            yield from _walk_dicts(v, path + (str(k),))
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            yield from _walk_dicts(v, path + (str(i),))


def _opt(v) -> str:
    return fmt(v) if v is not None else "·"


def timeline_view(path: str) -> int:
    """Render every windowed metric series and SLO attainment block in
    a stamped run JSON; returns the number of blocks rendered (0 means
    the run carried no time-resolved telemetry)."""
    payload = load(path)
    print(path)
    for line in manifest_lines(payload):
        print(line)
    rendered = 0
    for p, node in _walk_dicts(payload):
        w = node.get("windows")
        if not (isinstance(w, dict) and "count" in w):
            continue
        rendered += 1
        name = ".".join(p) or "(root)"
        tag = ", wrapped (ring lapped)" if w.get("wrapped") else ""
        print(f"\n  windows  {name}  [n_windows={w.get('n_windows')} "
              f"window_len={w.get('window_len')}{tag}]")
        print(f"    {'slot':>4}  {'count':>8}  {'mean':>12}  "
              f"{'min':>12}  {'max':>12}")
        for slot, count, mean, mn, mx in window_series(node):
            mark = "  <- last" if slot == w.get("last_slot") else ""
            print(f"    {slot:>4}  {count:>8}  {_opt(mean):>12}  "
                  f"{_opt(mn):>12}  {_opt(mx):>12}{mark}")
    for p, node in _walk_dicts(payload):
        if not ("deadline_ms" in node and "measured" in node
                and "per_tier_variant" in node):
            continue
        rendered += 1
        name = ".".join(p) or "(root)"
        m, pr = node["measured"], node["predicted"]
        print(f"\n  slo  {name}  [deadline {fmt(node['deadline_ms'])} ms, "
              f"{node['requests']} request(s)]")
        print(f"    {'':<12}  {'attained':>8}  {'violated':>8}  "
              f"{'attainment':>10}")
        print(f"    {'measured':<12}  {m['attained']:>8}  "
              f"{m['violated']:>8}  {m['attainment']:>10.1%}")
        print(f"    {'predicted':<12}  {pr['attained']:>8}  "
              f"{pr['violated']:>8}  {pr['attainment']:>10.1%}")
        print(f"    attainment gap (predicted - measured): "
              f"{node['attainment_gap']:+.1%}")
        per = node["per_tier_variant"]
        if per:
            width = max(len(k) for k in per)
            for key in sorted(per):
                tv = per[key]
                print(f"    {key:<{width}}  "
                      f"{tv['dispatched']:>4} dispatched  "
                      f"measured {tv['attainment_measured']:.1%}  "
                      f"predicted {tv['attainment_predicted']:.1%}")
        q = node.get("quantiles") or {}
        exact, hist = q.get("exact_ms") or {}, q.get("hist_ms") or {}
        keys = [k for k in ("p50", "p90", "p95", "p99") if k in exact]
        if keys:
            print(f"    {'quantile':<10}  {'exact_ms':>12}  "
                  f"{'hist_ms':>12}")
            for k in keys:
                print(f"    {k:<10}  {fmt(exact[k]):>12}  "
                      f"{_opt(hist.get(k)):>12}")
            if hist:
                tag = "  CLIPPED (bound void)" if hist.get("clipped") \
                    else ""
                print(f"    (hist bound: one bin_width = "
                      f"{fmt(hist.get('bin_width'))} ms{tag})")
    for p, node in _walk_dicts(payload):
        if not ("coefficients" in node and "before" in node
                and "after" in node):
            continue
        rendered += 1
        name = ".".join(p) or "(root)"
        before, after = node["before"], node["after"]
        print(f"\n  calibration  {name}  "
              f"[{after.get('requests')} request(s)]")
        coeff = node["coefficients"]
        width = max(len(t) for t in coeff)
        print(f"    {'tier':<{width}}  {'compute_scale':>13}  "
              f"{'hop_offset_ms':>13}  {'requests':>8}  "
              f"{'resid_rms_ms':>12}")
        for tier, c in coeff.items():
            print(f"    {tier:<{width}}  {fmt(c['compute_scale']):>13}  "
                  f"{fmt(c['hop_offset_ms']):>13}  "
                  f"{c.get('requests', 0):>8}  "
                  f"{_opt(c.get('resid_rms_ms')):>12}")
        print(f"    {'':<8}  {'gap_x':>10}  {'measured_ms':>12}  "
              f"{'predicted_ms':>13}  {'attainment':>10}")
        for label, blk in (("before", before), ("after", after)):
            att = blk.get("attainment_measured")
            att_s = f"{att:.1%}" if att is not None else "·"
            print(f"    {label:<8}  {_opt(blk.get('gap_x')):>10}  "
                  f"{_opt(blk.get('measured_mean_ms')):>12}  "
                  f"{_opt(blk.get('predicted_mean_ms')):>13}  "
                  f"{att_s:>10}")
        rt = node.get("retrained")
        if rt:
            print(f"    retrained policy: holdout_reward_ratio "
                  f"{fmt(rt.get('holdout_reward_ratio'))} "
                  f"({rt.get('train_steps')} steps, "
                  f"{rt.get('cells')} cells)")
    if not rendered:
        print("\n  (no windowed metrics, SLO, or calibration blocks in "
              "this run)")
    return rendered


def history(path: str, name: str, substr: str, last: int) -> None:
    """Per-metric trajectory over the appended ``history.jsonl`` rows
    (oldest -> newest), restricted to one bench ``name`` and keys
    containing ``substr``. Nested ``suites.*`` detail rows are skipped
    unless explicitly matched by ``--filter``."""
    if not os.path.exists(path):
        print(f"{path}: no history yet (rows are appended by "
              "benchmarks.common.save_json)")
        return
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            if name and r.get("_name") != name:
                continue
            rows.append(r)
    rows = rows[-last:]
    if not rows:
        print(f"{path}: no rows" + (f" for name {name!r}" if name else ""))
        return
    print(f"{path}: {len(rows)} run(s)"
          + (f" of {name!r}" if name else "") + ", oldest -> newest")
    for r in rows:
        print(f"  {r.get('_created_utc', '?'):<26} "
              f"git {str(r.get('_git_sha'))[:12]}")
    print()
    keys = sorted({k for r in rows for k in r
                   if not k.startswith("_") and is_number(r[k])})
    if substr:
        keys = [k for k in keys if substr in k]
    else:
        keys = [k for k in keys if not k.startswith("suites.")]
    if not keys:
        print("  (no matching numeric metrics)")
        return
    width = max(len(k) for k in keys)
    for k in keys:
        vals = [r.get(k) for r in rows]
        present = [v for v in vals if is_number(v)]
        traj = " -> ".join(fmt(v) if is_number(v) else "·" for v in vals)
        tail = ""
        if len(present) >= 2:
            tail = f"  ({rel_diff(present[0], present[-1]):+.1%} overall)"
        print(f"  {k:<{width}}  {traj}{tail}")


def main() -> None:
    ap = argparse.ArgumentParser(
        description="pretty-print one manifest-stamped run JSON, diff "
                    "two, or render the local results/history.jsonl")
    ap.add_argument("paths", nargs="*",
                    help="one run; two with --diff; optional history "
                         "path with --history")
    ap.add_argument("--diff", action="store_true",
                    help="diff two runs metric-by-metric")
    ap.add_argument("--threshold", type=float, default=0.05,
                    help="relative move that gets flagged (default 5%%)")
    ap.add_argument("--fail-on-move", action="store_true",
                    help="with --diff: exit 1 when any metric moved "
                         ">= threshold")
    ap.add_argument("--history", action="store_true",
                    help="render per-metric trajectories from "
                         "history.jsonl (default results/history.jsonl)")
    ap.add_argument("--timeline", action="store_true",
                    help="render windowed metric series and SLO "
                         "attainment tables from run JSONs")
    ap.add_argument("--name", default="BENCH_fleet",
                    help="history: bench name to select ('' for all)")
    ap.add_argument("--filter", default="",
                    help="history: only metrics containing this "
                         "substring (also unhides suites.* keys)")
    ap.add_argument("--last", type=int, default=10,
                    help="history: number of most recent runs")
    args = ap.parse_args()
    if sum((args.diff, args.history, args.timeline)) > 1:
        ap.error("--diff, --history and --timeline are mutually exclusive")
    if args.timeline:
        if not args.paths:
            ap.error("--timeline needs at least one run JSON")
        for p in args.paths:
            timeline_view(p)
    elif args.history:
        default = os.path.join(os.path.dirname(__file__), "..", "results",
                               "history.jsonl")
        path = args.paths[0] if args.paths else default
        history(path, args.name, args.filter, max(args.last, 1))
    elif args.diff:
        if len(args.paths) != 2:
            ap.error("--diff needs exactly two paths")
        moved = diff(args.paths[0], args.paths[1], args.threshold)
        if args.fail_on_move and moved:
            sys.exit(1)
    else:
        if not args.paths:
            ap.error("give at least one run JSON (or --history)")
        for p in args.paths:
            show(p)


if __name__ == "__main__":
    main()
