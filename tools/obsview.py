"""Pretty-print or diff manifest-stamped run JSONs.

Every ``benchmarks/run.py --json`` output (and anything written through
``benchmarks.common.save_json``) carries a ``repro.obs.report``
manifest. This tool renders one run — provenance header plus a flat
metric table — or diffs two runs metric-by-metric, flagging moves
above a threshold.

Usage:
  python tools/obsview.py results/BENCH_fleet.json
  python tools/obsview.py --diff old.json new.json [--threshold 0.05]

Stdlib only; exit code 0 always (a diff is information, not a gate).
"""
import argparse
import json
import numbers


def flatten(obj, prefix=""):
    """Flat dict of dotted-path -> scalar, skipping the manifest."""
    out = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            if k == "manifest":
                continue
            out.update(flatten(v, f"{prefix}{k}."))
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            out.update(flatten(v, f"{prefix}{i}."))
    else:
        out[prefix[:-1]] = obj
    return out


def load(path) -> dict:
    with open(path) as f:
        return json.load(f)


def manifest_lines(payload: dict):
    m = payload.get("manifest")
    if not m:
        return ["  (no manifest)"]
    git = m.get("git") or {}
    sha = git.get("sha") or "?"
    dirty = "+dirty" if git.get("dirty") else ""
    lines = [
        f"  git      {sha[:12]}{dirty} ({git.get('branch', '?')})",
        f"  created  {m.get('created_utc', '?')}",
        f"  jax      {m.get('jax_version', '?')} on "
        f"{m.get('backend', '?')} x{m.get('device_count', '?')}",
        f"  python   {m.get('python', '?')}",
    ]
    if m.get("mesh_shape"):
        lines.append(f"  mesh     {m['mesh_shape']}")
    if m.get("config_hash"):
        lines.append(f"  config   {m['config_hash']}")
    if m.get("wall_seconds") is not None:
        lines.append(f"  wall     {float(m['wall_seconds']):.1f}s")
    return lines


def fmt(v) -> str:
    if isinstance(v, bool) or not isinstance(v, numbers.Real):
        return str(v)
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return f"{v:.6g}"


def show(path: str) -> None:
    payload = load(path)
    print(path)
    for line in manifest_lines(payload):
        print(line)
    print()
    flat = flatten(payload)
    if not flat:
        print("  (no metrics)")
        return
    width = max(len(k) for k in flat)
    for k in sorted(flat):
        print(f"  {k:<{width}}  {fmt(flat[k])}")


def diff(path_a: str, path_b: str, threshold: float) -> None:
    a, b = load(path_a), load(path_b)
    fa, fb = flatten(a), flatten(b)
    print(f"--- {path_a}")
    for line in manifest_lines(a):
        print(line)
    print(f"+++ {path_b}")
    for line in manifest_lines(b):
        print(line)
    print()
    keys = sorted(set(fa) | set(fb))
    width = max(len(k) for k in keys) if keys else 0
    moved = 0
    for k in keys:
        va, vb = fa.get(k), fb.get(k)
        if va == vb:
            continue
        if isinstance(va, numbers.Real) and isinstance(vb, numbers.Real) \
                and not isinstance(va, bool) and not isinstance(vb, bool):
            base = abs(va) if va else 1.0
            rel = (vb - va) / base
            mark = " <-- " if abs(rel) >= threshold else "     "
            print(f"  {k:<{width}}  {fmt(va):>14} -> {fmt(vb):>14} "
                  f"({rel:+.1%}){mark}")
            moved += abs(rel) >= threshold
        else:
            print(f"  {k:<{width}}  {fmt(va):>14} -> {fmt(vb):>14}")
            moved += 1
    print(f"\n{moved} metric(s) moved >= {threshold:.0%} "
          f"(of {len(keys)} compared)")


def main() -> None:
    ap = argparse.ArgumentParser(
        description="pretty-print one manifest-stamped run JSON or "
                    "diff two")
    ap.add_argument("paths", nargs="+", help="one run, or two with --diff")
    ap.add_argument("--diff", action="store_true",
                    help="diff two runs metric-by-metric")
    ap.add_argument("--threshold", type=float, default=0.05,
                    help="relative move that gets flagged (default 5%%)")
    args = ap.parse_args()
    if args.diff:
        if len(args.paths) != 2:
            ap.error("--diff needs exactly two paths")
        diff(args.paths[0], args.paths[1], args.threshold)
    else:
        for p in args.paths:
            show(p)


if __name__ == "__main__":
    main()
