"""Docs link-check: every relative markdown link in README.md, docs/,
and the per-package READMEs must resolve to a real file or directory.

Usage:  python tools/check_links.py   (exit 1 on any dangling link)

External links (http/https/mailto) and pure in-page anchors are
skipped — this guards the repo's own structure, not the internet.
"""
import os
import re
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

#: inline markdown links: [text](target); images share the syntax
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_SKIP = ("http://", "https://", "mailto:", "#")


def md_files():
    yield os.path.join(ROOT, "README.md")
    for base in ("docs", "src", "tests", "benchmarks", "examples"):
        for dirpath, dirnames, filenames in os.walk(os.path.join(ROOT, base)):
            dirnames[:] = [d for d in dirnames if d != "__pycache__"]
            for f in filenames:
                if f.endswith(".md"):
                    yield os.path.join(dirpath, f)


def check(path) -> list:
    bad = []
    with open(path, encoding="utf-8") as f:
        text = f.read()
    # drop fenced code blocks — `[x](y)` inside code is not a link
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    for m in _LINK.finditer(text):
        target = m.group(1)
        if target.startswith(_SKIP):
            continue
        target = target.split("#", 1)[0]
        if not target:
            continue
        resolved = os.path.normpath(
            os.path.join(os.path.dirname(path), target))
        if not os.path.exists(resolved):
            bad.append((target, resolved))
    return bad


def main() -> int:
    failures = 0
    for path in md_files():
        if not os.path.exists(path):
            print(f"MISSING FILE: {os.path.relpath(path, ROOT)}")
            failures += 1
            continue
        for target, resolved in check(path):
            rel = os.path.relpath(path, ROOT)
            print(f"DANGLING: {rel}: ({target}) -> "
                  f"{os.path.relpath(resolved, ROOT)}")
            failures += 1
    if failures:
        print(f"{failures} dangling link(s)")
        return 1
    print("all relative markdown links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
