"""CI observability smoke: instrumented train + route on tiny budgets.

Five gates (ISSUE 6 + the ISSUE 8 SLO identity):
  1. an instrumented FleetQLearning training run records coherent
     in-scan metrics (counts match, rewards inside the dynamics range);
  2. a span-instrumented route(dispatch=real engines) emits trace JSON
     that passes the Chrome trace-event schema validator and reloads;
  3. the gap_breakdown components satisfy both exact sum identities
     (per-request queueing+compute == e2e; wall batching+compute+
     dispatch == total);
  4. SLO accounting is exact: attained + violated == dispatched
     requests overall AND per (tier, variant), the `request.e2e` span
     durations reproduce the served e2e latencies, the trace carries
     the `slo.attainment` counter track, and the histogram quantiles
     agree with the host-exact ones within one bin width (unless the
     accumulator's underflow/overflow counts flag clipping);
  5. metrics overhead: instrumented vs uninstrumented FleetDQN RL-loop
     throughput < OVERHEAD_GATE, best-of-N with retries so CI timer
     noise doesn't flake the gate. The budget (128 cells, chunk 200)
     is the smallest where per-chunk host dispatch is amortized; at
     --tiny scale (16 cells, chunk 20) dispatch dominates the step and
     the ratio measures Python overhead, not the accumulator.

Usage:  PYTHONPATH=src python tools/obs_smoke.py [--skip-overhead]
Exit 1 on the first failed gate.
"""
import argparse
import json
import os
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(ROOT, "src"))
sys.path.insert(0, ROOT)

OVERHEAD_GATE = 1.05      # uninstrumented/instrumented steps-per-s
TRACE_PATH = os.path.join(ROOT, "results", "obs_trace_smoke.json")


def check(name: str, ok: bool, detail: str = "") -> None:
    print(f"[obs_smoke] {'ok  ' if ok else 'FAIL'} {name}"
          f"{' — ' + detail if detail else ''}", flush=True)
    if not ok:
        sys.exit(1)


def train_and_route():
    import numpy as np
    from repro.fleet.api import FleetOrchestrator, TraceSource
    from repro.fleet.dynamics import MAX_RESPONSE_MS
    from repro.fleet.population import FleetQLearning
    from repro.launch.serve import build_engines, get_config
    from repro.obs import SpanRecorder, run_manifest, validate_chrome_trace

    src = TraceSource.load(os.path.join(ROOT, "tests", "data",
                                        "trace_small.npz"))
    agent = FleetQLearning(src, seed=0)
    steps = 2 * src.horizon
    agent.run(steps)

    # gate 1: in-scan metrics are coherent
    s = agent.metrics_summary()
    check("metrics.counts", s["reward"]["count"] == src.cells * steps,
          f"{s['reward']['count']} == {src.cells * steps}")
    floor = -MAX_RESPONSE_MS / 1000.0
    check("metrics.reward_range",
          floor <= s["reward"]["min"] <= s["reward"]["max"] <= 0.0,
          f"[{s['reward']['min']:.3f}, {s['reward']['max']:.3f}]")
    check("metrics.hist_mass",
          sum(s["reward"]["hist"]) == s["reward"]["count"])

    # gate 2+3: spans through a real engine dispatch
    engines = build_engines(get_config("edge-ladder"), variants=("d0",),
                            max_len=48)
    rec = SpanRecorder()
    res = FleetOrchestrator(agent).route(
        dispatch=engines, max_new_tokens=2, batch_size=4, prompt_len=8,
        spans=rec, with_edge_util=True)
    gb = res.summary()["gap_breakdown"]
    w, pr = gb["wall_ms"], gb["per_request_ms"]
    check("gap.wall_identity",
          abs(w["batching"] + w["compute"] + w["dispatch"] - w["total"])
          < 1e-6 and w["dispatch"] >= 0.0,
          f"{w['batching']:.1f}+{w['compute']:.1f}+{w['dispatch']:.1f}"
          f" == {w['total']:.1f} ms")
    check("gap.e2e_identity",
          abs(pr["queueing"] + pr["compute"] - pr["e2e"]) < 1e-6,
          f"{pr['queueing']:.1f}+{pr['compute']:.1f} == {pr['e2e']:.1f} ms")
    check("gap.queue_nonneg",
          all(r.queue_ms >= 0.0 for r in res.served))

    path = rec.save(TRACE_PATH, manifest=run_manifest())
    with open(path) as f:
        trace = json.load(f)
    validate_chrome_trace(trace)
    names = {e["name"] for e in trace["traceEvents"]}
    need = {"route.decide", "route.dispatch", "dispatch.batch_build",
            "engine.generate", "engine.prefill", "engine.decode",
            "request.e2e"}
    check("trace.schema_and_spans", need <= names,
          f"{len(trace['traceEvents'])} events -> {path}")

    # gate 4: SLO accounting is exact at every granularity
    slo = res.slo()
    n, m, p = slo["requests"], slo["measured"], slo["predicted"]
    check("slo.measured_identity", m["attained"] + m["violated"] == n,
          f"{m['attained']} + {m['violated']} == {n}")
    check("slo.predicted_identity", p["attained"] + p["violated"] == n,
          f"{p['attained']} + {p['violated']} == {n}")
    check("slo.per_tier_identity",
          all(tv["measured_attained"] + tv["measured_violated"]
              == tv["dispatched"]
              and tv["predicted_attained"] + tv["predicted_violated"]
              == tv["dispatched"]
              for tv in slo["per_tier_variant"].values())
          and sum(tv["dispatched"]
                  for tv in slo["per_tier_variant"].values()) == n,
          f"{len(slo['per_tier_variant'])} (tier, variant) group(s)")
    e2e = np.sort(np.asarray([r.e2e_ms for r in res.served]))
    spans_ms = np.sort(np.asarray(rec.durations_ms("request.e2e")))
    check("slo.spans_match_served",
          spans_ms.size == e2e.size
          and np.allclose(spans_ms, e2e, rtol=1e-6),
          f"{spans_ms.size} request.e2e span(s)")
    check("slo.counter_track",
          any(e["ph"] == "C" and e["name"] == "slo.attainment"
              for e in trace["traceEvents"]))
    q = slo["quantiles"]
    exact, hist = q["exact_ms"], q["hist_ms"]
    if hist["clipped"]:
        print("[obs_smoke] skip slo.quantile_bound — histogram clipped "
              f"(underflow {hist['underflow']}, overflow "
              f"{hist['overflow']})", flush=True)
    else:
        worst = max(abs(exact[k] - hist[k])
                    for k in ("p50", "p90", "p95", "p99"))
        check("slo.quantile_bound", worst <= hist["bin_width"] + 1e-9,
              f"max |exact - hist| {worst:.1f} <= bin "
              f"{hist['bin_width']:.1f} ms")


def overhead_gate():
    """Best-of-N timing, retried: the accumulator update is a handful of
    elementwise ops against a full RL step, so the true ratio is ~1.0;
    retries absorb CI scheduler noise without weakening the gate."""
    from benchmarks.bench_fleet_dqn import bench_rl
    from repro.fleet import FleetDQN, FleetDQNConfig

    cells, steps, chunk = 128, 400, 200
    best = float("inf")
    for attempt in range(3):
        on = min(bench_rl(FleetDQN, cells, steps, chunk,
                          cfg=FleetDQNConfig(), seed=0)
                 for _ in range(2))
        off = min(bench_rl(FleetDQN, cells, steps, chunk,
                           cfg=FleetDQNConfig(), seed=0, metrics=False)
                  for _ in range(2))
        ratio = off / on
        best = min(best, ratio)
        print(f"[obs_smoke] overhead attempt {attempt + 1}: "
              f"{ratio:.3f}x (instrumented {on:.0f} vs "
              f"uninstrumented {off:.0f} steps/s)", flush=True)
        if best < OVERHEAD_GATE:
            break
    check("metrics.overhead", best < OVERHEAD_GATE,
          f"{best:.3f}x < {OVERHEAD_GATE}x")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-overhead", action="store_true",
                    help="schema/identity gates only (no timing)")
    args = ap.parse_args()
    train_and_route()
    if not args.skip_overhead:
        overhead_gate()
    print("[obs_smoke] all gates passed", flush=True)


if __name__ == "__main__":
    main()
