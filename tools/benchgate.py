"""Perf regression gate over ``results/BENCH_fleet.json``.

``benchmarks/run.py --json`` writes the fleet headline metrics; this
tool diffs a FRESH run against the tracked baseline with a per-key
direction+tolerance table and exits nonzero on regression — so a perf
regression fails the build instead of merging silently.

Usage:
  # full gate: fresh run vs tracked baseline
  REPRO_BENCH_OUT=/tmp/fresh PYTHONPATH=src python -m benchmarks.run --json
  python tools/benchgate.py results/BENCH_fleet.json /tmp/fresh/BENCH_fleet.json

  # structural mode (CI): the baseline itself is well-formed — manifest
  # present, every gated key populated — without rerunning benchmarks
  python tools/benchgate.py --structural results/BENCH_fleet.json

Comparisons are manifest-aware: a baseline recorded on another backend
or device count is not comparable (CPU CI numbers vs an accelerator
run would always "regress") — the gate refuses with exit 2 unless
``--force``. Exit codes: 0 pass, 1 regression, 2 not-comparable /
structurally broken / usage error.

Tolerances are sized for CI-class shared CPU runners where wall-clock
throughputs jitter tens of percent run-to-run; quality metrics
(``dqn_holdout_reward_ratio``) gate on an absolute floor instead.
``--tolerance-scale`` widens/narrows every relative band at once.
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.obs.report import flatten, is_number, rel_diff  # noqa: E402

#: key -> (direction, tolerance). Directions:
#:   higher  — throughput-like, regression when new < old * (1 - tol)
#:   lower   — overhead/gap-like, regression when new > old * (1 + tol)
#:   floor   — quality, regression when new < tol (absolute; baseline
#:             value is informational only)
#:   ceiling — quality, regression when new > tol (absolute; the dual
#:             of floor — e.g. the calibrated model error must stay
#:             within 1.5x of the measured engines regardless of what
#:             the baseline recorded)
RULES = {
    "env_steps_per_s":            ("higher", 0.40),
    "rl_steps_per_s":             ("higher", 0.40),
    "dqn_rl_steps_per_s":         ("higher", 0.40),
    "converged_cells_per_s":      ("higher", 0.50),
    "trace_env_steps_per_s":      ("higher", 0.40),
    "sharded_env_steps_per_s":    ("higher", 0.40),
    "dqn_holdout_reward_ratio":   ("floor", 0.95),
    "dqn_obs_overhead_x":         ("lower", 0.10),
    "trace_serving_gap_x":        ("lower", 0.60),
    # ISSUE 8 — SLO attainment through the serving bridge. Attainment
    # fractions gate on absolute floors (a fraction of requests meeting
    # the QoS deadline, not a throughput); p99 and the windowed-metrics
    # overhead ratio are wall-clock-ish and get the wide CI bands.
    "slo_attainment_measured":    ("floor", 0.50),
    "slo_attainment_predicted":   ("floor", 0.50),
    "p99_ms":                     ("lower", 0.60),
    "windowed_overhead_x":        ("lower", 0.10),
    # ISSUE 9 — async serving bridge + sim-to-real calibration. The
    # bridge throughput gets the wide CI band of the other wall-clock
    # metrics; the calibration quality gates are absolute: the fitted
    # model must land within 1.5x of the measured engines (ceiling)
    # and the policy retrained on calibrated dynamics must still match
    # the oracle on a calibrated holdout (floor).
    "bridge_throughput_rps":      ("higher", 0.50),
    "calibrated_gap_x":           ("ceiling", 1.5),
    "calibrated_dqn_holdout_reward_ratio": ("floor", 0.95),
    # ISSUE 10 — fused RL hot path. Throughputs get the usual CI bands;
    # the speedup ratios gate on absolute floors (fused/unfused on the
    # same box in the same run, so runner speed divides out): the fused
    # tabular act+update must hold >= 2x the legacy step (measured
    # ~2.0-2.4x, floor at 1.7 for jitter) and the fused constrained DQN
    # head must stay measurably ahead (~1.18x measured, floor 1.02).
    "rl_fused_tabular_steps_per_s":  ("higher", 0.40),
    "rl_unfused_tabular_steps_per_s": ("higher", 0.40),
    "rl_fused_tabular_speedup_x":    ("floor", 1.7),
    "rl_fused_dqn_steps_per_s":      ("higher", 0.40),
    "rl_unfused_dqn_steps_per_s":    ("higher", 0.40),
    "rl_fused_dqn_speedup_x":        ("floor", 1.02),
}

#: manifest fields that must match for numbers to be comparable
COMPARABLE_FIELDS = ("backend", "device_count")


def load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def check_comparable(base: dict, new: dict, force: bool):
    """Refuse cross-backend / cross-device-count diffs (exit 2) unless
    forced; returns a list of human-readable mismatch lines."""
    mb, mn = base.get("manifest"), new.get("manifest")
    problems = []
    if not mb or not mn:
        problems.append("manifest missing on "
                        + ("both" if not mb and not mn else
                           "baseline" if not mb else "new run"))
    else:
        for field in COMPARABLE_FIELDS:
            if mb.get(field) != mn.get(field):
                problems.append(f"{field}: baseline={mb.get(field)!r} "
                                f"new={mn.get(field)!r}")
        if mb.get("jax_version") != mn.get("jax_version"):
            print(f"note: jax_version differs "
                  f"({mb.get('jax_version')} -> {mn.get('jax_version')}) "
                  f"— comparing anyway")
    if problems and not force:
        print("NOT COMPARABLE (use --force to diff anyway):")
        for p in problems:
            print(f"  {p}")
        sys.exit(2)
    return problems


def gate(base: dict, new: dict, scale: float) -> int:
    """Apply RULES; print one line per gated key; return #regressions."""
    fb, fn = flatten(base), flatten(new)
    regressions = 0
    width = max(len(k) for k in RULES)
    for key, (direction, tol) in RULES.items():
        vb, vn = fb.get(key), fn.get(key)
        if not is_number(vn):
            print(f"  {key:<{width}}  SKIP (new run has no value: {vn!r})")
            continue
        if direction == "floor":
            ok = vn >= tol
            detail = f"{vn:.6g} vs floor {tol:.6g}"
        elif direction == "ceiling":
            ok = vn <= tol
            detail = f"{vn:.6g} vs ceiling {tol:.6g}"
        elif not is_number(vb):
            print(f"  {key:<{width}}  SKIP (baseline has no value: {vb!r})")
            continue
        else:
            rel = rel_diff(vb, vn)
            t = tol * scale
            ok = rel >= -t if direction == "higher" else rel <= t
            detail = (f"{vb:.6g} -> {vn:.6g} ({rel:+.1%}, "
                      f"{direction}-better, tol {t:.0%})")
        print(f"  {key:<{width}}  {'ok  ' if ok else 'REGR'}  {detail}")
        regressions += not ok
    return regressions


def structural(base: dict) -> int:
    """Baseline well-formedness: manifest fields + every gated key
    present and numeric. Returns #problems."""
    problems = []
    m = base.get("manifest")
    if not m:
        problems.append("no manifest attached")
    else:
        for field in COMPARABLE_FIELDS + ("git", "created_utc",
                                          "jax_version"):
            if m.get(field) is None:
                problems.append(f"manifest.{field} missing/null")
    fb = flatten(base)
    for key in RULES:
        if not is_number(fb.get(key)):
            problems.append(f"gated key {key!r} missing or non-numeric "
                            f"({fb.get(key)!r})")
    for p in problems:
        print(f"  STRUCTURAL: {p}")
    return len(problems)


def main() -> None:
    ap = argparse.ArgumentParser(
        description="gate a fresh bench JSON against the tracked "
                    "baseline (exit 1 on regression, 2 on mismatch)")
    ap.add_argument("paths", nargs="+",
                    help="baseline.json new.json — or one baseline "
                         "with --structural")
    ap.add_argument("--structural", action="store_true",
                    help="only check the baseline is well-formed "
                         "(manifest + all gated keys populated)")
    ap.add_argument("--force", action="store_true",
                    help="diff across backend/device-count mismatches")
    ap.add_argument("--tolerance-scale", type=float, default=1.0,
                    help="multiply every relative tolerance band "
                         "(floors/ceilings unaffected)")
    args = ap.parse_args()

    if args.structural:
        if len(args.paths) != 1:
            ap.error("--structural takes exactly one path")
        base = load(args.paths[0])
        print(f"structural check: {args.paths[0]}")
        n = structural(base)
        print(f"{n} structural problem(s)")
        sys.exit(0 if n == 0 else 2)

    if len(args.paths) != 2:
        ap.error("need baseline.json new.json (or --structural one.json)")
    base, new = load(args.paths[0]), load(args.paths[1])
    print(f"gate: {args.paths[1]} vs baseline {args.paths[0]}")
    check_comparable(base, new, args.force)
    n = gate(base, new, args.tolerance_scale)
    print(f"{n} regression(s)")
    sys.exit(0 if n == 0 else 1)


if __name__ == "__main__":
    main()
