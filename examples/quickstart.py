"""Quickstart: train a reduced gemma-family model on synthetic data,
checkpoint it, and serve a few generations — the whole substrate in one
script (CPU, ~2 min).

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_pytree, save_pytree
from repro.configs import get_config, reduced
from repro.models import build_model
from repro.serving import ServingEngine
from repro.training import AdamWConfig, init_state, make_train_step
from repro.training.data import batches


def main():
    cfg = reduced(get_config("gemma-7b"))
    import dataclasses
    cfg = dataclasses.replace(cfg, vocab_size=128)
    model = build_model(cfg)
    state = init_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, AdamWConfig(
        lr=1e-3, warmup_steps=5, total_steps=60)))

    print("training 60 steps on a synthetic Markov LM...")
    for i, b in enumerate(batches(cfg.vocab_size, 8, 64, 60, seed=1)):
        state, metrics = step(state, {k: jnp.asarray(v) for k, v in b.items()})
        if i % 10 == 0 or i == 59:
            print(f"  step {i:3d}  loss {float(metrics['loss']):7.3f}")

    save_pytree("/tmp/repro_quickstart", state["params"])
    params = load_pytree("/tmp/repro_quickstart", state["params"])
    print("checkpoint round-tripped")

    eng = ServingEngine(model, params, max_len=96)
    prompt = np.arange(16, dtype=np.int32)[None] % cfg.vocab_size
    out, wall = eng.generate(prompt, max_new_tokens=12)
    print(f"generated {out.shape[1]} tokens in {wall*1e3:.0f} ms: {out[0]}")


if __name__ == "__main__":
    main()
