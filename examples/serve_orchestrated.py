"""END-TO-END DRIVER (deliverable b): multi-user inference serving with
RL orchestration over real engines — the paper's Fig. 4 runtime through
the redesigned fleet front door (``repro.fleet.api``).

A small fleet of cells (heterogeneous Table-5 network patterns) is
trained online by the batched tabular agent; each wave, ONE
``FleetOrchestrator.route(dispatch=engines)`` call routes every active
user to a (tier, model-variant), batches the requests per engine
(``RequestBatcher``), runs REAL jitted transformer engines (the d0..d7
edge-ladder), and reports the measured wall-clock next to the latency
model's prediction — the paper's Table-8 predicted-vs-measured
protocol, now fleet-wide.

  PYTHONPATH=src python examples/serve_orchestrated.py [--waves 4]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.core import THRESHOLDS
from repro.fleet import (FleetConfig, FleetOrchestrator, FleetQConfig,
                         FleetQLearning, SyntheticSource,
                         mixed_table5_fleet)
from repro.launch.serve import build_engines


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cells", type=int, default=4)
    ap.add_argument("--users", type=int, default=3)
    ap.add_argument("--waves", type=int, default=4)
    ap.add_argument("--threshold", default="85%")
    args = ap.parse_args()
    th = THRESHOLDS[args.threshold]

    print("1) training the fleet orchestrator online "
          f"({args.cells} cells x {args.users} users)...")
    cfg = FleetConfig(cells=args.cells, users=args.users)
    scen = mixed_table5_fleet(jax.random.PRNGKey(0), args.cells, args.users)
    agent = FleetQLearning(SyntheticSource(cfg, scen=scen),
                           cfg=FleetQConfig(eps_decay=2e-3,
                                            accuracy_threshold=th), seed=0)
    res = agent.train(max_steps=8000, check_every=200)
    print(f"   {100 * res.frac_converged:.0f}% of cells converged; median "
          f"greedy {np.median(res.greedy_ms):.1f} ms "
          f"(optimal {np.median(res.optimal_ms):.1f} ms)")

    print("2) bringing up tier engines (device/edge/cloud x variant "
          "ladder)...")
    engines = build_engines(get_config("edge-ladder"),
                            variants=("d0", "d2", "d5", "d7"), max_len=48)

    print("3) route -> batch -> serve, one call per wave:")
    orch = FleetOrchestrator(agent)
    gaps = []
    for wave in range(args.waves):
        out = orch.route(dispatch=engines, max_new_tokens=4, batch_size=4,
                         prompt_len=12, seed=wave)
        s = out.summary()
        gaps.append(s["gap_x"])
        pretty = [f"c{r.cell}u{r.user}:{r.variant}@{r.tier}"
                  f"({r.measured_ms:.0f}ms/pred {r.predicted_ms:.0f}ms)"
                  for r in out.served[:6]]
        more = "" if len(out.served) <= 6 else f" +{len(out.served) - 6} more"
        print(f"   wave {wave}: {s['requests']} requests in {s['batches']} "
              f"batches, measured {s['measured_mean_ms']:.0f} ms vs "
              f"predicted {s['predicted_mean_ms']:.0f} ms "
              f"(gap {s['gap_x']:.2f}x)")
        print(f"      {' '.join(pretty)}{more}")
        agent.step()                    # keep learning online between waves
    print(f"4) mean measured/predicted gap over {args.waves} waves: "
          f"{np.mean(gaps):.2f}x (threshold {args.threshold})")


if __name__ == "__main__":
    main()
