"""END-TO-END DRIVER (deliverable b): multi-user inference serving with
RL orchestration over real engines — the paper's Fig. 4 runtime at
reduced scale.

Five simulated end-users issue prompt waves; the cloud-hosted
Intelligent Orchestrator (trained online) picks (tier, model-variant)
per user; requests are batched and served by REAL jitted transformer
engines (the d0..d7 ladder of the edge-ladder config), and measured
wall-clock response times flow back as the environment signal.

  PYTHONPATH=src python examples/serve_orchestrated.py [--waves 4]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (EXPERIMENTS, THRESHOLDS, EndEdgeCloudEnv,
                        IntelligentOrchestrator, QLearningAgent, train_agent)
from repro.configs import get_config
from repro.launch.serve import build_engines
from repro.serving import Request, RequestBatcher


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--users", type=int, default=3)
    ap.add_argument("--waves", type=int, default=4)
    ap.add_argument("--threshold", default="85%")
    args = ap.parse_args()
    th = THRESHOLDS[args.threshold]

    print("1) training the Intelligent Orchestrator online...")
    env = EndEdgeCloudEnv(args.users, EXPERIMENTS["EXP-A"],
                          accuracy_threshold=th, seed=0)
    agent = QLearningAgent(env.spec, seed=0)
    res = train_agent(agent, env, 20000)
    print(f"   converged at {res.converged_at}; greedy {res.greedy_ms:.1f} ms "
          f"(optimal {res.best_ms:.1f} ms)")

    print("2) bringing up tier engines (device/edge/cloud x variant ladder)...")
    cfg = get_config("edge-ladder")
    engines = build_engines(cfg, variants=("d0", "d2", "d5", "d7"), max_len=48)
    # fill ladder gaps: any local decision maps to nearest available variant
    avail = sorted(int(v[1]) for v in engines["S"])

    orch = IntelligentOrchestrator(agent, env, engines)
    state = env.reset()
    rng = np.random.default_rng(0)
    all_ms = []
    for wave in range(args.waves):
        decision = orch.decide(state)
        decision = tuple(a if a >= 8 else min(avail, key=lambda v: abs(v - a))
                         for a in decision)
        prompts = [rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
                   for _ in range(args.users)]
        t0 = time.perf_counter()
        results = orch.dispatch(decision, prompts)
        joint = env.spec.encode_action(decision)
        state, _, info = env.step(joint)
        all_ms.append(info["avg_response_ms"])
        pretty = [f"u{u}:{v}@{t}({ms:.0f}ms)" for u, (v, t, ms)
                  in enumerate(results)]
        print(f"   wave {wave}: {' '.join(pretty)}  "
              f"env_avg={info['avg_response_ms']:.1f}ms "
              f"acc={info['avg_accuracy']:.1f}%")
    print(f"3) mean env response over {args.waves} waves: "
          f"{np.mean(all_ms):.1f} ms (threshold {args.threshold})")


if __name__ == "__main__":
    main()
