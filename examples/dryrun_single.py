"""Minimal dry-run example: lower ONE (arch x shape) onto the production
mesh and print its roofline terms — the building block of deliverable (g).

  PYTHONPATH=src python examples/dryrun_single.py --arch gemma3-4b --shape decode_32k
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

if __name__ == "__main__":
    from repro.launch import dryrun
    sys.exit(dryrun.main())
