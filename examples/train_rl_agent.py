"""Train the paper's RL orchestration agents (Q-Learning + Deep
Q-Learning) on the calibrated end-edge-cloud environment, reproduce the
convergence-to-optimal claim, and compare against SOTA [36] and fixed
strategies.

  PYTHONPATH=src python examples/train_rl_agent.py [--users 3]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (EXPERIMENTS, THRESHOLDS, DQNAgent, DQNConfig,
                        EndEdgeCloudEnv, QLearningAgent, bruteforce_optimal,
                        fixed_strategy_response, make_sota_agent, train_agent)
from repro.core.spaces import restricted_actions


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--users", type=int, default=3)
    ap.add_argument("--threshold", default="85%")
    ap.add_argument("--steps", type=int, default=40000)
    args = ap.parse_args()
    th = THRESHOLDS[args.threshold]

    env = EndEdgeCloudEnv(args.users, EXPERIMENTS["EXP-A"],
                          accuracy_threshold=th, seed=0)
    print(f"== {args.users} users, threshold {args.threshold} ==")
    for s in ("device", "edge", "cloud"):
        ms, acc = fixed_strategy_response(env, s)
        print(f"fixed {s:6s}: {ms:7.1f} ms (acc {acc:.1f}%)")
    _, sota_ms, _, _ = bruteforce_optimal(env, 0.0,
                                          restricted_actions(env.spec))
    print(f"SOTA[36] optimum (CO-only): {sota_ms:7.1f} ms")
    a, opt_ms, opt_acc, n = bruteforce_optimal(env, th)
    print(f"bruteforce optimum ({n} actions): {opt_ms:7.1f} ms "
          f"acc {opt_acc:.1f}% -> {env.spec.decode_action(a)}")

    print("\ntraining Q-Learning (Alg. 1)...")
    ql = QLearningAgent(env.spec, seed=0)
    res = train_agent(ql, env, args.steps, check_every=200, log_every=5000)
    print(f"  converged at step {res.converged_at}; greedy "
          f"{res.greedy_ms:.1f} ms; prediction accuracy "
          f"{res.prediction_accuracy*100:.0f}%")

    print("\ntraining Deep Q-Learning (Alg. 2, replay buffer)...")
    form = "paper" if args.users <= 3 else "factored"
    env = EndEdgeCloudEnv(args.users, EXPERIMENTS["EXP-A"],
                          accuracy_threshold=th, seed=1)
    dq = DQNAgent(env.spec, DQNConfig(form=form, train_every=2), seed=1,
                  accuracy_threshold=th)
    res = train_agent(dq, env, min(args.steps, 20000), check_every=500)
    print(f"  converged at step {res.converged_at}; greedy "
          f"{res.greedy_ms:.1f} ms; prediction accuracy "
          f"{res.prediction_accuracy*100:.0f}%")

    print(f"\nspeedup vs SOTA at {args.threshold}: "
          f"{(1 - opt_ms / sota_ms) * 100:.1f}%")


if __name__ == "__main__":
    main()
