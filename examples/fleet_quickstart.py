"""Fleet quickstart: train a population of edge cells and route all of
their decisions with one vectorized greedy pass.

  PYTHONPATH=src python examples/fleet_quickstart.py

Three acts:
  1. spin up a heterogeneous fleet (cells drawn from the paper's four
     Table-5 scenarios) and batch-train tabular Q-learning — every host
     step advances EVERY cell inside one jitted call;
  2. check per-cell convergence against the vectorized brute-force
     oracle (the paper's "prediction accuracy" protocol, per cell);
  3. stand up a FleetOrchestrator and serve the whole fleet's routing
     decisions from a single argmax+gather.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.fleet import (FleetConfig, FleetOrchestrator, FleetQConfig,
                         FleetQLearning, init_fleet, mixed_table5_fleet)

CELLS, USERS = 256, 2

def main():
    # -- 1. heterogeneous static fleet, batched training ----------------
    scen = mixed_table5_fleet(jax.random.PRNGKey(0), CELLS, USERS)
    agent = FleetQLearning(
        scen, FleetConfig(cells=CELLS, users=USERS),
        FleetQConfig(eps_decay=2e-3, accuracy_threshold=85.0), seed=0)
    print(f"fleet: {CELLS} cells x {USERS} users, "
          f"Q-table {agent.q.shape} ({agent.q.size * 4 / 1e6:.1f} MB)")
    res = agent.train(max_steps=8000, check_every=200)
    print(f"trained {res.steps} steps in {res.wall_seconds:.1f}s "
          f"({res.steps * CELLS / res.wall_seconds:,.0f} env-steps/s)")

    # -- 2. per-cell convergence vs the brute-force oracle ---------------
    print(f"converged: {100 * res.frac_converged:.1f}% of cells "
          f"({res.cells_per_second:.0f} cells/s); "
          f"median greedy {np.median(res.greedy_ms):.1f} ms "
          f"vs optimal {np.median(res.optimal_ms):.1f} ms")

    # -- 3. orchestrate the whole fleet in one pass ----------------------
    orch = FleetOrchestrator(agent)
    decisions, _ = orch.route()
    dec = np.asarray(decisions)
    local = (dec < 8).sum()
    print(f"routing {CELLS * USERS} users: {local} local, "
          f"{(dec == 8).sum()} edge, {(dec == 9).sum()} cloud")

    # -- bonus: a fully dynamic fleet (Markov links, diurnal Poisson
    #    load, churn, heterogeneous sizes) steps just as cheaply --------
    cfg = FleetConfig(cells=CELLS, users=5, p_r2w=0.05, p_w2r=0.15,
                      arrival_rate=1.0, diurnal_period=500,
                      p_join=0.01, p_leave=0.01, min_users=2, max_users=5)
    dyn = FleetQLearning(init_fleet(jax.random.PRNGKey(1), cfg), cfg,
                         FleetQConfig(track_links=False), seed=1)
    for _ in range(100):
        info = dyn.step()
    print(f"dynamic fleet: mean response "
          f"{float(np.asarray(info['mean_ms']).mean()):.0f} ms over "
          f"{int(np.asarray(dyn.scen.active).sum())} active users")


if __name__ == "__main__":
    main()
