"""Fleet quickstart: train a population of edge cells and route all of
their decisions with one vectorized greedy pass.

  PYTHONPATH=src python examples/fleet_quickstart.py

Four acts:
  1. spin up a heterogeneous fleet (cells drawn from the paper's four
     Table-5 scenarios) and batch-train tabular Q-learning — every host
     step advances EVERY cell inside one jitted call;
  2. check per-cell convergence against the vectorized brute-force
     oracle (the paper's "prediction accuracy" protocol, per cell);
  3. stand up a FleetOrchestrator and serve the whole fleet's routing
     decisions from a single argmax+gather;
  4. train ONE shared-policy FleetDQN on the pooled experience of the
     fleet and route cells it has NEVER seen — including cell sizes
     absent from training — at ~the brute-force optimum (the per-cell
     Q-table cannot do this; see src/repro/fleet/README.md for the
     tabular-vs-DQN decision guide).
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.fleet import (FleetConfig, FleetDQN, FleetDQNConfig,
                         FleetOrchestrator, FleetQConfig, FleetQLearning,
                         holdout_reward_ratio, init_fleet,
                         mixed_table5_fleet)

CELLS, USERS = 256, 2

def main():
    # -- 1. heterogeneous static fleet, batched training ----------------
    scen = mixed_table5_fleet(jax.random.PRNGKey(0), CELLS, USERS)
    agent = FleetQLearning(
        scen, FleetConfig(cells=CELLS, users=USERS),
        FleetQConfig(eps_decay=2e-3, accuracy_threshold=85.0), seed=0)
    print(f"fleet: {CELLS} cells x {USERS} users, "
          f"Q-table {agent.q.shape} ({agent.q.size * 4 / 1e6:.1f} MB)")
    res = agent.train(max_steps=8000, check_every=200)
    print(f"trained {res.steps} steps in {res.wall_seconds:.1f}s "
          f"({res.steps * CELLS / res.wall_seconds:,.0f} env-steps/s)")

    # -- 2. per-cell convergence vs the brute-force oracle ---------------
    print(f"converged: {100 * res.frac_converged:.1f}% of cells "
          f"({res.cells_per_second:.0f} cells/s); "
          f"median greedy {np.median(res.greedy_ms):.1f} ms "
          f"vs optimal {np.median(res.optimal_ms):.1f} ms")

    # -- 3. orchestrate the whole fleet in one pass ----------------------
    orch = FleetOrchestrator(agent)
    decisions, _ = orch.route()
    dec = np.asarray(decisions)
    local = (dec < 8).sum()
    print(f"routing {CELLS * USERS} users: {local} local, "
          f"{(dec == 8).sum()} edge, {(dec == 9).sum()} cloud")

    # -- 4. ONE shared policy for the whole fleet — and for cells it
    #    has never seen. Train a FleetDQN on 2-3-user cells under a QoS
    #    goal (act + env + on-device replay + minibatch update, all in
    #    one jitted scan), then score its cold-start decisions on a
    #    held-out fleet that includes 1-user cells. ---------------------
    users, th = 3, 85.0
    train_scen = mixed_table5_fleet(jax.random.PRNGKey(2), 128, users,
                                    min_users=2, max_users=3)
    dqn = FleetDQN(train_scen,
                   FleetConfig(cells=128, users=users, arrival_rate=1.2),
                   FleetDQNConfig(accuracy_threshold=th), seed=0)
    dqn.run(800)
    hold = mixed_table5_fleet(jax.random.PRNGKey(9), 64, users,
                              min_users=1, max_users=3)
    ev = holdout_reward_ratio(dqn, hold, th)
    print(f"shared DQN on 64 held-out cells (sizes 1-3, trained on 2-3): "
          f"{100 * ev.ratio:.1f}% of the brute-force optimal reward, "
          f"{100 * ev.feasible.mean():.0f}% QoS-feasible")
    FleetOrchestrator(dqn).route(scen=hold)   # same serving entry point

    # -- bonus: a fully dynamic fleet (Markov links, diurnal Poisson
    #    load, churn, heterogeneous sizes) steps just as cheaply --------
    cfg = FleetConfig(cells=CELLS, users=5, p_r2w=0.05, p_w2r=0.15,
                      arrival_rate=1.0, diurnal_period=500,
                      p_join=0.01, p_leave=0.01, min_users=2, max_users=5)
    dyn = FleetQLearning(init_fleet(jax.random.PRNGKey(1), cfg), cfg,
                         FleetQConfig(track_links=False), seed=1)
    for _ in range(100):
        info = dyn.step()
    print(f"dynamic fleet: mean response "
          f"{float(np.asarray(info['mean_ms']).mean()):.0f} ms over "
          f"{int(np.asarray(dyn.scen.active).sum())} active users")


if __name__ == "__main__":
    main()
