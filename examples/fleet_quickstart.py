"""Fleet quickstart: train a population of edge cells and route all of
their decisions with one vectorized greedy pass.

  PYTHONPATH=src python examples/fleet_quickstart.py

Seven acts:
  1. spin up a heterogeneous fleet (cells drawn from the paper's four
     Table-5 scenarios) and batch-train tabular Q-learning — every host
     step advances EVERY cell inside one jitted call;
  2. check per-cell convergence against the vectorized brute-force
     oracle (the paper's "prediction accuracy" protocol, per cell);
  3. stand up a FleetOrchestrator and serve the whole fleet's routing
     decisions from a single argmax+gather;
  4. train ONE shared-policy FleetDQN on the pooled experience of the
     fleet and route cells it has NEVER seen — including cell sizes
     absent from training — at ~the brute-force optimum (the per-cell
     Q-table cannot do this; see src/repro/fleet/README.md for the
     tabular-vs-DQN decision guide);
  5. share infrastructure: put 60% of the cells behind ONE hot edge
     with a queueing cloud, and route around it with the coupled
     best-response oracle — topology-aware routing beats the
     topology-blind per-cell optimum on expected reward;
  6. replay a recorded trace: capture a dynamic fleet's stream as a
     FleetTrace (per-cell arrival timestamps + link series), feed it
     back through TraceSource — the ScenarioSource front door
     (repro.fleet.api) — and train/route against the EXACT recorded
     workload instead of the generators;
  7. watch it all: in-scan metrics (repro.obs) recorded at device speed
     during a DQN run, a span-instrumented route through real serving
     engines, the measured-vs-predicted gap decomposed into queueing /
     batching / compute, and a Chrome-trace JSON you can drop into
     https://ui.perfetto.dev.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.fleet import (FleetConfig, FleetDQN, FleetDQNConfig,
                         FleetOrchestrator, FleetQConfig, FleetQLearning,
                         SyntheticSource, TraceSource, dynamics,
                         edge_utilization, fleet_bruteforce,
                         fleet_topology_expected_response,
                         holdout_reward_ratio, hot_edge_topology,
                         init_fleet, mixed_table5_fleet, record_trace,
                         topology_bruteforce, with_topology)
from repro.core.spaces import SpaceSpec

CELLS, USERS = 256, 2

def main():
    # -- 1. heterogeneous static fleet, batched training ----------------
    scen = mixed_table5_fleet(jax.random.PRNGKey(0), CELLS, USERS)
    agent = FleetQLearning(
        scen, FleetConfig(cells=CELLS, users=USERS),
        FleetQConfig(eps_decay=2e-3, accuracy_threshold=85.0), seed=0)
    print(f"fleet: {CELLS} cells x {USERS} users, "
          f"Q-table {agent.q.shape} ({agent.q.size * 4 / 1e6:.1f} MB)")
    res = agent.train(max_steps=8000, check_every=200)
    print(f"trained {res.steps} steps in {res.wall_seconds:.1f}s "
          f"({res.steps * CELLS / res.wall_seconds:,.0f} env-steps/s)")

    # -- 2. per-cell convergence vs the brute-force oracle ---------------
    print(f"converged: {100 * res.frac_converged:.1f}% of cells "
          f"({res.cells_per_second:.0f} cells/s); "
          f"median greedy {np.median(res.greedy_ms):.1f} ms "
          f"vs optimal {np.median(res.optimal_ms):.1f} ms")

    # -- 3. orchestrate the whole fleet in one pass ----------------------
    orch = FleetOrchestrator(agent)
    decisions, _ = orch.route()
    dec = np.asarray(decisions)
    local = (dec < 8).sum()
    print(f"routing {CELLS * USERS} users: {local} local, "
          f"{(dec == 8).sum()} edge, {(dec == 9).sum()} cloud")

    # -- 4. ONE shared policy for the whole fleet — and for cells it
    #    has never seen. Train a FleetDQN on 2-3-user cells under a QoS
    #    goal (act + env + on-device replay + minibatch update, all in
    #    one jitted scan), then score its cold-start decisions on a
    #    held-out fleet that includes 1-user cells. ---------------------
    users, th = 3, 85.0
    train_scen = mixed_table5_fleet(jax.random.PRNGKey(2), 128, users,
                                    min_users=2, max_users=3)
    dqn = FleetDQN(train_scen,
                   FleetConfig(cells=128, users=users, arrival_rate=1.2),
                   FleetDQNConfig(accuracy_threshold=th), seed=0)
    dqn.run(800)
    hold = mixed_table5_fleet(jax.random.PRNGKey(9), 64, users,
                              min_users=1, max_users=3)
    ev = holdout_reward_ratio(dqn, hold, th)
    print(f"shared DQN on 64 held-out cells (sizes 1-3, trained on 2-3): "
          f"{100 * ev.ratio:.1f}% of the brute-force optimal reward, "
          f"{100 * ev.feasible.mean():.0f}% QoS-feasible")
    FleetOrchestrator(dqn).route(scen=hold)   # same serving entry point

    # -- 5. route around a hot edge. 60% of 32 cells share ONE edge
    #    server and the cloud queues fleet-wide; the per-cell optimum
    #    (topology-blind — exactly acts 1-4's oracle) piles offloads
    #    onto the hot edge, while the coupled best-response oracle
    #    spreads them out. ------------------------------------------
    cells_t, users_t, th_t = 32, 2, 89.0
    scen_t = mixed_table5_fleet(jax.random.PRNGKey(5), cells_t, users_t)
    topo = hot_edge_topology(cells_t, 4, hot_fraction=0.6,
                             cloud_servers=8.0)
    spec = SpaceSpec(users_t)
    pu = jnp.asarray(spec.decode_actions_batch(spec.all_actions()))
    _, blind_idx = fleet_bruteforce(scen_t, pu, th_t)   # topology-blind
    b_ms, b_acc = fleet_topology_expected_response(
        pu[blind_idx], scen_t.end_b, scen_t.edge_b, topo, scen_t.member)
    a_ms, aware_idx, converged, rounds = topology_bruteforce(
        with_topology(scen_t, topo), pu, th_t)          # topology-aware
    _, a_acc = fleet_topology_expected_response(
        pu[aware_idx], scen_t.end_b, scen_t.edge_b, topo, scen_t.member)
    r_blind = float(dynamics.reward(b_ms, b_acc, th_t, xp=jnp).mean())
    r_aware = float(dynamics.reward(a_ms, a_acc, th_t, xp=jnp).mean())
    hot_b = float(edge_utilization(pu[blind_idx], topo,
                                   active=scen_t.member)[0])
    hot_a = float(edge_utilization(pu[aware_idx], topo,
                                   active=scen_t.member)[0])
    print(f"hot edge: blind routing loads it with {hot_b:.0f} jobs "
          f"(reward {r_blind:.3f}); best-response ({rounds} sweeps, "
          f"converged={converged}) drops it to {hot_a:.0f} "
          f"(reward {r_aware:.3f}, +{r_aware - r_blind:.3f})")

    # -- 6. trace replay through the api front door: record 64 steps
    #    of a dynamic fleet as arrival timestamps + link series, then
    #    replay the EXACT stream — TraceSource slots into the same
    #    agents/orchestrator as the synthetic generators. -------------
    rec_cfg = FleetConfig(cells=64, users=2, p_r2w=0.05, p_w2r=0.15,
                          arrival_rate=1.0, p_join=0.02, p_leave=0.02)
    trace = record_trace(SyntheticSource(rec_cfg), jax.random.PRNGKey(6),
                         steps=64)
    src = TraceSource(trace)
    replayed = FleetQLearning(src, cfg=FleetQConfig(eps_decay=2e-3), seed=0)
    replayed.run(4 * src.horizon)                 # the trace wraps
    dec_t, _ = FleetOrchestrator(replayed).route()
    print(f"trace replay: {len(trace.arrival_time)} recorded requests over "
          f"{src.horizon} frames x {src.cells} cells; trained on the "
          f"replayed stream and routed {int(np.asarray(dec_t).size)} users")

    # -- 7. observability: the telemetry from act 4's kind of DQN run
    #    was already recorded — for free, inside the jitted scan (zero
    #    host syncs; repro.obs.metrics). Then route a trace-trained
    #    fleet through REAL serving engines with a SpanRecorder
    #    attached and decompose the predicted-vs-measured gap. ---------
    ms = dqn.metrics_summary()
    print(f"obs: DQN telemetry from act 4 — reward mean "
          f"{ms['reward']['mean']:.3f} (min {ms['reward']['min']:.3f}), "
          f"replay fill {100 * ms['replay_fill']['max']:.0f}%, "
          f"epsilon {ms['epsilon']['max']:.2f} -> "
          f"{ms['epsilon']['min']:.2f} over {ms['epsilon']['count']} steps")
    from repro.launch.serve import build_engines, get_config
    from repro.obs import SpanRecorder, run_manifest
    engines = build_engines(get_config("edge-ladder"), variants=("d0",),
                            max_len=48)
    small = TraceSource(record_trace(
        SyntheticSource(FleetConfig(cells=8, users=2, arrival_rate=1.0)),
        jax.random.PRNGKey(7), steps=12))
    routed = FleetQLearning(small, seed=0)
    routed.run(2 * small.horizon)
    rec = SpanRecorder()
    result = FleetOrchestrator(routed).route(
        dispatch=engines, max_new_tokens=2, batch_size=4, prompt_len=8,
        spans=rec)
    gb = result.gap_breakdown()
    w, comp = gb["wall_ms"], gb["gap_components_x"]
    print(f"obs: served {len(result.served)} requests — compute gap "
          f"{gb['gap_x']:.2f}x, end-to-end {comp['e2e']:.2f}x "
          f"(= {comp['queueing']:.2f}x queueing + {comp['compute']:.2f}x "
          f"compute); wall {w['total']:.0f} ms = {w['batching']:.0f} "
          f"batching + {w['compute']:.0f} compute + {w['dispatch']:.0f} "
          f"dispatch")
    trace_path = os.path.join(os.path.dirname(__file__), "..", "results",
                              "quickstart_trace.json")
    rec.save(trace_path, manifest=run_manifest())
    print(f"obs: Chrome trace -> {os.path.relpath(trace_path)} "
          f"(load it at https://ui.perfetto.dev or chrome://tracing)")

    # -- bonus: a fully dynamic fleet (Markov links, diurnal Poisson
    #    load, churn, heterogeneous sizes) steps just as cheaply --------
    cfg = FleetConfig(cells=CELLS, users=5, p_r2w=0.05, p_w2r=0.15,
                      arrival_rate=1.0, diurnal_period=500,
                      p_join=0.01, p_leave=0.01, min_users=2, max_users=5)
    dyn = FleetQLearning(init_fleet(jax.random.PRNGKey(1), cfg), cfg,
                         FleetQConfig(track_links=False), seed=1)
    for _ in range(100):
        info = dyn.step()
    print(f"dynamic fleet: mean response "
          f"{float(np.asarray(info['mean_ms']).mean()):.0f} ms over "
          f"{int(np.asarray(dyn.scen.active).sum())} active users")


if __name__ == "__main__":
    main()
