"""Fleet-scale shared-policy DQN (ISSUE-2 acceptance): RL-loop
throughput of ``fleet.policy.FleetDQN`` vs the tabular
``FleetQLearning``, per-step timing across fleet sizes (flat == no host
sync inside the scan), and held-out convergence vs the brute-force
oracle on mixed Table-5 fleets.

Emits:
  fleet_dqn_rl_steps,<us/env-step>,steps_per_s=... cells=...
  fleet_dqn_vs_tabular,<ratio>,DQN/tabular RL-loop throughput
  fleet_dqn_step_cells{n},<us/fleet-step>,one jitted step at n cells
  fleet_dqn_step_flatness,<ratio>,largest/smallest per-step time ...
  fleet_dqn_obs_overhead_x,<ratio>,uninstrumented/instrumented throughput
  fleet_dqn_holdout_ratio,<ratio>,expected reward vs bruteforce ...
  fleet_dqn_training,<us/cell-step>,converged_cells_per_s=...

``--tiny`` (CLI) shrinks every budget to a few seconds of work — the CI
smoke mode that keeps this script from rotting.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax

from benchmarks.common import FAST, Timer, emit, save_json
from repro.fleet import (FleetConfig, FleetDQN, FleetDQNConfig, FleetQConfig,
                         FleetQLearning, holdout_reward_ratio,
                         mixed_table5_fleet)

USERS = 3
THRESHOLD = 85.0


def bench_rl(agent_cls, cells: int, steps: int, chunk: int, **kw) -> float:
    """Full RL loop (act + env + replay/TD update) env-steps/sec."""
    scen = mixed_table5_fleet(jax.random.PRNGKey(0), cells, USERS)
    agent = agent_cls(scen, FleetConfig(cells=cells, users=USERS), **kw)
    agent.run(chunk)                               # compile
    n_chunks = max(1, steps // chunk)
    with Timer() as t:
        for _ in range(n_chunks):
            ms, _ = agent.run(chunk)
        jax.block_until_ready(ms)
    return n_chunks * chunk * cells / t.seconds


def bench_step_scaling(sizes, steps: int, chunk: int):
    """us per fleet step (NOT per cell-step) at each fleet size: with
    act + env + replay push + mini-batch update all inside one scan and
    zero host sync, per-step wall time stays near-flat in fleet size
    until the vectorized env work dominates the fixed-size update."""
    out = {}
    for cells in sizes:
        sps = bench_rl(FleetDQN, cells, steps, chunk,
                       cfg=FleetDQNConfig(), seed=0)
        out[cells] = 1e6 / (sps / cells)           # us per fleet step
        emit(f"fleet_dqn_step_cells{cells}", out[cells],
             f"one jitted step (act+env+replay+update) at {cells} cells")
    flat = max(out.values()) / min(out.values())
    span = max(sizes) // min(sizes)
    emit("fleet_dqn_step_flatness", flat,
         f"largest/smallest per-step time over a {span}x size span "
         f"(1.0 = perfectly flat; >> {span} would mean host sync)")
    return out, flat


def bench_obs_overhead(cells: int, steps: int, chunk: int) -> float:
    """Instrumented-vs-uninstrumented RL-loop throughput: the obs
    accumulator rides the scan carry with elementwise updates and zero
    host syncs, so the ratio should sit at ~1.0 (no per-step
    regression — the ISSUE-6 acceptance; tools/obs_smoke.py gates it
    at < 1.05 in CI with noise-tolerant best-of-N timing)."""
    on = bench_rl(FleetDQN, cells, steps, chunk,
                  cfg=FleetDQNConfig(), seed=0)
    off = bench_rl(FleetDQN, cells, steps, chunk,
                   cfg=FleetDQNConfig(), seed=0, metrics=False)
    ratio = off / on
    emit("fleet_dqn_obs_overhead_x", ratio,
         f"uninstrumented/instrumented steps-per-s at {cells} cells "
         "(1.0 = metrics are free)")
    return ratio


def bench_holdout(train_cells: int, train_steps: int, hold_cells: int):
    """Train one shared policy on 2-3-user Table-5 cells, score the
    expected reward of its greedy decisions on a HELD-OUT fleet that
    includes 1-user cells (a size absent from training) against the
    per-cell brute-force optimum."""
    train_scen = mixed_table5_fleet(jax.random.PRNGKey(0), train_cells,
                                    USERS, min_users=2, max_users=3)
    fc = FleetConfig(cells=train_cells, users=USERS, arrival_rate=1.2)
    agent = FleetDQN(train_scen, fc,
                     FleetDQNConfig(accuracy_threshold=THRESHOLD), seed=0)
    with Timer() as t:
        agent.run(train_steps)
    hold = mixed_table5_fleet(jax.random.PRNGKey(99), hold_cells, USERS,
                              min_users=1, max_users=3)
    ratio = holdout_reward_ratio(agent, hold, THRESHOLD).ratio
    emit("fleet_dqn_holdout_ratio", ratio,
         f"expected reward vs bruteforce on {hold_cells} held-out cells "
         f"incl. unseen sizes after {train_steps} steps (target >=0.95)")
    return ratio, train_steps * train_cells / t.seconds


def main(tiny: bool = False):
    if tiny:
        cells, steps, chunk = 16, 40, 20
        sizes, tr_cells, tr_steps, hold = (8, 16), 16, 60, 16
    elif FAST:
        cells, steps, chunk = 256, 400, 50
        sizes, tr_cells, tr_steps, hold = (64, 256), 128, 800, 128
    else:
        cells, steps, chunk = 1024, 2000, 50
        sizes, tr_cells, tr_steps, hold = (64, 256, 1024), 256, 2000, 256

    dqn_sps = bench_rl(FleetDQN, cells, steps, chunk,
                       cfg=FleetDQNConfig(), seed=0)
    tab_sps = bench_rl(FleetQLearning, cells, steps, chunk,
                       cfg=FleetQConfig(eps_decay=0.0), seed=0)
    emit("fleet_dqn_rl_steps", 1e6 / dqn_sps,
         f"steps_per_s={dqn_sps:.0f} cells={cells} "
         "(act+env+replay+minibatch update)")
    emit("fleet_dqn_vs_tabular", dqn_sps / tab_sps,
         f"DQN/tabular RL-loop throughput at {cells} cells "
         f"(tabular {tab_sps:.0f} steps/s)")
    # fused head vs legacy at the constrained operating point — the
    # constraint head (top-k + combo filter) is where the fused op wins
    fused_sps = bench_rl(
        FleetDQN, cells, steps, chunk, seed=0,
        cfg=FleetDQNConfig(accuracy_threshold=THRESHOLD))
    unfused_sps = bench_rl(
        FleetDQN, cells, steps, chunk, seed=0, impl="xla",
        cfg=FleetDQNConfig(accuracy_threshold=THRESHOLD))
    fused_x = fused_sps / unfused_sps
    emit("fleet_dqn_rl_steps_fused", 1e6 / fused_sps,
         f"steps_per_s={fused_sps:.0f} fused head, threshold={THRESHOLD}")
    emit("fleet_dqn_rl_steps_unfused", 1e6 / unfused_sps,
         f"steps_per_s={unfused_sps:.0f} legacy impl='xla', "
         f"threshold={THRESHOLD}")
    emit("fleet_dqn_fused_speedup", fused_x,
         "x fused constraint head vs unfused (ISSUE-10: measurably >1)")
    per_step, flatness = bench_step_scaling(sizes, steps, chunk)
    obs_overhead = bench_obs_overhead(cells, steps, chunk)
    ratio, train_sps = bench_holdout(tr_cells, tr_steps, hold)
    emit("fleet_dqn_training", 1e6 / train_sps,
         f"cell-steps_per_s={train_sps:.0f} during holdout training")
    metrics = {
        "cells": cells, "users": USERS,
        "dqn_rl_steps_per_s": dqn_sps,
        "tabular_rl_steps_per_s": tab_sps,
        "rl_fused_dqn_steps_per_s": fused_sps,
        "rl_unfused_dqn_steps_per_s": unfused_sps,
        "rl_fused_dqn_speedup_x": fused_x,
        "us_per_fleet_step": {str(k): v for k, v in per_step.items()},
        "step_flatness": flatness,
        "obs_overhead_x": obs_overhead,
        "holdout_reward_ratio": ratio,
        "train_cell_steps_per_s": train_sps,
    }
    save_json("fleet_dqn", metrics)
    return metrics


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="seconds-scale budgets (CI smoke)")
    main(tiny=ap.parse_args().tiny)
