"""Kernel micro-benchmarks: interpret-mode Pallas vs jnp oracle (CPU
wall time is NOT the TPU target — correctness + structural cost only)
plus analytic FLOP counts per call and, via ``profile_kernel``, the
compiler's own cost model (``repro.obs.prof``) next to the analytic
count — the two should agree within fusion slop."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Timer, emit, save_json
from repro.kernels import ops, ref
from repro.obs.prof import profile_fn


def _time(fn, *args, reps=3, **kw):
    fn(*args, **kw)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args, **kw))
    return (time.perf_counter() - t0) / reps * 1e6


def profile_kernel(fn, *args, name=None, **kw):
    """Compiled-cost profile of one kernel call as a JSON-ready dict
    (flops / bytes accessed / arithmetic intensity / roofline terms;
    see ``repro.obs.prof.CostProfile``). Keyword args are closed over
    so implementation switches (``impl=``, ``causal=``) profile the
    variant actually benchmarked."""
    prof = profile_fn(lambda *a: fn(*a, **kw), *args,
                      name=name or getattr(fn, "__name__", "kernel"))
    return prof.as_dict()


def main(tiny: bool = False):
    out = {}
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)

    b, s, h, kv, hd = 1, 512, 8, 2, 64
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, hd), jnp.float32)
    flops = 4 * b * h * s * s * hd
    us_k = _time(ops.flash_attention, q, k, v, causal=True)
    us_r = _time(ops.flash_attention, q, k, v, causal=True, impl="ref")
    emit("kernel_flash_attn_512", us_k, f"{flops/1e6:.0f}MFLOP_ref{us_r:.0f}us")
    out["flash"] = {"us_pallas_interpret": us_k, "us_ref": us_r, "flops": flops}

    bq, hq, kvq, hdq, sq = 4, 16, 4, 128, 2048
    qq = jax.random.normal(ks[0], (bq, hq, hdq), jnp.float32)
    kc = jax.random.normal(ks[1], (bq, sq, kvq, hdq), jnp.float32)
    vc = jax.random.normal(ks[2], (bq, sq, kvq, hdq), jnp.float32)
    kv_pos = jnp.tile(jnp.arange(sq)[None], (bq, 1))
    cur = jnp.full((bq,), sq - 1)
    us_k = _time(ops.decode_attention, qq, kc, vc, kv_pos, cur)
    us_r = _time(ops.decode_attention, qq, kc, vc, kv_pos, cur, impl="ref")
    emit("kernel_decode_attn_2k", us_k, f"ref{us_r:.0f}us")
    out["decode"] = {"us_pallas_interpret": us_k, "us_ref": us_r}

    m, kk, n = 512, 512, 512
    x = jax.random.normal(ks[0], (m, kk))
    w = jax.random.normal(ks[1], (kk, n))
    xq, sx = ref.quantize_ref(x)
    wq, sw = ref.quantize_ref(w, axis=0)
    us_k = _time(ops.int8_matmul, xq, sx, wq, sw)
    us_r = _time(ops.int8_matmul, xq, sx, wq, sw, impl="ref")
    emit("kernel_int8_matmul_512", us_k,
         f"{2*m*kk*n/1e6:.0f}MFLOP_ref{us_r:.0f}us")
    # exemplar compiled-cost profile: the compiler's flop count for the
    # ref matmul vs the analytic 2mkn, plus its roofline position
    prof = profile_kernel(ops.int8_matmul, xq, sx, wq, sw, impl="ref",
                          name="int8_matmul_512_ref")
    emit("kernel_int8_matmul_512_prof", 0.0,
         f"compiled_{prof['flops']/1e6:.0f}MFLOP_analytic_"
         f"{2*m*kk*n/1e6:.0f}MFLOP_intensity{prof['arithmetic_intensity']:.1f}_"
         f"{prof['dominant']}")
    out["int8"] = {"us_pallas_interpret": us_k, "us_ref": us_r,
                   "profile": prof}

    bt, st, di, nn = 1, 256, 128, 16
    u = jax.random.normal(ks[0], (bt, st, di)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bt, st, di))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (di, nn)) * 0.3)
    B = jax.random.normal(ks[3], (bt, st, nn))
    C = jax.random.normal(ks[4], (bt, st, nn))
    D = jnp.ones((di,))
    us_k = _time(ops.selective_scan, u, dt, A, B, C, D)
    us_r = _time(ops.selective_scan, u, dt, A, B, C, D, impl="ref")
    emit("kernel_selective_scan_256", us_k, f"ref{us_r:.0f}us")
    out["scan"] = {"us_pallas_interpret": us_k, "us_ref": us_r}

    # fused tabular RL act+update (ISSUE-10): interpret-mode kernel vs
    # the fused-jnp formulation that runs in production on CPU, with the
    # compiler's roofline position of the latter
    tc, ts, tk = (16, 9, 27) if tiny else (64, 36, 100)
    tq = jax.random.normal(ks[0], (tc, ts, tk), jnp.float32)
    s = jax.random.randint(ks[1], (tc,), 0, ts).astype(jnp.int32)
    a = jax.random.randint(ks[2], (tc,), 0, tk).astype(jnp.int32)
    s2 = jax.random.randint(ks[3], (tc,), 0, ts).astype(jnp.int32)
    r = -jax.random.uniform(ks[4], (tc,), jnp.float32)
    tab_kw = dict(alpha=0.9, gamma=0.1)
    us_k = _time(ops.fused_tabular_update, tq, s, a, r, s2,
                 impl="pallas", bc=8, **tab_kw)
    us_r = _time(ops.fused_tabular_update, tq, s, a, r, s2, impl="ref",
                 **tab_kw)
    prof = profile_kernel(ops.fused_tabular_update, tq, s, a, r, s2,
                          impl="ref", name=f"tabular_rl_{tc}_ref",
                          **tab_kw)
    emit(f"kernel_tabular_rl_{tc}", us_k,
         f"ref{us_r:.0f}us intensity{prof['arithmetic_intensity']:.2f}_"
         f"{prof['dominant']}")
    out["tabular_rl"] = {"us_pallas_interpret": us_k, "us_ref": us_r,
                         "profile": prof}

    # fused DQN featurize + constraint head (ISSUE-10)
    from repro.fleet import dynamics
    dc, dn, dh = (16, 2, 16) if tiny else (128, 3, 64)
    kd = jax.random.split(jax.random.PRNGKey(7), 10)
    mem = (jax.random.uniform(kd[0], (dc, dn)) < 0.8).astype(jnp.float32)
    act = mem * (jax.random.uniform(kd[1], (dc, dn)) < 0.7)
    end_b = (jax.random.uniform(kd[2], (dc, dn)) < 0.5).astype(jnp.float32)
    agg = jax.random.normal(kd[3], (dc, 8), jnp.float32)
    dims = [11, dh, dh, 10]
    params = [{"w": jax.random.normal(kd[4 + 2 * i],
                                      (dims[i], dims[i + 1])) * 0.3,
               "b": jax.random.normal(kd[5 + 2 * i], (dims[i + 1],)) * 0.1}
              for i in range(3)]
    allowed = jnp.ones((dn, 10), jnp.float32)
    acc_table = jnp.asarray(dynamics.accuracies(np.arange(10)),
                            jnp.float32)
    head_kw = dict(threshold=85.0, topk=3)
    us_k = _time(ops.dqn_head, act, mem, end_b, agg, params, allowed,
                 acc_table, impl="pallas", bc=dc, **head_kw)
    us_r = _time(ops.dqn_head, act, mem, end_b, agg, params, allowed,
                 acc_table, impl="ref", **head_kw)
    prof = profile_kernel(ops.dqn_head, act, mem, end_b, agg, params,
                          allowed, acc_table, impl="ref",
                          name=f"dqn_head_{dc}_ref", **head_kw)
    emit(f"kernel_dqn_head_{dc}", us_k,
         f"ref{us_r:.0f}us intensity{prof['arithmetic_intensity']:.2f}_"
         f"{prof['dominant']}")
    out["dqn_head"] = {"us_pallas_interpret": us_k, "us_ref": us_r,
                       "profile": prof}

    save_json("bench_kernels", out)
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="seconds-scale RL-kernel shapes (CI smoke)")
    main(tiny=ap.parse_args().tiny)
