"""Paper Fig. 5: avg response/accuracy for 1..5 users x 5 thresholds,
ours (RL=bruteforce-verified optimum + QL spot checks) vs SOTA [36] vs
fixed strategies, EXP-A."""
from benchmarks.common import FAST, Timer, emit, save_json
from repro.core import (EXPERIMENTS, THRESHOLDS, EndEdgeCloudEnv,
                        QLearningAgent, bruteforce_optimal, train_agent)
from repro.core.baselines import fixed_strategy_response
from repro.core.spaces import restricted_actions

# paper Fig.5 five-user reference points (ms)
PAPER_5U = {"Min": 72.08, "80%": 103.88, "85%": 143.81, "89%": 269.80,
            "Max": 418.91}


def main():
    out = {}
    for n in range(1, 6):
        env = EndEdgeCloudEnv(n, EXPERIMENTS["EXP-A"], noise=0)
        row = {}
        for s in ("device", "edge", "cloud"):
            row[f"fixed_{s}"], _ = fixed_strategy_response(env, s)
        _, sota_ms, sota_acc, _ = bruteforce_optimal(
            env, 0.0, restricted_actions(env.spec))
        row["sota_ms"], row["sota_acc"] = sota_ms, sota_acc
        for tname, th in THRESHOLDS.items():
            a, ms, acc, _ = bruteforce_optimal(env, th)
            row[f"ours_{tname}_ms"], row[f"ours_{tname}_acc"] = ms, acc
            row[f"ours_{tname}_decision"] = env.spec.decode_action(a)
        out[f"users{n}"] = row
        emit(f"fig5_users{n}_ours_89", 0.0,
             f"{row['ours_89%_ms']:.1f}ms_acc{row['ours_89%_acc']:.1f}")
        emit(f"fig5_users{n}_sota", 0.0, f"{sota_ms:.1f}ms")

    # RL spot-check: trained QL reaches the bruteforce point (C1)
    spot_users = (2,) if FAST else (2, 3, 5)
    for n in spot_users:
        env = EndEdgeCloudEnv(n, EXPERIMENTS["EXP-A"],
                              accuracy_threshold=89.0, seed=0)
        ag = QLearningAgent(env.spec, seed=0)
        with Timer() as t:
            res = train_agent(ag, env, 40000 if FAST else 400000)
        emit(f"fig5_ql_spot_users{n}", t.us,
             f"pred_acc={res.prediction_accuracy:.3f}_steps={res.converged_at}")
        out[f"ql_spot_users{n}"] = {"converged_at": res.converged_at,
                                    "pred_acc": res.prediction_accuracy}

    # headline claim: speedup at 89% vs SOTA, 5 users
    r5 = out["users5"]
    speedup = 1 - r5["ours_89%_ms"] / r5["sota_ms"]
    acc_loss = r5["sota_acc"] - r5["ours_89%_acc"]
    emit("fig5_headline_speedup_5u", 0.0,
         f"{speedup*100:.1f}%_accloss{acc_loss:.2f}pp_paper35%/0.8pp")
    out["headline"] = {"speedup": speedup, "acc_loss_pp": acc_loss,
                       "paper_5u_ms": PAPER_5U}
    save_json("bench_fig5", out)
    return out


if __name__ == "__main__":
    main()
