"""Paper Fig. 1: impact of network / users / accuracy on response time."""
import numpy as np

from benchmarks.common import Timer, emit, save_json
from repro.core import EXPERIMENTS, EndEdgeCloudEnv, Scenario
from repro.core.baselines import fixed_strategy_response


def main():
    out = {}
    # (a) tiers x network condition, 1 user
    weak = Scenario.from_string("weak", "W|W")
    reg = Scenario.from_string("reg", "R|R")
    for net, sc in (("regular", reg), ("weak", weak)):
        env = EndEdgeCloudEnv(1, sc, noise=0)
        row = {}
        for strat in ("device", "edge", "cloud"):
            with Timer() as t:
                ms, _ = fixed_strategy_response(env, strat)
            row[strat] = ms
            emit(f"fig1a_{net}_{strat}", t.us, f"{ms:.1f}ms")
        out[f"fig1a_{net}"] = row
    # sanity ordering (paper): regular -> cloud best; weak -> device best
    assert out["fig1a_regular"]["cloud"] < out["fig1a_regular"]["device"]
    assert out["fig1a_weak"]["device"] < out["fig1a_weak"]["edge"]

    # (b) users 1..5 x fixed strategy (regular net)
    for n in range(1, 6):
        env = EndEdgeCloudEnv(n, EXPERIMENTS["EXP-A"], noise=0)
        row = {s: fixed_strategy_response(env, s)[0]
               for s in ("device", "edge", "cloud")}
        out[f"fig1b_users{n}"] = row
        emit(f"fig1b_users{n}", 0.0,
             "|".join(f"{s}={v:.0f}ms" for s, v in row.items()))

    # (c) response vs accuracy pareto (1..5 users, all tiers, all models)
    pareto = []
    for n in (1, 3, 5):
        env = EndEdgeCloudEnv(n, EXPERIMENTS["EXP-A"], noise=0)
        acts = env.spec.all_actions()
        if len(acts) > 100000:
            acts = np.random.default_rng(0).choice(acts, 100000, replace=False)
        ms, acc = env.expected_response_batch(acts)
        for a_level in (74.2, 81.1, 85.0, 88.2, 89.9):
            sel = np.abs(acc - a_level) < 1.0
            if sel.any():
                pareto.append({"users": n, "acc": a_level,
                               "best_ms": float(ms[sel].min())})
    out["fig1c"] = pareto
    emit("fig1c_pareto_points", 0.0, len(pareto))
    save_json("bench_fig1", out)
    return out


if __name__ == "__main__":
    main()
