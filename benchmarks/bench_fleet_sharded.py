"""Device-sharded fleet execution (ISSUE-5 acceptance): per-device
throughput as the cell population scales across a ``('fleet',)`` mesh,
and the cost of the two topology-aggregation modes.

Measurements (all on a forced multi-device CPU host platform, so the
numbers exercise real SPMD partitioning + collectives, not accelerator
speed):

* ``sharded_env_steps``     — cell-steps/sec of the jitted fleet env
  step with scenario + Q-state sharded along cells, at fleet sizes
  ``devices * {base, 4*base, 16*base}``; per-device throughput should
  stay ~flat as the fleet grows (weak scaling of the population axis).
* ``sharded_rl_steps``      — the tabular act+env+TD loop, sharded.
* ``topology_local_agg``    — ``shard.local_expected_response`` (the
  shard_map path over a locality-capped ``random_topology(...,
  shard_local=True)``: per-edge aggregation never leaves the device).
* ``topology_alltoall_agg`` — the unchanged global segment-sum path
  under GSPMD on an unconstrained assignment (the compiler's
  cross-device reduction).

When invoked directly this script forces
``--xla_force_host_platform_device_count=8`` before jax initializes;
when imported by ``benchmarks/run.py`` (where jax is already live on
one device) ``main()`` relaunches itself as a subprocess and folds the
child's metrics back into ``results/BENCH_fleet.json``.

``--tiny`` (CLI) shrinks every budget to a few seconds of work — the CI
smoke mode that keeps this script from rotting.
"""
import json
import os
import subprocess
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

_FORCE = "--xla_force_host_platform_device_count"
if __name__ == "__main__" and _FORCE not in os.environ.get("XLA_FLAGS", ""):
    # must happen before jax initializes (it locks the device count)
    os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + f" {_FORCE}=8"

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import FAST, RESULTS_DIR, Timer, emit, save_json
from repro.fleet import (FleetConfig, FleetQConfig, FleetQLearning,
                         SyntheticSource, init_fleet, make_fleet_env_step,
                         shard, topology)

USERS = 3


def bench_env_scaling(cells_grid, host_steps, chunk):
    """Cell-steps/sec of the sharded fleet env step at each fleet size;
    returns {cells: steps_per_s}."""
    mesh = shard.fleet_mesh()
    out = {}
    for cells in cells_grid:
        cfg = FleetConfig(cells=cells, users=USERS, arrival_rate=1.0,
                          p_r2w=0.05, p_w2r=0.1)
        source = SyntheticSource(cfg, mesh=mesh)
        env_step = make_fleet_env_step(source)

        def run_chunk(key, scen, actions):
            def body(carry, a):
                key, scen = carry
                key, k = jax.random.split(key)
                scen2, _, ms, _, _ = env_step(k, scen, a)
                return (key, scen2), ms.mean()
            (key, scen), ms = jax.lax.scan(body, (key, scen), actions)
            return key, scen, ms

        run_chunk = jax.jit(run_chunk)
        scen, _ = source.reset(jax.random.PRNGKey(0))
        rng = np.random.default_rng(1)
        actions = shard.shard_array(
            jnp.asarray(rng.integers(0, 10, (chunk, cells, USERS)),
                        jnp.int32), mesh, axis=1)
        key = jax.random.PRNGKey(2)
        key, scen, _ = run_chunk(key, scen, actions)         # compile
        jax.block_until_ready(scen.end_b)
        n_chunks = max(1, host_steps // chunk)
        with Timer() as t:
            for _ in range(n_chunks):
                key, scen, ms = run_chunk(key, scen, actions)
                jax.block_until_ready(ms)    # bound the collective queue
        out[cells] = n_chunks * chunk * cells / t.seconds
    return out


def bench_rl_sharded(cells, host_steps, chunk):
    """Sharded tabular RL loop (act + env + TD) cell-steps/sec."""
    mesh = shard.fleet_mesh()
    cfg = FleetConfig(cells=cells, users=USERS, arrival_rate=1.0)
    agent = FleetQLearning(SyntheticSource(cfg), cfg=FleetQConfig(
        eps_decay=0.0), mesh=mesh)
    agent.run(chunk)                                         # compile
    jax.block_until_ready(agent.q)
    n_chunks = max(1, host_steps // chunk)
    with Timer() as t:
        for _ in range(n_chunks):
            agent.run(chunk)
        jax.block_until_ready(agent.q)
    return n_chunks * chunk * cells / t.seconds


def bench_topology_agg(cells, edges_per_dev, iters):
    """us/call of one fleet-wide contention-coupled evaluation under
    mode (a) shard-local aggregation vs mode (b) global all-to-all."""
    mesh = shard.fleet_mesh()
    ndev = jax.device_count()
    n_edges = edges_per_dev * ndev
    scen = init_fleet(jax.random.PRNGKey(0),
                      FleetConfig(cells=cells, users=USERS,
                                  arrival_rate=1.0))
    scen = shard.shard_scenario(scen, mesh)
    pu = shard.shard_array(
        jnp.asarray(np.random.default_rng(0).integers(0, 10, (cells, USERS)),
                    jnp.int32), mesh)
    topo_local = shard.shard_topology(
        topology.random_topology(jax.random.PRNGKey(1), cells, n_edges,
                                 shard_local=True, n_shards=ndev,
                                 cloud_servers=float(cells)), mesh)
    topo_free = shard.shard_topology(
        topology.random_topology(jax.random.PRNGKey(1), cells, n_edges,
                                 cloud_servers=float(cells)), mesh)

    local = jax.jit(lambda p, t, s: shard.local_expected_response(
        p, s.end_b, s.edge_b, t, mesh, active=s.active))
    glob = jax.jit(lambda p, t, s: topology.topology_expected_response(
        p, s.end_b, s.edge_b, t, active=s.active, xp=jnp))

    def time_one(fn, topo):
        jax.block_until_ready(fn(pu, topo, scen))            # compile
        with Timer() as t:
            for _ in range(iters):
                # block every call: a deep queue of collective-bearing
                # executions can deadlock the CPU all-reduce rendezvous
                # on an oversubscribed forced host platform, and the
                # per-eval latency (not pipelined throughput) is the
                # number being compared anyway
                jax.block_until_ready(fn(pu, topo, scen)[0])
        return t.us / iters

    return time_one(local, topo_local), time_one(glob, topo_free)


def _run(tiny: bool) -> dict:
    ndev = jax.device_count()
    base = 32 if tiny else 256
    if tiny:
        env_steps, rl_steps, chunk, agg_iters = 60, 40, 20, 20
    elif FAST:
        env_steps, rl_steps, chunk, agg_iters = 400, 200, 50, 100
    else:
        env_steps, rl_steps, chunk, agg_iters = 2000, 1000, 50, 1000
    grid = [ndev * base, ndev * 4 * base, ndev * 16 * base]

    scaling = bench_env_scaling(grid, env_steps, chunk)
    per_dev = {c: s / ndev for c, s in scaling.items()}
    # flatness over the two LARGEST sizes: small fleets are dispatch-
    # bound (throughput still climbing), the saturated regime is where
    # per-device cell-steps/s must stop moving as the population grows
    top2 = [per_dev[c] for c in grid[-2:]]
    flat = min(top2) / max(top2)
    for c, s in scaling.items():
        emit(f"sharded_env_steps_{c}", 1e6 / s,
             f"steps_per_s={s:.0f} per_device={per_dev[c]:.0f} "
             f"devices={ndev}")
    emit("sharded_env_flatness", flat,
         "min/max per-device cell-steps/s over the two largest fleets "
         "(1.0 = perfectly flat scaling)")

    rl_sps = bench_rl_sharded(grid[1], rl_steps, chunk)
    emit("sharded_rl_steps", 1e6 / rl_sps,
         f"steps_per_s={rl_sps:.0f} cells={grid[1]} (act+env+TD, sharded)")

    local_us, alltoall_us = bench_topology_agg(grid[1], 4, agg_iters)
    emit("topology_local_agg", local_us,
         "us/fleet-eval, shard-local (shard_map, on-device segment-sum)")
    emit("topology_alltoall_agg", alltoall_us,
         "us/fleet-eval, all-to-all (GSPMD global segment-sum); "
         f"local is {alltoall_us / local_us:.2f}x cheaper"
         if alltoall_us >= local_us else
         f"us/fleet-eval, all-to-all; all-to-all is "
         f"{local_us / alltoall_us:.2f}x cheaper here")

    metrics = {
        "devices": ndev,
        "cells_grid": grid,
        "sharded_env_steps_per_s": scaling[grid[-1]],
        "per_device_env_steps_per_s": {str(c): v for c, v in
                                       per_dev.items()},
        "per_device_flatness": flat,
        "sharded_rl_steps_per_s": rl_sps,
        "topology_local_agg_us": local_us,
        "topology_alltoall_agg_us": alltoall_us,
        "local_vs_alltoall_x": alltoall_us / local_us,
    }
    save_json("fleet_sharded", metrics)
    return metrics


def main(tiny: bool = False) -> dict:
    if jax.device_count() > 1:
        return _run(tiny)
    if os.environ.get("REPRO_SHARDED_BENCH_CHILD"):
        # we ARE the relaunched child and the device count is still 1:
        # forcing the host platform had no effect (e.g. jax defaults to
        # a single-accelerator backend here) — fail loudly instead of
        # relaunching forever
        raise RuntimeError(
            "forced host platform still reports 1 device; run with "
            f"JAX_PLATFORMS=cpu XLA_FLAGS='{_FORCE}=8' to benchmark the "
            "sharded fleet on this machine")
    # jax already initialized single-device (benchmarks.run imports every
    # suite) — relaunch so the forced host platform takes effect
    env = dict(os.environ)
    env["XLA_FLAGS"] = env.get("XLA_FLAGS", "") + f" {_FORCE}=8"
    env["REPRO_SHARDED_BENCH_CHILD"] = "1"
    cmd = [sys.executable, os.path.abspath(__file__)]
    if tiny:
        cmd.append("--tiny")
    subprocess.run(cmd, env=env, check=True)
    with open(os.path.join(RESULTS_DIR, "fleet_sharded.json")) as f:
        return json.load(f)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="seconds-scale budgets (CI smoke)")
    main(tiny=ap.parse_args().tiny)
