"""Fleet simulator throughput (ISSUE-1 acceptance): env-steps/sec of the
jitted (cells, users) fleet env step vs looping the scalar
EndEdgeCloudEnv.step, plus the full RL loop and cells-to-convergence/sec
of population training.

Both env measurements are apples-to-apples: actions are drawn OUTSIDE
the timed region (a (steps,) array for the scalar env, a
(steps, cells, N) array scanned over for the fleet), and the timed work
is simulate + reward + state transition.

Emits:
  fleet_scalar_env_steps,<us/step>,steps_per_s=...
  fleet_vector_env_steps,<us/env-step>,steps_per_s=... cells=...
  fleet_speedup,<ratio>,target>=100x
  fleet_rl_steps,<us/env-step>,full RL loop (act+env+TD) steps_per_s=...
  fleet_training,<us/cell-step>,converged_cells_per_s=...

``--tiny`` (CLI) shrinks every budget to a few seconds of work — the CI
smoke mode that keeps this script from rotting.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import FAST, Timer, emit, save_json
from repro.core import EXPERIMENTS, EndEdgeCloudEnv
from repro.fleet import (FleetConfig, FleetQConfig, FleetQLearning,
                         SyntheticSource, make_fleet_env_step,
                         mixed_table5_fleet)

CELLS = 1024 if FAST else 4096
USERS = 5


def bench_scalar(steps: int) -> float:
    """env.step()/sec of the Python-loop env, random actions precomputed."""
    env = EndEdgeCloudEnv(USERS, EXPERIMENTS["EXP-A"], seed=0)
    rng = np.random.default_rng(0)
    acts = [int(a) for a in
            rng.integers(0, env.spec.n_joint_actions, steps)]
    with Timer() as t:
        for a in acts:
            env.step(a)
    return steps / t.seconds


def bench_fleet_env(host_steps: int, cells: int = CELLS,
                    chunk: int = 50) -> float:
    """env-steps/sec of the jitted fleet env step (scan of ``chunk``
    steps per host call over precomputed per-user actions)."""
    cfg = FleetConfig(cells=cells, users=USERS)
    scen = mixed_table5_fleet(jax.random.PRNGKey(0), cells, USERS)
    env_step = make_fleet_env_step(SyntheticSource(cfg))

    def run_chunk(key, scen, actions):          # actions: (chunk, cells, N)
        def body(carry, a):
            key, scen = carry
            key, k = jax.random.split(key)
            scen2, _, ms, _, _ = env_step(k, scen, a)
            return (key, scen2), ms.mean()
        (key, scen), ms = jax.lax.scan(body, (key, scen), actions)
        return key, scen, ms

    run_chunk = jax.jit(run_chunk)
    rng = np.random.default_rng(1)
    actions = jnp.asarray(rng.integers(0, 10, (chunk, cells, USERS)),
                          jnp.int32)
    key = jax.random.PRNGKey(2)
    key, scen, _ = run_chunk(key, scen, actions)     # compile
    jax.block_until_ready(scen.end_b)
    n_chunks = max(1, host_steps // chunk)
    with Timer() as t:
        for _ in range(n_chunks):
            key, scen, ms = run_chunk(key, scen, actions)
        jax.block_until_ready(ms)
    return n_chunks * chunk * cells / t.seconds


def bench_fleet_rl(host_steps: int, cells: int = CELLS,
                   chunk: int = 50, impl: str = "pallas") -> float:
    """Full RL loop (greedy/explore + env + TD update) env-steps/sec.
    ``impl`` selects the hot path: ``'pallas'`` = the fused act+update
    op (ISSUE-10), ``'xla'`` = the legacy unfused step. Measures the
    bare loop (``metrics=False``): the telemetry accumulator adds the
    same constant cost to both impls and its overhead is gated
    separately (``fleet_dqn_obs_overhead_x``)."""
    scen = mixed_table5_fleet(jax.random.PRNGKey(0), cells, USERS)
    agent = FleetQLearning(scen, FleetConfig(cells=cells, users=USERS),
                           FleetQConfig(eps_decay=0.0), impl=impl,
                           metrics=False)
    agent.run(chunk)                               # compile
    jax.block_until_ready(agent.q)
    n_chunks = max(1, host_steps // chunk)
    with Timer() as t:
        for _ in range(n_chunks):
            agent.run(chunk)
        jax.block_until_ready(agent.q)
    return n_chunks * chunk * cells / t.seconds


def main(tiny: bool = False):
    if tiny:
        cells, sc_steps, env_steps, rl_steps = 32, 200, 100, 40
        tr_cells, tr_steps, chunk = 16, 400, 20
    elif FAST:
        cells, sc_steps, env_steps, rl_steps = CELLS, 1000, 400, 200
        tr_cells, tr_steps, chunk = 64, 4000, 50
    else:
        cells, sc_steps, env_steps, rl_steps = CELLS, 5000, 2000, 1000
        tr_cells, tr_steps, chunk = 64, 20000, 50
    scalar_sps = bench_scalar(sc_steps)
    fleet_sps = bench_fleet_env(env_steps, cells, chunk)
    # fused-vs-unfused pair: interleaved best-of-N — alternating the two
    # impls equalizes load drift across the pair, best-of filters
    # scheduler noise (the ratio is the headline, not the absolutes)
    reps = 1 if tiny else 3
    rl_f, rl_u = [], []
    for _ in range(reps):
        rl_f.append(bench_fleet_rl(rl_steps, cells, chunk))
        rl_u.append(bench_fleet_rl(rl_steps, cells, chunk, impl="xla"))
    rl_sps, rl_unfused_sps = max(rl_f), max(rl_u)
    rl_fused_x = rl_sps / rl_unfused_sps
    speedup = fleet_sps / scalar_sps
    emit("fleet_scalar_env_steps", 1e6 / scalar_sps,
         f"steps_per_s={scalar_sps:.0f}")
    emit("fleet_vector_env_steps", 1e6 / fleet_sps,
         f"steps_per_s={fleet_sps:.0f} cells={cells}")
    emit("fleet_speedup", speedup, "x vs scalar env (target >=100x)")
    emit("fleet_rl_steps", 1e6 / rl_sps,
         f"steps_per_s={rl_sps:.0f} (act+env+TD, {rl_sps/scalar_sps:.1f}x "
         f"scalar env alone)")
    emit("fleet_rl_steps_unfused", 1e6 / rl_unfused_sps,
         f"steps_per_s={rl_unfused_sps:.0f} (legacy impl='xla' step)")
    emit("fleet_rl_fused_speedup", rl_fused_x,
         "x fused act+update vs unfused (ISSUE-10 target >=2x)")

    # population training: converged cells / second (2-user cells)
    scen = mixed_table5_fleet(jax.random.PRNGKey(1), tr_cells, 2)
    agent = FleetQLearning(scen, FleetConfig(cells=tr_cells, users=2),
                           FleetQConfig(eps_decay=2e-3,
                                        accuracy_threshold=85.0))
    res = agent.train(max_steps=tr_steps, check_every=200)
    emit("fleet_training", 1e6 * res.wall_seconds / (res.steps * tr_cells),
         f"converged_cells_per_s={res.cells_per_second:.1f} "
         f"frac={res.frac_converged:.2f}")
    metrics = {
        "cells": cells, "users": USERS,
        "scalar_steps_per_s": scalar_sps,
        "fleet_env_steps_per_s": fleet_sps,
        "fleet_rl_steps_per_s": rl_sps,
        "rl_fused_tabular_steps_per_s": rl_sps,
        "rl_unfused_tabular_steps_per_s": rl_unfused_sps,
        "rl_fused_tabular_speedup_x": rl_fused_x,
        "speedup_x": speedup,
        "train_frac_converged": res.frac_converged,
        "train_converged_cells_per_s": res.cells_per_second,
    }
    save_json("fleet_throughput", metrics)
    return metrics


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="seconds-scale budgets (CI smoke)")
    main(tiny=ap.parse_args().tiny)
