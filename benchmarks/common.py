"""Shared benchmark helpers.

REPRO_BENCH_MODE=fast (default) caps RL step budgets so the whole suite
finishes in minutes on CPU; =full uses paper-scale budgets (Table 11).
"""
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

FAST = os.environ.get("REPRO_BENCH_MODE", "fast") != "full"

RESULTS_DIR = os.environ.get("REPRO_BENCH_OUT",
                             os.path.join(os.path.dirname(__file__), "..",
                                          "results"))


def emit(name: str, us_per_call: float, derived):
    """One CSV row: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.3f},{derived}", flush=True)


def save_json(name: str, obj, **manifest_extra):
    """Write ``results/<name>.json``, stamped with the provenance
    manifest (git SHA, jax version, config hash, ...; see
    ``repro.obs.report``) so every BENCH JSON says what produced it.
    ``manifest_extra`` (e.g. ``wall_seconds=...``) merges into the
    manifest.

    Additionally appends one flattened row (numeric metrics + git sha +
    ``created_utc``) to the gitignored ``results/history.jsonl`` — the
    local perf trail rendered by ``tools/obsview.py --history``, so the
    trend between checked-in baseline updates is never lost."""
    from repro.obs.report import attach_manifest, flatten, is_number
    os.makedirs(RESULTS_DIR, exist_ok=True)
    payload = attach_manifest(dict(obj), **manifest_extra)
    with open(os.path.join(RESULTS_DIR, name + ".json"), "w") as f:
        json.dump(payload, f, indent=1, default=str)
    m = payload["manifest"]
    row = {"_name": name,
           "_created_utc": m.get("created_utc"),
           "_git_sha": (m.get("git") or {}).get("sha")}
    row.update({k: v for k, v in flatten(payload).items()
                if is_number(v)})
    with open(os.path.join(RESULTS_DIR, "history.jsonl"), "a") as f:
        f.write(json.dumps(row, default=str) + "\n")


TRACE_FIXTURE = os.path.join(os.path.dirname(__file__), "..", "tests",
                             "data", "trace_small.npz")


def trace_fixture_agent(train_steps: int, seed: int = 0, **agent_kw):
    """Train a ``FleetQLearning`` agent on the golden trace fixture —
    the shared setup of every serving-path benchmark (bench_slo,
    bench_trace_replay, bench_bridge)."""
    from repro.fleet import FleetQConfig, FleetQLearning, TraceSource
    src = TraceSource.load(TRACE_FIXTURE)
    agent = FleetQLearning(src, cfg=FleetQConfig(eps_decay=5e-3),
                           seed=seed, **agent_kw)
    agent.run(train_steps)
    return agent


def serving_engines(variants=("d0",), max_len: int = 48, hop_ms=None):
    """The edge-ladder engine fleet every serving benchmark dispatches
    to — COLD: executables compile on first use (bench_trace_replay
    times this deliberately). ``hop_ms`` (per-tier dict) adds real
    network-hop sleeps emulating physically separate tiers — used by
    bench_bridge; every other suite keeps the local (hop-free) fleet."""
    from repro.configs import get_config
    from repro.launch.serve import build_engines
    return build_engines(get_config("edge-ladder"), variants=variants,
                         max_len=max_len, hop_ms=hop_ms)


def warmed_engines(orch, variants=("d0",), max_len: int = 48, **route_kw):
    """``serving_engines`` plus a throwaway route through ``orch`` so
    every engine shape is compiled before anything is timed."""
    engines = serving_engines(variants=variants, max_len=max_len)
    orch.route(dispatch=engines, **route_kw)
    return engines


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0

    @property
    def us(self):
        return self.seconds * 1e6
