"""Shared benchmark helpers.

REPRO_BENCH_MODE=fast (default) caps RL step budgets so the whole suite
finishes in minutes on CPU; =full uses paper-scale budgets (Table 11).
"""
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

FAST = os.environ.get("REPRO_BENCH_MODE", "fast") != "full"

RESULTS_DIR = os.environ.get("REPRO_BENCH_OUT",
                             os.path.join(os.path.dirname(__file__), "..",
                                          "results"))


def emit(name: str, us_per_call: float, derived):
    """One CSV row: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.3f},{derived}", flush=True)


def save_json(name: str, obj, **manifest_extra):
    """Write ``results/<name>.json``, stamped with the provenance
    manifest (git SHA, jax version, config hash, ...; see
    ``repro.obs.report``) so every BENCH JSON says what produced it.
    ``manifest_extra`` (e.g. ``wall_seconds=...``) merges into the
    manifest.

    Additionally appends one flattened row (numeric metrics + git sha +
    ``created_utc``) to the gitignored ``results/history.jsonl`` — the
    local perf trail rendered by ``tools/obsview.py --history``, so the
    trend between checked-in baseline updates is never lost."""
    from repro.obs.report import attach_manifest, flatten, is_number
    os.makedirs(RESULTS_DIR, exist_ok=True)
    payload = attach_manifest(dict(obj), **manifest_extra)
    with open(os.path.join(RESULTS_DIR, name + ".json"), "w") as f:
        json.dump(payload, f, indent=1, default=str)
    m = payload["manifest"]
    row = {"_name": name,
           "_created_utc": m.get("created_utc"),
           "_git_sha": (m.get("git") or {}).get("sha")}
    row.update({k: v for k, v in flatten(payload).items()
                if is_number(v)})
    with open(os.path.join(RESULTS_DIR, "history.jsonl"), "a") as f:
        f.write(json.dumps(row, default=str) + "\n")


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0

    @property
    def us(self):
        return self.seconds * 1e6
