"""Shared benchmark helpers.

REPRO_BENCH_MODE=fast (default) caps RL step budgets so the whole suite
finishes in minutes on CPU; =full uses paper-scale budgets (Table 11).
"""
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

FAST = os.environ.get("REPRO_BENCH_MODE", "fast") != "full"

RESULTS_DIR = os.environ.get("REPRO_BENCH_OUT",
                             os.path.join(os.path.dirname(__file__), "..",
                                          "results"))


def emit(name: str, us_per_call: float, derived):
    """One CSV row: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.3f},{derived}", flush=True)


def save_json(name: str, obj):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, name + ".json"), "w") as f:
        json.dump(obj, f, indent=1, default=str)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0

    @property
    def us(self):
        return self.seconds * 1e6
