"""Benchmark runner: one module per paper table/figure. Prints
``name,us_per_call,derived`` CSV rows (see common.emit).

  PYTHONPATH=src python -m benchmarks.run [--only fig5,table11] [--json]
  REPRO_BENCH_MODE=full for paper-scale RL budgets.

``--json`` additionally writes ``results/BENCH_fleet.json``: the
fleet-scale headline numbers (env steps/sec, tabular + DQN RL-loop
steps/sec, converged cells/sec, DQN held-out reward ratio, topology
overhead/uplift, trace-replay speedup, sharded per-device throughput
and local-vs-alltoall aggregation cost, compiled-cost RL stage
fractions and the scaling-cliff diagnosis, SLO attainment measured vs
predicted + P99 tail + windowed-metrics overhead, async-bridge vs sync
dispatch throughput and the sim-to-real calibration loop) in one
machine-readable file
so the perf trajectory is tracked across PRs (see docs/BENCHMARKS.md).
Every JSON is stamped with a provenance manifest (git SHA, jax
version, config hash — ``repro.obs.report``); pretty-print or diff
runs with ``python tools/obsview.py``.
"""
import argparse
import sys
import time

from benchmarks import (bench_adaptation, bench_bridge,
                        bench_fig1_motivation,
                        bench_fig5_user_variability, bench_fig7_transfer,
                        bench_fleet_dqn, bench_fleet_sharded,
                        bench_fleet_throughput, bench_kernels,
                        bench_overhead, bench_profile, bench_slo,
                        bench_table8_decisions, bench_table9_constraints,
                        bench_table10_sota, bench_table11_convergence,
                        bench_topology, bench_trace_replay)
from benchmarks.common import save_json

SUITES = {
    "fig1": bench_fig1_motivation,
    "fig5": bench_fig5_user_variability,
    "table8": bench_table8_decisions,
    "table9": bench_table9_constraints,
    "table10": bench_table10_sota,
    "table11": bench_table11_convergence,
    "fig7": bench_fig7_transfer,
    "overhead": bench_overhead,
    "kernels": bench_kernels,
    "adaptation": bench_adaptation,   # beyond-paper: mid-run network shift
    "fleet": bench_fleet_throughput,  # beyond-paper: vectorized fleet sim
    "fleet_dqn": bench_fleet_dqn,     # beyond-paper: shared-policy fleet DQN
    "topology": bench_topology,       # beyond-paper: shared edges + cloud q
    "trace_replay": bench_trace_replay,  # beyond-paper: trace + serving bridge
    "fleet_sharded": bench_fleet_sharded,  # beyond-paper: multi-device fleet
    "profile": bench_profile,  # compiled-cost stage fracs + cliff diagnosis
    "slo": bench_slo,  # windowed metrics overhead + SLO attainment/tails
    "bridge": bench_bridge,  # async bridge throughput + calibration loop
}

#: suites whose main() returns the headline dict folded into BENCH_fleet.json
FLEET_SUITES = ("fleet", "fleet_dqn", "topology", "trace_replay",
                "fleet_sharded", "profile", "slo", "bridge")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    ap.add_argument("--json", action="store_true",
                    help="write results/BENCH_fleet.json (fleet headline "
                         "metrics; implies running the fleet suites)")
    args = ap.parse_args()
    names = list(SUITES) if not args.only else args.only.split(",")
    if args.json:
        names += [n for n in FLEET_SUITES if n not in names]
    print("name,us_per_call,derived")
    t0 = time.time()
    failures = []
    fleet_metrics = {}
    for name in names:
        print(f"# --- {name} ---", flush=True)
        try:
            out = SUITES[name].main()
            if name in FLEET_SUITES and isinstance(out, dict):
                fleet_metrics[name] = out
        except Exception as e:  # noqa
            import traceback
            traceback.print_exc()
            failures.append((name, e))
    if args.json:
        tp = fleet_metrics.get("fleet", {})
        dqn = fleet_metrics.get("fleet_dqn", {})
        topo = fleet_metrics.get("topology", {})
        trace = fleet_metrics.get("trace_replay", {})
        sh = fleet_metrics.get("fleet_sharded", {})
        prof = fleet_metrics.get("profile", {})
        slo = fleet_metrics.get("slo", {})
        br = fleet_metrics.get("bridge", {})
        save_json("BENCH_fleet", {
            "env_steps_per_s": tp.get("fleet_env_steps_per_s"),
            "rl_steps_per_s": tp.get("fleet_rl_steps_per_s"),
            "rl_fused_tabular_steps_per_s":
                tp.get("rl_fused_tabular_steps_per_s"),
            "rl_unfused_tabular_steps_per_s":
                tp.get("rl_unfused_tabular_steps_per_s"),
            "rl_fused_tabular_speedup_x":
                tp.get("rl_fused_tabular_speedup_x"),
            "dqn_rl_steps_per_s": dqn.get("dqn_rl_steps_per_s"),
            "rl_fused_dqn_steps_per_s": dqn.get("rl_fused_dqn_steps_per_s"),
            "rl_unfused_dqn_steps_per_s":
                dqn.get("rl_unfused_dqn_steps_per_s"),
            "rl_fused_dqn_speedup_x": dqn.get("rl_fused_dqn_speedup_x"),
            "converged_cells_per_s": tp.get("train_converged_cells_per_s"),
            "dqn_holdout_reward_ratio": dqn.get("holdout_reward_ratio"),
            "dqn_step_flatness": dqn.get("step_flatness"),
            "dqn_obs_overhead_x": dqn.get("obs_overhead_x"),
            "topology_env_overhead_x": topo.get("topology_env_overhead_x"),
            "topology_hot_edge_uplift": topo.get("hot_edge_reward_uplift"),
            "trace_env_steps_per_s": trace.get("trace_env_steps_per_s"),
            "trace_replay_speedup_x": trace.get("trace_replay_speedup_x"),
            "trace_serving_gap_x": trace.get("serving", {}).get("gap_x"),
            "trace_serving_p95_ms": trace.get("serving", {}).get("p95_ms"),
            "trace_serving_p99_ms": trace.get("serving", {}).get("p99_ms"),
            "slo_attainment_measured": slo.get("slo_attainment_measured"),
            "slo_attainment_predicted": slo.get("slo_attainment_predicted"),
            "slo_attainment_gap": slo.get("slo_attainment_gap"),
            "p99_ms": slo.get("p99_ms"),
            "windowed_overhead_x": slo.get("windowed_overhead_x"),
            "sync_throughput_rps": br.get("sync_throughput_rps"),
            "bridge_throughput_rps": br.get("bridge_throughput_rps"),
            "bridge_vs_sync_x": br.get("bridge_vs_sync_x"),
            "uncalibrated_gap_x": br.get("uncalibrated_gap_x"),
            "calibrated_gap_x": br.get("calibrated_gap_x"),
            "calibrated_dqn_holdout_reward_ratio":
                br.get("calibrated_dqn_holdout_reward_ratio"),
            "calibration": br.get("calibration"),
            "sharded_devices": sh.get("devices"),
            "sharded_env_steps_per_s": sh.get("sharded_env_steps_per_s"),
            "sharded_per_device_env_steps_per_s":
                sh.get("per_device_env_steps_per_s"),
            "sharded_per_device_flatness": sh.get("per_device_flatness"),
            "sharded_local_vs_alltoall_x": sh.get("local_vs_alltoall_x"),
            "rl_stage_fracs": prof.get("rl_stage_fracs"),
            "rl_dominant_stage": prof.get("dominant_stage_flops"),
            "env_flops_per_cell": prof.get("env_flops_per_cell"),
            "cliff_cells": prof.get("cliff_cells"),
            "cliff_classification": prof.get("cliff_classification"),
            "suites": fleet_metrics,
        }, wall_seconds=time.time() - t0,
            failures=[n for n, _ in failures])
        print("# wrote results/BENCH_fleet.json", flush=True)
    print(f"# done in {time.time()-t0:.0f}s; failures: "
          f"{[n for n, _ in failures] or 'none'}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
