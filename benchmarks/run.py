"""Benchmark runner: one module per paper table/figure. Prints
``name,us_per_call,derived`` CSV rows (see common.emit).

  PYTHONPATH=src python -m benchmarks.run [--only fig5,table11]
  REPRO_BENCH_MODE=full for paper-scale RL budgets.
"""
import argparse
import sys
import time

from benchmarks import (bench_adaptation, bench_fig1_motivation,
                        bench_fig5_user_variability, bench_fig7_transfer,
                        bench_fleet_throughput, bench_kernels,
                        bench_overhead, bench_table8_decisions,
                        bench_table9_constraints, bench_table10_sota,
                        bench_table11_convergence)

SUITES = {
    "fig1": bench_fig1_motivation,
    "fig5": bench_fig5_user_variability,
    "table8": bench_table8_decisions,
    "table9": bench_table9_constraints,
    "table10": bench_table10_sota,
    "table11": bench_table11_convergence,
    "fig7": bench_fig7_transfer,
    "overhead": bench_overhead,
    "kernels": bench_kernels,
    "adaptation": bench_adaptation,   # beyond-paper: mid-run network shift
    "fleet": bench_fleet_throughput,  # beyond-paper: vectorized fleet sim
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    args = ap.parse_args()
    names = list(SUITES) if not args.only else args.only.split(",")
    print("name,us_per_call,derived")
    t0 = time.time()
    failures = []
    for name in names:
        print(f"# --- {name} ---", flush=True)
        try:
            SUITES[name].main()
        except Exception as e:  # noqa
            import traceback
            traceback.print_exc()
            failures.append((name, e))
    print(f"# done in {time.time()-t0:.0f}s; failures: "
          f"{[n for n, _ in failures] or 'none'}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
