"""Roofline report (deliverable g): reads results/dryrun.jsonl and emits
results/roofline.md — per (arch x shape x mesh): the three roofline terms,
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs ratio, per-device memory, and
a one-line "what would move the dominant term" note.

  PYTHONPATH=src python -m benchmarks.roofline_report [--jsonl results/dryrun.jsonl]
"""
import argparse
import json
import os
from collections import OrderedDict

NOTES = {
    ("compute",): "raise MXU utilization: larger fused matmul tiles / "
                  "fewer small ops; already near roofline if useful~1",
    ("memory", "train"): "cut HBM traffic: tighter remat policy, fused "
                         "attention (flash) instead of materialized scores, "
                         "smaller loss chunks",
    ("memory", "decode"): "decode is cache-bandwidth-bound by nature: "
                          "donate cache buffers (in-place update), int8/kv "
                          "quantization, GQA already minimizes KV reads",
    ("memory", "prefill"): "fuse attention (flash kernel) and keep "
                           "activations bf16; avoid cache copies",
    ("collective",): "reshard: move FSDP gathers off the critical path "
                     "(overlap), all-to-all instead of all-gather for MoE "
                     "dispatch, reduce-scatter gradients",
}


def note_for(row):
    dom = row["dominant"]
    return NOTES.get((dom, row["kind"]), NOTES.get((dom,), ""))


def load(path):
    rows = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            # keep the LAST result for each combo (reruns supersede)
            rows[(r["arch"], r["shape"], r.get("mesh", "?"))] = r
    return rows


def fmt_table(rows, mesh):
    fitproof = mesh != "16x16"   # the multi-pod pass skips the unrolled
    # cost compile: it proves the 'pod' axis shards (lower+compile+fit);
    # roofline terms are single-pod per the spec.
    out = []
    hdr = f"### mesh {mesh}"
    if fitproof:
        hdr += (" — compile/fit proof only (roofline terms are single-pod;"
                " this pass compiles the runtime scan program)")
    out.append(hdr + "\n")
    if fitproof:
        out.append("| arch | shape | compiled | temp/dev | args/dev |")
        out.append("|---|---|---|---|---|")
    else:
        out.append("| arch | shape | compute | memory | collective | dominant "
                   "| useful | temp/dev | fits 16G | note |")
        out.append("|---|---|---|---|---|---|---|---|---|---|")
    for (arch, shape, m), r in sorted(rows.items()):
        if m != mesh:
            continue
        if not r.get("ok"):
            out.append(f"| {arch} | {shape} | FAIL | "
                       f"{r.get('error', '')[:60]} | |")
            continue
        temp = (r.get("temp_bytes_per_device") or 0) / 2**30
        arg = (r.get("arg_bytes_per_device") or 0) / 2**30
        if fitproof:
            out.append(f"| {arch} | {shape} | OK | {temp:.1f}G | {arg:.1f}G |")
            continue
        fits = "Y" if (temp + arg) <= 16.0 else f"N({temp+arg:.0f}G)"
        out.append(
            f"| {arch} | {shape} "
            f"| {r['compute_s']*1e3:.1f} ms "
            f"| {r['memory_s']*1e3:.1f} ms "
            f"| {r['collective_s']*1e3:.1f} ms "
            f"| {r['dominant']} "
            f"| {min(r['useful_flops_ratio'], 99):.2f} "
            f"| {temp:.1f}G "
            f"| {fits} "
            f"| {note_for(r)[:58]} |")
    return "\n".join(out) + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jsonl", default="results/dryrun.jsonl")
    ap.add_argument("--out", default="results/roofline.md")
    args = ap.parse_args()
    rows = load(args.jsonl)
    parts = ["# Roofline table (per device per step; v5e constants)\n"]
    for mesh in ("16x16", "2x16x16"):
        if any(m == mesh for (_, _, m) in rows):
            parts.append(fmt_table(rows, mesh))
    n_ok = sum(1 for r in rows.values() if r.get("ok"))
    parts.append(f"\n{n_ok}/{len(rows)} combos lowered+compiled OK.\n")
    txt = "\n".join(parts)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        f.write(txt)
    print(txt)


if __name__ == "__main__":
    main()
