"""Multi-edge-cell topology (ISSUE-3 acceptance): throughput of the
topology-aware fleet env step vs the isolated-cell path across
``(cells, edges)`` shapes, and expected reward of topology-aware vs
topology-blind routing under a hot-edge scenario.

Blind routing is exactly what PR 1/2 shipped: each cell picks its
isolated brute-force optimum as if it owned a private edge and cloud.
Aware routing is the coupled ``topology_bruteforce`` best-response
oracle. Both are evaluated under the SAME shared contention, so the gap
is purely the value of seeing neighbor pressure.

Emits:
  topology_env_cells{c}_edges{e},<us/env-step>,steps_per_s=...
  topology_env_overhead,<ratio>,topology/isolated env-step time ...
  topology_hot_edge_blind_reward,<reward>,isolated-optimal decisions ...
  topology_hot_edge_aware_reward,<reward>,best-response decisions ...
  topology_hot_edge_uplift,<delta>,aware - blind expected reward ...
  topology_oracle_rounds,<n>,best-response sweeps to the fixed point

``--tiny`` (CLI) shrinks every budget to a few seconds of work — the CI
smoke mode that keeps this script from rotting.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import FAST, Timer, emit, save_json
from repro.core.spaces import SpaceSpec
from repro.fleet import (FleetConfig, SyntheticSource, dynamics,
                         fleet_bruteforce,
                         fleet_topology_expected_response,
                         hot_edge_topology, make_fleet_env_step,
                         mixed_table5_fleet, topology_bruteforce,
                         with_topology)

USERS = 3
THRESHOLD = 89.0          # forces offloading, so shared contention binds


def bench_env(host_steps: int, cells: int, n_edges, chunk: int = 50):
    """env-steps/sec of the jitted fleet env step (scan of ``chunk``
    steps per host call), isolated (``n_edges=None``) or shared."""
    cfg = FleetConfig(cells=cells, users=USERS, n_edges=n_edges,
                      assignment="skewed", cloud_servers=4.0 * cells
                      if n_edges else float("inf"))
    source = SyntheticSource(cfg)
    scen, _ = source.reset(jax.random.PRNGKey(0))
    env_step = make_fleet_env_step(source)

    def run_chunk(key, scen, actions):          # actions: (chunk, cells, N)
        def body(carry, a):
            key, scen = carry
            key, k = jax.random.split(key)
            scen2, _, ms, _, _ = env_step(k, scen, a)
            return (key, scen2), ms.mean()
        (key, scen), ms = jax.lax.scan(body, (key, scen), actions)
        return key, scen, ms

    run_chunk = jax.jit(run_chunk)
    rng = np.random.default_rng(1)
    actions = jnp.asarray(rng.integers(0, 10, (chunk, cells, USERS)),
                          jnp.int32)
    key = jax.random.PRNGKey(2)
    key, scen, _ = run_chunk(key, scen, actions)     # compile
    jax.block_until_ready(scen.end_b)
    n_chunks = max(1, host_steps // chunk)
    with Timer() as t:
        for _ in range(n_chunks):
            key, scen, ms = run_chunk(key, scen, actions)
        jax.block_until_ready(ms)
    return n_chunks * chunk * cells / t.seconds


def bench_hot_edge(cells: int, n_edges: int, users: int = 2,
                   hot_fraction: float = 0.6, cloud_servers: float = 8.0):
    """Expected reward of aware vs blind routing when ``hot_fraction``
    of the cells share one edge and the cloud queues fleet-wide."""
    scen = mixed_table5_fleet(jax.random.PRNGKey(0), cells, users)
    topo = hot_edge_topology(cells, n_edges, hot_fraction=hot_fraction,
                             cloud_servers=cloud_servers)
    scen_t = with_topology(scen, topo)
    spec = SpaceSpec(users)
    pu = jnp.asarray(spec.decode_actions_batch(spec.all_actions()))
    # blind: per-cell isolated optimum, then judged under shared load
    _, blind_idx = fleet_bruteforce(scen, pu, THRESHOLD)
    b_ms, b_acc = fleet_topology_expected_response(
        pu[blind_idx], scen.end_b, scen.edge_b, topo, scen.member)
    r_blind = float(dynamics.reward(b_ms, b_acc, THRESHOLD, xp=jnp).mean())
    # aware: coupled best-response oracle
    a_ms, a_idx, converged, rounds = topology_bruteforce(scen_t, pu,
                                                         THRESHOLD)
    _, a_acc = fleet_topology_expected_response(
        pu[a_idx], scen.end_b, scen.edge_b, topo, scen.member)
    r_aware = float(dynamics.reward(a_ms, a_acc, THRESHOLD, xp=jnp).mean())
    emit("topology_hot_edge_blind_reward", r_blind,
         f"isolated-optimal decisions under a {hot_fraction:.0%}-hot "
         f"edge ({cells} cells, {n_edges} edges)")
    emit("topology_hot_edge_aware_reward", r_aware,
         f"best-response decisions, converged={converged} "
         f"(target > blind)")
    emit("topology_hot_edge_uplift", r_aware - r_blind,
         "aware - blind expected reward (rewards are negative; > 0 "
         "means routing around the hot edge pays)")
    emit("topology_oracle_rounds", rounds,
         "best-response sweeps to the fixed point")
    return r_blind, r_aware, converged, rounds


def main(tiny: bool = False):
    if tiny:
        shapes, steps, chunk = [(16, 4)], 60, 20
        hot_cells, hot_edges = 16, 4
    elif FAST:
        shapes, steps, chunk = [(256, 16), (1024, 32)], 300, 50
        hot_cells, hot_edges = 48, 4
    else:
        shapes, steps, chunk = [(256, 16), (1024, 32), (4096, 64)], 1000, 50
        hot_cells, hot_edges = 64, 4

    env_sps = {}
    overhead = None
    for cells, n_edges in shapes:
        iso = bench_env(steps, cells, None, chunk)
        topo = bench_env(steps, cells, n_edges, chunk)
        env_sps[f"{cells}x{n_edges}"] = topo
        overhead = iso / topo
        emit(f"topology_env_cells{cells}_edges{n_edges}", 1e6 / topo,
             f"steps_per_s={topo:.0f} (isolated path {iso:.0f}/s)")
    emit("topology_env_overhead", overhead,
         "isolated/topology env-step throughput at the largest shape "
         "(segment-sum + queue cost; ~1 means the aggregation is free)")

    r_blind, r_aware, converged, rounds = bench_hot_edge(hot_cells,
                                                         hot_edges)
    metrics = {
        "users": USERS,
        "topology_env_steps_per_s": env_sps,
        "topology_env_overhead_x": overhead,
        "hot_edge_blind_reward": r_blind,
        "hot_edge_aware_reward": r_aware,
        "hot_edge_reward_uplift": r_aware - r_blind,
        "oracle_converged": bool(converged),
        "oracle_rounds": int(rounds),
    }
    save_json("topology", metrics)
    return metrics


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="seconds-scale budgets (CI smoke)")
    main(tiny=ap.parse_args().tiny)
