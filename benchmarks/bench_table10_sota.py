"""Paper Table 10: the SOTA [36] baseline's decisions (CO-only, d0) per
experiment, 5 users."""
from benchmarks.common import emit, save_json
from repro.core import EXPERIMENTS, EndEdgeCloudEnv, bruteforce_optimal
from repro.core.spaces import restricted_actions

PAPER = {"EXP-A": 418.91, "EXP-B": 472.88, "EXP-C": 464.59, "EXP-D": 506.62}


def main():
    out = {}
    for exp, sc in EXPERIMENTS.items():
        env = EndEdgeCloudEnv(5, sc, noise=0)
        a, ms, acc, _ = bruteforce_optimal(env, 0.0,
                                           restricted_actions(env.spec))
        out[exp] = {"decision": env.spec.decode_action(a), "ms": ms,
                    "acc": acc, "paper_ms": PAPER[exp]}
        emit(f"table10_{exp}", 0.0,
             f"{ms:.1f}ms|paper{PAPER[exp]:.1f}|acc{acc:.1f}")
    save_json("bench_table10", out)
    return out


if __name__ == "__main__":
    main()
