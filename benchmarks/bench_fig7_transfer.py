"""Paper Fig. 7: transfer-learning warm start cuts convergence time
(paper: up to 12.5x QL / 3.3x DQL)."""
from benchmarks.common import FAST, Timer, emit, save_json
from repro.core import (EXPERIMENTS, DQNAgent, DQNConfig, EndEdgeCloudEnv,
                        QLearningAgent, QLearningConfig, transfer_experiment)
from repro.core.spaces import SpaceSpec


def main():
    out = {}
    n = 2 if FAST else 3

    def make_env(th):
        return EndEdgeCloudEnv(n, EXPERIMENTS["EXP-A"],
                               accuracy_threshold=th, seed=7)

    def make_ql():
        return QLearningAgent(SpaceSpec(n), QLearningConfig(eps_decay=1e-2),
                              seed=7)

    with Timer() as t:
        scr, wrm = transfer_experiment(make_ql, make_env, 0.0, 85.0,
                                       max_steps=60000, check_every=100)
    sp = (scr.converged_at or 60000) / max(1, (wrm.converged_at or 60000))
    emit("fig7_ql_transfer", t.us,
         f"scratch={scr.converged_at}_warm={wrm.converged_at}_speedup={sp:.1f}x")
    out["ql"] = {"scratch": scr.converged_at, "warm": wrm.converged_at,
                 "speedup": sp}

    def make_dq():
        return DQNAgent(SpaceSpec(n), DQNConfig(form="paper", train_every=2),
                        seed=7, accuracy_threshold=85.0)

    with Timer() as t:
        scr, wrm = transfer_experiment(make_dq, make_env, 0.0, 85.0,
                                       max_steps=8000 if FAST else 30000,
                                       check_every=250)
    sp = (scr.converged_at or 1e9) / max(1, (wrm.converged_at or 1e9))
    emit("fig7_dql_transfer", t.us,
         f"scratch={scr.converged_at}_warm={wrm.converged_at}_speedup={sp:.1f}x")
    out["dql"] = {"scratch": scr.converged_at, "warm": wrm.converged_at,
                  "speedup": sp}
    save_json("bench_fig7", out)
    return out


if __name__ == "__main__":
    main()
