"""SLO attainment + tail latency through the serving bridge (ISSUE-8).

Two measurements:

1. **Windowed-metrics overhead** — the `(n_windows, lanes)` ring added
   to the in-scan accumulator is a handful of scatter-adds per step, so
   the RL-loop throughput with windows on vs off must stay ~1.0x
   (`windowed_overhead_x`, gated < 1.10 by tools/benchgate.py, with the
   ISSUE-8 acceptance target < 1.05 at the default budget).
2. **SLO through real engines** — train briefly on the golden trace
   fixture (with windowed metrics on, so the saved JSON carries a
   learning-curve series that ``tools/obsview.py --timeline`` renders),
   warm the engines with a throwaway route, then dispatch every active
   user with the scenario QoS deadline stamped on each request.
   ``RouteResult.slo()`` yields measured vs predicted attainment (the
   ~2.4x ``trace_serving_gap_x`` makes the model OVERSTATE deliverable
   SLO — ``slo_attainment_gap`` quantifies by how much) and the P99
   end-to-end tail from the host-exact quantile source.

Emits:
  windowed_overhead_x,<ratio>,windows-off/windows-on RL throughput
  slo_requests,<n>,requests dispatched with a deadline stamped
  slo_attainment_measured,<frac>,measured e2e <= deadline fraction
  slo_attainment_predicted,<frac>,latency-model prediction vs deadline
  slo_attainment_gap,<frac>,predicted - measured attainment
  slo_p99_ms,<ms>,measured P99 end-to-end latency

``--tiny`` (CLI) shrinks every budget to a few seconds of work — the CI
smoke mode that keeps the SLO path from rotting.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax

from benchmarks.bench_fleet_dqn import bench_rl
from benchmarks.common import (FAST, Timer, emit, save_json,
                               trace_fixture_agent, warmed_engines)
from repro.fleet import FleetDQN, FleetDQNConfig, FleetOrchestrator


def bench_windowed_overhead(cells: int, steps: int, chunk: int,
                            n_windows: int = 8) -> float:
    """Windows-off / windows-on RL-loop throughput (best of 2 each, so
    one noisy timing doesn't report the ring as costly)."""
    window_len = max(1, chunk // n_windows)
    on = min(bench_rl(FleetDQN, cells, steps, chunk, cfg=FleetDQNConfig(),
                      seed=0, n_windows=n_windows, window_len=window_len)
             for _ in range(2))
    off = min(bench_rl(FleetDQN, cells, steps, chunk, cfg=FleetDQNConfig(),
                       seed=0)
              for _ in range(2))
    ratio = off / on
    emit("windowed_overhead_x", ratio,
         f"windows-off/windows-on steps-per-s at {cells} cells, "
         f"{n_windows}x{window_len}-step ring (1.0 = windows are free)")
    return ratio


def bench_slo_serving(train_steps: int, max_new_tokens: int = 2,
                      n_windows: int = 8):
    """Train on the trace fixture, dispatch through warmed engines with
    the QoS deadline stamped, and report attainment + P99."""
    agent = trace_fixture_agent(train_steps, n_windows=n_windows,
                                window_len=max(1, train_steps // n_windows))
    orch = FleetOrchestrator(agent)
    kw = dict(max_new_tokens=max_new_tokens, batch_size=4, prompt_len=8)
    engines = warmed_engines(orch, **kw)
    kw = dict(dispatch=engines, **kw)
    with Timer() as t:
        res = orch.route(**kw)
    slo = res.slo()
    meas = slo["measured"]["attainment"]
    pred = slo["predicted"]["attainment"]
    p99 = slo["quantiles"]["exact_ms"]["p99"]
    emit("slo_requests", slo["requests"],
         f"requests with deadline {slo['deadline_ms']:.0f} ms stamped "
         f"({t.seconds:.1f}s warmed dispatch wall)")
    emit("slo_attainment_measured", meas,
         f"{slo['measured']['attained']}/{slo['requests']} measured "
         "e2e (queue + emulated compute) within deadline")
    emit("slo_attainment_predicted", pred,
         f"{slo['predicted']['attained']}/{slo['requests']} predicted "
         "by the latency model — the gap vs measured is the Table-8 "
         "prediction error expressed as overstated SLO")
    emit("slo_attainment_gap", slo["attainment_gap"],
         "predicted - measured attainment (positive = model overstates)")
    emit("slo_p99_ms", p99, "measured P99 end-to-end latency "
         f"(P50 {slo['quantiles']['exact_ms']['p50']:.0f} ms)")
    return slo, agent.metrics_summary()


def main(tiny: bool = False):
    if tiny:
        cells, steps, chunk, train = 16, 40, 20, 32
    elif FAST:
        cells, steps, chunk, train = 256, 400, 200, 200
    else:
        cells, steps, chunk, train = 1024, 2000, 200, 1000

    overhead = bench_windowed_overhead(cells, steps, chunk)
    slo, train_summary = bench_slo_serving(train)
    metrics = {
        "windowed_overhead_x": overhead,
        "slo_requests": slo["requests"],
        "slo_attainment_measured": slo["measured"]["attainment"],
        "slo_attainment_predicted": slo["predicted"]["attainment"],
        "slo_attainment_gap": slo["attainment_gap"],
        "p99_ms": slo["quantiles"]["exact_ms"]["p99"],
        "slo": slo,
        # windowed learning-curve series (reward per window) — the
        # block tools/obsview.py --timeline renders from this JSON
        "training_reward": train_summary["reward"],
    }
    save_json("slo", metrics)
    return metrics


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="seconds-scale budgets (CI smoke)")
    main(tiny=ap.parse_args().tiny)
