"""Async serving bridge + sim-to-real calibration loop (ISSUE-9).

Two measurements over the same warmed engine fleet:

1. **Bridge vs sync dispatch throughput** — route the identical
   workload through ``FleetOrchestrator.route(dispatch=engines)`` (the
   one-shot synchronous drain) and through ``route(..., bridge=True)``
   (per-(tier, variant) queues with overlapped batch formation and
   drain). The workload is a balanced three-tier spread
   (``SpreadPolicy``: users round-robin over S/E/C, every user active)
   against engines with EMULATED NETWORK HOPS (``HOP_MS``: a real
   per-batch sleep for the edge/cloud tiers) — the case the bridge
   exists for: the paper's tiers are physically separate machines whose
   comm latency and compute genuinely overlap, a property a single
   shared host loses (its "tiers" contend for the same cores, so
   overlapping pure-CPU engines is a wash). The sync path pays every
   hop serialized; the bridge overlaps them across tiers. Both paths
   are warmed first so compile never skews the comparison; best-of-N
   walls
   from ``RouteResult.timings`` give ``sync_throughput_rps`` /
   ``bridge_throughput_rps`` and their ratio ``bridge_vs_sync_x``
   (> 1 = the overlap wins; gated by tools/benchgate.py on the bridge
   band).
2. **Calibration loop** — ``fleet.calibrate.calibrate_serving`` routes
   the same spread fleet uncalibrated (so every tier contributes fit
   data), fits per-tier (compute_scale, hop_offset_ms) coefficients
   from the measured engine walls, routes again on the calibrated
   model, and retrains a ``FleetDQN`` on ``CalibratedDynamics``.
   ``calibrated_gap_x`` is the after-fit measured/predicted ratio
   (gated as a ceiling: within 1.5x of the real engines, from an
   uncalibrated model error of ~0.1-2.4x), and
   ``calibrated_dqn_holdout_reward_ratio`` shows the retrained policy
   still matches the oracle on calibrated holdout dynamics.

Emits:
  sync_throughput_rps,<rps>,one-shot synchronous drain
  bridge_throughput_rps,<rps>,async bridge (overlapped formation/drain)
  bridge_vs_sync_x,<ratio>,bridge/sync dispatch throughput
  bridge_overlap_x,<ratio>,engine compute / post-submit wall
  calibrated_gap_x,<ratio>,measured/predicted after the fit (1.0 = ideal)
  uncalibrated_gap_x,<ratio>,the same route before the fit
  calibrated_dqn_holdout_reward_ratio,<frac>,retrained policy vs oracle

``--tiny`` (CLI) shrinks every budget to a few seconds of work — the CI
smoke mode that keeps the bridge AND calibration paths from rotting.
"""
import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp

from benchmarks.common import FAST, emit, save_json, serving_engines
from repro.fleet import (CalibratedDynamics, FleetConfig, FleetDQN,
                         FleetOrchestrator, SyntheticSource,
                         apply_calibration, calibrate_serving, dynamics,
                         init_fleet, holdout_reward_ratio)

ROUTE_KW = dict(max_new_tokens=2, batch_size=4, prompt_len=8)

#: per-batch network hop to the edge / cloud tiers (device tier is
#: local) — WiFi-RTT / WAN-RTT scale, like the paper's testbed. The
#: calibration fit absorbs these into its per-hop comm offsets.
HOP_MS = {"E": 25.0, "C": 50.0}


class SpreadPolicy:
    """Balanced three-tier placement: user slots round-robin over
    (local d0, edge, cloud). The throughput workload — loads the S/E/C
    engines evenly so the sync-serialized drain has something for the
    bridge to overlap, like the paper's physically-separate tiers."""

    def __init__(self, users: int):
        self.users = users

    def decisions(self, counts, scen):
        idx = jnp.arange(scen.cells)[:, None] * scen.users \
            + jnp.arange(scen.users)[None, :]
        acts = jnp.asarray([0, dynamics.A_EDGE, dynamics.A_CLOUD])
        return acts[idx % 3], jnp.zeros((scen.cells,), jnp.int32)


def _rps(res) -> float:
    """Requests per second of one dispatched route, from the same
    ``timings['wall_ms']`` both paths account (identities hold on
    each, so the walls are comparable end to end)."""
    return len(res.served) / (res.timings["wall_ms"] / 1e3)


def bench_bridge_throughput(orch, scen, engines, best_of: int = 3):
    """Best-of-N dispatch throughput, sync drain vs async bridge, on
    the identical warmed workload. The two paths are measured
    INTERLEAVED (sync, bridge, sync, bridge, ...) so slow drift on the
    host — frequency scaling, background load — hits both equally
    instead of biasing whichever ran last."""
    kw = dict(scen=scen, dispatch=engines, **ROUTE_KW)
    orch.route(**kw)                      # warm the sync path
    orch.route(bridge=True, **kw)         # warm the bridge path
    sync_rps, bres = [], []
    for _ in range(best_of):
        sync_rps.append(_rps(orch.route(**kw)))
        bres.append(orch.route(bridge=True, **kw))
    sync = max(sync_rps)
    bridge = max(_rps(r) for r in bres)
    overlap = max(r.bridge["overlap_x"] for r in bres)
    emit("sync_throughput_rps", sync,
         "requests/s through the one-shot synchronous drain "
         f"(best of {best_of})")
    emit("bridge_throughput_rps", bridge,
         "requests/s through the async bridge — overlapped batch "
         f"formation + drain (best of {best_of})")
    emit("bridge_vs_sync_x", bridge / sync,
         "bridge/sync dispatch throughput (> 1 = overlap wins)")
    emit("bridge_overlap_x", overlap,
         "engine compute wall / post-submit dispatch wall (> 1 only "
         "when batches genuinely overlap)")
    return sync, bridge, overlap


def bench_calibration(orch, scen, engines, dqn_steps: int,
                      train_cells: int = 512, holdout_cells: int = 32):
    """The full sim-to-real loop: fit on measured engine walls, route
    calibrated, retrain a FleetDQN on the calibrated dynamics.

    The calibrated landscape is nearly flat (testbed walls compress
    the modeled latency range ~30x), so the oracle-vs-policy gaps live
    in a few weak-link cells and sit at the shared net's resolution
    floor. Two standard countermeasures keep the retrain honest AND
    stable: a LARGE training fleet (``train_cells`` — every link
    configuration lands in the pooled replay often enough to be
    resolved; at 32 cells the ratio plateaus ~0.89) and EARLY STOPPING
    on a validation fleet — the DQN oscillates through the optimum
    rather than settling on it (observed ratio series 0.33 → 0.52 →
    1.0 → 0.46 over one run), so the best-validation checkpoint is
    what gets scored, on a DISJOINT holdout fleet."""

    def retrain(calib):
        cfg = FleetConfig(cells=train_cells, users=3, arrival_rate=None)
        dqn = FleetDQN(CalibratedDynamics(SyntheticSource(cfg), calib),
                       seed=0)
        ecfg = FleetConfig(cells=holdout_cells, users=3,
                           arrival_rate=None)
        val = apply_calibration(init_fleet(jax.random.PRNGKey(11), ecfg),
                                calib)
        # snapshots must COPY: dqn.run donates its param buffers, so a
        # borrowed mid-run snapshot would be deleted by later chunks
        snap = lambda: jax.tree_util.tree_map(jnp.copy, dqn.params)
        chunk = max(dqn_steps // 10, 16)
        best, best_params, best_at, trained = -1.0, snap(), 0, 0
        while trained < dqn_steps:
            dqn.run(chunk)
            trained += chunk
            v = float(holdout_reward_ratio(dqn, val).ratio)
            if v > best:
                best, best_params, best_at = v, snap(), trained
            if best >= 1.0 - 1e-6:
                break
        dqn.params = best_params
        held = apply_calibration(init_fleet(jax.random.PRNGKey(7), ecfg),
                                 calib)
        ev = holdout_reward_ratio(dqn, held)
        return {"holdout_reward_ratio": float(ev.ratio),
                "train_steps": best_at, "budget_steps": dqn_steps,
                "cells": holdout_cells, "train_cells": train_cells,
                "validation_ratio": best}

    report, _fit, _after = calibrate_serving(
        orch, scen, engines, route_kw=ROUTE_KW, retrain=retrain)
    emit("uncalibrated_gap_x", report["before"]["gap_x"],
         "measured/predicted before the fit (warm engines; the model "
         "error the calibration removes)")
    emit("calibrated_gap_x", report["after"]["gap_x"],
         "measured/predicted after fitting per-tier compute_scale + "
         "hop_offset_ms (1.0 = the calibrated model is exact)")
    emit("calibrated_dqn_holdout_reward_ratio",
         report["retrained"]["holdout_reward_ratio"],
         f"retrained-on-calibrated FleetDQN vs oracle reward on a "
         f"{holdout_cells}-cell calibrated holdout fleet")
    return report


def main(tiny: bool = False):
    if tiny:
        cells, dqn_steps, train_cells, best_of = 8, 64, 32, 2
    elif FAST:
        cells, dqn_steps, train_cells, best_of = 32, 2500, 512, 5
    else:
        cells, dqn_steps, train_cells, best_of = 64, 3000, 512, 5

    cfg = FleetConfig(cells=cells, users=3, arrival_rate=None)
    scen = init_fleet(jax.random.PRNGKey(0), cfg)
    orch = FleetOrchestrator(SpreadPolicy(cfg.users))
    engines = serving_engines(hop_ms=HOP_MS)
    sync, bridge, overlap = bench_bridge_throughput(orch, scen, engines,
                                                    best_of=best_of)
    report = bench_calibration(orch, scen, engines, dqn_steps,
                               train_cells=train_cells)
    metrics = {
        "sync_throughput_rps": sync,
        "bridge_throughput_rps": bridge,
        "bridge_vs_sync_x": bridge / sync,
        "bridge_overlap_x": overlap,
        "uncalibrated_gap_x": report["before"]["gap_x"],
        "calibrated_gap_x": report["after"]["gap_x"],
        "calibrated_dqn_holdout_reward_ratio":
            report["retrained"]["holdout_reward_ratio"],
        # the block tools/obsview.py --timeline renders from this JSON
        "calibration": report,
    }
    save_json("bridge", metrics)
    return metrics


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="seconds-scale budgets (CI smoke)")
    main(tiny=ap.parse_args().tiny)
