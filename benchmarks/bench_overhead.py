"""Paper §6.2 + Fig. 8 + Table 12: runtime overheads — agent step time,
resource-monitoring cost, message-broadcasting budget."""
import time

import numpy as np

from benchmarks.common import Timer, emit, save_json
from repro.core import (EXPERIMENTS, DQNAgent, DQNConfig, EndEdgeCloudEnv,
                        QLearningAgent)
from repro.core.env import T_ORCH, T_UP_EDGE


def main():
    out = {}
    env = EndEdgeCloudEnv(5, EXPERIMENTS["EXP-A"], seed=0)
    s = env.reset()

    # Q-Learning agent invocation (paper: 0.6 ms on cloud CPU)
    ql = QLearningAgent(env.spec, seed=0)
    for _ in range(50):
        a = ql.act(s); s2, r, _ = env.step(a); ql.update(s, a, r, s2); s = s2
    t0 = time.perf_counter()
    for _ in range(500):
        a = ql.act(s)
        s2, r, _ = env.step(a)
        ql.update(s, a, r, s2)
        s = s2
    ql_ms = (time.perf_counter() - t0) / 500 * 1e3
    emit("overhead_ql_step", ql_ms * 1e3, f"{ql_ms:.3f}ms_paper0.6ms")
    out["ql_step_ms"] = ql_ms

    # DQN agent invocation (paper: 11 ms on RTX5000)
    dq = DQNAgent(env.spec, DQNConfig(form="factored"), seed=0,
                  accuracy_threshold=89.0)
    for _ in range(80):
        a = dq.act(s); s2, r, _ = env.step(a); dq.update(s, a, r, s2); s = s2
    t0 = time.perf_counter()
    for _ in range(200):
        a = dq.act(s)
        s2, r, _ = env.step(a)
        dq.update(s, a, r, s2)
        s = s2
    dq_ms = (time.perf_counter() - t0) / 200 * 1e3
    emit("overhead_dql_step", dq_ms * 1e3, f"{dq_ms:.3f}ms_paper11ms")
    out["dql_step_ms"] = dq_ms

    # resource monitoring: state observation cost vs min response time
    t0 = time.perf_counter()
    for _ in range(2000):
        env._observe()
    mon_ms = (time.perf_counter() - t0) / 2000 * 1e3
    min_resp = 72.08
    emit("overhead_monitoring", mon_ms * 1e3,
         f"{mon_ms/min_resp*100:.3f}%_of_min_resp_paper<0.8%")
    out["monitoring_ms"] = mon_ms

    # message broadcasting budget (model constants = Table 12)
    out["table12"] = {"orch_regular_ms": T_ORCH[0], "orch_weak_ms": T_ORCH[1],
                      "upload_regular_ms": T_UP_EDGE[0],
                      "upload_weak_ms": T_UP_EDGE[1]}
    emit("overhead_broadcast_regular", 0.0, f"{T_ORCH[0]}ms_paper21.4ms")
    emit("overhead_broadcast_weak", 0.0, f"{T_ORCH[1]}ms_paper141ms")
    save_json("bench_overhead", out)
    return out


if __name__ == "__main__":
    main()
