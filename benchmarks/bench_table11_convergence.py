"""Paper Table 11 + Fig. 6: convergence steps for Q-Learning vs Deep
Q-Learning vs SOTA [36] vs brute-force, per user count and threshold.

fast mode: N in {2,3}; full mode: N in {3,4,5} with paper-scale budgets.
"""
from benchmarks.common import FAST, Timer, emit, save_json
from repro.core import (EXPERIMENTS, THRESHOLDS, DQNAgent, DQNConfig,
                        EndEdgeCloudEnv, QLearningAgent,
                        bruteforce_complexity, make_sota_agent, train_agent)

PAPER_N5 = {"QL": 1.05e6, "DQL": 6.5e4, "SOTA": 2.5e4, "BF": 4.2e12}


def main():
    out = {}
    users = (2, 3) if FAST else (3, 4, 5)
    thresholds = ("Min", "85%", "Max") if FAST else tuple(THRESHOLDS)
    budget = {2: 30000, 3: 60000, 4: 150000, 5: 400000}
    for n in users:
        for tname in thresholds:
            th = THRESHOLDS[tname]
            env = EndEdgeCloudEnv(n, EXPERIMENTS["EXP-A"],
                                  accuracy_threshold=th, seed=0)
            ql = QLearningAgent(env.spec, seed=0)
            with Timer() as t:
                r_ql = train_agent(ql, env, budget[n], check_every=200)
            emit(f"table11_QL_{n}u_{tname}", t.us,
                 f"steps={r_ql.converged_at}_pred={r_ql.prediction_accuracy:.2f}")

            env = EndEdgeCloudEnv(n, EXPERIMENTS["EXP-A"],
                                  accuracy_threshold=th, seed=0)
            form = "paper" if n <= 3 else "factored"
            dq = DQNAgent(env.spec, DQNConfig(form=form, train_every=2),
                          seed=0, accuracy_threshold=th)
            dq_budget = min(budget[n], 20000 if FAST else 80000)
            with Timer() as t:
                r_dq = train_agent(dq, env, dq_budget, check_every=500)
            emit(f"table11_DQL{form[0]}_{n}u_{tname}", t.us,
                 f"steps={r_dq.converged_at}_pred={r_dq.prediction_accuracy:.2f}")

            out[f"{n}u_{tname}"] = {
                "QL_steps": r_ql.converged_at, "QL_pred": r_ql.prediction_accuracy,
                "DQL_steps": r_dq.converged_at, "DQL_pred": r_dq.prediction_accuracy,
                "DQL_form": form,
                "bruteforce_pairs": bruteforce_complexity(n)}
        # SOTA converges faster (smaller space) — Max threshold only
        env = EndEdgeCloudEnv(n, EXPERIMENTS["EXP-A"],
                              accuracy_threshold=0.0, seed=0)
        sota = make_sota_agent(env.spec, seed=0)
        with Timer() as t:
            r_s = train_agent(sota, env, budget[n], check_every=200)
        emit(f"table11_SOTA_{n}u", t.us, f"steps={r_s.converged_at}")
        out[f"{n}u_SOTA"] = r_s.converged_at
        emit(f"table11_bruteforce_{n}u", 0.0,
             f"{bruteforce_complexity(n):.1e}_pairs")
    save_json("bench_table11", out)
    return out


if __name__ == "__main__":
    main()
