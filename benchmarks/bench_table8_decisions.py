"""Paper Table 8: orchestration decisions per #users x experiment at the
Max accuracy threshold (side-by-side with the paper's decisions)."""
from benchmarks.common import emit, save_json
from repro.core import EXPERIMENTS, EndEdgeCloudEnv, bruteforce_optimal

PAPER_AVG = {  # Table 8 Avg Res (ms) for 1..5 users
    "EXP-A": [363.47, 363.17, 397.53, 410.35, 418.91],
    "EXP-B": [403.30, 416.78, 431.90, 457.96, 472.88],
    "EXP-C": [471.65, 467.80, 488.21, 480.70, 464.59],
    "EXP-D": [585.68, 527.39, 491.77, 501.07, 506.62],
}


def _fmt(per):
    tier = {8: "E", 9: "C"}
    return ",".join(f"d0@{tier[p]}" if p >= 8 else f"d{p}@L" for p in per)


def main():
    out = {}
    for exp, sc in EXPERIMENTS.items():
        rows = []
        for n in range(1, 6):
            env = EndEdgeCloudEnv(n, sc, noise=0)
            a, ms, acc, _ = bruteforce_optimal(env, 89.9)
            per = env.spec.decode_action(a)
            rows.append({"users": n, "decision": _fmt(per), "ms": ms,
                         "paper_ms": PAPER_AVG[exp][n - 1]})
            emit(f"table8_{exp}_users{n}", 0.0,
                 f"{_fmt(per)}|{ms:.1f}ms|paper{PAPER_AVG[exp][n-1]:.1f}")
        out[exp] = rows
    save_json("bench_table8", out)
    return out


if __name__ == "__main__":
    main()
