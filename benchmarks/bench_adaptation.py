"""BEYOND-PAPER: online adaptation to a mid-run network shift.

The paper argues (§6.1.2) its online agent "adapts to varying network
conditions" but only reports per-scenario steady states. Here we measure
the transient: train under EXP-A (all regular), hot-switch the network to
EXP-D (all weak) WITHOUT resetting the agent, and count steps until the
greedy policy is optimal for the new conditions. Exploration is re-armed
on drift detection (reward collapse), which is the practical deployment
recipe the paper leaves implicit.
"""
import numpy as np

from benchmarks.common import FAST, Timer, emit, save_json
from repro.core import (EXPERIMENTS, EndEdgeCloudEnv, QLearningAgent,
                        bruteforce_optimal, train_agent)


def main():
    out = {}
    n, th = (2, 85.0) if FAST else (3, 85.0)
    env_a = EndEdgeCloudEnv(n, EXPERIMENTS["EXP-A"], accuracy_threshold=th,
                            seed=11)
    agent = QLearningAgent(env_a.spec, seed=11)
    res_a = train_agent(agent, env_a, 30000, check_every=200)
    out["phase_a"] = {"converged_at": res_a.converged_at,
                      "greedy_ms": res_a.greedy_ms}
    emit("adapt_phaseA_converged", 0.0, res_a.converged_at)

    # hot switch: same agent, weak network everywhere
    env_d = EndEdgeCloudEnv(n, EXPERIMENTS["EXP-D"], accuracy_threshold=th,
                            seed=12)
    _, opt_d, _, _ = bruteforce_optimal(env_d, th)
    # drift detection: reward for the stale greedy policy collapses ->
    # re-arm exploration instead of cold restart
    stale_ms, _ = env_d.expected_response(agent.greedy_action(env_d.reset()))
    agent.eps = 0.5
    with Timer() as t:
        res_d = train_agent(agent, env_d, 30000, check_every=200)
    out["phase_d"] = {
        "stale_policy_ms": stale_ms, "optimal_ms": opt_d,
        "reconverged_at": res_d.converged_at,
        "greedy_ms": res_d.greedy_ms,
        "recovery_vs_scratch": (res_a.converged_at or 1)}
    emit("adapt_phaseD_stale_policy", 0.0, f"{stale_ms:.1f}ms_vs_opt{opt_d:.1f}")
    emit("adapt_phaseD_reconverged", t.us, res_d.converged_at)
    save_json("bench_adaptation", out)
    return out


if __name__ == "__main__":
    main()
