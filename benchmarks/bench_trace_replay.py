"""Trace replay + serving bridge (ISSUE-4): throughput of the fleet env
step when fed from a recorded ``TraceSource`` vs the synthetic
generators, and the prediction-vs-measured latency gap when routed
decisions are dispatched to REAL serving engines through
``FleetOrchestrator.route(dispatch=engines)`` (the paper's Table-8
methodology: the latency model's prediction next to the measured
wall-clock of actual batched inference).

A trace step is a pure gather of prerecorded frames, so replay should
be at least as fast as generating links/arrivals/churn on the fly —
``trace_replay_speedup_x`` reports the ratio.

Emits:
  trace_env_cells{c},<us/env-step>,steps_per_s=... (trace source)
  trace_replay_speedup_x,<ratio>,trace/synthetic env-step throughput
  trace_serving_requests,<n>,requests dispatched through the bridge
  trace_serving_gap_x,<ratio>,measured/predicted mean latency ...
  trace_serving_p95_ms / trace_serving_p99_ms,<ms>,measured e2e tails

``--tiny`` (CLI) shrinks every budget to a few seconds of work — the CI
smoke mode that keeps the trace-replay AND serving-bridge paths from
rotting.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (FAST, Timer, emit, save_json,
                               serving_engines, trace_fixture_agent)
from repro.fleet import (FleetConfig, FleetOrchestrator, SyntheticSource,
                         TraceSource, make_fleet_env_step, record_trace)
from repro.obs import timeline

USERS = 3


def _bench_env_steps(source, scen0, host_steps: int, chunk: int) -> float:
    """env-steps/sec of ``make_fleet_env_step(source)`` inside a jitted
    scan (same harness as bench_fleet_throughput/bench_topology)."""
    env_step = make_fleet_env_step(source)
    cells = scen0.cells

    def run_chunk(key, scen, actions):          # actions: (chunk, cells, N)
        def body(carry, a):
            key, scen = carry
            key, k = jax.random.split(key)
            scen2, _, ms, _, _ = env_step(k, scen, a)
            return (key, scen2), ms.mean()
        (key, scen), ms = jax.lax.scan(body, (key, scen), actions)
        return key, scen, ms

    run_chunk = jax.jit(run_chunk)
    rng = np.random.default_rng(1)
    actions = jnp.asarray(rng.integers(0, 10, (chunk, cells, USERS)),
                          jnp.int32)
    key = jax.random.PRNGKey(2)
    key, scen, _ = run_chunk(key, scen0, actions)    # compile
    jax.block_until_ready(scen.end_b)
    n_chunks = max(1, host_steps // chunk)
    with Timer() as t:
        for _ in range(n_chunks):
            key, scen, ms = run_chunk(key, scen, actions)
        jax.block_until_ready(ms)
    return n_chunks * chunk * cells / t.seconds


def bench_replay_throughput(cells: int, horizon: int, host_steps: int,
                            chunk: int):
    """Record a synthetic stream, then compare env-step throughput of
    replaying the trace vs generating the scenario on the fly."""
    cfg = FleetConfig(cells=cells, users=USERS, p_r2w=0.05, p_w2r=0.15,
                      arrival_rate=1.0, diurnal_period=horizon,
                      p_join=0.02, p_leave=0.02, min_users=1,
                      max_users=USERS)
    synth = SyntheticSource(cfg)
    trace = TraceSource(record_trace(synth, jax.random.PRNGKey(0), horizon))
    synth_scen, _ = synth.reset(jax.random.PRNGKey(0))
    trace_scen, _ = trace.reset(jax.random.PRNGKey(0))
    synth_sps = _bench_env_steps(synth, synth_scen, host_steps, chunk)
    trace_sps = _bench_env_steps(trace, trace_scen, host_steps, chunk)
    emit(f"trace_env_cells{cells}", 1e6 / trace_sps,
         f"steps_per_s={trace_sps:.0f} replaying a {horizon}-frame trace "
         f"(synthetic generators {synth_sps:.0f}/s)")
    emit("trace_replay_speedup_x", trace_sps / synth_sps,
         "trace/synthetic env-step throughput (replay is a frame gather; "
         ">= ~1 means traces are never the bottleneck)")
    return trace_sps, synth_sps


def bench_serving_bridge(train_steps: int, max_new_tokens: int = 2):
    """Train briefly on the golden trace fixture, route through the
    orchestrator, dispatch every active user to real engines, and report
    the prediction-vs-measured latency gap."""
    agent = trace_fixture_agent(train_steps)
    engines = serving_engines()     # cold on purpose: compile is timed
    with Timer() as t:
        res = FleetOrchestrator(agent).route(
            dispatch=engines, max_new_tokens=max_new_tokens, batch_size=4,
            prompt_len=8)
    s = res.summary()
    emit("trace_serving_requests", s["requests"],
         f"requests dispatched in {s['batches']} engine batches "
         f"({t.seconds:.1f}s wall incl. compile)")
    emit("trace_serving_gap_x", s["gap_x"],
         f"measured/predicted mean latency (measured "
         f"{s['measured_mean_ms']:.0f} ms vs model "
         f"{s['predicted_mean_ms']:.0f} ms; the paper's Table-8 "
         "prediction-vs-measured protocol over real engines)")
    # tail latency next to the mean: the mean hides the queueing tail
    # the SLO work (bench_slo) gates on
    q = timeline.exact_quantiles([r.e2e_ms for r in res.served],
                                 qs=(0.95, 0.99))
    emit("trace_serving_p95_ms", q["p95"],
         "measured P95 end-to-end (queue + emulated compute) wall")
    emit("trace_serving_p99_ms", q["p99"],
         "measured P99 end-to-end wall")
    s["p95_ms"], s["p99_ms"] = q["p95"], q["p99"]
    return s


def main(tiny: bool = False):
    if tiny:
        cells, horizon, steps, chunk, train = 16, 16, 60, 20, 32
    elif FAST:
        cells, horizon, steps, chunk, train = 256, 64, 300, 50, 200
    else:
        cells, horizon, steps, chunk, train = 1024, 128, 1000, 50, 1000

    trace_sps, synth_sps = bench_replay_throughput(cells, horizon, steps,
                                                   chunk)
    serve = bench_serving_bridge(train)
    metrics = {
        "users": USERS,
        "trace_env_steps_per_s": trace_sps,
        "synthetic_env_steps_per_s": synth_sps,
        "trace_replay_speedup_x": trace_sps / synth_sps,
        "serving": serve,
    }
    save_json("trace_replay", metrics)
    return metrics


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="seconds-scale budgets (CI smoke)")
    main(tiny=ap.parse_args().tiny)
