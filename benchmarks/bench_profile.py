"""Compiled-cost profile of the fleet hot paths (ISSUE-7 acceptance):
the RL loop's per-stage cost breakdown and the scaling-cliff diagnosis,
via ``repro.obs.prof``.

Measurements:

* ``profile_dqn_stage_*`` / ``profile_tabular_stage_*`` — each RL-loop
  stage's compiled flops fraction and measured wall fraction
  (``obs.prof.stage_costs``: stages compiled separately, wall recorded
  through ``SpanRecorder`` spans). The dominant stage is the fusion
  the ROADMAP's "Pallas-fused RL hot path" item should write.
* ``profile_sweep_single`` / ``profile_sweep_sharded`` — the cells-grid
  scaling sweep (``obs.prof.scaling_sweep``): compiled flops/cell vs
  measured device-time/cell, single-device and on the forced
  multi-device ``('fleet',)`` mesh, naming the first fleet size whose
  device-time per cell-step leaves the flat regime and classifying the
  cliff as runtime overhead vs algorithmic growth — the diagnosis the
  ROADMAP's "Million-cell fleets" flatness item asks for.

Like ``bench_fleet_sharded``, invoking this file directly forces
``--xla_force_host_platform_device_count=8`` before jax initializes;
when imported by ``benchmarks/run.py`` (jax already live on one
device) ``main()`` relaunches itself as a subprocess and folds the
child's metrics back in. ``--tiny`` is the CI smoke mode.
"""
import json
import os
import subprocess
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

_FORCE = "--xla_force_host_platform_device_count"
if __name__ == "__main__" and _FORCE not in os.environ.get("XLA_FLAGS", ""):
    # must happen before jax initializes (it locks the device count)
    os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + f" {_FORCE}=8"

import jax

from benchmarks.common import FAST, RESULTS_DIR, emit, save_json
from repro.fleet import (FleetConfig, FleetQConfig, FleetQLearning, shard)
from repro.fleet.api import SyntheticSource
from repro.fleet.policy import FleetDQN, FleetDQNConfig
from repro.obs import SpanRecorder
from repro.obs.prof import scaling_sweep, stage_costs

USERS = 3


def _emit_stages(tag: str, rep: dict) -> None:
    for name, st in rep["stages"].items():
        emit(f"profile_{tag}_stage_{name}", st["wall_ms"] * 1e3,
             f"flop_frac={rep['flop_fracs'][name]:.3f} "
             f"wall_frac={rep['wall_fracs'][name]:.3f} "
             f"intensity={st['arithmetic_intensity']:.2f} "
             f"dominant={st['dominant']}")
    emit(f"profile_{tag}_dominant", 0.0,
         f"flops={rep['dominant_stage_flops']} "
         f"wall={rep['dominant_stage_wall']} "
         f"(the fusion the Pallas item should write)")


def _run(tiny: bool) -> dict:
    ndev = jax.device_count()
    if tiny:
        cells, reps, base, steps, chunk = 32, 2, 16, 40, 10
    elif FAST:
        cells, reps, base, steps, chunk = 256, 5, 256, 400, 50
    else:
        cells, reps, base, steps, chunk = 1024, 9, 256, 2000, 50

    spans = SpanRecorder()
    dqn = FleetDQN(
        SyntheticSource(FleetConfig(cells=cells, users=USERS,
                                    arrival_rate=1.0)),
        cfg=FleetDQNConfig(replay_capacity=4096 if tiny else 65536))
    dqn_rep = stage_costs(dqn, reps=reps, spans=spans)
    _emit_stages("dqn", dqn_rep)

    tab = FleetQLearning(
        SyntheticSource(FleetConfig(cells=cells, users=USERS,
                                    arrival_rate=1.0)),
        cfg=FleetQConfig(eps_decay=0.0))
    tab_rep = stage_costs(tab, reps=reps, spans=spans)
    _emit_stages("tabular", tab_rep)

    # scaling sweeps: same grid shape as bench_fleet_sharded so the
    # cliff diagnosis localizes the same flatness number
    grid = [ndev * base, ndev * 4 * base, ndev * 16 * base]
    single = scaling_sweep(grid, users=USERS, mesh=None, steps=steps,
                           chunk=chunk)
    emit("profile_sweep_single", 0.0,
         f"cliff={single['cliff_cells']} class={single['classification']}")
    sharded = single
    if ndev > 1:
        sharded = scaling_sweep(grid, users=USERS,
                                mesh=shard.fleet_mesh(), steps=steps,
                                chunk=chunk)
        emit("profile_sweep_sharded", 0.0,
             f"cliff={sharded['cliff_cells']} "
             f"class={sharded['classification']}")
    print(f"# {sharded['summary']}", flush=True)

    metrics = {
        "cells": cells,
        "users": USERS,
        "devices": ndev,
        "rl_stage_fracs": dqn_rep["flop_fracs"],
        "rl_stage_wall_fracs": dqn_rep["wall_fracs"],
        "tabular_stage_fracs": tab_rep["flop_fracs"],
        "dominant_stage_flops": dqn_rep["dominant_stage_flops"],
        "dominant_stage_wall": dqn_rep["dominant_stage_wall"],
        "dqn_stages": dqn_rep,
        "tabular_stages": tab_rep,
        # per-cell compiled cost of one env step at the largest size
        "env_flops_per_cell": single["flops_per_cell"][str(grid[-1])],
        "sweep_single": single,
        "sweep_sharded": sharded,
        "cliff_cells": sharded["cliff_cells"],
        "cliff_classification": sharded["classification"],
        "cliff_summary": sharded["summary"],
    }
    save_json("bench_profile", metrics)
    return metrics


def main(tiny: bool = False) -> dict:
    if jax.device_count() > 1:
        return _run(tiny)
    if os.environ.get("REPRO_PROFILE_BENCH_CHILD"):
        raise RuntimeError(
            "forced host platform still reports 1 device; run with "
            f"JAX_PLATFORMS=cpu XLA_FLAGS='{_FORCE}=8' to profile the "
            "sharded sweep on this machine")
    # jax already initialized single-device (benchmarks.run imports every
    # suite) — relaunch so the forced host platform takes effect
    env = dict(os.environ)
    env["XLA_FLAGS"] = env.get("XLA_FLAGS", "") + f" {_FORCE}=8"
    env["REPRO_PROFILE_BENCH_CHILD"] = "1"
    cmd = [sys.executable, os.path.abspath(__file__)]
    if tiny:
        cmd.append("--tiny")
    subprocess.run(cmd, env=env, check=True)
    with open(os.path.join(RESULTS_DIR, "bench_profile.json")) as f:
        return json.load(f)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="seconds-scale budgets (CI smoke)")
    main(tiny=ap.parse_args().tiny)
