"""Paper Table 9: decisions + avg response/accuracy per threshold per
experiment (5 users)."""
from benchmarks.common import emit, save_json
from repro.core import (EXPERIMENTS, THRESHOLDS, EndEdgeCloudEnv,
                        bruteforce_optimal)

PAPER = {  # (avg ms, avg acc) Table 9
    ("EXP-A", "Min"): (72.08, 72.80), ("EXP-A", "80%"): (103.88, 81.11),
    ("EXP-A", "85%"): (143.81, 85.06), ("EXP-A", "89%"): (269.80, 89.10),
    ("EXP-A", "Max"): (418.91, 89.90),
    ("EXP-B", "Min"): (106.76, 72.80), ("EXP-B", "80%"): (139.92, 83.23),
    ("EXP-B", "85%"): (176.21, 85.05), ("EXP-B", "89%"): (303.50, 89.10),
    ("EXP-B", "Max"): (472.88, 89.90),
    ("EXP-C", "Min"): (119.28, 72.80), ("EXP-C", "80%"): (149.52, 81.11),
    ("EXP-C", "85%"): (190.76, 85.47), ("EXP-C", "89%"): (318.45, 89.10),
    ("EXP-C", "Max"): (464.59, 89.90),
    ("EXP-D", "Min"): (158.53, 72.80), ("EXP-D", "80%"): (182.53, 81.12),
    ("EXP-D", "85%"): (225.32, 85.06), ("EXP-D", "89%"): (356.75, 89.10),
    ("EXP-D", "Max"): (506.62, 89.90),
}


def main():
    out = {}
    worst_rel = 0.0
    for exp, sc in EXPERIMENTS.items():
        env = EndEdgeCloudEnv(5, sc, noise=0)
        for tname, th in THRESHOLDS.items():
            a, ms, acc, _ = bruteforce_optimal(env, th)
            p_ms, p_acc = PAPER[(exp, tname)]
            rel = abs(ms - p_ms) / p_ms
            worst_rel = max(worst_rel, rel) if tname != "Max" else worst_rel
            out[f"{exp}_{tname}"] = {
                "decision": env.spec.decode_action(a), "ms": ms, "acc": acc,
                "paper_ms": p_ms, "paper_acc": p_acc, "rel_err": rel}
            emit(f"table9_{exp}_{tname}", 0.0,
                 f"{ms:.1f}ms/{acc:.1f}%|paper{p_ms:.1f}/{p_acc:.1f}|rel{rel*100:.0f}%")
    emit("table9_worst_rel_err_nonmax", 0.0, f"{worst_rel*100:.1f}%")
    save_json("bench_table9", out)
    return out


if __name__ == "__main__":
    main()
