"""Minimal npz+json pytree checkpointing (params, optimizer state, RL
agents). Leaves are saved flattened with their tree paths as keys;
non-native dtypes (bfloat16) are stored as uint16 bit patterns with the
true dtype recorded in the json sidecar."""
from __future__ import annotations

import json
import os

import jax
import ml_dtypes
import numpy as np

_BITCAST = {"bfloat16": np.uint16}


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save_pytree(path: str, tree):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat, _ = _flatten(tree)
    dtypes, stored = {}, {}
    for k, v in flat.items():
        dtypes[k] = str(v.dtype)
        if str(v.dtype) in _BITCAST:
            v = v.view(_BITCAST[str(v.dtype)])
        stored[k] = v
    np.savez(path + ".npz", **stored)
    with open(path + ".json", "w") as f:
        json.dump(dtypes, f)


def load_pytree(path: str, like):
    """Restore into the structure of ``like`` (shapes must match)."""
    data = np.load(path + ".npz")
    with open(path + ".json") as f:
        dtypes = json.load(f)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p)
        arr = data[key]
        want = dtypes[key]
        if want in _BITCAST:
            arr = arr.view(getattr(ml_dtypes, want))
        leaves.append(jax.numpy.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
