from repro.checkpoint.checkpoint import load_pytree, save_pytree
