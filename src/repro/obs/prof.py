"""Compiled-cost profiling: what a jitted fleet program *costs* before
it runs, and where the RL hot path and the scaling cliff actually are.

The repo's wall-clock benchmarks say how fast things ARE; this seam
says what they SHOULD cost. Everything here is built on the ahead-of-
time pipeline ``jax.jit(fn).lower(*args).compile()`` →
``cost_analysis()`` / ``memory_analysis()``, the same machinery
``repro.launch.dryrun`` uses for the model stack — generalized so any
fleet program gets the treatment:

* :class:`CostProfile` / :func:`profile_fn` — flops, bytes accessed,
  temp/arg/output bytes, arithmetic intensity, and the roofline terms
  (``compute_s`` / ``memory_s`` / ``dominant``) against per-backend
  peak constants. No execution happens: the numbers come out of the
  compiled executable, so they are deterministic across runs and
  machines with the same compiler.
* :func:`stage_costs` — compile the fleet RL loop's stages SEPARATELY
  (encode/act, env step, replay push+sample, TD/DQN update) and report
  each stage's fraction of the loop's compiled cost next to measured
  wall time (recorded through ``obs.spans.SpanRecorder``). This is the
  map the ROADMAP's "Pallas-fused RL hot path" item needs: the stage
  with the dominant flop/wall fraction is the fusion to write.
* :func:`scaling_sweep` — compiled flops/device vs measured wall time
  across a cells grid (single-device or on a fleet mesh), classifying
  a per-device flatness cliff as *runtime* overhead (flops/cell flat,
  device-time/cell grows — dispatch/partitioning, fix the harness) vs
  *algorithmic* growth (flops/cell grows — superlinear work, fix the
  program), and naming the first offending fleet size.

Caveat inherited from ``launch.dryrun``: XLA counts a ``lax.scan``
body ONCE, not times the trip count — so cost profiles here are taken
on single-step programs and wall time on the scanned program, never
the other way around.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.spans import SpanRecorder, span


@dataclasses.dataclass(frozen=True)
class BackendPeaks:
    """Peak rates the roofline terms are computed against."""
    flops_per_s: float
    bytes_per_s: float
    note: str = ""


#: Per-backend peak constants. The TPU row is the v5e pair shared with
#: ``repro.launch.mesh`` (PEAK_BF16_FLOPS / HBM_BW); cpu/gpu rows are
#: order-of-magnitude reference points (CI-class 2-core host, A100-40G)
#: — the roofline terms are for *comparing programs and stages*, not
#: for predicting absolute wall time on this machine.
PEAKS: Dict[str, BackendPeaks] = {
    "tpu": BackendPeaks(197e12, 819e9, "v5e (launch.mesh constants)"),
    "gpu": BackendPeaks(312e12, 1555e9, "A100-40G bf16"),
    "cpu": BackendPeaks(1e11, 5e10, "CI-class 2-core host, rough"),
}


def backend_peaks(backend: Optional[str] = None) -> BackendPeaks:
    """Peak constants for ``backend`` (default: the current jax
    backend); unknown backends fall back to the cpu row."""
    b = backend or jax.default_backend()
    return PEAKS.get(b, PEAKS["cpu"])


def _normalize_cost_analysis(ca) -> dict:
    """jaxlib has returned ``cost_analysis()`` as a dict, a 1-element
    list of dicts, or None across versions; normalize to one dict."""
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


@dataclasses.dataclass
class CostProfile:
    """Compiled-cost profile of one jitted program.

    ``flops`` / ``bytes_accessed`` come from the compiler's
    ``cost_analysis`` of the optimized (post-SPMD) module — under a
    mesh they are PER-DEVICE numbers. ``temp/arg/out_bytes`` come from
    ``memory_analysis`` (per-device buffer sizes of the executable).
    """
    name: str
    flops: float
    bytes_accessed: float
    arg_bytes: int
    out_bytes: int
    temp_bytes: int
    backend: str
    peak_flops_per_s: float
    peak_bytes_per_s: float

    @property
    def arithmetic_intensity(self) -> float:
        """flops per byte accessed (0 when the compiler reports no
        traffic — e.g. a constant-folded program)."""
        return self.flops / self.bytes_accessed if self.bytes_accessed \
            else 0.0

    @property
    def ridge_intensity(self) -> float:
        """The roofline ridge point of this backend (flops/byte above
        which a program is compute-bound at peak)."""
        return self.peak_flops_per_s / self.peak_bytes_per_s

    @property
    def compute_s(self) -> float:
        return self.flops / self.peak_flops_per_s

    @property
    def memory_s(self) -> float:
        return self.bytes_accessed / self.peak_bytes_per_s

    @property
    def dominant(self) -> str:
        return "compute" if self.compute_s >= self.memory_s else "memory"

    def as_dict(self) -> dict:
        """JSON-ready dict (fields + the derived roofline terms)."""
        d = dataclasses.asdict(self)
        d.update(arithmetic_intensity=self.arithmetic_intensity,
                 ridge_intensity=self.ridge_intensity,
                 compute_s=self.compute_s, memory_s=self.memory_s,
                 dominant=self.dominant)
        return d

    @classmethod
    def from_compiled(cls, compiled, name: str,
                      peaks: Optional[BackendPeaks] = None) -> "CostProfile":
        """Build from an already-compiled ``jax.stages.Compiled``."""
        peaks = peaks or backend_peaks()
        ca = _normalize_cost_analysis(compiled.cost_analysis())
        ma = compiled.memory_analysis()
        return cls(
            name=name,
            flops=float(ca.get("flops", 0.0)),
            bytes_accessed=float(ca.get("bytes accessed", 0.0)),
            arg_bytes=int(getattr(ma, "argument_size_in_bytes", 0) or 0),
            out_bytes=int(getattr(ma, "output_size_in_bytes", 0) or 0),
            temp_bytes=int(getattr(ma, "temp_size_in_bytes", 0) or 0),
            backend=jax.default_backend(),
            peak_flops_per_s=peaks.flops_per_s,
            peak_bytes_per_s=peaks.bytes_per_s)


def profile_fn(fn: Callable, *args, name: Optional[str] = None,
               peaks: Optional[BackendPeaks] = None,
               static_argnums=(), **jit_kwargs) -> CostProfile:
    """Lower + compile ``fn(*args)`` and wrap its compiled cost and
    memory analyses into a :class:`CostProfile`. Nothing executes —
    donated buffers (``donate_argnums``) stay valid."""
    jfn = jax.jit(fn, static_argnums=static_argnums, **jit_kwargs)
    compiled = jfn.lower(*args).compile()
    return CostProfile.from_compiled(
        compiled, name or getattr(fn, "__name__", "fn"), peaks)


# ---------------------------------------------------------------------------
# Stage breakdown of the fleet RL loops
# ---------------------------------------------------------------------------


def _median_wall_ms(jfn, args, name: str, reps: int,
                    spans: Optional[SpanRecorder]) -> float:
    """Median host wall of ``reps`` blocked executions, recorded as
    ``prof.stage.{name}`` spans on ``spans`` (one per rep)."""
    rec = spans if spans is not None else SpanRecorder()
    tag = f"prof.stage.{name}"
    jax.block_until_ready(jfn(*args))                        # compile/warm
    for _ in range(reps):
        with span(rec, tag):
            jax.block_until_ready(jfn(*args))
    return float(np.median(rec.durations_ms(tag)[-reps:]))


def _dqn_stage_fns(agent):
    """(name -> (fn, args)) decomposition of ``FleetDQN._make_step``:
    the same closures the fused scan body is built from, compiled one
    stage at a time. Args are the agent's live carries, so shapes and
    shardings match the real loop."""
    from repro.fleet.api import make_env_step
    from repro.fleet.policy import encode_fleet_state
    from repro.fleet.replay import replay_push, replay_sample

    cfg = agent.cfg
    act = agent._make_act(agent._make_greedy())
    env_step = make_env_step(agent.source,
                             threshold=cfg.accuracy_threshold,
                             noise=cfg.noise)
    train_step = agent._make_train_step()
    key = jax.random.PRNGKey(0)
    scen, counts, buf = agent.scen, agent.counts, agent.buffer
    s = jax.block_until_ready(encode_fleet_state(counts, scen))
    a = jnp.zeros((scen.cells, scen.users), jnp.int32)
    r = jnp.zeros((scen.cells,), jnp.float32)
    bs = jnp.zeros((cfg.batch_size, agent.state_dim), jnp.float32)
    ba = jnp.zeros((cfg.batch_size, scen.users), jnp.int32)
    br = jnp.zeros((cfg.batch_size,), jnp.float32)

    def encode_act(params, counts, scen, eps, key):
        return act(params, counts, scen, eps, key)

    def replay(key, buf, s, a, r, s2):
        buf = replay_push(buf, s, a, r, s2)
        return buf, replay_sample(key, buf, cfg.batch_size)

    def update(params, opt, s, a, r, s2):
        return train_step(params, opt, s, a, r, s2)

    # the act closure above already routes through the agent's fused
    # head when one is active — only the reported stage name changes
    act_name = ("encode_act" if getattr(agent, "_op_impl", "xla") == "xla"
                else "fused_encode_act")
    return {
        act_name: (encode_act,
                   (agent.params, counts, scen, agent.eps, key)),
        "env_step": (lambda key, scen, a: env_step(key, scen, a),
                     (key, scen, a)),
        "replay": (replay, (key, buf, s, a, r, s)),
        "update": (update, (agent.params, agent.opt, bs, ba, br, bs)),
    }


def _tabular_stage_fns(agent):
    """(name -> (fn, args)) decomposition of ``FleetQLearning``'s step.

    Legacy (``impl='xla'``) stages: eps-greedy act (state index +
    gather + argmax), env step, TD scatter-update. Fused agents
    replace the last with ``fused_update_act`` — the single
    ``kernels.ops.fused_tabular_update`` call that covers the TD
    update AND the next step's act-side gather/argmax (the scan
    carries its ``greedy2``), so ``encode_act`` shrinks to the state
    index + exploration draw."""
    from repro.fleet.api import make_env_step

    cfg = agent.cfg
    env_step = make_env_step(agent.source,
                             threshold=cfg.accuracy_threshold,
                             noise=cfg.noise)
    pu, n_actions = agent.pu_table, agent.n_actions
    key = jax.random.PRNGKey(0)
    scen, counts = agent.scen, agent.counts
    a0 = jnp.zeros((scen.cells,), jnp.int32)
    r = jnp.zeros((scen.cells,), jnp.float32)

    if getattr(agent, "_op_impl", "xla") != "xla":
        from repro.kernels import ops
        s0 = jnp.zeros((scen.cells,), jnp.int32)
        g0 = jnp.zeros((scen.cells,), jnp.int32)

        def encode_act(counts, scen, greedy, eps, key):
            s = agent._state_index(counts, scen)
            a = agent._explore(greedy, eps, key)
            return s, a, pu[a]

        def fused_update_act(q, s, a, r, s2):
            return ops.fused_tabular_update(
                q, s, a, r, s2, alpha=cfg.alpha, gamma=cfg.gamma,
                **agent._op_kwargs)

        return {
            "encode_act": (encode_act,
                           (counts, scen, g0, agent.eps, key)),
            "env_step": (lambda key, scen, a: env_step(key, scen, a),
                         (key, scen, jnp.zeros((scen.cells, scen.users),
                                               jnp.int32))),
            "fused_update_act": (fused_update_act,
                                 (agent.q, s0, a0, r, s0)),
        }

    def encode_act(q, counts, scen, eps, key):
        cells = jnp.arange(q.shape[0])
        s = agent._state_index(counts, scen)
        u = jax.random.uniform(key, (q.shape[0],))
        rand = jnp.minimum((u / jnp.maximum(eps, 1e-9)
                            * n_actions).astype(jnp.int32), n_actions - 1)
        a = jnp.where(u < eps, rand, q[cells, s].argmax(-1))
        return a, pu[a]

    def td_update(q, counts, scen, a, r, counts2, scen2):
        cells = jnp.arange(q.shape[0])
        s = agent._state_index(counts, scen)
        s2 = agent._state_index(counts2, scen2)
        td = r + cfg.gamma * q[cells, s2].max(-1) - q[cells, s, a]
        return q.at[cells, s, a].add(cfg.alpha * td)

    return {
        "encode_act": (encode_act,
                       (agent.q, counts, scen, agent.eps, key)),
        "env_step": (lambda key, scen, a: env_step(key, scen, a),
                     (key, scen, jnp.zeros((scen.cells, scen.users),
                                           jnp.int32))),
        "update": (td_update, (agent.q, counts, scen, a0, r, counts,
                               scen)),
    }


def stage_costs(agent, reps: int = 5,
                spans: Optional[SpanRecorder] = None,
                peaks: Optional[BackendPeaks] = None) -> dict:
    """Fractional compiled-cost breakdown of a fleet agent's RL loop.

    Compiles each stage of the agent's per-step program separately
    (``FleetDQN``: encode/act, env step, replay push+sample, DQN
    update; ``FleetQLearning``: encode/act, env step, TD update),
    profiles the compiled cost of each, and measures ``reps`` blocked
    executions per stage through ``SpanRecorder`` spans
    (``prof.stage.{name}`` on ``spans`` when given).

    Returns ``{"kind", "cells", "users", "backend", "stages": {name:
    profile-dict + wall_ms}, "flop_fracs", "byte_fracs", "wall_fracs",
    "dominant_stage_flops", "dominant_stage_wall"}`` — the flop/wall
    fractions are the map of which fusion the Pallas item should write.

    Note the stages are compiled as standalone programs: their summed
    cost is an upper bound on the fused scan body (XLA fuses across
    stage boundaries), but the *fractions* are what localize the hot
    stage, and they are deterministic across recompiles.
    """
    stage_fns = (_dqn_stage_fns(agent) if hasattr(agent, "buffer")
                 else _tabular_stage_fns(agent))
    kind = "dqn" if hasattr(agent, "buffer") else "tabular"
    stages = {}
    for name, (fn, args) in stage_fns.items():
        jfn = jax.jit(fn)
        prof = CostProfile.from_compiled(jfn.lower(*args).compile(),
                                         name, peaks)
        wall = _median_wall_ms(jfn, args, name, reps, spans)
        stages[name] = {**prof.as_dict(), "wall_ms": wall}

    def fracs(key):
        tot = sum(s[key] for s in stages.values())
        return {n: s[key] / tot if tot else 0.0
                for n, s in stages.items()}

    flop_fracs = fracs("flops")
    wall_fracs = fracs("wall_ms")
    return {
        "kind": kind,
        "cells": int(agent.scen.cells),
        "users": int(agent.scen.users),
        "backend": jax.default_backend(),
        "stages": stages,
        "flop_fracs": flop_fracs,
        "byte_fracs": fracs("bytes_accessed"),
        "wall_fracs": wall_fracs,
        "dominant_stage_flops": max(flop_fracs, key=flop_fracs.get),
        "dominant_stage_wall": max(wall_fracs, key=wall_fracs.get),
    }


# ---------------------------------------------------------------------------
# Scaling sweep: localize and classify the per-device flatness cliff
# ---------------------------------------------------------------------------


def _make_run_chunk(env_step):
    def run_chunk(key, scen, actions):
        def body(carry, a):
            key, scen = carry
            key, k = jax.random.split(key)
            scen2, _, ms, _, _ = env_step(k, scen, a)
            return (key, scen2), ms.mean()
        (key, scen), ms = jax.lax.scan(body, (key, scen), actions)
        return key, scen, ms
    return run_chunk


def scaling_sweep(cells_grid: Sequence[int], users: int = 3, mesh=None,
                  steps: int = 200, chunk: int = 20,
                  cliff_tol: float = 0.5, flop_tol: float = 0.15,
                  config_kwargs: Optional[Dict[str, Any]] = None) -> dict:
    """Sweep the fleet env step over ``cells_grid`` and classify the
    per-device scaling cliff.

    For each fleet size the SINGLE-STEP env program is lowered and
    compiled for its per-device flops (scan bodies are counted once by
    ``cost_analysis``, so cost comes from the unscanned program), and
    the SCANNED program (``chunk`` steps per host call) is timed for
    measured wall — the cross-reference that separates the two cliff
    kinds:

    * ``flops/cell`` flat but device-time/cell grows by more than
      ``cliff_tol`` over the grid's best → **runtime** overhead
      (dispatch, partitioning, collective latency — the program's work
      is linear; fix the harness);
    * ``flops/cell`` grows by more than ``flop_tol`` → **algorithmic**
      growth (the compiled program itself does superlinear per-cell
      work; fix the program).

    ``cliff_cells`` names the first grid size whose device-time per
    cell-step exceeds ``(1 + cliff_tol) x`` the grid minimum (None when
    the sweep is flat). With ``mesh`` the scenario and action stream
    shard along the fleet axis and all numbers are per-device.
    """
    from repro.fleet import shard
    from repro.fleet.api import SyntheticSource, make_env_step
    from repro.fleet.scenarios import FleetConfig

    ndev = int(np.prod(list(mesh.shape.values()))) if mesh is not None \
        else 1
    cfg_kw = dict(arrival_rate=1.0, p_r2w=0.05, p_w2r=0.1)
    cfg_kw.update(config_kwargs or {})
    flops_per_cell: Dict[int, float] = {}
    us_dev_per_cell: Dict[int, float] = {}
    per_device_sps: Dict[int, float] = {}
    for cells in cells_grid:
        cfg = FleetConfig(cells=cells, users=users, **cfg_kw)
        source = SyntheticSource(cfg, mesh=mesh)
        env_step = make_env_step(source)
        scen, _ = source.reset(jax.random.PRNGKey(0))
        key = jax.random.PRNGKey(1)
        a1 = jnp.zeros((cells, users), jnp.int32)
        actions = jnp.zeros((chunk, cells, users), jnp.int32)
        if mesh is not None:
            a1 = shard.shard_array(a1, mesh)
            actions = shard.shard_array(actions, mesh, axis=1)
        # compiled cost of ONE step (per-device under a mesh)
        prof = profile_fn(lambda k, s, a: env_step(k, s, a), key, scen, a1,
                          name=f"env_step_{cells}")
        flops_per_cell[cells] = prof.flops / (cells / ndev)
        # measured wall of the scanned program
        run_chunk = jax.jit(_make_run_chunk(env_step))
        key, scen, ms = run_chunk(key, scen, actions)        # compile
        jax.block_until_ready(ms)
        n_chunks = max(1, steps // chunk)
        t0 = time.perf_counter()
        for _ in range(n_chunks):
            key, scen, ms = run_chunk(key, scen, actions)
            jax.block_until_ready(ms)
        dt = time.perf_counter() - t0
        total = n_chunks * chunk * cells
        per_device_sps[cells] = total / dt / ndev
        us_dev_per_cell[cells] = dt * ndev / total * 1e6

    grid = list(cells_grid)
    best = min(us_dev_per_cell.values())
    best_cells = min(us_dev_per_cell, key=us_dev_per_cell.get)
    flop_floor = min(flops_per_cell.values())
    offending = [c for c in grid
                 if us_dev_per_cell[c] > (1.0 + cliff_tol) * best]
    cliff = offending[0] if offending else None
    if cliff is None:
        classification = "flat"
        summary = (f"flat: device-time per cell-step within "
                   f"{cliff_tol:.0%} of the best ({best:.2f}us at "
                   f"{best_cells} cells) across the grid")
    else:
        algorithmic = (flops_per_cell[cliff]
                       > (1.0 + flop_tol) * flop_floor)
        classification = "algorithmic" if algorithmic else "runtime"
        ratio = us_dev_per_cell[cliff] / best
        summary = (
            f"cliff at {cliff} cells: device-time per cell-step "
            f"{us_dev_per_cell[cliff]:.2f}us is {ratio:.1f}x the best "
            f"({best:.2f}us at {best_cells} cells) while compiled "
            f"flops/cell "
            + (f"grows {flops_per_cell[cliff] / flop_floor:.2f}x — "
               f"algorithmic growth (the program does superlinear "
               f"per-cell work)" if algorithmic else
               f"stays flat ({flops_per_cell[cliff]:.0f} vs "
               f"{flop_floor:.0f}) — runtime overhead (dispatch/"
               f"partitioning, not the program)"))
    top2 = [per_device_sps[c] for c in grid[-2:]]
    return {
        "grid": grid,
        "users": users,
        "devices": ndev,
        "sharded": mesh is not None,
        "backend": jax.default_backend(),
        "flops_per_cell": {str(c): flops_per_cell[c] for c in grid},
        "us_device_per_cell_step": {str(c): us_dev_per_cell[c]
                                    for c in grid},
        "per_device_cell_steps_per_s": {str(c): per_device_sps[c]
                                        for c in grid},
        "flatness": min(top2) / max(top2),
        "cliff_cells": cliff,
        "classification": classification,
        "summary": summary,
    }
