"""Host-side spans emitting Chrome-trace/Perfetto-compatible JSON.

``SpanRecorder.span`` times a host-side region *and* enters a
``jax.profiler.TraceAnnotation`` of the same name, so when a run is also
captured with ``jax.profiler.trace(...)`` the device work nests under
our spans in the profiler timeline. Independently of the jax profiler,
the recorder keeps its own event list and serialises it to the Chrome
trace-event format, which both ``chrome://tracing`` and
https://ui.perfetto.dev load directly.

Every instrumentation point in the repo takes an optional
``spans=None`` argument and calls the module-level :func:`span` helper,
which is a no-op ``nullcontext`` when the recorder is ``None`` — the
uninstrumented path stays allocation-free.

Format reference: the Trace Event Format doc (Chromium). We emit
"X" (complete) events with microsecond ``ts``/``dur`` relative to the
recorder's creation, plus optional "i" (instant) and "C" (counter)
events; :func:`validate_chrome_trace` checks the subset we emit.
"""
from __future__ import annotations

import contextlib
import json
import os
import threading
import time
from typing import Any, Dict, List, Optional

import jax

_ALLOWED_PH = ("X", "i", "C", "B", "E", "M")


def _jsonable(args: Dict[str, Any]) -> Dict[str, Any]:
    return {k: v if isinstance(v, (bool, int, float, str)) else str(v)
            for k, v in args.items()}


class SpanRecorder:
    """Collects timed spans; serialises to Chrome trace-event JSON."""

    def __init__(self, clock=time.perf_counter):
        self._clock = clock
        self._t0 = clock()
        self._pid = os.getpid()
        self.events: List[Dict[str, Any]] = []

    def _us(self, t: float) -> float:
        return (t - self._t0) * 1e6

    @contextlib.contextmanager
    def span(self, name: str, **args):
        """Time a region; nests device work via TraceAnnotation."""
        t_start = self._clock()
        with jax.profiler.TraceAnnotation(name):
            try:
                yield self
            finally:
                t_end = self._clock()
                self.events.append({
                    "name": name,
                    "cat": "repro.obs",
                    "ph": "X",
                    "ts": self._us(t_start),
                    "dur": (t_end - t_start) * 1e6,
                    "pid": self._pid,
                    "tid": threading.get_ident() & 0x7FFFFFFF,
                    "args": _jsonable(args),
                })

    def complete(self, name: str, t_start: float, dur_s: float, **args):
        """Record a retrospective 'X' event from host clock stamps.

        ``t_start`` is a stamp on the recorder's own clock (default
        ``time.perf_counter`` — the clock the serving stack stamps
        ``Request.arrival_time`` with) and ``dur_s`` a duration in
        seconds. Used for per-request end-to-end latency events, whose
        interval (submit -> drain + emulated compute) is only known
        after the batch drains. ``ts`` clamps at the recorder's birth
        so traces stay schema-valid even for stamps predating it.
        """
        self.events.append({
            "name": name,
            "cat": "repro.obs",
            "ph": "X",
            "ts": max(0.0, self._us(t_start)),
            "dur": max(0.0, dur_s * 1e6),
            "pid": self._pid,
            "tid": threading.get_ident() & 0x7FFFFFFF,
            "args": _jsonable(args),
        })

    def instant(self, name: str, **args):
        self.events.append({
            "name": name, "cat": "repro.obs", "ph": "i", "s": "t",
            "ts": self._us(self._clock()), "pid": self._pid,
            "tid": threading.get_ident() & 0x7FFFFFFF,
            "args": _jsonable(args),
        })

    def counter(self, name: str, **values):
        self.events.append({
            "name": name, "cat": "repro.obs", "ph": "C",
            "ts": self._us(self._clock()), "pid": self._pid,
            "tid": threading.get_ident() & 0x7FFFFFFF,
            "args": {k: float(v) for k, v in values.items()},
        })

    def durations_ms(self, name: str) -> List[float]:
        """Host durations (ms) of all complete spans with this name."""
        return [e["dur"] / 1e3 for e in self.events
                if e["ph"] == "X" and e["name"] == name]

    def chrome_trace(self, manifest: Optional[dict] = None) -> dict:
        trace = {
            "traceEvents": sorted(self.events, key=lambda e: e["ts"]),
            "displayTimeUnit": "ms",
        }
        if manifest is not None:
            trace["otherData"] = manifest
        return trace

    def save(self, path: str, manifest: Optional[dict] = None) -> str:
        """Validate and write the trace JSON; returns the path."""
        trace = validate_chrome_trace(self.chrome_trace(manifest))
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(trace, f, indent=1)
        return path


def span(recorder: Optional[SpanRecorder], name: str, **args):
    """None-safe span: a nullcontext when no recorder is attached."""
    if recorder is None:
        return contextlib.nullcontext()
    return recorder.span(name, **args)


def validate_chrome_trace(trace: dict) -> dict:
    """Check a trace dict against the Chrome trace-event schema subset
    we emit; raises ``ValueError`` on the first violation, returns the
    trace unchanged otherwise (so it chains into ``json.dump``)."""
    if not isinstance(trace, dict):
        raise ValueError(f"trace must be a dict, got {type(trace).__name__}")
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        raise ValueError("trace['traceEvents'] must be a list")
    for i, e in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(e, dict):
            raise ValueError(f"{where} must be a dict")
        if not isinstance(e.get("name"), str) or not e["name"]:
            raise ValueError(f"{where}: missing/empty 'name'")
        ph = e.get("ph")
        if ph not in _ALLOWED_PH:
            raise ValueError(f"{where}: bad phase {ph!r} (allowed {_ALLOWED_PH})")
        if not isinstance(e.get("ts"), (int, float)) or e["ts"] < 0:
            raise ValueError(f"{where}: 'ts' must be a non-negative number")
        for key in ("pid", "tid"):
            if not isinstance(e.get(key), int):
                raise ValueError(f"{where}: '{key}' must be an int")
        if ph == "X":
            if not isinstance(e.get("dur"), (int, float)) or e["dur"] < 0:
                raise ValueError(f"{where}: 'X' event needs non-negative 'dur'")
        if "args" in e and not isinstance(e["args"], dict):
            raise ValueError(f"{where}: 'args' must be a dict")
    try:
        json.dumps(trace)
    except TypeError as exc:
        raise ValueError(f"trace is not JSON-serialisable: {exc}") from exc
    return trace
