"""Run manifests: provenance stamped onto bench JSONs and train results.

A manifest answers "what produced this number?" — git SHA (+dirty
flag), jax/jaxlib versions, backend and device count, mesh shape,
a stable hash of the config, and wall-clock context. It is attached to
every ``benchmarks/run.py --json`` payload (via ``benchmarks.common.
save_json``) and to ``FleetTrainResult``; ``tools/obsview.py`` reads it
back to pretty-print or diff runs, and ``tools/benchgate.py`` diffs a
fresh run against the tracked baseline through the shared
:func:`flatten` / :func:`rel_diff` helpers below.

Everything here is fault-tolerant: a missing git binary or a non-repo
checkout yields ``None`` fields, never an exception — provenance must
not take down a benchmark. ``jax`` is imported lazily (only
``run_manifest`` needs it) so the stdlib-level helpers stay cheap to
import from the ``tools/`` scripts.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import numbers
import os
import platform
import subprocess
import sys
from datetime import datetime, timezone
from typing import Any, Optional

MANIFEST_SCHEMA = "repro.obs/manifest-v1"


def flatten(obj: Any, prefix: str = "") -> dict:
    """Flat dict of dotted-path -> scalar, skipping the manifest.

    THE shared flattening of nested run JSONs — ``tools/obsview.py``
    (show/diff/history) and ``tools/benchgate.py`` both read metrics
    through it, so a key renders and gates under the same dotted path.
    """
    out = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            if k == "manifest":
                continue
            out.update(flatten(v, f"{prefix}{k}."))
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            out.update(flatten(v, f"{prefix}{i}."))
    else:
        out[prefix[:-1]] = obj
    return out


def is_number(v: Any) -> bool:
    """True for real numerics that compare as metrics (bools excluded —
    a flipped flag is a structural change, not a relative move)."""
    return isinstance(v, numbers.Real) and not isinstance(v, bool)


def rel_diff(a: float, b: float) -> float:
    """Signed relative move from ``a`` to ``b``; a zero base falls back
    to an absolute difference (base 1.0) so dividing never explodes."""
    base = abs(a) if a else 1.0
    return (b - a) / base

_REPO_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", ".."))


def config_hash(config: Any) -> str:
    """Stable short hash of a config (dataclass, dict, or anything with
    a deterministic repr via ``default=str``)."""
    if dataclasses.is_dataclass(config) and not isinstance(config, type):
        config = dataclasses.asdict(config)
    blob = json.dumps(config, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def _git(*args: str) -> Optional[str]:
    try:
        out = subprocess.run(
            ("git", "-C", _REPO_ROOT) + args,
            capture_output=True, text=True, timeout=10)
    except (OSError, subprocess.TimeoutExpired):
        return None
    return out.stdout.strip() if out.returncode == 0 else None


def git_info() -> dict:
    sha = _git("rev-parse", "HEAD")
    status = _git("status", "--porcelain")
    return {
        "sha": sha,
        "branch": _git("rev-parse", "--abbrev-ref", "HEAD"),
        "dirty": bool(status) if status is not None else None,
    }


def run_manifest(config: Any = None, mesh=None, **extra) -> dict:
    """The provenance stamp. ``mesh`` is a ``jax.sharding.Mesh`` (or
    None); ``extra`` keys (e.g. ``wall_seconds=...``) merge in last."""
    import jax
    try:
        import jaxlib
        jaxlib_version = getattr(jaxlib, "__version__", None)
    except ImportError:
        jaxlib_version = None
    m = {
        "schema": MANIFEST_SCHEMA,
        "created_utc": datetime.now(timezone.utc).isoformat(
            timespec="seconds"),
        "git": git_info(),
        "jax_version": jax.__version__,
        "jaxlib_version": jaxlib_version,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "device_kinds": sorted({d.device_kind for d in jax.devices()}),
        "mesh_shape": ({str(k): int(v) for k, v in dict(mesh.shape).items()}
                       if mesh is not None else None),
        "config_hash": config_hash(config) if config is not None else None,
        "python": platform.python_version(),
        "platform": platform.platform(),
        "argv": list(sys.argv),
    }
    m.update(extra)
    return m


def attach_manifest(payload: dict, config: Any = None, mesh=None,
                    **extra) -> dict:
    """Return a copy of ``payload`` with a ``manifest`` key added; the
    input dict is not mutated."""
    out = dict(payload)
    out["manifest"] = run_manifest(config=config, mesh=mesh, **extra)
    return out
