"""Run manifests: provenance stamped onto bench JSONs and train results.

A manifest answers "what produced this number?" — git SHA (+dirty
flag), jax/jaxlib versions, backend and device count, mesh shape,
a stable hash of the config, and wall-clock context. It is attached to
every ``benchmarks/run.py --json`` payload (via ``benchmarks.common.
save_json``) and to ``FleetTrainResult``; ``tools/obsview.py`` reads it
back to pretty-print or diff runs.

Everything here is fault-tolerant: a missing git binary or a non-repo
checkout yields ``None`` fields, never an exception — provenance must
not take down a benchmark.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import platform
import subprocess
import sys
from datetime import datetime, timezone
from typing import Any, Optional

import jax

MANIFEST_SCHEMA = "repro.obs/manifest-v1"

_REPO_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", ".."))


def config_hash(config: Any) -> str:
    """Stable short hash of a config (dataclass, dict, or anything with
    a deterministic repr via ``default=str``)."""
    if dataclasses.is_dataclass(config) and not isinstance(config, type):
        config = dataclasses.asdict(config)
    blob = json.dumps(config, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def _git(*args: str) -> Optional[str]:
    try:
        out = subprocess.run(
            ("git", "-C", _REPO_ROOT) + args,
            capture_output=True, text=True, timeout=10)
    except (OSError, subprocess.TimeoutExpired):
        return None
    return out.stdout.strip() if out.returncode == 0 else None


def git_info() -> dict:
    sha = _git("rev-parse", "HEAD")
    status = _git("status", "--porcelain")
    return {
        "sha": sha,
        "branch": _git("rev-parse", "--abbrev-ref", "HEAD"),
        "dirty": bool(status) if status is not None else None,
    }


def run_manifest(config: Any = None, mesh=None, **extra) -> dict:
    """The provenance stamp. ``mesh`` is a ``jax.sharding.Mesh`` (or
    None); ``extra`` keys (e.g. ``wall_seconds=...``) merge in last."""
    try:
        import jaxlib
        jaxlib_version = getattr(jaxlib, "__version__", None)
    except ImportError:
        jaxlib_version = None
    m = {
        "schema": MANIFEST_SCHEMA,
        "created_utc": datetime.now(timezone.utc).isoformat(
            timespec="seconds"),
        "git": git_info(),
        "jax_version": jax.__version__,
        "jaxlib_version": jaxlib_version,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "device_kinds": sorted({d.device_kind for d in jax.devices()}),
        "mesh_shape": ({str(k): int(v) for k, v in dict(mesh.shape).items()}
                       if mesh is not None else None),
        "config_hash": config_hash(config) if config is not None else None,
        "python": platform.python_version(),
        "platform": platform.platform(),
        "argv": list(sys.argv),
    }
    m.update(extra)
    return m


def attach_manifest(payload: dict, config: Any = None, mesh=None,
                    **extra) -> dict:
    """Return a copy of ``payload`` with a ``manifest`` key added; the
    input dict is not mutated."""
    out = dict(payload)
    out["manifest"] = run_manifest(config=config, mesh=mesh, **extra)
    return out
