"""repro.obs — observability for the fleet reproduction.

Four independent seams, all optional and all zero-cost when unused:

* :mod:`repro.obs.metrics` — ``MetricsAccumulator``, a jit-safe pytree
  of count/sum/sumsq/min/max + fixed-bin histograms that rides inside
  the ``lax.scan`` carry of fleet training loops with zero host syncs.
* :mod:`repro.obs.spans` — ``SpanRecorder``, a host-side span recorder
  emitting Chrome-trace/Perfetto JSON, wrapping
  ``jax.profiler.TraceAnnotation`` so device work nests under spans.
* :mod:`repro.obs.report` — ``run_manifest``/``attach_manifest``, the
  provenance stamp (git SHA, jax version, mesh shape, config hash)
  attached to bench JSONs and training results, plus the shared
  ``flatten``/``rel_diff`` helpers behind ``tools/obsview.py`` and the
  ``tools/benchgate.py`` perf-regression gate.
* :mod:`repro.obs.prof` — ``CostProfile``/``stage_costs``/
  ``scaling_sweep``, compiled-cost profiling of jitted fleet programs
  (flops / bytes / roofline terms from ``cost_analysis``), the RL-loop
  stage breakdown, and the scaling-cliff classifier.
* :mod:`repro.obs.timeline` — pure-numpy time-resolved reductions:
  exact vs histogram-derived latency quantiles (P50/P90/P95/P99 with a
  one-bin-width agreement bound), SLO attainment counting, and the
  windowed learning-curve series behind ``tools/obsview.py
  --timeline``.

The package imports only jax/numpy/stdlib; every other layer may import
it (see docs/ARCHITECTURE.md layering rules).
"""
from repro.obs.metrics import MetricDef, MetricsAccumulator
from repro.obs.prof import (BackendPeaks, CostProfile, backend_peaks,
                            profile_fn, scaling_sweep, stage_costs)
from repro.obs.report import (attach_manifest, config_hash, flatten,
                              rel_diff, run_manifest)
from repro.obs.spans import SpanRecorder, span, validate_chrome_trace
from repro.obs.timeline import (QUANTILES, attainment, exact_quantiles,
                                hist_quantiles, quantile_key, window_series)

__all__ = [
    "BackendPeaks",
    "CostProfile",
    "MetricDef",
    "MetricsAccumulator",
    "QUANTILES",
    "SpanRecorder",
    "attach_manifest",
    "attainment",
    "backend_peaks",
    "config_hash",
    "exact_quantiles",
    "flatten",
    "hist_quantiles",
    "profile_fn",
    "quantile_key",
    "rel_diff",
    "run_manifest",
    "scaling_sweep",
    "span",
    "stage_costs",
    "validate_chrome_trace",
    "window_series",
]
