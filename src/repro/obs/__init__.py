"""repro.obs — observability for the fleet reproduction.

Three independent seams, all optional and all zero-cost when unused:

* :mod:`repro.obs.metrics` — ``MetricsAccumulator``, a jit-safe pytree
  of count/sum/sumsq/min/max + fixed-bin histograms that rides inside
  the ``lax.scan`` carry of fleet training loops with zero host syncs.
* :mod:`repro.obs.spans` — ``SpanRecorder``, a host-side span recorder
  emitting Chrome-trace/Perfetto JSON, wrapping
  ``jax.profiler.TraceAnnotation`` so device work nests under spans.
* :mod:`repro.obs.report` — ``run_manifest``/``attach_manifest``, the
  provenance stamp (git SHA, jax version, mesh shape, config hash)
  attached to bench JSONs and training results.

The package imports only jax/numpy/stdlib; every other layer may import
it (see docs/ARCHITECTURE.md layering rules).
"""
from repro.obs.metrics import MetricDef, MetricsAccumulator
from repro.obs.report import attach_manifest, config_hash, run_manifest
from repro.obs.spans import SpanRecorder, span, validate_chrome_trace

__all__ = [
    "MetricDef",
    "MetricsAccumulator",
    "SpanRecorder",
    "attach_manifest",
    "config_hash",
    "run_manifest",
    "span",
    "validate_chrome_trace",
]
