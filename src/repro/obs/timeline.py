"""Time-resolved telemetry: quantiles with error bounds + SLO math.

This module is the host-side half of the timeline seam (ISSUE 8): pure
numpy/stdlib functions over values that the device-side machinery
(``obs.metrics.MetricsAccumulator`` histograms and windowed rings,
``obs.spans.SpanRecorder`` durations, ``fleet.api.RouteResult`` served
requests) already collected. Nothing here touches jax, so every layer
— including the stdlib-only ``tools/obsview.py`` — can import it.

Two quantile sources, one agreement contract:

* :func:`exact_quantiles` — order statistics (``inverted_cdf``) over
  the raw host-side values (e.g. ``SpanRecorder.durations_ms``).
* :func:`hist_quantiles` — the same order statistic located inside a
  fixed-bin integer histogram (e.g. an accumulator's ``hist`` leaf),
  reported as the bin midpoint. Because the q-th order statistic lies
  *inside* the selected bin, the estimate is within one ``bin_width``
  of the exact value — **unless** the statistic was clipped into an
  edge bin, which is exactly what the accumulator's explicit
  ``underflow``/``overflow`` counts flag (``clipped=True``, and a
  ``UserWarning`` unless ``warn=False``).

SLO scoring is one comparison per request — measured end-to-end
(queueing + compute) against the deadline stamped at submit — kept
here so ``RouteResult.slo()``, ``tools/obs_smoke.py`` and the
benchmarks cannot disagree about what "attained" means.
"""
from __future__ import annotations

import warnings
from typing import Dict, Sequence, Tuple

import numpy as np

#: the standard report quantiles (P50/P90/P95/P99)
QUANTILES = (0.50, 0.90, 0.95, 0.99)


def quantile_key(q: float) -> str:
    """0.95 -> 'p95' (the key both quantile sources report under)."""
    return f"p{round(q * 100):g}"


def exact_quantiles(values, qs: Sequence[float] = QUANTILES
                    ) -> Dict[str, float]:
    """Exact order-statistic quantiles of raw host-side values.

    Uses the ``inverted_cdf`` method (the q-th quantile IS one of the
    samples, no interpolation) so the histogram bound of
    :func:`hist_quantiles` is exact: both sources report the same order
    statistic, one precisely and one to within its bin. Empty input
    returns ``{}``.
    """
    v = np.asarray(values, np.float64).ravel()
    if v.size == 0:
        return {}
    return {quantile_key(q): float(np.percentile(v, q * 100.0,
                                                 method="inverted_cdf"))
            for q in qs}


def hist_quantiles(hist, edges, qs: Sequence[float] = QUANTILES, *,
                   underflow: int = 0, overflow: int = 0,
                   warn: bool = True) -> Dict[str, object]:
    """Quantiles from a fixed-bin integer histogram, with error bound.

    ``hist`` is per-bin counts, ``edges`` the ``len(hist)+1`` bin
    edges. For each q the q-th order statistic's bin is located by
    cumulative count and reported as the bin midpoint, so
    ``|hist - exact| <= bin_width`` whenever that statistic landed
    in-range. ``underflow``/``overflow`` are the accumulator's explicit
    out-of-range counts: when nonzero the edge bins contain clipped
    mass, the bound no longer holds for quantiles landing there, and
    the result carries ``clipped=True`` (plus a ``UserWarning`` unless
    ``warn=False``).

    Returns ``{p50: .., ..., "bin_width": w, "n": total,
    "underflow": u, "overflow": o, "clipped": bool}`` — or just the
    bookkeeping keys when the histogram is empty.
    """
    h = np.asarray(hist, np.int64).ravel()
    e = np.asarray(edges, np.float64).ravel()
    if e.size != h.size + 1:
        raise ValueError(f"edges must have len(hist)+1 entries, got "
                         f"{e.size} for {h.size} bins")
    underflow, overflow = int(underflow), int(overflow)
    clipped = underflow > 0 or overflow > 0
    n = int(h.sum())
    out: Dict[str, object] = {
        "bin_width": float(e[1] - e[0]) if h.size else 0.0,
        "n": n, "underflow": underflow, "overflow": overflow,
        "clipped": clipped,
    }
    if clipped and warn:
        warnings.warn(
            f"histogram has {underflow} underflow / {overflow} overflow "
            "samples clipped into the edge bins; quantiles touching "
            "those bins are not bounded by bin_width", UserWarning,
            stacklevel=2)
    if n == 0:
        return out
    cum = np.cumsum(h)
    mids = (e[:-1] + e[1:]) / 2.0
    for q in qs:
        rank = max(1, int(np.ceil(q * n)))      # 1-based order statistic
        b = int(np.searchsorted(cum, rank))
        out[quantile_key(q)] = float(mids[b])
    return out


def attainment(measured_ms, deadline_ms: float) -> Tuple[int, int]:
    """(attained, violated) counts of measured latencies vs a deadline.

    A request attains its SLO iff its end-to-end latency is at or below
    the deadline — the exact complement split, so
    ``attained + violated == len(measured_ms)`` always (the identity
    ``tools/obs_smoke.py`` gates on).
    """
    v = np.asarray(measured_ms, np.float64).ravel()
    attained = int((v <= deadline_ms).sum())
    return attained, int(v.size) - attained


def window_series(entry: dict) -> list:
    """Flatten one ``summary()`` stream's ``windows`` block into render
    rows ``(slot, count, mean, min, max)`` — the shape
    ``tools/obsview.py --timeline`` prints. Slots are in ring order;
    ``entry["windows"]["wrapped"]`` says whether the run lapped it.
    """
    w = entry.get("windows")
    if not w:
        return []
    return [(i, int(c), m, lo, hi) for i, (c, m, lo, hi) in
            enumerate(zip(w["count"], w["mean"], w["min"], w["max"]))]
