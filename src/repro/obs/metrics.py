"""In-scan metrics: a jit-safe pytree accumulator with exact merges.

``MetricsAccumulator`` lives inside the ``lax.scan`` carry of the fleet
training loops (``FleetQLearning.run``, ``FleetDQN.run``), so telemetry
is recorded at full device speed with **zero host syncs** — nothing is
fetched until :meth:`MetricsAccumulator.summary` is called on the host.

Design constraints, in order:

1. *Bit-identical under sharding.* The fleet parity discipline
   (``fleet.shard``, CHANGES.md) only holds for per-cell elementwise
   work plus integer cross-device sums. Each metric therefore carries a
   ``lanes`` axis (lanes = cells for per-cell signals): updates are
   elementwise along lanes, histograms are integer scatter-adds, and
   the only cross-lane reduction — producing the scalar mean/std/min/
   max — happens host-side in float64 numpy at ``summary()`` time.
   A sharded accumulator (lane leaves sharded along the fleet axis via
   :meth:`place`) is bit-identical to the single-device one.
2. *Plain merge.* ``merge`` is plain ``+`` on count/total/sumsq/hist
   and ``min``/``max`` on extrema — associative, and exact on the
   integer leaves and extrema, which is what lets the partitioner (or a
   host loop over shards) reduce accumulators freely; float sums carry
   the usual reassociation ULPs across *different* chunkings.
3. *Fixed shapes.* Every leaf has a static shape, so the accumulator
   scans and donates like the Q-table / replay buffer it travels with.

Values outside ``[lo, hi)`` clip into the edge bins of the histogram
(they still count exactly toward count/total/sumsq/min/max), so a
mis-estimated range degrades the histogram, never the moments.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class MetricDef:
    """Static description of one metric stream.

    lo/hi  : histogram range (values outside clip into the edge bins)
    bins   : number of fixed-width histogram bins
    lanes  : independent accumulation lanes. Use ``lanes=cells`` for
             per-cell signals so updates stay elementwise along the
             fleet axis (the sharding-exactness mechanism); ``lanes=1``
             for scalars like epsilon.
    """
    lo: float = 0.0
    hi: float = 1.0
    bins: int = 32
    lanes: int = 1

    def __post_init__(self):
        if not self.hi > self.lo:
            raise ValueError(f"MetricDef needs hi > lo, got [{self.lo}, {self.hi})")
        if self.bins < 1 or self.lanes < 1:
            raise ValueError("MetricDef needs bins >= 1 and lanes >= 1")


_LANE_LEAVES = ("count", "total", "sumsq", "mn", "mx")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class MetricsAccumulator:
    """A dict of named metric streams as a registered pytree.

    Per metric the leaves are::

        count : (lanes,) i32   samples per lane
        total : (lanes,) f32   sum per lane
        sumsq : (lanes,) f32   sum of squares per lane
        mn/mx : (lanes,) f32   running extrema (+inf / -inf when empty)
        hist  : (bins,)  i32   fixed-bin histogram over all lanes

    ``data`` maps name -> leaf dict; ``defs`` (static aux data) maps
    name -> :class:`MetricDef`.
    """
    data: Dict[str, Dict[str, jnp.ndarray]]
    defs: Dict[str, MetricDef]

    # -- pytree protocol -------------------------------------------------
    def tree_flatten(self):
        names = tuple(sorted(self.data))
        children = tuple(self.data[n] for n in names)
        return children, (names, tuple((n, self.defs[n]) for n in names))

    @classmethod
    def tree_unflatten(cls, aux, children):
        names, defs = aux
        return cls(dict(zip(names, children)), dict(defs))

    # -- construction ----------------------------------------------------
    @classmethod
    def create(cls, defs: Mapping[str, MetricDef]) -> "MetricsAccumulator":
        data = {}
        for name, df in defs.items():
            data[name] = {
                "count": jnp.zeros((df.lanes,), jnp.int32),
                "total": jnp.zeros((df.lanes,), jnp.float32),
                "sumsq": jnp.zeros((df.lanes,), jnp.float32),
                "mn": jnp.full((df.lanes,), jnp.inf, jnp.float32),
                "mx": jnp.full((df.lanes,), -jnp.inf, jnp.float32),
                "hist": jnp.zeros((df.bins,), jnp.int32),
            }
        return cls(data, dict(defs))

    # -- accumulation (pure; jit/scan/donation friendly) -----------------
    def update(self, values: Mapping[str, jnp.ndarray]) -> "MetricsAccumulator":
        """Fold one observation per metric into a new accumulator.

        Each value is reshaped to ``(lanes, k)``; the ``k`` samples per
        lane fold elementwise into that lane. With ``k == 1`` (the fleet
        training case) the per-lane update is a single elementwise
        add/min/max — exactly the op class the sharding parity relies
        on. Metrics not named in ``values`` pass through unchanged, so
        the pytree structure is stable under jit.
        """
        data = dict(self.data)
        for name, val in values.items():
            if name not in data:
                raise KeyError(f"unknown metric {name!r}; have {sorted(data)}")
            df = self.defs[name]
            x = jnp.asarray(val, jnp.float32)
            if x.size % df.lanes:
                raise ValueError(
                    f"metric {name!r}: value of size {x.size} does not "
                    f"split into {df.lanes} lanes")
            x = x.reshape(df.lanes, -1)
            k = x.shape[1]
            d = data[name]
            scale = df.bins / (df.hi - df.lo)
            idx = jnp.clip(((x - df.lo) * scale).astype(jnp.int32),
                           0, df.bins - 1)
            data[name] = {
                "count": d["count"] + jnp.int32(k),
                "total": d["total"] + x.sum(-1),
                "sumsq": d["sumsq"] + (x * x).sum(-1),
                "mn": jnp.minimum(d["mn"], x.min(-1)),
                "mx": jnp.maximum(d["mx"], x.max(-1)),
                "hist": d["hist"].at[idx.ravel()].add(1),
            }
        return MetricsAccumulator(data, self.defs)

    def merge(self, other: "MetricsAccumulator") -> "MetricsAccumulator":
        """Associative combine: sum / sum / min / max / sum.

        Merging chunked accumulators equals single-stream accumulation
        exactly on the integer leaves (count, hist) and the extrema;
        the float total/sumsq agree up to summation-reassociation ULPs
        — the same caveat CHANGES.md documents for eager-vs-jit. The
        *sharded-vs-single-device* guarantee is stronger (bit-identical)
        because there the program and its reduction order are identical,
        only the layout differs.
        """
        if self.defs != other.defs:
            raise ValueError("cannot merge accumulators with different specs")
        data = {}
        for name, d in self.data.items():
            o = other.data[name]
            data[name] = {
                "count": d["count"] + o["count"],
                "total": d["total"] + o["total"],
                "sumsq": d["sumsq"] + o["sumsq"],
                "mn": jnp.minimum(d["mn"], o["mn"]),
                "mx": jnp.maximum(d["mx"], o["mx"]),
                "hist": d["hist"] + o["hist"],
            }
        return MetricsAccumulator(data, self.defs)

    # -- placement -------------------------------------------------------
    def place(self, shard_fn: Callable, replicate_fn: Callable
              ) -> "MetricsAccumulator":
        """Place leaves for sharded training.

        Lane leaves of multi-lane metrics (lanes = cells) go through
        ``shard_fn`` (shard along the fleet axis); histograms and
        single-lane leaves go through ``replicate_fn``. With this
        placement the jitted update partitions into per-device
        elementwise work plus an integer scatter — bit-identical to the
        single-device program.
        """
        data = {}
        for name, d in self.data.items():
            lane_fn = shard_fn if self.defs[name].lanes > 1 else replicate_fn
            data[name] = {
                k: (replicate_fn(v) if k == "hist" else lane_fn(v))
                for k, v in d.items()
            }
        return MetricsAccumulator(data, dict(self.defs))

    # -- host-side reporting ---------------------------------------------
    def summary(self) -> Dict[str, dict]:
        """Fetch + reduce on the host (the only device->host transfer).

        Cross-lane reduction happens here in float64 numpy, keeping the
        device program free of float cross-device reductions.
        """
        out = {}
        for name, d in self.data.items():
            df = self.defs[name]
            count = np.asarray(d["count"], np.int64)
            total = np.asarray(d["total"], np.float64)
            sumsq = np.asarray(d["sumsq"], np.float64)
            n = int(count.sum())
            entry = {
                "count": n,
                "lanes": df.lanes,
                "hist": [int(v) for v in np.asarray(d["hist"])],
                "edges": [float(v) for v in
                          np.linspace(df.lo, df.hi, df.bins + 1)],
            }
            if n:
                mean = float(total.sum() / n)
                var = max(float(sumsq.sum() / n) - mean * mean, 0.0)
                valid = count > 0
                entry.update(
                    mean=mean,
                    std=math.sqrt(var),
                    min=float(np.asarray(d["mn"])[valid].min()),
                    max=float(np.asarray(d["mx"])[valid].max()),
                )
            else:
                entry.update(mean=None, std=None, min=None, max=None)
            out[name] = entry
        return out

    def lane_means(self, name: str) -> np.ndarray:
        """Per-lane means (NaN for empty lanes) — e.g. per-cell reward."""
        d = self.data[name]
        count = np.asarray(d["count"], np.float64)
        total = np.asarray(d["total"], np.float64)
        with np.errstate(invalid="ignore", divide="ignore"):
            return total / np.where(count > 0, count, np.nan)
