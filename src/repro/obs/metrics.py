"""In-scan metrics: a jit-safe pytree accumulator with exact merges.

``MetricsAccumulator`` lives inside the ``lax.scan`` carry of the fleet
training loops (``FleetQLearning.run``, ``FleetDQN.run``), so telemetry
is recorded at full device speed with **zero host syncs** — nothing is
fetched until :meth:`MetricsAccumulator.summary` is called on the host.

Design constraints, in order:

1. *Bit-identical under sharding.* The fleet parity discipline
   (``fleet.shard``, CHANGES.md) only holds for per-cell elementwise
   work plus integer cross-device sums. Each metric therefore carries a
   ``lanes`` axis (lanes = cells for per-cell signals): updates are
   elementwise along lanes, histograms are integer scatter-adds, and
   the only cross-lane reduction — producing the scalar mean/std/min/
   max — happens host-side in float64 numpy at ``summary()`` time.
   A sharded accumulator (lane leaves sharded along the fleet axis via
   :meth:`place`) is bit-identical to the single-device one.
2. *Plain merge.* ``merge`` is plain ``+`` on count/total/sumsq/hist
   and ``min``/``max`` on extrema — associative, and exact on the
   integer leaves and extrema, which is what lets the partitioner (or a
   host loop over shards) reduce accumulators freely; float sums carry
   the usual reassociation ULPs across *different* chunkings.
3. *Fixed shapes.* Every leaf has a static shape, so the accumulator
   scans and donates like the Q-table / replay buffer it travels with.

Values outside ``[lo, hi)`` clip into the edge bins of the histogram
(they still count exactly toward count/total/sumsq/min/max), and the
per-stream ``underflow``/``overflow`` integer counters record exactly
how many samples did so — so a mis-estimated range degrades the
histogram *visibly* (``quantiles()`` warns on clipped tails), never
the moments.

Time resolution (ISSUE 8): a ``MetricDef`` with ``n_windows > 0``
additionally carries a ``(n_windows, lanes)`` ring of per-window
count/total/min/max leaves. The window slot is ``step // window_len``
(mod ``n_windows``) — an integer index into the replicated window
axis, scatter-updated elementwise along the lane axis, i.e. the same
op class as the base update — so windowed telemetry inherits the full
sharding bit-identity, and ``summary()`` reports a learning-curve
time series instead of one number per run.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import timeline


@dataclasses.dataclass(frozen=True)
class MetricDef:
    """Static description of one metric stream.

    lo/hi  : histogram range (values outside clip into the edge bins
             and bump the per-stream underflow/overflow counters)
    bins   : number of fixed-width histogram bins
    lanes  : independent accumulation lanes. Use ``lanes=cells`` for
             per-cell signals so updates stay elementwise along the
             fleet axis (the sharding-exactness mechanism); ``lanes=1``
             for scalars like epsilon.
    n_windows : > 0 adds a ``(n_windows, lanes)`` ring of per-window
             count/total/min/max leaves; update ``step`` lands in slot
             ``(step // window_len) % n_windows``. 0 (default) keeps
             the stream windowless (no extra leaves).
    window_len : updates per window slot (the time resolution of the
             ring; size it as ``total_steps // n_windows`` to cover a
             run without wrapping).
    """
    lo: float = 0.0
    hi: float = 1.0
    bins: int = 32
    lanes: int = 1
    n_windows: int = 0
    window_len: int = 1

    def __post_init__(self):
        if not self.hi > self.lo:
            raise ValueError(f"MetricDef needs hi > lo, got [{self.lo}, {self.hi})")
        if self.bins < 1 or self.lanes < 1:
            raise ValueError("MetricDef needs bins >= 1 and lanes >= 1")
        if self.n_windows < 0 or self.window_len < 1:
            raise ValueError(
                "MetricDef needs n_windows >= 0 and window_len >= 1")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class MetricsAccumulator:
    """A dict of named metric streams as a registered pytree.

    Per metric the leaves are::

        count     : (lanes,) i32   samples per lane
        total     : (lanes,) f32   sum per lane
        sumsq     : (lanes,) f32   sum of squares per lane
        mn/mx     : (lanes,) f32   running extrema (+inf/-inf when empty)
        hist      : (bins,)  i32   fixed-bin histogram over all lanes
        underflow : ()       i32   samples below lo (clipped into bin 0)
        overflow  : ()       i32   samples at/above hi (clipped into
                                   bin bins-1)

    and, when the def declares ``n_windows > 0``, the per-window ring::

        wcount    : (n_windows, lanes) i32
        wtotal    : (n_windows, lanes) f32
        wmn/wmx   : (n_windows, lanes) f32

    ``data`` maps name -> leaf dict; ``defs`` (static aux data) maps
    name -> :class:`MetricDef`; ``step`` is the accumulator's own i32
    update counter — it selects the window slot, so windowed streams
    need no external clock threaded through the scan.
    """
    data: Dict[str, Dict[str, jnp.ndarray]]
    defs: Dict[str, MetricDef]
    step: jnp.ndarray = None

    # -- pytree protocol -------------------------------------------------
    def tree_flatten(self):
        names = tuple(sorted(self.data))
        children = (self.step,) + tuple(self.data[n] for n in names)
        return children, (names, tuple((n, self.defs[n]) for n in names))

    @classmethod
    def tree_unflatten(cls, aux, children):
        names, defs = aux
        return cls(dict(zip(names, children[1:])), dict(defs), children[0])

    # -- construction ----------------------------------------------------
    @classmethod
    def create(cls, defs: Mapping[str, MetricDef]) -> "MetricsAccumulator":
        data = {}
        for name, df in defs.items():
            data[name] = {
                "count": jnp.zeros((df.lanes,), jnp.int32),
                "total": jnp.zeros((df.lanes,), jnp.float32),
                "sumsq": jnp.zeros((df.lanes,), jnp.float32),
                "mn": jnp.full((df.lanes,), jnp.inf, jnp.float32),
                "mx": jnp.full((df.lanes,), -jnp.inf, jnp.float32),
                "hist": jnp.zeros((df.bins,), jnp.int32),
                "underflow": jnp.zeros((), jnp.int32),
                "overflow": jnp.zeros((), jnp.int32),
            }
            if df.n_windows:
                data[name].update(
                    wcount=jnp.zeros((df.n_windows, df.lanes), jnp.int32),
                    wtotal=jnp.zeros((df.n_windows, df.lanes), jnp.float32),
                    wmn=jnp.full((df.n_windows, df.lanes), jnp.inf,
                                 jnp.float32),
                    wmx=jnp.full((df.n_windows, df.lanes), -jnp.inf,
                                 jnp.float32))
        return cls(data, dict(defs), jnp.zeros((), jnp.int32))

    # -- accumulation (pure; jit/scan/donation friendly) -----------------
    def update(self, values: Mapping[str, jnp.ndarray]) -> "MetricsAccumulator":
        """Fold one observation per metric into a new accumulator.

        Each value is reshaped to ``(lanes, k)``; the ``k`` samples per
        lane fold elementwise into that lane. With ``k == 1`` (the fleet
        training case) the per-lane update is a single elementwise
        add/min/max — exactly the op class the sharding parity relies
        on. Windowed streams additionally scatter the same elementwise
        row update into slot ``(step // window_len) % n_windows`` of
        their ring — an integer index on the *replicated* window axis,
        so the partitioned program stays bit-identical too. Metrics not
        named in ``values`` pass through unchanged, so the pytree
        structure is stable under jit.
        """
        data = dict(self.data)
        for name, val in values.items():
            if name not in data:
                raise KeyError(f"unknown metric {name!r}; have {sorted(data)}")
            df = self.defs[name]
            x = jnp.asarray(val, jnp.float32)
            if x.size % df.lanes:
                raise ValueError(
                    f"metric {name!r}: value of size {x.size} does not "
                    f"split into {df.lanes} lanes")
            x = x.reshape(df.lanes, -1)
            k = x.shape[1]
            d = data[name]
            scale = df.bins / (df.hi - df.lo)
            idx = jnp.clip(((x - df.lo) * scale).astype(jnp.int32),
                           0, df.bins - 1)
            data[name] = {
                "count": d["count"] + jnp.int32(k),
                "total": d["total"] + x.sum(-1),
                "sumsq": d["sumsq"] + (x * x).sum(-1),
                "mn": jnp.minimum(d["mn"], x.min(-1)),
                "mx": jnp.maximum(d["mx"], x.max(-1)),
                "hist": d["hist"].at[idx.ravel()].add(1),
                # integer cross-lane sums — the second op class the
                # sharding discipline admits (bit-exact psum)
                "underflow": d["underflow"]
                + (x < df.lo).sum().astype(jnp.int32),
                "overflow": d["overflow"]
                + (x >= df.hi).sum().astype(jnp.int32),
            }
            if df.n_windows:
                slot = (self.step // df.window_len) % df.n_windows
                data[name].update(
                    wcount=d["wcount"].at[slot].add(jnp.int32(k)),
                    wtotal=d["wtotal"].at[slot].add(x.sum(-1)),
                    wmn=d["wmn"].at[slot].min(x.min(-1)),
                    wmx=d["wmx"].at[slot].max(x.max(-1)))
        return MetricsAccumulator(data, self.defs, self.step + 1)

    def merge(self, other: "MetricsAccumulator") -> "MetricsAccumulator":
        """Associative combine: sum / sum / min / max / sum.

        Merging chunked accumulators equals single-stream accumulation
        exactly on the integer leaves (count, hist) and the extrema;
        the float total/sumsq agree up to summation-reassociation ULPs
        — the same caveat CHANGES.md documents for eager-vs-jit. The
        *sharded-vs-single-device* guarantee is stronger (bit-identical)
        because there the program and its reduction order are identical,
        only the layout differs.
        """
        if self.defs != other.defs:
            raise ValueError("cannot merge accumulators with different specs")
        data = {}
        for name, d in self.data.items():
            o = other.data[name]
            data[name] = {
                "count": d["count"] + o["count"],
                "total": d["total"] + o["total"],
                "sumsq": d["sumsq"] + o["sumsq"],
                "mn": jnp.minimum(d["mn"], o["mn"]),
                "mx": jnp.maximum(d["mx"], o["mx"]),
                "hist": d["hist"] + o["hist"],
                "underflow": d["underflow"] + o["underflow"],
                "overflow": d["overflow"] + o["overflow"],
            }
            if self.defs[name].n_windows:
                # window slots merge positionally: meaningful when both
                # halves cover the same time axis (e.g. shard merges);
                # sequential chunks should share ONE accumulator instead
                data[name].update(
                    wcount=d["wcount"] + o["wcount"],
                    wtotal=d["wtotal"] + o["wtotal"],
                    wmn=jnp.minimum(d["wmn"], o["wmn"]),
                    wmx=jnp.maximum(d["wmx"], o["wmx"]))
        return MetricsAccumulator(data, self.defs,
                                  jnp.maximum(self.step, other.step))

    # -- placement -------------------------------------------------------
    def place(self, shard_fn: Callable, replicate_fn: Callable
              ) -> "MetricsAccumulator":
        """Place leaves for sharded training.

        Lane leaves of multi-lane metrics (lanes = cells) go through
        ``shard_fn(x, axis)`` (shard along the fleet axis — axis 0 of
        the base leaves, axis 1 of the ``(n_windows, lanes)`` ring);
        histograms, under/overflow counters, the step counter, and
        single-lane leaves go through ``replicate_fn``. With this
        placement the jitted update partitions into per-device
        elementwise work plus integer scatters/sums — bit-identical to
        the single-device program.
        """
        replicated = ("hist", "underflow", "overflow")
        data = {}
        for name, d in self.data.items():
            sharded = self.defs[name].lanes > 1
            leaf = {}
            for k, v in d.items():
                if k in replicated or not sharded:
                    leaf[k] = replicate_fn(v)
                elif k in ("wcount", "wtotal", "wmn", "wmx"):
                    leaf[k] = shard_fn(v, 1)      # lanes are axis 1
                else:
                    leaf[k] = shard_fn(v, 0)
            data[name] = leaf
        return MetricsAccumulator(data, dict(self.defs),
                                  replicate_fn(self.step))

    # -- host-side reporting ---------------------------------------------
    def summary(self) -> Dict[str, dict]:
        """Fetch + reduce on the host (the only device->host transfer).

        Cross-lane reduction happens here in float64 numpy, keeping the
        device program free of float cross-device reductions.
        """
        out = {}
        for name, d in self.data.items():
            df = self.defs[name]
            count = np.asarray(d["count"], np.int64)
            total = np.asarray(d["total"], np.float64)
            sumsq = np.asarray(d["sumsq"], np.float64)
            n = int(count.sum())
            entry = {
                "count": n,
                "lanes": df.lanes,
                "hist": [int(v) for v in np.asarray(d["hist"])],
                "edges": [float(v) for v in
                          np.linspace(df.lo, df.hi, df.bins + 1)],
                "underflow": int(d["underflow"]),
                "overflow": int(d["overflow"]),
            }
            if df.n_windows:
                wc = np.asarray(d["wcount"], np.int64)     # (W, lanes)
                wt = np.asarray(d["wtotal"], np.float64)
                wmn = np.asarray(d["wmn"], np.float64)
                wmx = np.asarray(d["wmx"], np.float64)
                cnt = wc.sum(-1)                            # (W,)
                with np.errstate(invalid="ignore", divide="ignore"):
                    mean = wt.sum(-1) / cnt
                filled = cnt > 0
                steps = int(self.step)
                entry["windows"] = {
                    "n_windows": df.n_windows,
                    "window_len": df.window_len,
                    "count": [int(v) for v in cnt],
                    "mean": [float(m) if ok else None
                             for m, ok in zip(mean, filled)],
                    "min": [float(v.min()) if ok else None for v, ok in
                            zip(np.where(wc > 0, wmn, np.inf), filled)],
                    "max": [float(v.max()) if ok else None for v, ok in
                            zip(np.where(wc > 0, wmx, -np.inf), filled)],
                    "last_slot": ((steps - 1) // df.window_len)
                    % df.n_windows if steps else None,
                    "wrapped": steps > df.n_windows * df.window_len,
                }
            if n:
                mean = float(total.sum() / n)
                var = max(float(sumsq.sum() / n) - mean * mean, 0.0)
                valid = count > 0
                entry.update(
                    mean=mean,
                    std=math.sqrt(var),
                    min=float(np.asarray(d["mn"])[valid].min()),
                    max=float(np.asarray(d["mx"])[valid].max()),
                )
            else:
                entry.update(mean=None, std=None, min=None, max=None)
            out[name] = entry
        return out

    def quantiles(self, name: str,
                  qs: Sequence[float] = timeline.QUANTILES,
                  warn: bool = True) -> Dict[str, object]:
        """Histogram-derived quantiles of one stream (host-side).

        Delegates to :func:`repro.obs.timeline.hist_quantiles`: each
        quantile is the midpoint of the bin holding that order
        statistic, within one ``bin_width`` of the exact value — and
        the stream's explicit underflow/overflow counts flag clipped
        tails (``clipped=True`` + a ``UserWarning`` unless
        ``warn=False``), where the bound no longer holds.
        """
        d = self.data[name]
        df = self.defs[name]
        return timeline.hist_quantiles(
            np.asarray(d["hist"]), np.linspace(df.lo, df.hi, df.bins + 1),
            qs, underflow=int(d["underflow"]), overflow=int(d["overflow"]),
            warn=warn)

    def lane_means(self, name: str) -> np.ndarray:
        """Per-lane means (NaN for empty lanes) — e.g. per-cell reward."""
        d = self.data[name]
        count = np.asarray(d["count"], np.float64)
        total = np.asarray(d["total"], np.float64)
        with np.errstate(invalid="ignore", divide="ignore"):
            return total / np.where(count > 0, count, np.nan)
