"""Decoder / encoder-decoder stacks with scan-over-layers.

Layers are grouped into homogeneous SEGMENTS (contiguous runs sharing the
same attention kind: global vs sliding). Each segment's params/caches are
stacked on a leading axis and executed with lax.scan, so mixed patterns
(gemma3's 5:1 local:global, hymba's 3 global layers) get exact per-kind
code paths — no lax.cond double-compute polluting the roofline — while
keeping the HLO O(#segments), not O(#layers).

Cache layout (pytree):
  {"pos": (), "segments": [seg_cache, ...], ("cross": ..., for enc-dec)}
  attn seg_cache: {"k","v": (Lseg, B, Sc, KV, hd)} with Sc = full context
    for global segments, min(window, ctx) ring buffer for sliding ones.
  ssm/hybrid add {"conv": (Lseg, B, K-1, di), "h": (Lseg, B, di, N)}.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed import sharding
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MOE


# ---------------------------------------------------------------------------
# Segments


@dataclasses.dataclass(frozen=True)
class Segment:
    start: int
    length: int
    is_global: bool


def segments_of(cfg) -> tuple:
    mask = cfg.global_layer_mask()
    segs = []
    i = 0
    for j in range(1, cfg.n_layers + 1):
        if j == cfg.n_layers or mask[j] != mask[i]:
            segs.append(Segment(i, j - i, mask[i]))
            i = j
    return tuple(segs)


def seg_window(cfg, seg: Segment, ctx: int) -> int:
    """Effective attention window of a segment (0 = unlimited/global)."""
    if not cfg.has_attention:
        return 0
    return 0 if seg.is_global else cfg.sliding_window


# ---------------------------------------------------------------------------
# Per-layer init (vmapped into stacked segment params)


def _init_layer(key, cfg, *, cross: bool = False, causal: bool = True):
    ks = jax.random.split(key, 8)
    p = {"ln1": L.init_rmsnorm(cfg.d_model)}
    if cfg.arch_type == "ssm":
        p["ssm"] = M.init_mamba(ks[0], cfg)
        return p
    p["attn"] = L.init_attention(ks[0], cfg)
    if cfg.arch_type == "hybrid":
        p["ssm"] = M.init_mamba(ks[1], cfg)
        p["ln_attn_out"] = L.init_rmsnorm(cfg.d_model)
        p["ln_ssm_out"] = L.init_rmsnorm(cfg.d_model)
    if cross:
        p["cross"] = L.init_attention(ks[2], cfg)
        p["ln_cross"] = L.init_rmsnorm(cfg.d_model)
    if cfg.moe is not None:
        p["ln2"] = L.init_rmsnorm(cfg.d_model)
        p["moe"] = MOE.init_moe(ks[3], cfg)
    elif cfg.has_mlp:
        p["ln2"] = L.init_rmsnorm(cfg.d_model)
        p["mlp"] = L.init_mlp(ks[3], cfg)
    return p


def init_segment(key, cfg, seg: Segment, **kw):
    keys = jax.random.split(key, seg.length)
    return jax.vmap(lambda k: _init_layer(k, cfg, **kw))(keys)


# ---------------------------------------------------------------------------
# Layer application — full-sequence (train / prefill)


def _mixer_full(p, h, cfg, window: int, positions, *, causal: bool = True,
                return_kv: bool = False):
    """Attention (+parallel SSM for hybrid) over a full sequence."""
    outs = []
    kv = None
    if cfg.has_attention:
        q, k, v = L.attention_qkv(p["attn"], h, cfg, positions,
                                  rope=(cfg.rope_theta > 0))
        q = sharding.logical(q, "batch", "seq", "heads", None)
        k = sharding.logical(k, "batch", "seq", "kv_heads", None)
        v = sharding.logical(v, "batch", "seq", "kv_heads", None)
        from repro.tuning import FLAGS
        if not causal:
            o = L.chunked_attention(q, k, v, causal=False,
                                    chunk=FLAGS["attn_chunk"],
                                    softcap=cfg.logit_softcap)
        elif window and h.shape[1] > window:
            o = L.local_banded_attention(q, k, v, window=window,
                                         softcap=cfg.logit_softcap)
        else:
            o = L.chunked_attention(q, k, v, causal=True, window=window,
                                    chunk=FLAGS["attn_chunk"],
                                    softcap=cfg.logit_softcap)
        o = sharding.logical(o, "batch", "seq", "heads", None)
        attn_out = L.linear(p["attn"]["wo"], o.reshape(*h.shape[:2], -1))
        outs.append(("attn", attn_out))
        if return_kv:
            kv = (k, v)
    ssm_cache = None
    if "ssm" in p:
        ssm_out, ssm_cache = M.mamba_block(p["ssm"], h, cfg)
        outs.append(("ssm", ssm_out))
    if cfg.arch_type == "hybrid":
        a = L.rmsnorm(p["ln_attn_out"], dict(outs)["attn"], cfg.rms_norm_eps)
        s = L.rmsnorm(p["ln_ssm_out"], dict(outs)["ssm"], cfg.rms_norm_eps)
        mixed = 0.5 * (a + s)
    else:
        mixed = outs[0][1]
    return mixed, kv, ssm_cache


def _ffn(p, x, cfg):
    if "moe" in p:
        h = L.rmsnorm(p["ln2"], x, cfg.rms_norm_eps)
        y, aux = MOE.moe_block(p["moe"], h, cfg,
                               shard_experts=sharding.shard_moe_dispatch)
        return x + y, aux["aux_loss"]
    if "mlp" in p:
        h = L.rmsnorm(p["ln2"], x, cfg.rms_norm_eps)
        h = sharding.logical(h, "batch", "seq", "embed")
        return x + L.mlp(p["mlp"], h, cfg.mlp_act), 0.0
    return x, 0.0


def layer_full(p, x, cfg, window: int, positions, *, causal: bool = True,
               cross_src=None, return_kv: bool = False):
    """One decoder layer over a full sequence.

    cross_src: encoder output (B, S_enc, D) for enc-dec decoders; each layer
    projects its own cross K/V (returned for caching when return_kv).
    Returns (x, kv, cross_kv, ssm_cache, aux).
    """
    hd = cfg.resolved_head_dim
    h = L.rmsnorm(p["ln1"], x, cfg.rms_norm_eps)
    mixed, kv, ssm_cache = _mixer_full(p, h, cfg, window, positions,
                                       causal=causal, return_kv=return_kv)
    x = x + mixed
    cross_kv = None
    if cross_src is not None and "cross" in p:
        hc = L.rmsnorm(p["ln_cross"], x, cfg.rms_norm_eps)
        b, se = cross_src.shape[:2]
        ck = L.linear(p["cross"]["wk"], cross_src).reshape(b, se, cfg.n_kv_heads, hd)
        cv = L.linear(p["cross"]["wv"], cross_src).reshape(b, se, cfg.n_kv_heads, hd)
        qc = L.linear(p["cross"]["wq"], hc).reshape(
            *hc.shape[:2], cfg.n_heads, hd)
        oc = L.chunked_attention(qc, ck, cv, causal=False)
        x = x + L.linear(p["cross"]["wo"], oc.reshape(*hc.shape[:2], -1))
        cross_kv = (ck, cv)
    x, aux = _ffn(p, x, cfg)
    x = sharding.logical(x, "batch", "seq", "embed")
    return x, kv, cross_kv, ssm_cache, aux


# ---------------------------------------------------------------------------
# Layer application — single-token decode


def layer_decode(p, x, cache_l, cfg, window: int, pos):
    """One decoder layer for one token. cache_l holds this layer's slices
    (incl. per-layer cross K/V "ck"/"cv" for enc-dec models).

    pos: scalar int32 absolute position of the incoming token.
    Returns (x, new_cache_l).
    """
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    h = L.rmsnorm(p["ln1"], x, cfg.rms_norm_eps)
    new_cache = {}
    outs = []
    if cfg.has_attention:
        positions = jnp.full((b, 1), pos)
        q, k, v = L.attention_qkv(p["attn"], h, cfg, positions,
                                  rope=(cfg.rope_theta > 0))
        kc, vc = cache_l["k"], cache_l["v"]          # (B, Sc, KV, hd)
        sc = kc.shape[1]
        slot = pos % sc
        int8_cache = "k_s" in cache_l
        if int8_cache:
            # quantize the new K/V rows (per slot-head symmetric scale)
            def _q(row):
                amax = jnp.max(jnp.abs(row.astype(jnp.float32)), -1) + 1e-8
                sc_ = amax / 127.0                      # (B,1,KV)
                rq = jnp.clip(jnp.round(row.astype(jnp.float32)
                                        / sc_[..., None]), -127, 127)
                return rq.astype(jnp.int8), sc_
            kq, ks_new = _q(k)
            vq, vs_new = _q(v)
            kc = jax.lax.dynamic_update_slice(kc, kq, (0, slot, 0, 0))
            vc = jax.lax.dynamic_update_slice(vc, vq, (0, slot, 0, 0))
            ks = jax.lax.dynamic_update_slice(cache_l["k_s"], ks_new,
                                              (0, slot, 0))
            vs = jax.lax.dynamic_update_slice(cache_l["v_s"], vs_new,
                                              (0, slot, 0))
            k_read = (kc.astype(jnp.float32) * ks[..., None]).astype(k.dtype)
            v_read = (vc.astype(jnp.float32) * vs[..., None]).astype(v.dtype)
            new_cache.update(k=kc, v=vc, k_s=ks, v_s=vs)
        else:
            kc = jax.lax.dynamic_update_slice(kc, k, (0, slot, 0, 0))
            vc = jax.lax.dynamic_update_slice(vc, v, (0, slot, 0, 0))
            k_read, v_read = kc, vc
            new_cache.update(k=kc, v=vc)
        # absolute position held by each ring slot after the write
        idx = jnp.arange(sc)
        kv_pos = pos - (pos - idx) % sc
        o = L.decode_attention(q, k_read, v_read,
                               kv_pos[None, :].repeat(b, 0),
                               jnp.full((b,), pos), window=window,
                               softcap=cfg.logit_softcap)
        attn_out = L.linear(p["attn"]["wo"], o.reshape(b, 1, -1))
        outs.append(("attn", attn_out))
    if "ssm" in p:
        ssm_out, ssm_new = M.mamba_block(
            p["ssm"], h, cfg, cache={"conv": cache_l["conv"], "h": cache_l["h"]})
        outs.append(("ssm", ssm_out))
        new_cache.update(conv=ssm_new["conv"], h=ssm_new["h"])
    if cfg.arch_type == "hybrid":
        a = L.rmsnorm(p["ln_attn_out"], dict(outs)["attn"], cfg.rms_norm_eps)
        s = L.rmsnorm(p["ln_ssm_out"], dict(outs)["ssm"], cfg.rms_norm_eps)
        mixed = 0.5 * (a + s)
    else:
        mixed = outs[0][1]
    x = x + mixed
    if "cross" in p and "ck" in cache_l:
        hc = L.rmsnorm(p["ln_cross"], x, cfg.rms_norm_eps)
        ck, cv = cache_l["ck"], cache_l["cv"]
        qc = L.linear(p["cross"]["wq"], hc).reshape(b, 1, cfg.n_heads, hd)
        npos = jnp.arange(ck.shape[1])
        oc = L.decode_attention(qc, ck, cv, npos[None, :].repeat(b, 0),
                                jnp.full((b,), ck.shape[1]))
        x = x + L.linear(p["cross"]["wo"], oc.reshape(b, 1, -1))
        new_cache.update(ck=ck, cv=cv)
    x, _ = _ffn(p, x, cfg)
    return x, new_cache


# ---------------------------------------------------------------------------
# Stacks


# Dry-run roofline mode: XLA's cost_analysis counts a lax.scan body ONCE
# (not x trip-count), so the launch/dryrun.py sets UNROLL_SEGMENTS=True to
# unroll the layer loop and get exact per-op FLOP/byte/collective counts.
# Runtime (training/serving) keeps the scan for O(1) HLO size.
UNROLL_SEGMENTS = False


def _scan_segment(body, x, seg_params, seg_xs=None, *, remat: bool = False):
    from repro.tuning import FLAGS
    if remat and FLAGS["remat_policy"] == "dots":
        f = jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    elif remat:
        f = jax.checkpoint(body)
    else:
        f = body
    xs = seg_params if seg_xs is None else (seg_params, seg_xs)
    if not UNROLL_SEGMENTS:
        return jax.lax.scan(f, x, xs)
    length = jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = []
    for i in range(length):
        x, y = f(x, jax.tree_util.tree_map(lambda a: a[i], xs))
        ys.append(y)
    stacked = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys)
    return x, stacked


def run_stack_full(segments, seg_params_list, x, cfg, ctx_positions, *,
                   causal=True, cross_src=None, want_cache: bool = False,
                   remat: bool = False):
    """Full-sequence pass over all segments.

    Returns (x, per_segment_cache_ys_or_None, total_aux_loss).
    """
    aux_total = jnp.zeros((), jnp.float32)
    seg_caches = []
    for seg, seg_params in zip(segments, seg_params_list):
        window = seg_window(cfg, seg, x.shape[1])

        def body(carry, p, _window=window):
            xx, aux = carry
            xx, kv, cross_kv, ssm_c, aux_l = layer_full(
                p, xx, cfg, _window, ctx_positions, causal=causal,
                cross_src=cross_src, return_kv=want_cache)
            ys = {}
            if want_cache and kv is not None:
                ys["k"], ys["v"] = kv
            if want_cache and cross_kv is not None:
                ys["ck"], ys["cv"] = cross_kv
            if want_cache and ssm_c is not None:
                ys["conv"], ys["h"] = ssm_c["conv"], ssm_c["h"]
            return (xx, aux + aux_l), ys

        (x, aux_total), ys = _scan_segment(body, (x, aux_total), seg_params,
                                           remat=remat)
        seg_caches.append(ys if want_cache else None)
    return x, seg_caches, aux_total


def run_stack_decode(segments, seg_params_list, x, cache, cfg, pos):
    """Single-token pass. cache: {'pos', 'segments': [stacked seg caches]}."""
    new_segs = []
    for seg, seg_params, seg_cache in zip(segments, seg_params_list,
                                          cache["segments"]):
        window = seg_window(cfg, seg, None)

        def body(xx, pc, _window=window):
            p, c = pc
            xx, new_c = layer_decode(p, xx, c, cfg, _window, pos)
            return xx, new_c

        x, new_c = _scan_segment(body, x, (seg_params, seg_cache))
        new_segs.append(new_c)
    return x, {"pos": pos + 1, "segments": new_segs}
