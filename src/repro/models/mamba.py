"""Mamba-1 selective-SSM block (Falcon-Mamba / Hymba SSM path).

Prefill/train: parallel associative scan over the sequence (the jnp
oracle mirrored by kernels/selective_scan.py). Decode: O(1) recurrent
step carrying (conv window, h state) in the cache.

Sharding: d_inner is the TP axis ('model'); the scan itself is
embarrassingly parallel over (batch, d_inner).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers as L


def init_mamba(key, cfg):
    s = cfg.ssm
    d, di, n = cfg.d_model, cfg.d_inner, s.state_dim
    dtr = s.resolved_dt_rank(d)
    ks = jax.random.split(key, 6)
    dtype = L.dt(cfg.dtype)
    # S4D-real initialization for A
    a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
    dt_init = jnp.exp(jax.random.uniform(ks[4], (di,), jnp.float32)
                      * (math.log(0.1) - math.log(0.001)) + math.log(0.001))
    inv_softplus = dt_init + jnp.log(-jnp.expm1(-dt_init))
    return {
        "in_proj": L.init_linear(ks[0], d, 2 * di, dtype, cfg.quant),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, di), jnp.float32)
                   * (1.0 / math.sqrt(s.d_conv))).astype(dtype),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": L.init_linear(ks[2], di, dtr + 2 * n, dtype),
        "dt_w": (jax.random.normal(ks[3], (dtr, di), jnp.float32)
                 * (dtr ** -0.5)).astype(jnp.float32),
        "dt_b": inv_softplus,
        "A_log": jnp.log(a),                       # (di, N) f32
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": L.init_linear(ks[5], di, d, dtype, cfg.quant,
                                  scale=1.0 / math.sqrt(di * max(1, 2 * cfg.n_layers))),
    }


def _ssm_params(params, xc, cfg):
    """xc: (..., di) post-conv activations -> dt (..,di), B,C (..,N)."""
    s = cfg.ssm
    dtr = s.resolved_dt_rank(cfg.d_model)
    proj = L.linear(params["x_proj"], xc).astype(jnp.float32)
    dt_r, b_, c_ = jnp.split(proj, [dtr, dtr + s.state_dim], axis=-1)
    dt = jax.nn.softplus(dt_r @ params["dt_w"] + params["dt_b"])
    return dt, b_, c_


def selective_scan_chunked(u, dt, A, B, C, D, chunk: int):
    """Chunked scan (Perf iteration): sequential lax.scan over chunks
    carrying h, associative scan within a chunk — bounds the materialized
    (Bt, S, di, N) state tensor to S=chunk (16x memory cut at chunk=256
    for train_4k) at the cost of serializing S/chunk chunk launches.

    Padding with dt=0 is exact: dA=1, dBu=0 (identity transitions)."""
    bt, s, di = u.shape
    n = A.shape[1]
    pad = (-s) % chunk
    if pad:
        zf = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        u, dt, B, C = zf(u), zf(dt), zf(B), zf(C)
    nc = (s + pad) // chunk
    sw = lambda x: x.reshape(bt, nc, chunk, -1).swapaxes(0, 1)
    uc, dtc, Bc, Cc = sw(u.astype(jnp.float32)), sw(dt.astype(jnp.float32)), \
        sw(B.astype(jnp.float32)), sw(C.astype(jnp.float32))

    def combine(a, b):
        (a1, b1), (a2, b2) = a, b
        return a1 * a2, b1 * a2 + b2

    def body(h0, inp):
        u_, dt_, b_, c_ = inp
        dA = jnp.exp(dt_[..., None] * A[None, None])
        dBu = (dt_ * u_)[..., None] * b_[:, :, None, :]
        aA, aB = jax.lax.associative_scan(combine, (dA, dBu), axis=1)
        h = aA * h0[:, None] + aB                      # (bt, chunk, di, n)
        y = jnp.einsum("bsdn,bsn->bsd", h, c_) + u_ * D[None, None]
        return h[:, -1], y

    h_last, ys = jax.lax.scan(jax.checkpoint(body),
                              jnp.zeros((bt, di, n), jnp.float32),
                              (uc, dtc, Bc, Cc))
    y = ys.swapaxes(0, 1).reshape(bt, s + pad, di)[:, :s]
    return y.astype(u.dtype), h_last


def selective_scan_ref(u, dt, A, B, C, D):
    """Associative-scan selective SSM (jnp oracle).

    u, dt: (Bt, S, di); A: (di, N); B, C: (Bt, S, N); D: (di,)
    Returns y: (Bt, S, di), h_last: (Bt, di, N).
    """
    uf = u.astype(jnp.float32)
    dA = jnp.exp(dt[..., None] * A[None, None])                 # (B,S,di,N)
    dBu = (dt * uf)[..., None] * B[:, :, None, :]               # (B,S,di,N)

    def combine(a, b):
        (a1, b1), (a2, b2) = a, b
        return a1 * a2, b1 * a2 + b2

    aA, aB = jax.lax.associative_scan(combine, (dA, dBu), axis=1)
    h = aB                                                      # (B,S,di,N)
    y = jnp.einsum("bsdn,bsn->bsd", h, C) + uf * D[None, None]
    return y.astype(u.dtype), h[:, -1].astype(jnp.float32)


def causal_conv1d(x, w, b, *, state=None):
    """Depthwise causal conv. x: (Bt,S,di); w: (K,di); state: (Bt,K-1,di)."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None].astype(x.dtype)
            for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else state
    return y + b.astype(x.dtype)[None, None], new_state


def mamba_block(params, x, cfg, *, cache=None):
    """x: (Bt, S, d_model) -> (y, new_cache).

    cache (decode): {'conv': (Bt, K-1, di), 'h': (Bt, di, N)} or None.
    For S>1 (prefill/train) uses the associative scan; S==1 with cache uses
    the recurrent step.
    """
    bt, s, _ = x.shape
    xz = L.linear(params["in_proj"], x)
    xi, z = jnp.split(xz, 2, axis=-1)                           # (Bt,S,di)

    conv_state = cache["conv"] if cache is not None else None
    xc, new_conv = causal_conv1d(xi, params["conv_w"], params["conv_b"],
                                 state=conv_state)
    xc = jax.nn.silu(xc)
    dt, b_, c_ = _ssm_params(params, xc, cfg)
    A = -jnp.exp(params["A_log"])                               # (di,N)

    from repro.tuning import FLAGS
    if s == 1 and cache is not None:
        h_prev = cache["h"]                                     # (Bt,di,N)
        dA = jnp.exp(dt[:, 0, :, None] * A[None])
        dBu = (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] * b_[:, 0, None, :]
        h = h_prev * dA + dBu
        y = jnp.einsum("bdn,bn->bd", h, c_[:, 0]) + xc[:, 0].astype(jnp.float32) * params["D"]
        y = y[:, None, :].astype(x.dtype)
        h_last = h
    elif FLAGS["mamba_chunk"] and s > FLAGS["mamba_chunk"]:
        y, h_last = selective_scan_chunked(xc, dt, A, b_, c_, params["D"],
                                           FLAGS["mamba_chunk"])
    else:
        y, h_last = selective_scan_ref(xc, dt, A, b_, c_, params["D"])

    y = y * jax.nn.silu(z)
    out = L.linear(params["out_proj"], y)
    new_cache = {"conv": new_conv, "h": h_last}
    return out, new_cache


def mamba_cache_spec(cfg, batch: int):
    s = cfg.ssm
    di, n = cfg.d_inner, s.state_dim
    return {"conv": ((batch, s.d_conv - 1, di), L.dt(cfg.dtype)),
            "h": ((batch, di, n), jnp.float32)}
