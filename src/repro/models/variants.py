"""Model-variant ladder (the paper's d0..d7 analogue for any architecture).

The paper's application-layer knob is a pool of MobileNet variants
(width multiplier x {FP32, Int8}, Table 4). Here any ModelConfig expands
into the same 8-point ladder: width in {1.0, 0.75, 0.5, 0.25} x quant in
{none, int8}. Each variant reports its MAC count (per generated token)
so the orchestration environment can price it, and carries an accuracy
metadata field taken from the paper's Table 4 for the paper-faithful
reproduction (or measured task metrics when available).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ModelConfig, scale_width
from repro.configs.edge_ladder import MOBILENET_TABLE4

WIDTHS = (1.0, 0.75, 0.5, 0.25)


@dataclasses.dataclass(frozen=True)
class Variant:
    vid: str                      # d0..d7
    cfg: ModelConfig
    million_macs: float           # per-token forward MACs (analytic)
    top1: float                   # paper Table 4 metadata
    top5: float
    dtype_tag: str                # fp32-equivalent ("none") or int8


def per_token_macs(cfg: ModelConfig) -> float:
    """Analytic forward MACs per generated token (weights touched once)."""
    return cfg.active_param_count() / 1e6


def build_ladder(cfg: ModelConfig) -> Dict[str, Variant]:
    """d0..d7 variants of ``cfg`` mirroring the paper's Table 4 ladder."""
    out = {}
    for i, (vid, _macs, dt_, t1, t5) in enumerate(MOBILENET_TABLE4):
        width = WIDTHS[i % 4]
        quant = "int8" if dt_ == "int8" else "none"
        vcfg = scale_width(cfg, width, quant=quant)
        out[vid] = Variant(vid=vid, cfg=vcfg,
                           million_macs=per_token_macs(vcfg),
                           top1=t1, top5=t5, dtype_tag=dt_)
    return out
