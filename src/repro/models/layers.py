"""Core building blocks: linear (incl. int8), norms, RoPE, attention.

All modules are functional: ``init_*`` returns a param pytree,
``apply``-style functions consume it. Parameters destined for the layer
scan carry a leading stacked-layer axis added by the caller
(transformer.py) via vmapped init.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# dtype helpers


def dt(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


# ---------------------------------------------------------------------------
# Linear (dense or int8-quantized)


def init_linear(key, d_in: int, d_out: int, dtype=jnp.bfloat16, quant: str = "none",
                scale: Optional[float] = None):
    std = scale if scale is not None else 1.0 / math.sqrt(d_in)
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * std
    if quant == "int8":
        s = jnp.max(jnp.abs(w), axis=0, keepdims=True) / 127.0 + 1e-8
        w_q = jnp.clip(jnp.round(w / s), -127, 127).astype(jnp.int8)
        return {"w_q": w_q, "s": s.astype(jnp.float32)}
    return {"w": w.astype(dtype)}


def linear(params, x):
    """y = x @ W. int8 path: dynamic per-token activation quantization and
    an int8 x int8 -> int32 contraction (MXU int8 path on TPU; mirrored by
    kernels/int8_matmul.py)."""
    if "w_q" in params:
        amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True).astype(jnp.float32) + 1e-8
        sx = amax / 127.0
        x_q = jnp.clip(jnp.round(x.astype(jnp.float32) / sx), -127, 127).astype(jnp.int8)
        acc = jax.lax.dot_general(
            x_q, params["w_q"], (((x_q.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        y = acc.astype(jnp.float32) * sx * params["s"]
        return y.astype(x.dtype)
    return jnp.dot(x, params["w"].astype(x.dtype))


# ---------------------------------------------------------------------------
# Norms


def init_rmsnorm(d: int):
    return {"g": jnp.zeros((d,), jnp.float32)}   # gemma-style (1+g)


def rmsnorm(params, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * (1.0 + params["g"])
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention cores. All take q (B,Sq,H,hd), k/v (B,Skv,KV,hd) with H = KV*G.

NEG_INF = -1e30


def _gqa_scores(q, k):
    """(B,Sq,KV,G,hd) x (B,Skv,KV,hd) -> (B,KV,G,Sq,Skv) in f32."""
    return jnp.einsum("bqkgh,bskh->bkgqs", q, k, preferred_element_type=jnp.float32)


def _split_groups(q, n_kv):
    b, s, h, hd = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, hd)


def chunked_attention(q, k, v, *, causal: bool, q_offset=0, window: int = 0,
                      chunk: int = 1024, softcap: float = 0.0):
    """Online-softmax attention, lax.scan over KV chunks (memory-bounded;
    the jnp mirror of kernels/flash_attention.py).

    window > 0 restricts to kv_pos in (q_pos - window, q_pos].
    q_offset: absolute position of q[0] (for decode / chunked prefill).
    """
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    n_kv = k.shape[2]
    chunk = min(chunk, skv)
    n_chunks = -(-skv // chunk)
    pad = n_chunks * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qg = _split_groups(q, n_kv)                       # (B,Sq,KV,G,hd)
    scale = 1.0 / math.sqrt(hd)
    q_pos = q_offset + jnp.arange(sq)

    kc = k.reshape(b, n_chunks, chunk, n_kv, hd).swapaxes(0, 1)
    vc = v.reshape(b, n_chunks, chunk, n_kv, hd).swapaxes(0, 1)

    def body(carry, inp):
        m, l, acc = carry
        ci, (kb, vb) = inp
        kv_pos = ci * chunk + jnp.arange(chunk)
        s = _gqa_scores(qg, kb) * scale               # (B,KV,G,Sq,C)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        mask = kv_pos[None, :] < skv + jnp.zeros((sq, 1), jnp.int32)  # valid (unpadded)
        if causal:
            mask &= kv_pos[None, :] <= q_pos[:, None]
        if window:
            mask &= kv_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(vb.dtype), vb,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    kv_g = q.shape[2] // n_kv
    m0 = jnp.full((b, n_kv, kv_g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, n_kv, kv_g, sq), jnp.float32)
    a0 = jnp.zeros((b, n_kv, kv_g, sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (jnp.arange(n_chunks), (kc, vc)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd)
    return out.astype(q.dtype)


def local_banded_attention(q, k, v, *, window: int, softcap: float = 0.0):
    """Sliding-window causal attention for prefill/train: block-local trick
    (block size = window; each block attends to itself + previous block with
    an exact in-band mask) -> O(S * 2W) instead of O(S^2)."""
    b, s, h, hd = q.shape
    n_kv = k.shape[2]
    w = min(window, s)
    nb = -(-s // w)
    pad = nb * w - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qb = q.reshape(b, nb, w, h, hd)
    kb = k.reshape(b, nb, w, n_kv, hd)
    vb = v.reshape(b, nb, w, n_kv, hd)
    k_prev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    v_prev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    k2 = jnp.concatenate([k_prev, kb], axis=2)        # (B,nb,2W,KV,hd)
    v2 = jnp.concatenate([v_prev, vb], axis=2)
    qg = qb.reshape(b, nb, w, n_kv, h // n_kv, hd)
    scale = 1.0 / math.sqrt(hd)
    s_ = jnp.einsum("bnqkgh,bnskh->bnkgqs", qg, k2,
                    preferred_element_type=jnp.float32) * scale
    if softcap:
        s_ = jnp.tanh(s_ / softcap) * softcap
    qpos = jnp.arange(w)[:, None]                     # within-block
    kpos = jnp.arange(2 * w)[None, :] - w             # relative to block start
    block_id = jnp.arange(nb)
    abs_valid = (block_id[:, None, None] * w + kpos[None]) >= 0   # (nb,W,2W)
    mask = (kpos <= qpos) & (kpos > qpos - w) & abs_valid
    s_ = jnp.where(mask[None, :, None, None], s_, NEG_INF)
    p = jax.nn.softmax(s_, axis=-1)
    o = jnp.einsum("bnkgqs,bnskh->bnqkgh", p.astype(v2.dtype), v2,
                   preferred_element_type=jnp.float32)
    o = o.reshape(b, nb * w, h, hd)[:, :s]
    return o.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, kv_pos, cur_pos, *, window: int = 0,
                     softcap: float = 0.0):
    """Single-token attention against a (possibly ring-buffered) cache.

    q: (B,1,H,hd); caches: (B,Sc,KV,hd); kv_pos: (B,Sc) absolute position of
    each slot (-1 = empty); cur_pos: (B,) position of the new token.
    The jnp mirror of kernels/decode_attention.py.
    """
    b, _, h, hd = q.shape
    n_kv = k_cache.shape[2]
    qg = _split_groups(q, n_kv)
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    valid = (kv_pos >= 0) & (kv_pos <= cur_pos[:, None])
    if window:
        valid &= kv_pos > (cur_pos[:, None] - window)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, 1, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block (projections + rope + core dispatch)


def init_attention(key, cfg, *, cross: bool = False):
    ks = jax.random.split(key, 4)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    dtype = dt(cfg.dtype)
    return {
        "wq": init_linear(ks[0], d, qd, dtype, cfg.quant),
        "wk": init_linear(ks[1], d, kvd, dtype, cfg.quant),
        "wv": init_linear(ks[2], d, kvd, dtype, cfg.quant),
        "wo": init_linear(ks[3], qd, d, dtype, cfg.quant,
                          scale=1.0 / math.sqrt(qd * max(1, 2 * cfg.n_layers))),
    }


def attention_qkv(params, x, cfg, positions=None, *, rope: bool = True):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = linear(params["wq"], x).reshape(b, s, cfg.n_heads, hd)
    k = linear(params["wk"], x).reshape(b, s, cfg.n_kv_heads, hd)
    v = linear(params["wv"], x).reshape(b, s, cfg.n_kv_heads, hd)
    if rope:
        if positions is None:
            positions = jnp.arange(s)[None, :]
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


# ---------------------------------------------------------------------------
# MLP


def init_mlp(key, cfg):
    ks = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    dtype = dt(cfg.dtype)
    if cfg.mlp_act in ("swiglu", "geglu"):
        return {"w_gate": init_linear(ks[0], d, f, dtype, cfg.quant),
                "w_up": init_linear(ks[1], d, f, dtype, cfg.quant),
                "w_down": init_linear(ks[2], f, d, dtype, cfg.quant,
                                      scale=1.0 / math.sqrt(f * max(1, 2 * cfg.n_layers)))}
    return {"w_up": init_linear(ks[0], d, f, dtype, cfg.quant),
            "w_down": init_linear(ks[1], f, d, dtype, cfg.quant,
                                  scale=1.0 / math.sqrt(f * max(1, 2 * cfg.n_layers)))}


def mlp(params, x, act: str):
    if act == "swiglu":
        h = jax.nn.silu(linear(params["w_gate"], x)) * linear(params["w_up"], x)
    elif act == "geglu":
        h = jax.nn.gelu(linear(params["w_gate"], x)) * linear(params["w_up"], x)
    else:
        h = jax.nn.gelu(linear(params["w_up"], x))
    return linear(params["w_down"], h)
