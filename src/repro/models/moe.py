"""Mixture-of-Experts layer: top-k router + capacity-based scatter dispatch.

Expert-parallel design (DESIGN.md §7): dispatched activations are laid out
(B, E, C, D) so that constraining E to the 'model' mesh axis turns the
dispatch/combine reshards into all-to-alls, while expert weights live
one-per-rank (E sharded over 'model'). Capacity per batch row
C = ceil(S * top_k / E * capacity_factor); overflowing tokens are dropped
(Switch/GShard semantics) and the router aux loss keeps load balanced.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L


def init_moe(key, cfg):
    m = cfg.moe
    ks = jax.random.split(key, 4)
    d, f, e = cfg.d_model, cfg.d_ff, m.n_experts
    dtype = L.dt(cfg.dtype)
    def ew(k, din, dout, scale):
        w = jax.random.normal(k, (e, din, dout), jnp.float32) * scale
        if cfg.quant == "int8":
            s = jnp.max(jnp.abs(w), axis=1, keepdims=True) / 127.0 + 1e-8
            wq = jnp.clip(jnp.round(w / s), -127, 127).astype(jnp.int8)
            return {"w_q": wq, "s": s.astype(jnp.float32)}
        return {"w": w.astype(dtype)}
    return {
        "router": L.init_linear(ks[0], d, e, jnp.float32),  # router in f32
        "w_gate": ew(ks[1], d, f, d ** -0.5),
        "w_up": ew(ks[2], d, f, d ** -0.5),
        "w_down": ew(ks[3], f, d, (f * max(1, 2 * cfg.n_layers)) ** -0.5),
    }


def _expert_matmul(p, x):
    """x: (B,E,C,Din) @ per-expert weights (E,Din,Dout)."""
    if "w_q" in p:
        amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True).astype(jnp.float32) + 1e-8
        sx = amax / 127.0
        xq = jnp.clip(jnp.round(x.astype(jnp.float32) / sx), -127, 127).astype(jnp.int8)
        acc = jnp.einsum("beci,eio->beco", xq, p["w_q"],
                         preferred_element_type=jnp.int32)
        return (acc.astype(jnp.float32) * sx * p["s"][None]).astype(x.dtype)
    return jnp.einsum("beci,eio->beco", x, p["w"].astype(x.dtype))


def capacity(seq: int, top_k: int, n_experts: int, cf: float) -> int:
    return max(1, int(-(-seq * top_k * cf // n_experts)))


def moe_block(params, x, cfg, *, shard_experts=None):
    """x: (B, S, D) -> (B, S, D), aux: dict with load-balance loss.

    shard_experts: optional callable applying a sharding constraint to the
    dispatched (B,E,C,D) tensors (injected by distributed/sharding.py).
    """
    from repro.tuning import FLAGS
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.n_experts, m.top_k
    cap = capacity(s, k, e, FLAGS["moe_cf"] or m.capacity_factor)

    logits = L.linear(params["router"], x.astype(jnp.float32))      # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)                 # (B,S,k)
    gate_vals = gate_vals / (jnp.sum(gate_vals, axis=-1, keepdims=True) + 1e-9)

    # Load-balance aux loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=(0, 1))                               # (E,)
    ce = jnp.mean(jax.nn.one_hot(expert_ids[..., 0], e), axis=(0, 1))
    aux_loss = e * jnp.sum(me * ce)

    # Position of each (token, slot) within its expert, per batch row.
    flat_ids = expert_ids.reshape(b, s * k)                         # (B,T)
    onehot = jax.nn.one_hot(flat_ids, e, dtype=jnp.int32)           # (B,T,E)
    pos_in_e = jnp.cumsum(onehot, axis=1) - onehot                  # (B,T,E)
    pos = jnp.take_along_axis(
        pos_in_e, flat_ids[..., None], axis=2)[..., 0]              # (B,T)
    keep = pos < cap

    # Dispatch: per-row scatter into (E, C, D), vmapped over the batch so
    # the batch becomes a true scatter batching dim — GSPMD keeps B
    # sharded over 'data' and reshards only E to 'model' (the expert-
    # parallel exchange). Out-of-capacity entries fall out via
    # mode='drop' (Switch/GShard token dropping).
    xk = jnp.broadcast_to(x[:, :, None, :], (b, s, k, d)).reshape(b, s * k, d)

    def _scatter_row(xrow, ids, prow):
        return jnp.zeros((e, cap, d), x.dtype).at[ids, prow].set(
            xrow, mode="drop")

    dispatched = jax.vmap(_scatter_row)(xk, flat_ids, pos)          # (B,E,C,D)
    if shard_experts is not None:
        dispatched = shard_experts(dispatched)

    # Expert weights: experts stay sharded over 'model'; the matrix dims
    # are FSDP-stored but must be gathered (constraint to replicated)
    # before use so GSPMD gathers the (small) weights instead of
    # all-reducing the (huge) dispatched activations.
    def _gathered(p):
        key = "w_q" if "w_q" in p else "w"
        from repro.distributed import sharding as _sh
        q = dict(p)
        q[key] = _sh.logical(p[key], "expert", None, None)
        return q

    h = jax.nn.silu(_expert_matmul(_gathered(params["w_gate"]), dispatched))
    h = h * _expert_matmul(_gathered(params["w_up"]), dispatched)
    out_e = _expert_matmul(_gathered(params["w_down"]), h)          # (B,E,C,D)
    if shard_experts is not None:
        out_e = shard_experts(out_e)

    # Combine: per-row gather of each (token, slot)'s expert output.
    def _gather_row(oe, ids, prow):
        return oe[ids, jnp.minimum(prow, cap - 1)]

    gathered = jax.vmap(_gather_row)(out_e, flat_ids, pos)          # (B,T,D)
    w = (gate_vals.reshape(b, s * k) * keep).astype(x.dtype)
    y = (gathered * w[..., None]).reshape(b, s, k, d).sum(axis=2)
    return y, {"aux_loss": aux_loss,
               "dropped_frac": 1.0 - jnp.mean(keep.astype(jnp.float32))}


def moe_block_dense_ref(params, x, cfg):
    """Oracle: every token through its top-k experts with NO capacity drop
    (dense einsum over all experts). Used by tests to validate dispatch."""
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.n_experts, m.top_k
    logits = L.linear(params["router"], x.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / (jnp.sum(gate_vals, axis=-1, keepdims=True) + 1e-9)
    comb = jnp.zeros((b, s, e), jnp.float32)
    comb = jnp.sum(jax.nn.one_hot(expert_ids, e) * gate_vals[..., None], axis=2)

    def one_expert(wg, wu, wd):
        h = jax.nn.silu(x @ wg.astype(x.dtype)) * (x @ wu.astype(x.dtype))
        return h @ wd.astype(x.dtype)
    ys = jax.vmap(one_expert, in_axes=0, out_axes=0)(
        params["w_gate"]["w"], params["w_up"]["w"], params["w_down"]["w"])
    y = jnp.einsum("ebsd,bse->bsd", ys.astype(jnp.float32), comb)
    return y.astype(x.dtype)
