"""Model facade: init / train forward / prefill / decode / specs.

The one entry point the rest of the framework uses:

    model = build_model(cfg)
    params = model.init(key)                       # or jax.eval_shape(model.init, key)
    loss, metrics = model.loss(params, batch)
    logits, cache = model.prefill(params, batch, max_len)
    logits, cache = model.decode(params, cache, tokens)

VLM ('vlm') and audio ('audio') archs take STUB frontend embeddings
("img_embeds" / "frames") in their batch — see DESIGN.md §2.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed import sharding
from repro.models import layers as L
from repro.models import transformer as T


class Model:
    def __init__(self, cfg):
        self.cfg = cfg
        self.segments = T.segments_of(cfg)
        if cfg.is_encdec:
            import dataclasses
            enc_cfg = dataclasses.replace(
                cfg, n_layers=cfg.n_enc_layers, attn_pattern="full",
                global_layers=(), global_interval=0, moe=None, ssm=None,
                arch_type="dense")
            self.enc_cfg = enc_cfg
            self.enc_segments = T.segments_of(enc_cfg)
        else:
            self.enc_cfg = None
            self.enc_segments = ()

    # ---------------- init ----------------
    def init(self, key):
        cfg = self.cfg
        ks = iter(jax.random.split(key, 8 + len(self.segments) + len(self.enc_segments)))
        params = {
            "embed": {"w": (jax.random.normal(next(ks), (cfg.padded_vocab, cfg.d_model), jnp.float32)
                            * (cfg.d_model ** -0.5)).astype(L.dt(cfg.dtype))},
            "final_norm": L.init_rmsnorm(cfg.d_model),
            "segments": [T.init_segment(next(ks), cfg, seg, cross=cfg.is_encdec)
                         for seg in self.segments],
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = L.init_linear(next(ks), cfg.d_model,
                                              cfg.padded_vocab, L.dt(cfg.dtype))
        if cfg.arch_type == "vlm":
            params["proj_img"] = L.init_linear(next(ks), cfg.d_model, cfg.d_model,
                                               L.dt(cfg.dtype))
        if cfg.is_encdec:
            params["encoder"] = {
                "segments": [T.init_segment(next(ks), self.enc_cfg, seg)
                             for seg in self.enc_segments],
                "final_norm": L.init_rmsnorm(cfg.d_model),
            }
        return params

    # ---------------- shared pieces ----------------
    def _embed(self, params, tokens):
        cfg = self.cfg
        x = jnp.take(params["embed"]["w"], tokens, axis=0)
        x = x.astype(L.dt(cfg.dtype)) * jnp.asarray(
            math.sqrt(cfg.d_model), L.dt(cfg.dtype))
        return sharding.logical(x, "batch", "seq", "embed")

    def _inputs_full(self, params, batch):
        """Token embeddings (+ prepended stub-modality embeddings)."""
        cfg = self.cfg
        x = self._embed(params, batch["tokens"])
        n_prefix = 0
        if cfg.arch_type == "vlm":
            img = L.linear(params["proj_img"], batch["img_embeds"].astype(x.dtype))
            x = jnp.concatenate([img, x], axis=1)
            n_prefix = img.shape[1]
        return x, n_prefix

    def _encode(self, params, frames):
        x = frames.astype(L.dt(self.cfg.dtype))
        x, _, _ = T.run_stack_full(self.enc_segments, params["encoder"]["segments"],
                                   x, self.enc_cfg, None, causal=False)
        return L.rmsnorm(params["encoder"]["final_norm"], x,
                         self.cfg.rms_norm_eps)

    def _logits(self, params, x):
        cfg = self.cfg
        x = L.rmsnorm(params["final_norm"], x, cfg.rms_norm_eps)
        if cfg.tie_embeddings:
            logits = jnp.einsum("bsd,vd->bsv", x,
                                params["embed"]["w"].astype(x.dtype))
        else:
            logits = L.linear(params["lm_head"], x)
        return sharding.logical(logits, "batch", "seq", "vocab")

    # ---------------- training forward ----------------
    def loss(self, params, batch, *, remat: bool = True,
             loss_chunk: int = 0):
        """Next-token cross-entropy. batch: tokens (B,S) (+stub embeds)."""
        from repro.tuning import FLAGS
        loss_chunk = loss_chunk or FLAGS["loss_chunk"]
        cfg = self.cfg
        x, n_prefix = self._inputs_full(params, batch)
        positions = jnp.arange(x.shape[1])[None, :]
        cross_src = self._encode(params, batch["frames"]) if cfg.is_encdec else None
        x, _, aux = T.run_stack_full(self.segments, params["segments"], x, cfg,
                                     positions, cross_src=cross_src,
                                     want_cache=False, remat=remat)
        x = L.rmsnorm(params["final_norm"], x, cfg.rms_norm_eps)
        x = x[:, n_prefix:]                      # predict only text tokens
        tokens = batch["tokens"]
        inputs_x, targets = x[:, :-1], tokens[:, 1:]

        head = (params["embed"]["w"].astype(x.dtype) if cfg.tie_embeddings
                else None)

        def chunk_loss(xc, tc, mc):
            if head is not None:
                logits = jnp.einsum("bsd,vd->bsv", xc, head)
            else:
                logits = L.linear(params["lm_head"], xc)
            logits = sharding.logical(logits, "batch", "seq", "vocab")
            logits = logits.astype(jnp.float32)
            # mask padded vocab columns
            if cfg.padded_vocab > cfg.vocab_size:
                neg = jnp.full((cfg.padded_vocab - cfg.vocab_size,), -1e30)
                logits = logits.at[..., cfg.vocab_size:].set(neg)
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
            return jnp.sum((lse - gold) * mc)

        s = inputs_x.shape[1]
        n_chunks = max(1, -(-s // loss_chunk))
        pad = n_chunks * loss_chunk - s
        if pad:
            inputs_x = jnp.pad(inputs_x, ((0, 0), (0, pad), (0, 0)))
            targets = jnp.pad(targets, ((0, 0), (0, pad)))
        xs = inputs_x.reshape(inputs_x.shape[0], n_chunks, loss_chunk, -1).swapaxes(0, 1)
        ts = targets.reshape(targets.shape[0], n_chunks, loss_chunk).swapaxes(0, 1)
        mask = (jnp.arange(n_chunks * loss_chunk) < s).reshape(n_chunks, loss_chunk)

        def body(tot, inp):
            xc, tc, mc = inp
            return tot + chunk_loss(xc, tc * mc, mc), None

        total, _ = T._scan_segment(body, jnp.zeros((), jnp.float32),
                                   (xs, ts, mask), remat=remat)
        # padded positions contribute lse(masked logits) - logit[0]; remove via mask
        # (we instead recompute exactly: mask inside)
        n_tok = inputs_x.shape[0] * s
        loss = total / n_tok
        metrics = {"loss": loss, "aux_loss": aux}
        if self.cfg.moe is not None:
            loss = loss + self.cfg.moe.aux_loss_weight * aux
        return loss, metrics

    # ---------------- prefill ----------------
    def prefill(self, params, batch, *, max_len: Optional[int] = None):
        """Run the full prompt; return (last-token logits, decode cache)."""
        cfg = self.cfg
        x, n_prefix = self._inputs_full(params, batch)
        s_total = x.shape[1]
        max_len = max_len or s_total
        positions = jnp.arange(s_total)[None, :]
        cross_src = self._encode(params, batch["frames"]) if cfg.is_encdec else None
        x, seg_ys, _ = T.run_stack_full(self.segments, params["segments"], x,
                                        cfg, positions, cross_src=cross_src,
                                        want_cache=True)
        logits = self._logits(params, x[:, -1:])
        cache = self._cache_from_prefill(seg_ys, s_total, max_len)
        return logits, cache

    def _cache_from_prefill(self, seg_ys, s: int, max_len: int):
        cfg = self.cfg
        segs = []
        for seg, ys in zip(self.segments, seg_ys):
            c = {}
            if "k" in ys:
                sc = self._seg_cache_len(seg, max_len)
                for name in ("k", "v"):
                    kv = ys[name]                       # (Lseg,B,S,KV,hd)
                    lseg, b = kv.shape[:2]
                    buf = jnp.zeros((lseg, b, sc) + kv.shape[3:], kv.dtype)
                    n_keep = min(s, sc)
                    last = kv[:, :, s - n_keep:]
                    slots = (jnp.arange(s - n_keep, s)) % sc
                    buf = buf.at[:, :, slots].set(last)
                    c[name] = buf
            if "ck" in ys:
                c["ck"], c["cv"] = ys["ck"], ys["cv"]
            if "conv" in ys:
                c["conv"], c["h"] = ys["conv"], ys["h"]
            segs.append(c)
        return {"pos": jnp.asarray(s, jnp.int32), "segments": segs}

    # ---------------- decode ----------------
    def decode(self, params, cache, tokens):
        """One decode step. tokens: (B, 1) int32. Returns (logits, cache)."""
        x = self._embed(params, tokens)
        pos = cache["pos"]
        x, new_cache = T.run_stack_decode(self.segments, params["segments"],
                                          x, cache, self.cfg, pos)
        logits = self._logits(params, x)
        return logits, new_cache

    # ---------------- specs (dry-run; no allocation) ----------------
    def _seg_cache_len(self, seg: T.Segment, ctx: int) -> int:
        if seg.is_global or self.cfg.attn_pattern == "full":
            return ctx
        return min(self.cfg.sliding_window, ctx)

    def cache_spec(self, batch: int, ctx: int):
        from repro.tuning import FLAGS
        cfg = self.cfg
        dt_ = L.dt(cfg.dtype)
        kv_int8 = FLAGS["kv_cache_dtype"] == "int8"
        kv_dt = jnp.int8 if kv_int8 else dt_
        hd = cfg.resolved_head_dim
        segs = []
        for seg in self.segments:
            c = {}
            if cfg.has_attention:
                sc = self._seg_cache_len(seg, ctx)
                shp = (seg.length, batch, sc, cfg.n_kv_heads, hd)
                c["k"] = jax.ShapeDtypeStruct(shp, kv_dt)
                c["v"] = jax.ShapeDtypeStruct(shp, kv_dt)
                if kv_int8:
                    c["k_s"] = jax.ShapeDtypeStruct(shp[:-1], jnp.float32)
                    c["v_s"] = jax.ShapeDtypeStruct(shp[:-1], jnp.float32)
            if cfg.is_encdec:
                shp = (seg.length, batch, cfg.enc_seq, cfg.n_kv_heads, hd)
                c["ck"] = jax.ShapeDtypeStruct(shp, dt_)
                c["cv"] = jax.ShapeDtypeStruct(shp, dt_)
            if cfg.ssm is not None:
                c["conv"] = jax.ShapeDtypeStruct(
                    (seg.length, batch, cfg.ssm.d_conv - 1, cfg.d_inner), dt_)
                c["h"] = jax.ShapeDtypeStruct(
                    (seg.length, batch, cfg.d_inner, cfg.ssm.state_dim),
                    jnp.float32)
            segs.append(c)
        return {"pos": jax.ShapeDtypeStruct((), jnp.int32), "segments": segs}

    def input_specs(self, shape):
        """ShapeDtypeStruct stand-ins for every model input of an
        InputShape (repro.configs.INPUT_SHAPES entry)."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        dt_ = L.dt(cfg.dtype)
        if shape.kind in ("train", "prefill"):
            s_text = s - (cfg.n_img_tokens if cfg.arch_type == "vlm" else 0)
            spec = {"tokens": jax.ShapeDtypeStruct((b, s_text), jnp.int32)}
            if cfg.arch_type == "vlm":
                spec["img_embeds"] = jax.ShapeDtypeStruct(
                    (b, cfg.n_img_tokens, cfg.d_model), dt_)
            if cfg.is_encdec:
                spec["frames"] = jax.ShapeDtypeStruct(
                    (b, cfg.enc_seq, cfg.d_model), dt_)
            return spec
        # decode: one token against a ctx-length cache
        return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
                "cache": self.cache_spec(b, s)}


def build_model(cfg) -> Model:
    return Model(cfg)
