"""Performance-tuning flags for the §Perf hillclimb (EXPERIMENTS.md).

Defaults = the paper-faithful / straightforward baseline. The dry-run CLI
and benchmarks flip these per iteration so before/after pairs are
attributable to exactly one change.
"""
FLAGS = {
    # decode: donate the KV cache so updates alias in place (no copy)
    "donate_cache": False,
    # mamba: chunked selective scan (0 = full associative scan baseline);
    # bounds the materialized (B, S, d_inner, N) state to chunk length
    "mamba_chunk": 0,
    # training loss: sequence chunk for the logits/CE scan
    "loss_chunk": 512,
    # attention: KV chunk for the online-softmax scan
    "attn_chunk": 1024,
    # decode KV cache storage dtype: "bf16" | "int8" (per-slot-head
    # symmetric scales; halves decode HBM traffic)
    "kv_cache_dtype": "bf16",
    # MoE capacity factor override (0.0 = use the config's value)
    "moe_cf": 0.0,
    # layer remat policy: "full" (recompute everything) | "dots"
    # (save matmul outputs, recompute elementwise) — memory<->HBM trade
    "remat_policy": "full",
}
