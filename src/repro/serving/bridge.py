"""Async serving bridge: continuous per-(tier, variant) request queues
over warmed ``ServingEngine``s.

``FleetOrchestrator._dispatch`` drains its batchers one (tier, variant)
queue at a time — batch formation and engine compute are serialized, so
three tiers' engines never overlap even though they model independent
machines (the paper's end / edge / cloud). This bridge is the
continuous analogue: one worker thread per (tier, variant) forms
batches (up to ``max_batch``, waiting at most ``max_wait_ms`` for
stragglers) and drains them concurrently, so the S/E/C engines run
overlapped exactly as the physically-separate tiers of the paper's
testbed would.

Robustness semantics (all counted, all conserved):

* **deadline-aware admission** — a request whose SLO budget is already
  exhausted at submit is shed instead of queued (``shed_deadline``);
* **bounded queues** — a full per-(tier, variant) queue sheds instead
  of growing without bound (``shed_overflow``);
* **per-queue timeout + retry-once reroute** — an engine call that
  exceeds ``engine_timeout_s`` abandons the batch; each affected
  request is rerouted ONCE to the tier's fallback queue (deadline
  permitting) and otherwise shed (``shed_timeout``). A failed tier
  degrades gracefully instead of stalling the drain loop;
* **drain timeout** — ``drain()`` bounds total wait; leftovers are
  shed (``shed_drain``) so the loop always completes.

Conservation identities (asserted in tests/test_bridge.py):

    submitted == admitted + shed_overflow + shed_deadline   (admission)
    admitted  == served + shed_timeout + shed_drain         (after drain)

so overall ``served + shed_total == submitted``. Every shed request is
reported with its reason in ``stats()["shed_requests"]`` (surfaced by
``RouteResult.summary()``), and sheds/reroutes/timeouts land in the
span stream as ``bridge.shed`` / ``bridge.reroute`` /
``bridge.timeout`` instants next to per-batch ``bridge.batch.*`` spans.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as _FutureTimeout
from typing import Dict, List, Optional, Tuple

from repro.serving.batching import Request, RequestBatcher

#: default tier fallback for retry-once-on-reroute: device decisions
#: fall back to the edge, the edge to the cloud, the cloud to the edge
#: (offloaded tiers always serve d0, mirroring ``api._tier_variant``)
DEFAULT_REROUTE = {"S": "E", "E": "C", "C": "E"}


@dataclasses.dataclass(frozen=True)
class BridgeConfig:
    """Knobs of the async bridge (all per (tier, variant) queue)."""
    max_batch: int = 8            # engine batch size cap
    max_wait_ms: float = 2.0      # batch-formation window for stragglers
    max_queue: int = 256          # bounded queue depth (overflow sheds)
    engine_timeout_s: float = 30.0   # per-batch engine call budget
    drain_timeout_s: float = 120.0   # total drain() budget
    min_slack_ms: float = 0.0     # extra SLO slack required at admission
    #: tier -> fallback tier for retry-once-on-reroute (None = default);
    #: rerouted requests serve the fallback tier's d0 engine
    reroute: Optional[Dict[str, str]] = None


class ServingBridge:
    """Overlapped batch formation + drain over ``{tier: {variant:
    ServingEngine}}``. One ``submit()`` per request, one ``drain()``
    to completion; ``stats()`` reports the conserved counters."""

    def __init__(self, engines, cfg: Optional[BridgeConfig] = None,
                 spans=None):
        self.engines = engines
        self.cfg = cfg or BridgeConfig()
        self.spans = spans
        self._reroute = (self.cfg.reroute if self.cfg.reroute is not None
                         else DEFAULT_REROUTE)
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._queues: Dict[Tuple[str, str], List[Request]] = {
            (t, v): [] for t, vs in engines.items() for v in vs}
        self._stop = False
        self._pending = 0            # admitted, not yet terminal
        self._terminal: set = set()  # rids already served or shed
        self._rerouted: set = set()  # rids that used their one retry
        #: rid -> (req, tier, variant) for batches handed to an engine
        self._inflight: Dict[int, Tuple[Request, str, str]] = {}
        # outcomes
        self.results: List[Tuple[Request, str, str]] = []
        self.batch_log: List[dict] = []
        self.shed_requests: List[dict] = []
        self.submitted = self.admitted = self.served = 0
        self.rerouted = self.timeouts = 0
        self.shed = {"overflow": 0, "deadline": 0, "timeout": 0,
                     "drain": 0}
        self._threads = [
            threading.Thread(target=self._worker, args=(key,), daemon=True,
                             name=f"bridge-{key[0]}/{key[1]}")
            for key in self._queues]
        for th in self._threads:
            th.start()

    # -- lifecycle ------------------------------------------------------
    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.stop()

    def stop(self):
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        for th in self._threads:
            th.join(timeout=1.0)

    # -- submit-side admission -----------------------------------------
    def submit(self, req: Request, tier: str, variant: str) -> bool:
        """Admit one request into the (tier, variant) queue. Returns
        False (and counts the shed) when admission rejects it:
        exhausted SLO budget or a full bounded queue."""
        key = (tier, variant)
        if key not in self._queues:
            raise KeyError(
                f"no engine for tier {tier!r} variant {variant!r}; "
                "build_engines(...) must cover the routed decisions")
        now = time.perf_counter()
        if not req.arrival_time:
            req.arrival_time = now
        self.submitted += 1
        elapsed_ms = (now - req.arrival_time) * 1e3
        if (req.deadline_ms != float("inf")
                and elapsed_ms + self.cfg.min_slack_ms >= req.deadline_ms):
            self._shed(req, tier, variant, "deadline", admitted=False)
            return False
        with self._cv:
            if len(self._queues[key]) >= self.cfg.max_queue:
                self._shed(req, tier, variant, "overflow", admitted=False,
                           locked=True)
                return False
            self.admitted += 1
            self._pending += 1
            self._queues[key].append(req)
            self._cv.notify_all()
        return True

    def drain(self, timeout_s: Optional[float] = None) -> bool:
        """Block until every admitted request is terminal (served or
        shed). On timeout, flush still-queued / in-flight requests as
        ``shed_drain`` so the identities still balance; returns True
        iff the drain completed without flushing."""
        budget = self.cfg.drain_timeout_s if timeout_s is None else timeout_s
        end = time.perf_counter() + budget
        with self._cv:
            while self._pending > 0:
                remaining = end - time.perf_counter()
                if remaining <= 0:
                    break
                self._cv.wait(min(remaining, 0.05))
            clean = self._pending == 0
            if not clean:
                for (tier, variant), q in self._queues.items():
                    for req in q:
                        self._shed(req, tier, variant, "drain",
                                   admitted=True, locked=True)
                    del q[:]
                # in-flight batches past the drain budget: shed them
                # terminally now; a late engine completion finds the
                # rids in _terminal and drops the stale result
                for rid, (req, tier, variant) in list(
                        self._inflight.items()):
                    self._shed(req, tier, variant, "drain",
                               admitted=True, locked=True)
        return clean

    # -- worker side ----------------------------------------------------
    def _worker(self, key):
        tier, variant = key
        eng = self.engines[tier][variant]
        batcher = RequestBatcher(self.cfg.max_batch)
        pool = ThreadPoolExecutor(max_workers=1,
                                  thread_name_prefix=f"eng-{tier}/{variant}")
        try:
            while True:
                with self._cv:
                    while not self._queues[key] and not self._stop:
                        self._cv.wait(0.05)
                    if not self._queues[key]:
                        if self._stop:
                            return
                        continue
                    # batch formation: wait up to max_wait_ms to fill
                    # max_batch with stragglers
                    t_end = time.perf_counter() + self.cfg.max_wait_ms / 1e3
                    while (len(self._queues[key]) < self.cfg.max_batch
                           and not self._stop):
                        left = t_end - time.perf_counter()
                        if left <= 0:
                            break
                        self._cv.wait(left)
                    reqs = self._queues[key][: self.cfg.max_batch]
                    del self._queues[key][: len(reqs)]
                    for r in reqs:
                        self._inflight[r.rid] = (r, tier, variant)
                if reqs:
                    self._serve(pool, eng, batcher, reqs, tier, variant)
        finally:
            pool.shutdown(wait=False)

    def _serve(self, pool, eng, batcher, reqs, tier, variant):
        spans = self.spans
        for breqs, toks, _lens in batcher.pack(reqs):
            t_form = time.perf_counter()
            fut = pool.submit(eng.serve_batch, breqs, toks, spans=spans,
                              t_drain=t_form)
            try:
                done = fut.result(timeout=self.cfg.engine_timeout_s)
            except _FutureTimeout:
                self._on_timeout(breqs, tier, variant)
                continue
            except Exception:
                # engine failure == timeout for routing purposes
                self._on_timeout(breqs, tier, variant)
                continue
            wall = time.perf_counter() - t_form
            if spans is not None:
                self.spans.complete(f"bridge.batch.{tier}/{variant}",
                                    t_form, wall, requests=len(breqs))
            with self._cv:
                fresh = [r for r in done if r.rid not in self._terminal]
                for r in fresh:
                    self._terminal.add(r.rid)
                    self._inflight.pop(r.rid, None)
                    self.results.append((r, tier, variant))
                self.served += len(fresh)
                self._pending -= len(fresh)
                if fresh:
                    self.batch_log.append({
                        "key": f"{tier}/{variant}",
                        "requests": len(fresh),
                        "serve_time": done[0].serve_time,
                        "response_time": done[0].response_time})
                self._cv.notify_all()

    def _on_timeout(self, breqs, tier, variant):
        """Engine call exceeded its budget (or raised): retry each
        request once on the fallback tier, shed the rest. The stuck
        call's eventual result is dropped — requests are re-enqueued as
        clones so the abandoned engine cannot race their stamps."""
        self.timeouts += 1
        if self.spans is not None:
            self.spans.instant("bridge.timeout", tier=tier, variant=variant,
                               requests=len(breqs))
        fb_tier = self._reroute.get(tier)
        fb_key = None
        if fb_tier is not None:
            cands = [k for k in self._queues if k[0] == fb_tier]
            pref = (fb_tier, "d0")
            fb_key = pref if pref in self._queues else \
                (cands[0] if cands else None)
        now = time.perf_counter()
        with self._cv:
            for r in breqs:
                if r.rid in self._terminal:
                    continue
                left_ms = (r.deadline_ms
                           - (now - r.arrival_time) * 1e3)
                can_retry = (r.rid not in self._rerouted
                             and fb_key is not None
                             and (r.deadline_ms == float("inf")
                                  or left_ms > self.cfg.min_slack_ms)
                             and len(self._queues[fb_key])
                             < self.cfg.max_queue)
                if can_retry:
                    self._rerouted.add(r.rid)
                    self.rerouted += 1
                    self._inflight.pop(r.rid, None)
                    clone = Request(r.rid, r.prompt,
                                    max_new_tokens=r.max_new_tokens,
                                    user=r.user,
                                    arrival_time=r.arrival_time,
                                    deadline_ms=r.deadline_ms)
                    self._queues[fb_key].append(clone)
                    if self.spans is not None:
                        self.spans.instant(
                            "bridge.reroute", rid=r.rid,
                            src=f"{tier}/{variant}",
                            dst=f"{fb_key[0]}/{fb_key[1]}")
                else:
                    self._shed(r, tier, variant, "timeout", admitted=True,
                               locked=True)
            self._cv.notify_all()

    def _shed(self, req, tier, variant, reason, admitted, locked=False):
        def _record():
            if req.rid in self._terminal:
                return
            self._terminal.add(req.rid)
            self._inflight.pop(req.rid, None)
            self.shed[reason] += 1
            self.shed_requests.append({
                "rid": req.rid, "tier": tier, "variant": variant,
                "reason": reason})
            if admitted:
                self._pending -= 1
            if self.spans is not None:
                self.spans.instant("bridge.shed", rid=req.rid, tier=tier,
                                   variant=variant, reason=reason)
        if locked:
            _record()
        else:
            with self._cv:
                _record()
                self._cv.notify_all()

    # -- reporting ------------------------------------------------------
    def stats(self) -> dict:
        """Conserved counters + per-shed detail. ``submitted ==
        admitted + shed(overflow) + shed(deadline)`` and ``served +
        shed(total) == submitted`` after a clean drain."""
        shed = dict(self.shed)
        shed["total"] = sum(shed.values())
        return {
            "submitted": self.submitted,
            "admitted": self.admitted,
            "served": self.served,
            "rerouted": self.rerouted,
            "timeouts": self.timeouts,
            "shed": shed,
            "shed_requests": list(self.shed_requests),
            "max_batch": self.cfg.max_batch,
            "max_wait_ms": self.cfg.max_wait_ms,
            "max_queue": self.cfg.max_queue,
        }
