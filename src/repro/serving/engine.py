"""Serving engine: jitted prefill + decode loop over a Model.

This is the "Intelligent Service" of the paper (Fig. 4): each tier
(device / edge / cloud) hosts one engine per model variant; the
orchestrator routes requests to (tier, variant). Executables are cached
per (batch, bucket-length) so steady-state traffic never re-traces.
"""
from __future__ import annotations

import functools
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.spans import span as _span
from repro.serving.batching import Request, RequestBatcher


class ServingEngine:
    def __init__(self, model, params, *, max_len: int = 512,
                 compute_scale: float = 1.0, hop_ms: float = 0.0):
        """compute_scale < 1 emulates a slower tier in the end-edge-cloud
        example (wall-time multiplied post-hoc); 1.0 = measure raw.

        hop_ms > 0 emulates the NETWORK HOP to a physically separate
        tier as a real per-batch sleep before compute. Unlike the
        post-hoc compute_scale it actually elapses (GIL released), so
        concurrent engines genuinely overlap it — the property of
        separate testbed machines that a single shared host loses, and
        the one the async bridge exists to exploit. The hop counts in
        both the raw batch wall and the stamped ``response_time`` (an
        orchestrator measuring a remote tier sees comm + compute)."""
        self.model = model
        self.params = params
        self.max_len = max_len
        self.compute_scale = compute_scale
        self.hop_ms = hop_ms
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, max_len=max_len))
        self._decode = jax.jit(model.decode)
        self._compiled: Dict[Tuple[int, int], bool] = {}

    def warmup(self, batch: int, prompt_len: int):
        toks = jnp.zeros((batch, prompt_len), jnp.int32)
        logits, cache = self._prefill(self.params, {"tokens": toks})
        self._decode(self.params, cache, jnp.zeros((batch, 1), jnp.int32))
        self._compiled[(batch, prompt_len)] = True

    def generate(self, tokens: np.ndarray, max_new_tokens: int = 16,
                 greedy: bool = True, spans=None):
        """tokens: (B, S) int32 -> (out_tokens (B, N), wall_seconds).

        ``spans`` (a ``repro.obs.spans.SpanRecorder``) wraps the call in
        ``engine.generate`` / ``engine.prefill`` / ``engine.decode``
        spans; the timed wall is unchanged (spans stamp the same host
        clock around the same work)."""
        with _span(spans, "engine.generate", batch=int(tokens.shape[0]),
                   prompt_len=int(tokens.shape[1]),
                   new_tokens=max_new_tokens,
                   compute_scale=self.compute_scale):
            t0 = time.perf_counter()
            toks = jnp.asarray(tokens, jnp.int32)
            with _span(spans, "engine.prefill"):
                logits, cache = self._prefill(self.params, {"tokens": toks})
            outs = []
            cur = jnp.argmax(logits[:, -1:, : self.model.cfg.vocab_size], -1)
            cur = cur.astype(jnp.int32)
            with _span(spans, "engine.decode", steps=max_new_tokens):
                for _ in range(max_new_tokens):
                    outs.append(cur)
                    logits, cache = self._decode(self.params, cache, cur)
                    cur = jnp.argmax(
                        logits[:, -1:, : self.model.cfg.vocab_size],
                        -1).astype(jnp.int32)
                out = jnp.concatenate(outs, axis=1)
                out.block_until_ready()
            wall = (time.perf_counter() - t0) / self.compute_scale
        return np.asarray(out), wall

    def serve_batch(self, reqs, toks, spans=None, t_drain=None):
        """Serve one already-formed batch (requests + padded tokens);
        fills response_time/output plus the queue/serve stamps the obs
        layer reads, and scores the SLO deadline stamped at submit
        (``deadline_met``: end-to-end queue + emulated compute against
        ``deadline_ms``). ``t_drain`` is the batch-formation stamp; it
        defaults to now, and queue_time is measured against it."""
        if not reqs:
            return []
        t_drain = time.perf_counter() if t_drain is None else t_drain
        if self.hop_ms:
            time.sleep(self.hop_ms / 1e3)   # the tier's network hop
        out, wall = self.generate(toks, max_new_tokens=reqs[0].max_new_tokens,
                                  spans=spans)
        wall += self.hop_ms / 1e3           # comm is not tier-speed-scaled
        raw = time.perf_counter() - t_drain
        for i, r in enumerate(reqs):
            r.output = out[i]
            r.response_time = wall
            r.queue_time = max(0.0, t_drain - r.arrival_time)
            r.serve_time = raw
            r.deadline_met = \
                (r.queue_time + r.response_time) * 1e3 <= r.deadline_ms
        return reqs

    def serve(self, batcher: RequestBatcher, spans=None):
        """Drain one batch from the batcher (empty drain returns [])."""
        t_drain = time.perf_counter()
        nxt = batcher.next_batch()
        if nxt is None or not nxt[0]:
            return []
        reqs, toks, _lens = nxt
        return self.serve_batch(reqs, toks, spans=spans, t_drain=t_drain)
