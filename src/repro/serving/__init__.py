from repro.serving.engine import ServingEngine
from repro.serving.batching import Request, RequestBatcher
from repro.serving.bridge import BridgeConfig, ServingBridge
