from repro.serving.engine import ServingEngine
from repro.serving.batching import Request, RequestBatcher
