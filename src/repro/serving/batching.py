"""Request batching: pad/pack incoming requests into fixed-shape batches
so the jitted prefill/decode executables are reused across traffic."""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 16
    user: int = 0                   # originating end-node (orchestration)
    # host perf_counter stamp; the batcher sets it at submit() if unset,
    # so queue_time below is measurable without caller cooperation
    arrival_time: float = 0.0
    # SLO deadline stamped at submit (ms of end-to-end latency budget,
    # queue + compute); inf = no deadline
    deadline_ms: float = float("inf")
    # filled by the engine:
    output: Optional[np.ndarray] = None
    response_time: float = 0.0      # emulated batch wall (s, /compute_scale)
    queue_time: float = 0.0         # submit -> batch-drain wait (s)
    serve_time: float = 0.0         # raw host wall of the serve call (s)
    # scored at drain: e2e (queue_time + response_time) <= deadline_ms;
    # None until the engine serves the request
    deadline_met: Optional[bool] = None


class RequestBatcher:
    """Greedy fixed-size batcher with right-padding to a bucket length."""

    def __init__(self, batch_size: int, buckets=(32, 64, 128, 256)):
        self.batch_size = batch_size
        self.buckets = tuple(sorted(buckets))
        self.queue: List[Request] = []

    def submit(self, req: Request):
        if not req.arrival_time:
            req.arrival_time = time.perf_counter()
        self.queue.append(req)

    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    def _pad(self, reqs: List[Request]):
        max_len = self._bucket(max(len(r.prompt) for r in reqs))
        toks = np.zeros((len(reqs), max_len), np.int32)
        lens = np.zeros((len(reqs),), np.int32)
        for i, r in enumerate(reqs):
            p = r.prompt[-max_len:]
            toks[i, :len(p)] = p
            lens[i] = len(p)
        return reqs, toks, lens

    def next_batch(self):
        """Pop up to batch_size requests; returns (requests, tokens, lengths)
        with tokens right-padded to a shared bucket length. Draining an
        empty queue returns an empty batch ([], (0, bucket) tokens,
        (0,) lengths) — not None, not an error — so async drain loops can
        poll without a sentinel check."""
        if not self.queue:
            return ([], np.zeros((0, self.buckets[0]), np.int32),
                    np.zeros((0,), np.int32))
        reqs = self.queue[: self.batch_size]
        self.queue = self.queue[self.batch_size:]
        return self._pad(reqs)

    def pack(self, reqs: List[Request]):
        """Pad an explicit request list into fixed-shape batches. A list
        larger than batch_size splits into multiple batches instead of
        silently truncating — the async bridge's batch-formation path."""
        return [self._pad(reqs[lo:lo + self.batch_size])
                for lo in range(0, len(reqs), self.batch_size)]
