"""AdamW + gradient clipping + schedules, in pure JAX (no optax dep).

State is a pytree mirroring params: {"m": ..., "v": ..., "step": ()}.
Moments are f32 regardless of param dtype (mixed-precision training with
bf16 params). All ops are elementwise -> the optimizer inherits the
parameter sharding (FSDP) with no extra collectives.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def constant_lr_adamw(lr: float, grad_clip: float = 10.0) -> AdamWConfig:
    """The RL agents' optimizer: constant LR (no warmup, no decay), no
    weight decay — shared by ``core.dqn`` and ``repro.fleet.policy`` so
    the scalar and fleet DQNs can't drift apart."""
    return AdamWConfig(lr=lr, warmup_steps=0, total_steps=10**9,
                       weight_decay=0.0, grad_clip=grad_clip,
                       min_lr_frac=1.0)


def init_opt_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def lr_at(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(1, cfg.warmup_steps)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def _is_matrix(p):
    return p.ndim >= 2


def apply_updates(params, grads, state, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) if cfg.grad_clip else 1.0
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        u = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        if cfg.weight_decay and _is_matrix(p):
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
