from repro.training.optimizer import AdamWConfig, apply_updates, init_opt_state
from repro.training.train_step import init_state, make_train_step
