"""Deterministic synthetic token pipeline.

Generates a stationary Markov-chain token stream (so the LM has learnable
structure and training loss visibly decreases) plus packing into fixed
(batch, seq) examples. Pure numpy on host, staged to device per step —
the standard host-pipeline shape, no filesystem dependency.
"""
from __future__ import annotations

import numpy as np


class SyntheticLM:
    """Order-1 Markov token source with a low-rank transition structure."""

    def __init__(self, vocab: int, seed: int = 0, rank: int = 16):
        rng = np.random.default_rng(seed)
        a = rng.standard_normal((vocab, rank)).astype(np.float32)
        b = rng.standard_normal((rank, vocab)).astype(np.float32)
        logits = (a @ b) / np.sqrt(rank) * 2.0
        self.probs = np.exp(logits - logits.max(1, keepdims=True))
        self.probs /= self.probs.sum(1, keepdims=True)
        self.vocab = vocab
        self.rng = rng

    def sample(self, batch: int, seq: int) -> np.ndarray:
        out = np.empty((batch, seq), np.int32)
        cur = self.rng.integers(0, self.vocab, batch)
        for t in range(seq):
            out[:, t] = cur
            # vectorized categorical draw per row
            u = self.rng.random(batch)
            cdf = np.cumsum(self.probs[cur], axis=1)
            cur = (u[:, None] < cdf).argmax(axis=1)
        return out


def batches(vocab: int, batch: int, seq: int, n_steps: int, seed: int = 0,
            extras=None):
    src = SyntheticLM(vocab, seed)
    for _ in range(n_steps):
        b = {"tokens": src.sample(batch, seq)}
        if extras:
            b.update({k: f(batch) for k, f in extras.items()})
        yield b
