"""Training step: loss -> grads -> AdamW update (the function lowered by
the train_4k dry-run shape)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.training.optimizer import AdamWConfig, apply_updates, init_opt_state


def make_train_step(model, opt_cfg: AdamWConfig, *, remat: bool = True):
    """Returns train_step(state, batch) -> (state, metrics) where
    state = {"params", "opt"}. Suitable for jax.jit with shardings."""

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch, remat=remat)
        return loss, metrics

    def train_step(state, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"], batch)
        params, opt, opt_metrics = apply_updates(state["params"], grads,
                                                 state["opt"], opt_cfg)
        metrics = dict(metrics, **opt_metrics, total_loss=loss)
        return {"params": params, "opt": opt}, metrics

    return train_step


def init_state(model, key):
    params = model.init(key)
    return {"params": params, "opt": init_opt_state(params)}
