"""State and action spaces (paper §4.2, Tables 2-3).

State (Eq. 3): S_tau = {P^E, M^E, B^E, P^C, M^C, B^C, P^S1, M^S1, B^S1, ...}
with Table-3 discretization: end-node P/M/B binary; edge/cloud P has nine
levels, M/B binary.

Action (paper §4.2 + §6.1): edge/cloud always run the most-accurate model
d0; end-nodes choose among l=8 models locally. Per-user action ids:
  0..7  -> execute locally with model d0..d7
  8     -> offload to edge (model d0)
  9     -> offload to cloud (model d0)
The joint action for N users is the base-10 tuple; |A| = 10^N (Table 11's
brute-force space, Eq. 5-6).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Tuple

import numpy as np

N_MODELS = 8
N_PER_USER_ACTIONS = N_MODELS + 2          # 8 local + edge + cloud
A_EDGE, A_CLOUD = 8, 9

EDGE_CPU_LEVELS = 9
CLOUD_CPU_LEVELS = 9


@dataclasses.dataclass(frozen=True)
class SpaceSpec:
    n_users: int

    @property
    def n_joint_actions(self) -> int:
        return N_PER_USER_ACTIONS ** self.n_users

    @property
    def state_dim(self) -> int:
        return 3 * (self.n_users + 2)

    # ---- actions ----
    def encode_action(self, per_user) -> int:
        a = 0
        for u in per_user:
            a = a * N_PER_USER_ACTIONS + int(u)
        return a

    def decode_action(self, a: int) -> Tuple[int, ...]:
        out = []
        for _ in range(self.n_users):
            out.append(a % N_PER_USER_ACTIONS)
            a //= N_PER_USER_ACTIONS
        return tuple(reversed(out))

    def decode_actions_batch(self, actions: np.ndarray) -> np.ndarray:
        """(K,) joint ids -> (K, N) per-user ids."""
        k = actions.shape[0]
        out = np.empty((k, self.n_users), np.int64)
        a = actions.astype(np.int64).copy()
        for i in range(self.n_users - 1, -1, -1):
            out[:, i] = a % N_PER_USER_ACTIONS
            a //= N_PER_USER_ACTIONS
        return out

    def encode_actions_batch(self, per_user: np.ndarray) -> np.ndarray:
        """(K, N) per-user ids -> (K,) joint ids (the vectorized
        ``encode_action``, inverse of ``decode_actions_batch``)."""
        a = np.zeros(np.asarray(per_user).shape[0], np.int64)
        for u in range(self.n_users):
            a = a * N_PER_USER_ACTIONS + np.asarray(per_user)[:, u]
        return a

    def all_actions(self) -> np.ndarray:
        return np.arange(self.n_joint_actions, dtype=np.int64)

    # ---- states ----
    def state_tuple(self, p_e, m_e, b_e, p_c, m_c, b_c, ends) -> tuple:
        """ends: sequence of (p, m, b) binaries per user."""
        flat = [int(p_e), int(m_e), int(b_e), int(p_c), int(m_c), int(b_c)]
        for (p, m, b) in ends:
            flat += [int(p), int(m), int(b)]
        return tuple(flat)

    def state_vector(self, state: tuple) -> np.ndarray:
        """Normalized float encoding for the DQN (CPU levels -> [0,1])."""
        v = np.asarray(state, np.float32).copy()
        v[0] /= EDGE_CPU_LEVELS - 1
        v[3] /= CLOUD_CPU_LEVELS - 1
        return v

    def action_vector(self, a: int) -> np.ndarray:
        """One-hot per-user encoding (N * 10) for the (s,a)->Q network."""
        per_user = self.decode_action(a)
        v = np.zeros((self.n_users, N_PER_USER_ACTIONS), np.float32)
        v[np.arange(self.n_users), list(per_user)] = 1.0
        return v.reshape(-1)

    def action_vectors_batch(self, actions: np.ndarray) -> np.ndarray:
        per_user = self.decode_actions_batch(actions)           # (K, N)
        k = actions.shape[0]
        v = np.zeros((k, self.n_users, N_PER_USER_ACTIONS), np.float32)
        v[np.arange(k)[:, None], np.arange(self.n_users)[None, :], per_user] = 1.0
        return v.reshape(k, -1)


def allowed_per_user(spec: SpaceSpec, actions) -> np.ndarray:
    """(n_users, N_PER_USER_ACTIONS) bool mask of the per-user action ids
    that appear in a joint candidate set — the factored DQN's action mask
    (shared by ``core.dqn`` and ``repro.fleet.policy``)."""
    pu = spec.decode_actions_batch(np.asarray(actions, np.int64))
    mask = np.zeros((spec.n_users, N_PER_USER_ACTIONS), bool)
    for u in range(spec.n_users):
        mask[u, np.unique(pu[:, u])] = True
    return mask


def restricted_actions(spec: SpaceSpec) -> np.ndarray:
    """SOTA [36] baseline action set: computation offloading only, always
    the most-accurate model -> per-user {local d0, edge, cloud} = 3^N."""
    per = [0, A_EDGE, A_CLOUD]
    joint = []
    for combo in itertools.product(per, repeat=spec.n_users):
        joint.append(spec.encode_action(combo))
    return np.asarray(joint, np.int64)
