"""FIFO experience replay (paper Algorithm 2, §5.4: capacity 1000,
mini-batch 64).

This is the host-side (numpy) buffer used by the scalar ``DQNAgent``'s
Python training loop; its one-at-a-time ``push`` never wraps mid-write,
so it indexes with the bare ``ptr``. The fleet-scale agent keeps its
pooled experience on device instead — see ``repro.fleet.replay`` — and
pushes whole batches, whose wraparound slot arithmetic lives in
``ring_slots`` below (here so both ring layouts are defined in one
module).
"""
from __future__ import annotations

import numpy as np


def ring_slots(ptr, n, capacity, xp=np):
    """The ``n`` ring-buffer slots written by a push starting at ``ptr``
    (wraps modulo ``capacity``). ``xp`` selects numpy vs jax.numpy so the
    host and on-device buffers index identically."""
    return (ptr + xp.arange(n)) % capacity


class ReplayBuffer:
    def __init__(self, capacity: int, state_dim: int, seed: int = 0):
        self.capacity = capacity
        self.s = np.zeros((capacity, state_dim), np.float32)
        self.a = np.zeros((capacity,), np.int64)
        self.r = np.zeros((capacity,), np.float32)
        self.s2 = np.zeros((capacity, state_dim), np.float32)
        self.ptr = 0
        self.full = False
        self.rng = np.random.default_rng(seed)

    def __len__(self):
        return self.capacity if self.full else self.ptr

    def push(self, s, a, r, s2):
        i = self.ptr
        self.s[i], self.a[i], self.r[i], self.s2[i] = s, a, r, s2
        self.ptr = (self.ptr + 1) % self.capacity
        self.full = self.full or self.ptr == 0

    def sample(self, batch: int):
        n = len(self)
        if n == 0:
            raise ValueError(
                "cannot sample from an empty ReplayBuffer: push at least "
                "one transition before calling sample()")
        idx = self.rng.integers(0, n, size=batch)
        return self.s[idx], self.a[idx], self.r[idx], self.s2[idx]
