"""Deep Q-Learning agent with experience replay (paper Algorithm 2).

Two network forms, both pure JAX (jit + grad; optimizer = repro AdamW):

* ``form='paper'`` — the paper's architecture: the network takes
  (state, action) as INPUT and emits a scalar Q ("DQN inputs include
  current state and possible action, and outputs the corresponding
  Q-value"). Action selection vmaps the net over candidate joint actions.
  Faithful but O(10^N) per argmax — used for N<=3 (as the paper's own
  Table 7 starts DQL at 3 users).
* ``form='factored'`` — beyond-paper fast variant (documented in
  EXPERIMENTS.md): the net maps state -> per-user action values (N x 10)
  and the joint Q is their sum (VDN-style decomposition). Argmax and the
  replay-target max are O(N*10), making 4-5-user training tractable on
  this host. Fidelity tests compare both forms on small N.

Hidden sizes follow paper §5.4: two fully-connected layers with 48/64/128
units for 3/4/5 users; replay capacity 1000, mini-batch 64, eps-greedy
with eps0=1 and per-N decay (Table 7).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.networks import make_factored_q, mlp_apply, mlp_init
from repro.core.replay import ReplayBuffer
from repro.core.spaces import (N_PER_USER_ACTIONS, SpaceSpec,
                               allowed_per_user)
from repro.training.optimizer import (apply_updates, constant_lr_adamw,
                                      init_opt_state)

PAPER_HIDDEN = {1: 32, 2: 32, 3: 48, 4: 64, 5: 128}
PAPER_EPS_DECAY = {3: 0.4, 4: 0.7, 5: 0.9}    # Table 7 (per 1000 steps here)


@dataclasses.dataclass
class DQNConfig:
    lr: float = 1e-3                  # paper Table 7
    gamma: float = 0.1
    eps_start: float = 1.0
    eps_decay_per_1k: Optional[float] = None   # None -> Table 7
    eps_min: float = 0.02
    replay_capacity: int = 1000       # paper §5.4
    batch_size: int = 64              # paper §5.4
    hidden: Optional[int] = None      # None -> paper §5.4 by n_users
    train_every: int = 1
    form: str = "paper"               # 'paper' | 'factored'


# MLP pieces live in core.networks (shared with repro.fleet.policy).
_mlp_init, _mlp_apply = mlp_init, mlp_apply


class DQNAgent:
    def __init__(self, spec: SpaceSpec, cfg: Optional[DQNConfig] = None,
                 actions: Optional[np.ndarray] = None, seed: int = 0,
                 accuracy_threshold: Optional[float] = None):
        """accuracy_threshold: the QoS goal (paper Fig. 4) — when given,
        the factored form's greedy pass enumerates per-user top-k combos
        and filters by the (known) model-accuracy table, restoring the
        global constraint the sum decomposition cannot represent."""
        self.accuracy_threshold = accuracy_threshold
        self.spec = spec
        self.cfg = cfg or DQNConfig()
        if self.cfg.eps_decay_per_1k is None:
            d = PAPER_EPS_DECAY.get(spec.n_users, 0.9)
            self.cfg = dataclasses.replace(self.cfg, eps_decay_per_1k=d)
        if self.cfg.hidden is None:
            self.cfg = dataclasses.replace(
                self.cfg, hidden=PAPER_HIDDEN.get(spec.n_users, 128))
        self.actions = (spec.all_actions() if actions is None
                        else np.asarray(actions))
        self.rng = np.random.default_rng(seed)
        self.eps = self.cfg.eps_start
        self.steps = 0
        self.buffer = ReplayBuffer(self.cfg.replay_capacity, spec.state_dim,
                                   seed=seed)
        h = self.cfg.hidden
        key = jax.random.PRNGKey(seed)
        if self.cfg.form == "paper":
            in_dim = spec.state_dim + spec.n_users * N_PER_USER_ACTIONS
            self.params = _mlp_init(key, [in_dim, h, h, 1])
            self._avecs = jnp.asarray(self.spec.action_vectors_batch(self.actions))
        else:
            out = spec.n_users * N_PER_USER_ACTIONS
            self.params = _mlp_init(key, [spec.state_dim, h, h, out])
            self._avecs = None
            # per-user local action ids implied by self.actions:
            self._allowed = allowed_per_user(spec, self.actions)
        self.opt_cfg = constant_lr_adamw(self.cfg.lr)
        self.opt = init_opt_state(self.params)
        self._build_fns()

    # ------------------------------------------------------------------
    def _build_fns(self):
        form = self.cfg.form
        gamma = self.cfg.gamma
        n = self.spec.n_users

        opt_cfg = self.opt_cfg

        if form == "paper":
            def q_all(params, svec, avecs):
                """Q(s, a) for all candidate actions: (K,)"""
                inp = jnp.concatenate(
                    [jnp.broadcast_to(svec[None], (avecs.shape[0], svec.shape[0])),
                     avecs], axis=1)
                return _mlp_apply(params, inp)[:, 0]

            def loss_fn(params, s, avec, r, s2, avecs):
                q = _mlp_apply(params, jnp.concatenate([s, avec], 1))[:, 0]
                q2 = jax.vmap(lambda sv: q_all(params, sv, avecs).max())(s2)
                target = r + gamma * jax.lax.stop_gradient(q2)
                return jnp.mean((q - target) ** 2)

            def train(params, opt, s, avec, r, s2, avecs):
                loss, grads = jax.value_and_grad(loss_fn)(params, s, avec, r,
                                                          s2, avecs)
                params, opt, _ = apply_updates(params, grads, opt, opt_cfg)
                return params, opt, loss

            self._q_all = jax.jit(q_all)
            self._train = jax.jit(train)
        else:
            per_user_q = make_factored_q(n, self._allowed)

            def loss_fn(params, s, aidx, r, s2):
                q = per_user_q(params, s)                       # (B,N,NA)
                qa = jnp.take_along_axis(q, aidx[..., None], 2)[..., 0].sum(1)
                q2 = per_user_q(params, s2).max(-1).sum(-1)
                target = r + gamma * jax.lax.stop_gradient(q2)
                return jnp.mean((qa - target) ** 2)

            def train(params, opt, s, aidx, r, s2):
                loss, grads = jax.value_and_grad(loss_fn)(params, s, aidx, r,
                                                          s2)
                params, opt, _ = apply_updates(params, grads, opt, opt_cfg)
                return params, opt, loss

            self._per_user_q = jax.jit(per_user_q)
            self._train = jax.jit(train)

    # ------------------------------------------------------------------
    def greedy_action(self, state: tuple) -> int:
        svec = self.spec.state_vector(state)
        if self.cfg.form == "paper":
            q = self._q_all(self.params, jnp.asarray(svec), self._avecs)
            return int(self.actions[int(np.argmax(np.asarray(q)))])
        q = np.asarray(self._per_user_q(self.params, jnp.asarray(svec[None])))[0]
        if self.accuracy_threshold is None:
            return self.spec.encode_action(q.argmax(-1))
        # constraint-aware greedy: per-user top-k -> feasible combos by the
        # known model-accuracy table (the agent's QoS-goal knowledge).
        from repro.core.env import TOP5
        from repro.core.spaces import A_EDGE
        from repro.fleet.dynamics import feasible
        k = min(4, q.shape[-1])
        topk = np.argsort(q, axis=-1)[:, ::-1][:, :k]           # (N, k)
        import itertools
        best, best_q = None, -np.inf
        th = self.accuracy_threshold
        for combo in itertools.product(range(k), repeat=self.spec.n_users):
            per = topk[np.arange(self.spec.n_users), list(combo)]
            acc = TOP5[np.where(per < A_EDGE, per, 0)].mean()
            if not feasible(acc, th):
                continue
            qs = q[np.arange(self.spec.n_users), per].sum()
            if qs > best_q:
                best_q, best = qs, per
        if best is None:
            best = q.argmax(-1)
        return self.spec.encode_action(best)

    def act(self, state: tuple) -> int:
        if self.rng.random() < self.eps:
            return int(self.actions[self.rng.integers(len(self.actions))])
        return self.greedy_action(state)

    def update(self, state, action: int, reward: float, next_state):
        svec = self.spec.state_vector(state)
        s2vec = self.spec.state_vector(next_state)
        self.buffer.push(svec, action, reward, s2vec)
        self.steps += 1
        # eps decay: Table 7 value applied per 1000 invocations
        if self.steps % 1000 == 0:
            self.eps = max(self.cfg.eps_min,
                           self.eps * (1.0 - self.cfg.eps_decay_per_1k))
        if len(self.buffer) < self.cfg.batch_size:
            return None
        if self.steps % self.cfg.train_every:
            return None
        s, a, r, s2 = self.buffer.sample(self.cfg.batch_size)
        if self.cfg.form == "paper":
            avec = jnp.asarray(self.spec.action_vectors_batch(a))
            self.params, self.opt, loss = self._train(
                self.params, self.opt, jnp.asarray(s), avec, jnp.asarray(r),
                jnp.asarray(s2), self._avecs)
        else:
            aidx = jnp.asarray(self.spec.decode_actions_batch(a))
            self.params, self.opt, loss = self._train(
                self.params, self.opt, jnp.asarray(s), aidx, jnp.asarray(r),
                jnp.asarray(s2))
        return float(loss)

    # transfer learning (paper Fig. 7)
    def warm_start_from(self, other: "DQNAgent"):
        self.params = jax.tree_util.tree_map(lambda x: x.copy(), other.params)
        self.opt = init_opt_state(self.params)
