"""Intelligent Orchestrator (paper Fig. 2/4): agent <-> environment glue,
training with convergence tracking, and exploitation over real serving
engines.

``train_agent`` reproduces the paper's §6 protocol: train online against
the environment, and every ``check_every`` steps score the *greedy*
policy against the brute-force optimum (the paper's "prediction
accuracy"); convergence = first step where the greedy expected response
is within ``tol`` of optimal and stays there for ``patience`` consecutive
checks (the paper reports 100% prediction accuracy at convergence).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

from repro.core.bruteforce import bruteforce_optimal
from repro.core.env import EndEdgeCloudEnv
from repro.fleet import dynamics


@dataclasses.dataclass
class TrainResult:
    converged_at: Optional[int]
    steps: int
    best_ms: float                 # brute-force optimal expected response
    greedy_ms: float               # final greedy expected response
    greedy_acc: float
    greedy_action: int
    history: List[dict]
    wall_seconds: float

    @property
    def prediction_accuracy(self) -> float:
        """1.0 if the greedy decision matches the brute-force optimum's
        expected response (paper §6.1)."""
        return 1.0 if self.greedy_ms <= self.best_ms * 1.005 + 1e-9 else \
            self.best_ms / max(self.greedy_ms, 1e-9)


def train_agent(agent, env: EndEdgeCloudEnv, max_steps: int,
                check_every: int = 200, tol: float = 0.01,
                patience: int = 3, log_every: int = 0) -> TrainResult:
    actions = getattr(agent, "actions", None)
    best_a, best_ms, _, _ = bruteforce_optimal(env, env.threshold, actions)
    state = env.reset()
    t0 = time.perf_counter()
    history = []
    converged_at = None
    streak = 0
    for step in range(1, max_steps + 1):
        a = agent.act(state)
        nxt, r, info = env.step(a)
        agent.update(state, a, r, nxt)
        state = nxt
        if step % check_every == 0:
            g = agent.greedy_action(state)
            g_ms, g_acc = env.expected_response(g)
            feasible = bool(dynamics.feasible(g_acc, env.threshold))
            ok = feasible and g_ms <= best_ms * (1 + tol)
            streak = streak + 1 if ok else 0
            history.append({"step": step, "greedy_ms": g_ms,
                            "greedy_acc": g_acc, "optimal_ms": best_ms,
                            "eps": agent.eps, "ok": ok})
            if log_every and step % log_every == 0:
                print(f"  step {step:>8d} greedy {g_ms:8.2f} ms "
                      f"(opt {best_ms:8.2f}) eps {agent.eps:.3f}")
            if streak >= patience and converged_at is None:
                converged_at = step - (patience - 1) * check_every
                break
    g = agent.greedy_action(state)
    g_ms, g_acc = env.expected_response(g)
    return TrainResult(converged_at, step, best_ms, g_ms, g_acc, g, history,
                       time.perf_counter() - t0)


class IntelligentOrchestrator:
    """Runtime component (cloud-hosted in the paper): receives the request
    wave, consults the trained agent, and dispatches to serving engines.

    engines: {tier: {variant_id: ServingEngine}} — optional; without
    engines the orchestrator is a pure policy head over the env model.
    """

    TIER_OF_ACTION = {8: "E", 9: "C"}

    def __init__(self, agent, env: EndEdgeCloudEnv,
                 engines: Optional[Dict] = None):
        self.agent = agent
        self.env = env
        self.engines = engines or {}

    def decide(self, state) -> tuple:
        """Greedy orchestration decision for the current state."""
        joint = self.agent.greedy_action(state)
        return self.env.spec.decode_action(joint)

    def dispatch(self, per_user, prompts):
        """Execute decisions on real engines (examples/serve_orchestrated).
        Returns per-user (variant, tier, response_ms)."""
        out = []
        for u, a in enumerate(per_user):
            if a < 8:
                tier, variant = "S", f"d{a}"
            else:
                tier, variant = self.TIER_OF_ACTION[int(a)], "d0"
            eng = self.engines[tier][variant]
            _, wall = eng.generate(prompts[u][None, :], max_new_tokens=4)
            out.append((variant, tier, wall * 1e3))
        return out
