"""Small fully-connected Q-networks shared by the scalar DQN agent
(``core.dqn``, paper Algorithm 2) and the fleet-scale shared-policy DQN
(``repro.fleet.policy``).

Two pieces live here so the two agents can never drift:

* ``mlp_init`` / ``mlp_apply`` — the paper's two-hidden-layer MLP (§5.4)
  as plain pytrees (list of {"w", "b"}), He-initialized, ReLU.
* ``make_factored_q`` — the VDN-style factored head: the network maps a
  state vector to ``n_users x N_PER_USER_ACTIONS`` per-user action
  values and the joint Q is their (masked) sum. Disallowed per-user
  actions are pinned to -1e30 so argmax / max never select them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.spaces import N_PER_USER_ACTIONS


def mlp_init(key, sizes):
    """He-initialized MLP params for layer ``sizes`` (list of widths)."""
    params = []
    for a, b in zip(sizes[:-1], sizes[1:]):
        k1, key = jax.random.split(key)
        params.append({"w": jax.random.normal(k1, (a, b), jnp.float32)
                       * np.sqrt(2.0 / a),
                       "b": jnp.zeros((b,), jnp.float32)})
    return params


def mlp_apply(params, x):
    for i, lyr in enumerate(params):
        x = x @ lyr["w"] + lyr["b"]
        if i < len(params) - 1:
            x = jax.nn.relu(x)
    return x


def make_factored_q(n_users: int, allowed):
    """Factored per-user Q head over an ``(n_users, N_PER_USER_ACTIONS)``
    allowed-action mask. Returns ``per_user_q(params, s)`` mapping
    ``(B, state_dim) -> (B, n_users, N_PER_USER_ACTIONS)`` with
    disallowed entries at -1e30."""
    allowed = jnp.asarray(allowed)

    def per_user_q(params, s):
        q = mlp_apply(params, s).reshape(-1, n_users, N_PER_USER_ACTIONS)
        return jnp.where(allowed[None], q, -1e30)

    return per_user_q
