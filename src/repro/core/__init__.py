from repro.core.env import (EXPERIMENTS, THRESHOLDS, EndEdgeCloudEnv,
                            Scenario)
from repro.core.spaces import SpaceSpec, restricted_actions
from repro.core.qlearning import QLearningAgent, QLearningConfig
from repro.core.dqn import DQNAgent, DQNConfig
from repro.core.bruteforce import bruteforce_complexity, bruteforce_optimal
from repro.core.orchestrator import (IntelligentOrchestrator, TrainResult,
                                     train_agent)
from repro.core.baselines import (fixed_strategy_action,
                                  fixed_strategy_response, make_sota_agent)
from repro.core.transfer import transfer_experiment
