"""Transfer-learning strategy (paper §6.2.1, Fig. 7): train a model with
the Min accuracy threshold from scratch, then initialize agents for other
thresholds from it to cut convergence time (paper: up to 12.5x for QL,
3.3x for DQL)."""
from __future__ import annotations

import copy
import dataclasses
from typing import Callable

from repro.core.env import EndEdgeCloudEnv
from repro.core.orchestrator import TrainResult, train_agent


def transfer_experiment(make_agent: Callable[[], object],
                        make_env: Callable[[float], EndEdgeCloudEnv],
                        source_threshold: float, target_threshold: float,
                        max_steps: int, check_every: int = 200):
    """Returns (scratch: TrainResult, transferred: TrainResult).

    make_agent() must return a fresh agent; make_env(threshold) a fresh
    environment. The source agent trains at ``source_threshold`` (the
    paper uses Min); the transferred agent warm-starts from it before
    training at ``target_threshold``.
    """
    src_agent = make_agent()
    src_env = make_env(source_threshold)
    train_agent(src_agent, src_env, max_steps, check_every=check_every)

    scratch = train_agent(make_agent(), make_env(target_threshold),
                          max_steps, check_every=check_every)

    warm = make_agent()
    warm.warm_start_from(src_agent)
    transferred = train_agent(warm, make_env(target_threshold), max_steps,
                              check_every=check_every)
    return scratch, transferred
