"""Brute-force oracle (paper §4.2.2 Eq. 5-6, Table 11 last column).

Searches the entire joint action space (10^N) against the environment's
noise-free expected model, exactly as the paper's design-time "true
optimal configuration" used to score the agents' prediction accuracy.
Fully vectorized; also used by tests as the optimality reference.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.env import EndEdgeCloudEnv
from repro.fleet.dynamics import feasible as _feasible


def bruteforce_optimal(env: EndEdgeCloudEnv, threshold: float,
                       actions: Optional[np.ndarray] = None):
    """Returns (best_action, best_ms, best_acc, n_evaluated)."""
    actions = env.spec.all_actions() if actions is None else actions
    ms, acc = env.expected_response_batch(actions)
    feasible = _feasible(acc, threshold)
    if not feasible.any():
        raise ValueError("no feasible action for threshold %.2f" % threshold)
    ms_f = np.where(feasible, ms, np.inf)
    i = int(np.argmin(ms_f))
    return int(actions[i]), float(ms[i]), float(acc[i]), len(actions)


def bruteforce_complexity(n_users: int) -> float:
    """Eq. 6: |S| x |A| state-action pairs the naive search visits."""
    l_end = 2 * 2 * 2
    l_up = 9 * 2 * 2
    return (l_end ** n_users) * (l_up ** 2) * (10.0 ** n_users)
