"""Epsilon-greedy tabular Q-Learning agent (paper Algorithm 1).

The Q-table is lazily materialized: rows (one per *visited* state) are
allocated on first visit — the full Table-3 state space (42M states for
N=5) is never built, matching how the paper's runtime agent behaves.
SARSA-style update exactly as Algorithm 1 lines 11-13:

  Q(S,A) <- Q(S,A) + alpha [R + gamma Q(S', argmax_a Q(S',a)) - Q(S,A)]

Hyper-parameters default to the paper's Table 7 (alpha=0.9, per-N epsilon
decay). The agent supports a restricted action set (the SOTA [36]
CO-only baseline uses {local-d0, edge, cloud}^N).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.core.spaces import SpaceSpec

# paper Table 7: per-user-count epsilon decay for Q-Learning
PAPER_EPS_DECAY = {1: 1e-1, 2: 1e-2, 3: 1e-2, 4: 1e-3, 5: 1e-4}


@dataclasses.dataclass
class QLearningConfig:
    alpha: float = 0.9               # paper Table 7
    gamma: float = 0.1               # paper §5.4: low discount converges best
    eps_start: float = 1.0
    eps_decay: Optional[float] = None  # None -> paper Table 7 by n_users
    eps_min: float = 0.01


class QLearningAgent:
    def __init__(self, spec: SpaceSpec, cfg: Optional[QLearningConfig] = None,
                 actions: Optional[np.ndarray] = None, seed: int = 0):
        self.spec = spec
        self.cfg = cfg or QLearningConfig()
        if self.cfg.eps_decay is None:
            decay = PAPER_EPS_DECAY.get(spec.n_users, 1e-4)
            self.cfg = dataclasses.replace(self.cfg, eps_decay=decay)
        self.actions = (spec.all_actions() if actions is None
                        else np.asarray(actions))
        self.n_actions = len(self.actions)
        self._aidx = {int(a): i for i, a in enumerate(self.actions)}
        self.q: Dict[tuple, np.ndarray] = {}
        self.eps = self.cfg.eps_start
        self.rng = np.random.default_rng(seed)
        self.steps = 0

    # ------------------------------------------------------------------
    def _row(self, state: tuple) -> np.ndarray:
        row = self.q.get(state)
        if row is None:
            row = np.zeros(self.n_actions, np.float32)
            self.q[state] = row
        return row

    def greedy_action(self, state: tuple) -> int:
        return int(self.actions[int(np.argmax(self._row(state)))])

    def act(self, state: tuple) -> int:
        if self.rng.random() < self.eps:
            return int(self.actions[self.rng.integers(self.n_actions)])
        return self.greedy_action(state)

    def update(self, state, action: int, reward: float, next_state):
        row = self._row(state)
        nxt = self._row(next_state)
        i = self._aidx[int(action)]
        td = reward + self.cfg.gamma * float(nxt.max()) - row[i]
        row[i] += self.cfg.alpha * td
        self.steps += 1
        # multiplicative decay per invocation (paper: "decay the exploration
        # by epsilon decay parameter per agent invocation")
        self.eps = max(self.cfg.eps_min, self.eps * (1.0 - self.cfg.eps_decay))

    # transfer learning (paper Fig. 7): warm-start from another agent
    def warm_start_from(self, other: "QLearningAgent"):
        for s, row in other.q.items():
            self.q[s] = row.copy()

    @property
    def table_entries(self) -> int:
        return len(self.q) * self.n_actions
