"""Baselines (paper §6.1): fixed strategies and the SOTA [36] CO-only
RL agent (offloading decisions only, always the most-accurate model)."""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.env import EndEdgeCloudEnv
from repro.core.qlearning import QLearningAgent, QLearningConfig
from repro.core.dqn import DQNAgent, DQNConfig
from repro.core.spaces import A_CLOUD, A_EDGE, SpaceSpec, restricted_actions


def fixed_strategy_action(spec: SpaceSpec, strategy: str) -> int:
    """'device' | 'edge' | 'cloud' — all users, most-accurate model d0."""
    per = {"device": 0, "edge": A_EDGE, "cloud": A_CLOUD}[strategy]
    return spec.encode_action([per] * spec.n_users)


def fixed_strategy_response(env: EndEdgeCloudEnv, strategy: str):
    a = fixed_strategy_action(env.spec, strategy)
    return env.expected_response(a)


def make_sota_agent(spec: SpaceSpec, *, algo: str = "q", seed: int = 0,
                    cfg=None):
    """SOTA [36]: same learner, action space restricted to computation
    offloading with d0 (3^N joint actions)."""
    acts = restricted_actions(spec)
    if algo == "q":
        return QLearningAgent(spec, cfg or QLearningConfig(), actions=acts,
                              seed=seed)
    return DQNAgent(spec, cfg or DQNConfig(form="factored"), actions=acts,
                    seed=seed)
