"""Multi-user end-edge-cloud environment (paper §3, §5).

A discrete-event latency model of the paper's AWS testbed (five
a1.medium ARM end-nodes, one a1.large edge, one a1.xlarge cloud; Table
6), calibrated against the paper's published anchors:

  anchor                                    paper      this model
  d0 local, fixed device strategy          459 ms      459
  cloud offload, 1 user (Table 8 Exp-A)    363.5 ms    364
  edge-only @ 5 users (Fig. 5)            ~1140 ms    ~1195
  cloud-only @ 5 users (Fig. 5)           ~665 ms     ~734
  all-d7-local (Table 9 Exp-A Min)         72.1 ms     72
  85% threshold mix (Table 9 Exp-A)        143.8 ms    ~144
  89% threshold mix (Table 9 Exp-A)        269.8 ms    ~270
  orchestration round trip (Table 12)      21.4/141    21.4/141

Response time of user i running model d at tier j (DESIGN.md §5):
  T = T_orch(B_i) + up_j(d) + T_comp1(d, j) * cpu_factor(n_j, c_j)
with shared-link and processor-sharing contention. Compute cost is
affine in the model's MACs with separate fp32/int8 slopes fitted to
Table 9 (see `_fit` comment); edge/cloud are 2x/4x the device (vCPU
ratio, Table 6).

The environment also exposes ``expected_response`` (noise-free, fixed
nominal state) used by the brute-force oracle.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.configs.edge_ladder import MOBILENET_TABLE4
from repro.core.spaces import (A_CLOUD, A_EDGE, CLOUD_CPU_LEVELS,
                               EDGE_CPU_LEVELS, N_PER_USER_ACTIONS, SpaceSpec)

# ---- model ladder metadata (paper Table 4) --------------------------------
MACS = np.array([m for _, m, _, _, _ in MOBILENET_TABLE4], np.float64)
IS_INT8 = np.array([dt == "int8" for _, _, dt, _, _ in MOBILENET_TABLE4])
TOP5 = np.array([t5 for _, _, _, _, t5 in MOBILENET_TABLE4], np.float64)
TOP1 = np.array([t1 for _, _, _, t1, _ in MOBILENET_TABLE4], np.float64)

# ---- calibrated constants (ms) --------------------------------------------
# _fit: device fp32 affine from (d0=459, 85%-row d2=158.4) -> a=50.8 b=0.7175
#       device int8 affine from (Min row d7=50.7, 89%-row d4=223) -> a=37.3 b=0.326
A_FP32, B_FP32 = 50.8, 0.7175          # ms, ms/MMAC
A_INT8, B_INT8 = 37.3, 0.326
TIER_SPEED = {"S": 1.0, "E": 2.0, "C": 4.0}   # vCPUs 1/2/4 (Table 6)
TIER_CORES = {"E": 2.0, "C": 4.0}
T_ORCH = {0: 21.4, 1: 141.0}           # B regular/weak (Table 12 totals)
T_UP_EDGE = {0: 120.0, 1: 280.0}       # image upload device->edge
T_HOP_CLOUD = {0: 108.0, 1: 230.0}     # edge->cloud hop
EDGE_LINK_CAP = 1.3
CLOUD_LINK_CAP = 2.4
MEM_BUSY_PENALTY = 1.15
MAX_RESPONSE_MS = 2500.0               # reward floor (constraint violation)


def t_comp_device(model_id) -> np.ndarray:
    m = np.asarray(model_id)
    macs, int8 = MACS[m], IS_INT8[m]
    return np.where(int8, A_INT8 + B_INT8 * macs, A_FP32 + B_FP32 * macs)


@dataclasses.dataclass
class Scenario:
    """Network-condition scenario (paper Table 5): 0=Regular, 1=Weak."""
    name: str
    end_b: Tuple[int, ...]            # per end-node
    edge_b: int

    @staticmethod
    def from_string(name: str, pattern: str):
        """pattern like 'RWRWR|W' (5 end-nodes | edge)."""
        ends, edge = pattern.split("|")
        conv = {"R": 0, "W": 1}
        return Scenario(name, tuple(conv[c] for c in ends), conv[edge])


# paper Table 5
EXPERIMENTS = {
    "EXP-A": Scenario.from_string("EXP-A", "RRRRR|R"),
    "EXP-B": Scenario.from_string("EXP-B", "RWRWR|W"),
    "EXP-C": Scenario.from_string("EXP-C", "WWWRR|R"),
    "EXP-D": Scenario.from_string("EXP-D", "WWWWW|W"),
}

# paper §6.1.1 accuracy thresholds (Top-5 averages)
THRESHOLDS = {"Min": 0.0, "80%": 80.0, "85%": 85.0, "89%": 89.0, "Max": 89.9}


class EndEdgeCloudEnv:
    """Gym-style multi-user orchestration environment."""

    def __init__(self, n_users: int, scenario: Scenario = None,
                 accuracy_threshold: float = 0.0, seed: int = 0,
                 noise: float = 0.02, exogenous: bool = False):
        self.spec = SpaceSpec(n_users)
        self.n = n_users
        self.scenario = scenario or EXPERIMENTS["EXP-A"]
        if len(self.scenario.end_b) < n_users:
            raise ValueError("scenario must cover all users")
        self.threshold = accuracy_threshold
        self.rng = np.random.default_rng(seed)
        self.noise = noise
        self.exogenous = exogenous
        self._last_counts = (0, 0)      # jobs at (edge, cloud) last step
        self._bg = np.zeros(2)          # exogenous background load, AR(1)
        self.reset()

    # ------------------------------------------------------------------
    def _cpu_levels(self):
        ne, nc = self._last_counts
        bg_e, bg_c = self._bg if self.exogenous else (0.0, 0.0)
        p_e = int(np.clip(round(ne / self.n * (EDGE_CPU_LEVELS - 1) + bg_e),
                          0, EDGE_CPU_LEVELS - 1))
        p_c = int(np.clip(round(nc / self.n * (CLOUD_CPU_LEVELS - 1) + bg_c),
                          0, CLOUD_CPU_LEVELS - 1))
        return p_e, p_c

    def _observe(self) -> tuple:
        p_e, p_c = self._cpu_levels()
        m_e = int(self._last_counts[0] > 2)
        m_c = int(self._last_counts[1] > 3)
        ends = [(0, 0, self.scenario.end_b[i]) for i in range(self.n)]
        return self.spec.state_tuple(p_e, m_e, self.scenario.edge_b,
                                     p_c, m_c, self.scenario.edge_b, ends)

    def reset(self) -> tuple:
        self._last_counts = (0, 0)
        self._bg = np.zeros(2)
        return self._observe()

    # ------------------------------------------------------------------
    def response_times(self, per_user: Sequence[int], *, noisy: bool = True,
                       counts: Optional[Tuple[int, int]] = None):
        """Vector of response times (ms) for a joint decision."""
        per_user = np.asarray(per_user)
        local = per_user < A_EDGE
        at_edge = per_user == A_EDGE
        at_cloud = per_user == A_CLOUD
        n_e = int(at_edge.sum()) if counts is None else counts[0]
        n_c = int(at_cloud.sum()) if counts is None else counts[1]

        b_i = np.asarray(self.scenario.end_b[: self.n])
        b_e = self.scenario.edge_b

        t = np.array([T_ORCH[b] for b in b_i])
        # local compute: chosen model at device speed
        model = np.where(local, per_user, 0)
        t_dev = t_comp_device(model)
        t = t + np.where(local, t_dev, 0.0)
        # edge: upload (shared link) + d0 at edge speed (processor sharing)
        up_e = np.array([T_UP_EDGE[b] for b in b_i])
        cpu_e = max(1.0, n_e / TIER_CORES["E"])
        link_e = max(1.0, n_e / EDGE_LINK_CAP)
        t_e = up_e * link_e + (t_comp_device(0) / TIER_SPEED["E"]) * cpu_e
        mem_e = MEM_BUSY_PENALTY if n_e > 2 else 1.0
        t = t + np.where(at_edge, t_e, 0.0) + np.where(
            at_edge, (t_comp_device(0) / TIER_SPEED["E"]) * cpu_e * (mem_e - 1.0), 0.0)
        # cloud: upload + hop (shared) + d0 at cloud speed
        cpu_c = max(1.0, n_c / TIER_CORES["C"])
        link_c = max(1.0, n_c / CLOUD_LINK_CAP)
        mem_c = MEM_BUSY_PENALTY if n_c > 3 else 1.0
        t_c = (up_e * link_c + T_HOP_CLOUD[b_e] * link_c
               + (t_comp_device(0) / TIER_SPEED["C"]) * cpu_c * mem_c)
        t = t + np.where(at_cloud, t_c, 0.0)
        if noisy and self.noise:
            t = t * self.rng.normal(1.0, self.noise, t.shape).clip(0.8, 1.2)
        return t

    def accuracies(self, per_user) -> np.ndarray:
        per_user = np.asarray(per_user)
        model = np.where(per_user < A_EDGE, per_user, 0)
        return TOP5[model]

    def expected_response(self, joint_action: int) -> Tuple[float, float]:
        """(mean response ms, mean top-5 accuracy), noise-free."""
        per_user = self.spec.decode_action(joint_action)
        t = self.response_times(per_user, noisy=False)
        return float(t.mean()), float(self.accuracies(per_user).mean())

    def expected_response_batch(self, actions: np.ndarray):
        """Vectorized (K,) joint actions -> (mean_ms (K,), mean_acc (K,))."""
        pu = self.spec.decode_actions_batch(actions)            # (K, N)
        local = pu < A_EDGE
        n_e = (pu == A_EDGE).sum(1)
        n_c = (pu == A_CLOUD).sum(1)
        b_i = np.asarray(self.scenario.end_b[: self.n])
        b_e = self.scenario.edge_b
        t = np.array([T_ORCH[b] for b in b_i])[None, :].repeat(len(pu), 0)
        t = t + np.where(local, t_comp_device(np.where(local, pu, 0)), 0.0)
        up_e = np.array([T_UP_EDGE[b] for b in b_i])[None, :]
        cpu_e = np.maximum(1.0, n_e / TIER_CORES["E"])[:, None]
        link_e = np.maximum(1.0, n_e / EDGE_LINK_CAP)[:, None]
        mem_e = np.where(n_e > 2, MEM_BUSY_PENALTY, 1.0)[:, None]
        t_e = up_e * link_e + (t_comp_device(0) / TIER_SPEED["E"]) * cpu_e * mem_e
        t = t + np.where(pu == A_EDGE, t_e, 0.0)
        cpu_c = np.maximum(1.0, n_c / TIER_CORES["C"])[:, None]
        link_c = np.maximum(1.0, n_c / CLOUD_LINK_CAP)[:, None]
        mem_c = np.where(n_c > 3, MEM_BUSY_PENALTY, 1.0)[:, None]
        t_c = (up_e * link_c + T_HOP_CLOUD[b_e] * link_c
               + (t_comp_device(0) / TIER_SPEED["C"]) * cpu_c * mem_c)
        t = t + np.where(pu == A_CLOUD, t_c, 0.0)
        acc = TOP5[np.where(local, pu, 0)].mean(1)
        return t.mean(1), acc

    # ------------------------------------------------------------------
    def step(self, joint_action: int):
        """Returns (next_state, reward, info). Reward per paper Eq. 4."""
        per_user = self.spec.decode_action(joint_action)
        t = self.response_times(per_user, noisy=True)
        acc = float(self.accuracies(per_user).mean())
        avg = float(t.mean())
        if acc > self.threshold or np.isclose(acc, self.threshold):
            reward = -avg
        else:
            reward = -MAX_RESPONSE_MS
        self._last_counts = (int((np.asarray(per_user) == A_EDGE).sum()),
                             int((np.asarray(per_user) == A_CLOUD).sum()))
        if self.exogenous:
            self._bg = 0.9 * self._bg + self.rng.normal(0, 0.5, 2)
        nxt = self._observe()
        info = {"avg_response_ms": avg, "avg_accuracy": acc,
                "violated": acc < self.threshold and not np.isclose(acc, self.threshold),
                "per_user_ms": t, "decision": per_user}
        return nxt, reward / 1000.0, info
