"""Multi-user end-edge-cloud environment (paper §3, §5).

A discrete-event latency model of the paper's AWS testbed (five
a1.medium ARM end-nodes, one a1.large edge, one a1.xlarge cloud; Table
6), calibrated against the paper's published anchors:

  anchor                                    paper      this model
  d0 local, fixed device strategy          459 ms      459
  cloud offload, 1 user (Table 8 Exp-A)    363.5 ms    364
  edge-only @ 5 users (Fig. 5)            ~1140 ms    ~1195
  cloud-only @ 5 users (Fig. 5)           ~665 ms     ~734
  all-d7-local (Table 9 Exp-A Min)         72.1 ms     72
  85% threshold mix (Table 9 Exp-A)        143.8 ms    ~144
  89% threshold mix (Table 9 Exp-A)        269.8 ms    ~270
  orchestration round trip (Table 12)      21.4/141    21.4/141

Response time of user i running model d at tier j (DESIGN.md §5):
  T = T_orch(B_i) + up_j(d) + T_comp1(d, j) * cpu_factor(n_j, c_j)
with shared-link and processor-sharing contention. Compute cost is
affine in the model's MACs with separate fp32/int8 slopes fitted to
Table 9 (see `_fit` comment in fleet/dynamics.py); edge/cloud are 2x/4x
the device (vCPU ratio, Table 6).

The latency/accuracy model itself lives in ``repro.fleet.dynamics`` as a
pure, batch-shaped kernel (one code path for scalar, oracle-batch, and
jitted fleet execution); this class is the stateful single-cell gym view
over it. ``expected_response`` (noise-free, fixed nominal state) is used
by the brute-force oracle.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np

from repro.fleet import dynamics
# re-exported for backward compatibility (benchmarks, agents import these)
from repro.fleet.dynamics import (A_FP32, A_INT8, B_FP32, B_INT8,
                                  CLOUD_LINK_CAP, EDGE_LINK_CAP, EXPERIMENTS,
                                  IS_INT8, MACS, MAX_RESPONSE_MS,
                                  MEM_BUSY_PENALTY, Scenario, T_HOP_CLOUD,
                                  T_ORCH, T_UP_EDGE, TIER_CORES, TIER_SPEED,
                                  TOP1, TOP5, t_comp_device)
from repro.core.spaces import (A_CLOUD, A_EDGE, CLOUD_CPU_LEVELS,
                               EDGE_CPU_LEVELS, SpaceSpec)

__all__ = [
    "EndEdgeCloudEnv", "Scenario", "EXPERIMENTS", "THRESHOLDS",
    "MACS", "IS_INT8", "TOP5", "TOP1", "t_comp_device",
    "A_FP32", "B_FP32", "A_INT8", "B_INT8", "TIER_SPEED", "TIER_CORES",
    "T_ORCH", "T_UP_EDGE", "T_HOP_CLOUD", "EDGE_LINK_CAP", "CLOUD_LINK_CAP",
    "MEM_BUSY_PENALTY", "MAX_RESPONSE_MS",
]


# paper §6.1.1 accuracy thresholds (Top-5 averages)
THRESHOLDS = {"Min": 0.0, "80%": 80.0, "85%": 85.0, "89%": 89.0, "Max": 89.9}


class EndEdgeCloudEnv:
    """Gym-style multi-user orchestration environment."""

    def __init__(self, n_users: int, scenario: Optional[Scenario] = None,
                 accuracy_threshold: float = 0.0, seed: int = 0,
                 noise: float = 0.02, exogenous: bool = False):
        self.spec = SpaceSpec(n_users)
        self.n = n_users
        self.scenario = scenario or EXPERIMENTS["EXP-A"]
        if len(self.scenario.end_b) < n_users:
            raise ValueError("scenario must cover all users")
        self.threshold = accuracy_threshold
        self.rng = np.random.default_rng(seed)
        self.noise = noise
        self.exogenous = exogenous
        self._last_counts = (0, 0)      # jobs at (edge, cloud) last step
        self._bg = np.zeros(2)          # exogenous background load, AR(1)
        self.reset()

    # ------------------------------------------------------------------
    def _cpu_levels(self):
        ne, nc = self._last_counts
        bg_e, bg_c = self._bg if self.exogenous else (0.0, 0.0)
        p_e = int(np.clip(round(ne / self.n * (EDGE_CPU_LEVELS - 1) + bg_e),
                          0, EDGE_CPU_LEVELS - 1))
        p_c = int(np.clip(round(nc / self.n * (CLOUD_CPU_LEVELS - 1) + bg_c),
                          0, CLOUD_CPU_LEVELS - 1))
        return p_e, p_c

    def _observe(self) -> tuple:
        p_e, p_c = self._cpu_levels()
        m_e = int(self._last_counts[0] > dynamics.EDGE_MEM_BUSY_AT)
        m_c = int(self._last_counts[1] > dynamics.CLOUD_MEM_BUSY_AT)
        ends = [(0, 0, self.scenario.end_b[i]) for i in range(self.n)]
        return self.spec.state_tuple(p_e, m_e, self.scenario.edge_b,
                                     p_c, m_c, self.scenario.edge_b, ends)

    def reset(self) -> tuple:
        self._last_counts = (0, 0)
        self._bg = np.zeros(2)
        return self._observe()

    # ------------------------------------------------------------------
    def response_times(self, per_user: Sequence[int], *, noisy: bool = True,
                       counts: Optional[Tuple[int, int]] = None):
        """Vector of response times (ms) for a joint decision. Thin wrapper
        over the shared ``fleet.dynamics.response_times`` kernel."""
        per_user = np.asarray(per_user)
        b_i = np.asarray(self.scenario.end_b[: self.n])
        t = dynamics.response_times(per_user, b_i, self.scenario.edge_b,
                                    counts=counts)
        if noisy and self.noise:
            t = t * self.rng.normal(1.0, self.noise, t.shape).clip(0.8, 1.2)
        return t

    def accuracies(self, per_user) -> np.ndarray:
        return dynamics.accuracies(np.asarray(per_user))

    def expected_response(self, joint_action: int) -> Tuple[float, float]:
        """(mean response ms, mean top-5 accuracy), noise-free."""
        per_user = self.spec.decode_action(joint_action)
        t = self.response_times(per_user, noisy=False)
        return float(t.mean()), float(self.accuracies(per_user).mean())

    def expected_response_batch(self, actions: np.ndarray):
        """Vectorized (K,) joint actions -> (mean_ms (K,), mean_acc (K,)).
        Same kernel as the scalar path, broadcast over the K axis."""
        pu = self.spec.decode_actions_batch(actions)            # (K, N)
        b_i = np.asarray(self.scenario.end_b[: self.n])
        ms, acc = dynamics.expected_response(pu, b_i[None, :],
                                             self.scenario.edge_b)
        return ms, acc

    # ------------------------------------------------------------------
    def step(self, joint_action: int):
        """Returns (next_state, reward, info). Reward per paper Eq. 4."""
        per_user = self.spec.decode_action(joint_action)
        t = self.response_times(per_user, noisy=True)
        acc = float(self.accuracies(per_user).mean())
        avg = float(t.mean())
        ok = bool(dynamics.feasible(acc, self.threshold))
        reward = float(dynamics.reward(avg, acc, self.threshold))
        self._last_counts = (int((np.asarray(per_user) == A_EDGE).sum()),
                             int((np.asarray(per_user) == A_CLOUD).sum()))
        if self.exogenous:
            self._bg = 0.9 * self._bg + self.rng.normal(0, 0.5, 2)
        nxt = self._observe()
        info = {"avg_response_ms": avg, "avg_accuracy": acc,
                "violated": not ok,
                "per_user_ms": t, "decision": per_user}
        return nxt, reward, info
