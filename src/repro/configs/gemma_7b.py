"""Gemma-7B dense decoder [arXiv:2403.08295].

28L, d_model 3072, 16 heads (MHA kv=16, head_dim 256), d_ff 24576
(GeGLU), vocab 256000, tied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b", arch_type="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
    d_ff=24576, vocab_size=256_000,
    mlp_act="geglu", rope_theta=10_000.0, tie_embeddings=True,
    citation="arXiv:2403.08295 (Gemma)",
)
