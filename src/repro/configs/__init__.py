from repro.configs.base import (ARCH_IDS, INPUT_SHAPES, InputShape, ModelConfig,
                                MoEConfig, SSMConfig, get_config, list_archs,
                                reduced, scale_width)
