"""Whisper-medium encoder-decoder [arXiv:2212.04356].

24 encoder + 24 decoder layers, d_model 1024, 16 heads (MHA: kv=16,
head_dim 64), d_ff 4096 (GELU), vocab 51865. The mel-spectrogram + conv
frontend is a STUB: input_specs() supplies (B, 1500, 1024) frame
embeddings consumed by the encoder; the decoder cross-attends. Decoder
uses learned-positional-free RoPE here (adaptation noted in DESIGN.md);
decode_32k exercises a 32768-entry self-cache + 1500-entry cross-cache.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", arch_type="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=51_865,
    n_enc_layers=24, enc_seq=1500,
    mlp_act="gelu", tie_embeddings=False,
    citation="arXiv:2212.04356 (Whisper)",
)
