"""DBRX-132B fine-grained MoE [hf:databricks/dbrx-base].

40L, d_model 6144, 48 heads (GQA kv=8, head_dim 128), per-expert d_ff
10752, 16 experts top-4, vocab 100352. Expert-parallel over the 'model'
mesh axis (one expert per rank on the 16-wide axis).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b", arch_type="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=10752, vocab_size=100_352,
    moe=MoEConfig(n_experts=16, top_k=4),
    mlp_act="swiglu", rope_theta=500_000.0, tie_embeddings=False,
    citation="hf:databricks/dbrx-base",
)
