"""Gemma3-4B dense decoder with 5:1 local:global attention
[hf:google/gemma-3-1b-pt family card, arXiv:2503.19786].

34L, d_model 2560, 8 heads (GQA kv=4, head_dim 256), d_ff 10240,
vocab 262144. Every 6th layer is global full attention; the other five
use a 1024-token sliding window -> long-context (128k+) capable, and the
only *dense* arch we run at long_500k (window caps the KV of 5/6 layers;
global layers shard their 524k KV over the 'data' axis).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b", arch_type="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=10240, vocab_size=262_144,
    attn_pattern="mixed", sliding_window=1024, global_interval=6,
    mlp_act="geglu", rope_theta=1_000_000.0,
    citation="hf:google/gemma-3-1b-pt; arXiv:2503.19786",
)
