"""Hymba-1.5B hybrid-head decoder [arXiv:2411.13676].

32L, d_model 1600, 25 attention heads (GQA kv=5, head_dim 64) running in
PARALLEL with Mamba heads inside every layer (outputs fused by learned
per-path norms + mean); d_ff 5504, vocab 32001, ssm_state 16. Per the
paper, most layers use sliding-window attention; 3 layers (first, middle,
last) are global -> long_500k eligible (hybrid).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", arch_type="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab_size=32_001,
    attn_pattern="mixed", sliding_window=1024, global_layers=(0, 15, 31),
    ssm=SSMConfig(state_dim=16, d_conv=4, expand=2),
    mlp_act="swiglu", rope_theta=10_000.0,
    citation="arXiv:2411.13676 (Hymba)",
)
