"""Granite-3.0 1B-A400M fine-grained MoE
[hf:ibm-granite/granite-3.0-1b-a400m-base].

24L, d_model 1024, 16 heads (GQA kv=8, head_dim 64), per-expert d_ff 512,
32 experts top-8, vocab 49155.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", arch_type="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49_155,
    moe=MoEConfig(n_experts=32, top_k=8),
    mlp_act="swiglu", rope_theta=10_000.0,
    citation="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
