"""Falcon-Mamba-7B pure Mamba-1 SSM [arXiv:2410.05355].

64 Mamba blocks, d_model 4096 (d_inner 8192, state 16, conv 4), no
attention, no separate MLP (d_ff=0), vocab 65024. O(1) decode state ->
long_500k eligible.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", arch_type="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0, head_dim=64,
    d_ff=0, vocab_size=65_024,
    attn_pattern="none",
    ssm=SSMConfig(state_dim=16, d_conv=4, expand=2),
    tie_embeddings=False,
    citation="arXiv:2410.05355 (Falcon-Mamba)",
)
