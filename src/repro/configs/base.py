"""Configuration system: model configs, input shapes, registry.

Every assigned architecture gets one module in this package defining a
``CONFIG`` ModelConfig with the exact dimensions from the assignment
(source paper / model card cited in the module docstring). Reduced
variants (for CPU smoke tests) are derived with :func:`reduced`.
"""
from __future__ import annotations

import dataclasses
import importlib
import math
from dataclasses import dataclass, field, replace
from typing import Optional


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # aux loss weight for load balancing (Switch-style)
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0          # 0 -> ceil(d_model / 16)

    def resolved_dt_rank(self, d_model: int) -> int:
        return self.dt_rank or max(1, math.ceil(d_model / 16))


@dataclass(frozen=True)
class ModelConfig:
    """Architecture-describing config (decoder-transformer centric).

    ``arch_type`` in {dense, moe, ssm, hybrid, vlm, audio}. VLM/audio keep
    the decoder backbone here; the modality frontend is a stub that
    supplies precomputed embeddings via input_specs (see DESIGN.md).
    """
    name: str
    arch_type: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                    # 0 -> d_model // n_heads
    # attention pattern
    attn_pattern: str = "full"           # full | sliding | mixed
    sliding_window: int = 4096
    global_interval: int = 0             # mixed: layer l is global iff (l+1) % interval == 0
    global_layers: tuple = ()            # explicit global-layer ids (hybrid)
    # mixture-of-experts
    moe: Optional[MoEConfig] = None
    # state-space
    ssm: Optional[SSMConfig] = None
    # encoder-decoder (audio)
    n_enc_layers: int = 0
    enc_seq: int = 0                     # encoder frames (whisper: 1500)
    # vlm
    n_img_tokens: int = 0
    # misc
    mlp_act: str = "swiglu"              # swiglu | geglu | gelu
    rope_theta: float = 10000.0
    rms_norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    quant: str = "none"                  # none | int8
    width_mult: float = 1.0
    tie_embeddings: bool = True
    logit_softcap: float = 0.0
    citation: str = ""

    # ---- derived -----------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, 256)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.resolved_head_dim

    @property
    def d_inner(self) -> int:
        return (self.ssm.expand * self.d_model) if self.ssm else 0

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def has_attention(self) -> bool:
        return self.arch_type != "ssm"

    @property
    def has_mlp(self) -> bool:
        return self.d_ff > 0

    def layer_is_global(self, layer_id: int) -> bool:
        """Whether ``layer_id`` uses full (global) attention."""
        if self.attn_pattern == "full":
            return True
        if self.attn_pattern == "sliding":
            return False
        if self.global_layers:
            return layer_id in self.global_layers
        if self.global_interval:
            return (layer_id + 1) % self.global_interval == 0
        return True

    def global_layer_mask(self) -> tuple:
        return tuple(self.layer_is_global(i) for i in range(self.n_layers))

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k: attention-free or (mostly) windowed."""
        if self.arch_type == "ssm":
            return True
        return self.attn_pattern in ("sliding", "mixed")

    def param_count(self) -> int:
        """Analytic parameter count (embedding included once if tied)."""
        d, hd = self.d_model, self.resolved_head_dim
        n_attn = self.n_layers if self.arch_type != "ssm" else 0
        p = 0
        # embeddings (+ lm head if untied)
        p += self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        if self.has_attention and self.arch_type != "ssm":
            per = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            p += n_attn * per
        if self.moe:
            per = d * self.moe.n_experts + self.moe.n_experts * 3 * d * self.d_ff
            p += self.n_layers * per
        elif self.has_mlp:
            n_mats = 3 if self.mlp_act in ("swiglu", "geglu") else 2
            p += self.n_layers * n_mats * d * self.d_ff
        if self.ssm is not None:
            di = self.d_inner
            dtr = self.ssm.resolved_dt_rank(d)
            per = (d * 2 * di                      # in_proj (x, z)
                   + di * self.ssm.d_conv          # conv
                   + di * (dtr + 2 * self.ssm.state_dim)  # x_proj
                   + dtr * di + di                 # dt_proj
                   + di * self.ssm.state_dim + di  # A_log, D
                   + di * d)                       # out_proj
            p += self.n_layers * per
        # norms
        p += self.n_layers * 2 * d + d
        if self.is_encdec:
            # encoder layers: self-attn + mlp; decoder additionally cross-attn
            enc = self.n_enc_layers * (2 * (d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d) // 2
                                       + 2 * d * self.d_ff + 2 * d)
            cross = self.n_layers * (d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d + d)
            p += enc + cross
        return p

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top_k experts)."""
        if not self.moe:
            return self.param_count()
        full = self.param_count()
        expert_p = self.n_layers * self.moe.n_experts * 3 * self.d_model * self.d_ff
        active_expert_p = self.n_layers * self.moe.top_k * 3 * self.d_model * self.d_ff
        return full - expert_p + active_expert_p


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}

ARCH_IDS = [
    "paligemma-3b", "dbrx-132b", "internlm2-20b", "gemma3-4b",
    "whisper-medium", "yi-34b", "granite-moe-1b-a400m", "hymba-1.5b",
    "falcon-mamba-7b", "gemma-7b",
]

_MODULE_FOR = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}
_MODULE_FOR["edge-ladder"] = "edge_ladder"


def get_config(arch_id: str) -> ModelConfig:
    mod_name = _MODULE_FOR.get(arch_id, arch_id.replace("-", "_").replace(".", "_"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def list_archs() -> list:
    return list(ARCH_IDS)


def reduced(cfg: ModelConfig, *, n_layers: int = 2, d_model: int = 256,
            n_heads: int = 4, d_ff: int = 512, vocab: int = 512,
            max_experts: int = 4) -> ModelConfig:
    """Smoke-test variant of the same family: <=2 layers, d_model<=512,
    <=4 experts, preserving arch_type/attention pattern/SSM-ness."""
    kv = max(1, min(cfg.n_kv_heads, n_heads // 2))
    moe = None
    if cfg.moe:
        ne = min(cfg.moe.n_experts, max_experts)
        moe = replace(cfg.moe, n_experts=ne, top_k=min(cfg.moe.top_k, max(1, ne // 2)))
    ssm = cfg.ssm
    upd = dict(
        n_layers=n_layers, d_model=d_model, n_heads=n_heads, n_kv_heads=kv,
        head_dim=d_model // n_heads, d_ff=(d_ff if cfg.d_ff else 0),
        vocab_size=vocab, moe=moe, ssm=ssm, sliding_window=min(cfg.sliding_window, 64),
        global_interval=min(cfg.global_interval, n_layers) if cfg.global_interval else 0,
        global_layers=tuple(g for g in cfg.global_layers if g < n_layers) or ((n_layers - 1,) if cfg.global_layers else ()),
        n_enc_layers=(n_layers if cfg.n_enc_layers else 0),
        enc_seq=(32 if cfg.enc_seq else 0),
        n_img_tokens=(8 if cfg.n_img_tokens else 0),
    )
    return replace(cfg, **upd)


def scale_width(cfg: ModelConfig, width_mult: float, quant: str = "none") -> ModelConfig:
    """Variant-ladder scaling (paper's MobileNet width multiplier analogue):
    shrink d_ff and q/kv width uniformly; quant switches matmul dtype."""
    def rnd(x, m=8):
        return max(m, int(round(x * width_mult / m)) * m)
    nh = max(1, int(round(cfg.n_heads * width_mult)))
    # keep GQA grouping valid: n_kv must divide n_heads
    nkv = max(d for d in range(1, nh + 1)
              if nh % d == 0 and d <= max(1, cfg.n_kv_heads))
    return replace(
        cfg,
        d_ff=rnd(cfg.d_ff) if cfg.d_ff else 0,
        n_heads=nh, n_kv_heads=nkv,
        width_mult=width_mult, quant=quant,
        name=f"{cfg.name}-w{width_mult}{'-int8' if quant == 'int8' else ''}",
    )
