"""PaliGemma-3B language backbone [arXiv:2407.07726].

SigLIP vision tower + projector are STUBS: input_specs() supplies 256
precomputed patch embeddings of shape (B, 256, 2048) that are prepended
to the text sequence (see models/model.py). Backbone = Gemma-2B-style
decoder: 18L, d_model 2048, 8 heads with MQA-style kv=1, head_dim 256,
d_ff 16384 (GeGLU), vocab 257216.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", arch_type="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=257_216,
    n_img_tokens=256, mlp_act="geglu", rope_theta=10_000.0,
    citation="arXiv:2407.07726 (PaliGemma); gemma backbone arXiv:2403.08295",
)
