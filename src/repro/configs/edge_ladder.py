"""The paper's own benchmark family, adapted (DESIGN.md SS2).

The paper serves MobileNetV1 at 8 operating points d0..d7 =
{width 1.0, 0.75, 0.5, 0.25} x {FP32, Int8} (Table 4). Our serving
substrate is a decoder transformer, so the ladder is realized as a small
transformer scaled by the same width multipliers x {bf16, int8}; the
Table-4 MACs and Top-1/Top-5 accuracies are retained as calibrated
metadata driving the orchestration environment (core/env.py).
"""
from repro.configs.base import ModelConfig, scale_width

CONFIG = ModelConfig(
    name="edge-ladder", arch_type="dense",
    n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
    d_ff=1024, vocab_size=8192,
    mlp_act="swiglu",
    citation="MobileNetV1 ladder, arXiv:1704.04861 Table 4 of the paper",
)

# Paper Table 4: (million MACs, dtype, top1, top5) for d0..d7.
MOBILENET_TABLE4 = (
    ("d0", 569, "fp32", 70.9, 89.9), ("d1", 317, "fp32", 68.4, 88.2),
    ("d2", 150, "fp32", 63.3, 84.9), ("d3", 41,  "fp32", 49.8, 74.2),
    ("d4", 569, "int8", 70.1, 88.9), ("d5", 317, "int8", 66.8, 87.0),
    ("d6", 150, "int8", 60.7, 83.2), ("d7", 41,  "int8", 48.0, 72.8),
)

_WIDTH = {569: 1.0, 317: 0.75, 150: 0.5, 41: 0.25}


def ladder():
    """d0..d7 transformer variant configs mirroring Table 4."""
    out = {}
    for did, macs, dt, _t1, _t5 in MOBILENET_TABLE4:
        q = "int8" if dt == "int8" else "none"
        out[did] = scale_width(CONFIG, _WIDTH[macs], quant=q)
    return out
