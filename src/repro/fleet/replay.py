"""On-device experience replay for fleet-scale training.

The scalar agent's ``core.replay.ReplayBuffer`` is host-side numpy: one
``push`` per transition, one ``sample`` per update, each crossing the
host-device boundary. A fleet pushes *cells* transitions per environment
step and trains inside a ``lax.scan`` — the buffer therefore has to be a
pure pytree of device arrays so push/sample can live inside the jitted
step with zero host sync, and donate like the fleet Q-table.

``FleetReplay`` is exactly that: state/action/reward/next-state rows
plus ``ptr``/``full`` as jax scalars. ``replay_push`` writes a whole
``(B, ...)`` batch of transitions at the ring position (wraparound
indices come from ``core.replay.ring_slots``, the single source of the
ring arithmetic), and ``replay_sample`` draws a uniform mini-batch from
the filled prefix. Both are pure functions of (buffer, arrays) -> arrays
— jit, scan, and donation friendly.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.replay import ring_slots


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class FleetReplay:
    """Ring buffer of transitions as a registered pytree.

    s    : (capacity, state_dim) f32   states
    a    : (capacity, *action_shape) i32 actions (per-user ids for fleet)
    r    : (capacity,) f32             rewards
    s2   : (capacity, state_dim) f32   next states
    ptr  : () i32                      next write position
    full : () bool                     True once the ring has wrapped
    """
    s: jnp.ndarray
    a: jnp.ndarray
    r: jnp.ndarray
    s2: jnp.ndarray
    ptr: jnp.ndarray
    full: jnp.ndarray

    def tree_flatten(self):
        return ((self.s, self.a, self.r, self.s2, self.ptr, self.full),
                None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def capacity(self) -> int:
        return self.s.shape[0]

    def __len__(self):
        """Host-side convenience; inside jit use ``replay_size``."""
        return int(replay_size(self))


def replay_init(capacity: int, state_dim: int, action_shape=()) -> FleetReplay:
    """An empty on-device buffer for ``capacity`` transitions."""
    return FleetReplay(
        s=jnp.zeros((capacity, state_dim), jnp.float32),
        a=jnp.zeros((capacity, *action_shape), jnp.int32),
        r=jnp.zeros((capacity,), jnp.float32),
        s2=jnp.zeros((capacity, state_dim), jnp.float32),
        ptr=jnp.int32(0),
        full=jnp.asarray(False))


def replay_size(buf: FleetReplay):
    """Number of valid transitions, as a traced i32 scalar."""
    return jnp.where(buf.full, buf.capacity, buf.ptr).astype(jnp.int32)


def replay_push(buf: FleetReplay, s, a, r, s2) -> FleetReplay:
    """Write a ``(B, ...)`` batch of transitions at the ring position.

    B is a static shape, so the wraparound scatter indices are computed
    with ``ring_slots`` under jit; pushing more rows than the buffer
    holds is a usage error caught at trace time.
    """
    n = s.shape[0]
    if n > buf.capacity:
        raise ValueError(f"pushing {n} transitions into a capacity-"
                         f"{buf.capacity} FleetReplay would self-overwrite")
    idx = ring_slots(buf.ptr, n, buf.capacity, xp=jnp)
    return FleetReplay(
        s=buf.s.at[idx].set(s),
        a=buf.a.at[idx].set(a.astype(buf.a.dtype)),
        r=buf.r.at[idx].set(r),
        s2=buf.s2.at[idx].set(s2),
        ptr=((buf.ptr + n) % buf.capacity).astype(jnp.int32),
        full=buf.full | (buf.ptr + n >= buf.capacity))


def replay_sample(key, buf: FleetReplay, batch: int):
    """Uniform mini-batch (s, a, r, s2) from the filled prefix.

    Sampling an empty buffer is undefined (rows are zeros); callers
    inside a scan push before they sample, so the clamp to >=1 below
    only guards the never-pushed case against an out-of-bounds gather.
    """
    n = jnp.maximum(replay_size(buf), 1)
    idx = jax.random.randint(key, (batch,), 0, n)
    return buf.s[idx], buf.a[idx], buf.r[idx], buf.s2[idx]
