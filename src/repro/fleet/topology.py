"""Multi-edge-cell topologies: shared edge servers, cross-cell
contention, and cloud queueing over the fleet batch.

The paper's contention model (§3, Table 6) stops at one cell: every
edge/cloud compute term scales with the number of co-located offloaders
*inside* that cell, and PR 1's fleet simulator inherited the
assumption — each cell in the ``(cells, users)`` batch owned a private
edge server and a private slice of cloud, so fleet-scale decisions
never interacted. Real end-edge-cloud deployments are topologies (the
regime DeepEdge, arXiv:2110.01863, and Dai et al., arXiv:2011.08442,
target): one edge server fronts several cells, and the cloud queues
across all of them.

This module is the pure, batch-shaped, jit/vmap-safe layer for that:

* ``Topology`` — a registered pytree holding the cell->edge assignment
  (an index vector over ``n_edges``), per-edge capacity tiers, and an
  M/M/c-style cloud queue size.
* ``shared_contention`` — the generalization of ``fleet.dynamics``'
  per-cell contention counts: edge job counts are aggregated across
  ALL cells sharing an edge (one segment-sum over the assignment) and
  divided by that edge's capacity tier; the fleet-wide cloud total
  drives a queueing multiplier (``cloud_load_multiplier``).
* generators — ``identity_topology`` (the isolated-cell reduction),
  ``random_topology``, ``skewed_topology`` (Zipf-weighted hot edges),
  ``hot_edge_topology`` (deterministic hot edge for benchmarks), and
  ``step_edge_failures`` (reroute a failed edge's cells, the scenario
  event behind ``FleetConfig.p_edge_fail``).

Everything plugs into the existing kernel through the ``counts`` /
``cloud_mult`` seam of ``dynamics.response_times``: a 1:1 assignment
with unit capacities and an unbounded cloud queue produces bit-exactly
the same effective counts (integer totals divided by 1.0) and a
multiplier of exactly 1.0, so the topology path reduces to the
isolated-cell path and every existing parity test keeps pinning the
kernel (tested in ``tests/test_topology.py``).

Layering: like ``dynamics``, this module never imports ``repro.core``
or its sibling fleet modules — ``scenarios`` attaches a ``Topology`` to
``FleetScenario`` and ``population`` builds the coupled oracle on top.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.fleet import dynamics

#: saturation ceiling of the M/M/c-style cloud queueing multiplier:
#: 1/(1-rho) diverges as utilization rho -> 1, so the multiplier is
#: clipped to [1, CLOUD_QUEUE_MAX] (rho >= 1 - 1/MAX pins the ceiling).
CLOUD_QUEUE_MAX = 8.0


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Topology:
    """Edge/cloud infrastructure shared by the cells of a fleet.

    cell_edge     : (cells,)   int32  index of the edge server serving
                                      each cell (values in [0, n_edges))
    edge_capacity : (n_edges,) f32    capacity tier of each edge server,
                                      as a multiple of the paper's
                                      a1.large edge (1.0 = Table 6)
    cloud_servers : ()         f32    M/M/c-style cloud queue size in
                                      concurrent jobs; ``inf`` disables
                                      cross-cell cloud queueing
    """
    cell_edge: jnp.ndarray
    edge_capacity: jnp.ndarray
    cloud_servers: jnp.ndarray

    def tree_flatten(self):
        return ((self.cell_edge, self.edge_capacity, self.cloud_servers),
                None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def cells(self) -> int:
        return self.cell_edge.shape[0]

    @property
    def n_edges(self) -> int:
        return self.edge_capacity.shape[0]


# ---------------------------------------------------------------------------
# generators
# ---------------------------------------------------------------------------


def edge_capacities(n_edges: int, capacity_tiers=(1.0,)) -> jnp.ndarray:
    """(n_edges,) capacities cycling deterministically through the tier
    tuple (edge j gets ``capacity_tiers[j % len(capacity_tiers)]``)."""
    t = jnp.asarray(capacity_tiers, jnp.float32)
    return t[jnp.arange(n_edges) % len(capacity_tiers)]


def identity_topology(cells: int, cloud_servers: float = np.inf) -> Topology:
    """The 1:1 reduction: every cell owns a unit-capacity edge and the
    cloud queue is unbounded — bit-exactly the isolated-cell model."""
    return Topology(jnp.arange(cells, dtype=jnp.int32),
                    jnp.ones((cells,), jnp.float32),
                    jnp.float32(cloud_servers))


def shard_blocks(cells: int, n_edges: int, n_shards: int):
    """Validated block sizes ``(cells_per_shard, edges_per_shard)`` of a
    shard-local layout: the first ``cells_per_shard`` cells and the
    first ``edges_per_shard`` edges belong to shard 0, and so on —
    exactly the contiguous blocks ``NamedSharding`` places on each
    device of a 1-D fleet mesh (``repro.fleet.shard``)."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if cells % n_shards or n_edges % n_shards:
        raise ValueError(
            f"shard-local layout needs cells ({cells}) and n_edges "
            f"({n_edges}) divisible by n_shards ({n_shards}) so the "
            "contiguous device blocks line up")
    return cells // n_shards, n_edges // n_shards


def random_topology(key, cells: int, n_edges: int, capacity_tiers=(1.0,),
                    cloud_servers: float = np.inf,
                    shard_local: bool = False,
                    n_shards: Optional[int] = None) -> Topology:
    """Uniform cell->edge assignment.

    ``shard_local=True`` caps the assignment's locality to the device
    blocks of an ``n_shards``-way fleet mesh (default: every local
    device): cells and edges are split into ``n_shards`` contiguous
    equal blocks, and a cell draws its edge uniformly WITHIN its own
    block — so when both arrays are sharded along the fleet axis, no
    edge is ever shared across devices and the per-edge segment-sum
    aggregation stays entirely shard-local
    (``repro.fleet.shard.local_contention``). The unconstrained
    assignment instead couples arbitrary cells, turning the aggregation
    into a cross-shard reduction (the all-to-all path)."""
    if not shard_local:
        ce = jax.random.randint(key, (cells,), 0, n_edges).astype(jnp.int32)
    else:
        if n_shards is None:
            n_shards = jax.device_count()
        cpb, epb = shard_blocks(cells, n_edges, n_shards)
        block = jnp.arange(cells, dtype=jnp.int32) // cpb
        ce = (block * epb
              + jax.random.randint(key, (cells,), 0, epb)).astype(jnp.int32)
    return Topology(ce, edge_capacities(n_edges, capacity_tiers),
                    jnp.float32(cloud_servers))


def is_shard_local(topo: Topology, n_shards: int) -> bool:
    """Host-side check of the shard-locality invariant: every cell's
    edge lies in the cell's own contiguous shard block (no edge spans
    devices when both arrays are sharded along the fleet axis)."""
    cpb, epb = shard_blocks(topo.cells, topo.n_edges, n_shards)
    ce = np.asarray(topo.cell_edge)
    return bool(((np.arange(topo.cells) // cpb) == (ce // epb)).all())


def skewed_topology(key, cells: int, n_edges: int, skew: float = 1.5,
                    capacity_tiers=(1.0,),
                    cloud_servers: float = np.inf) -> Topology:
    """Zipf-weighted assignment: edge j attracts cells with probability
    proportional to ``(j+1)^-skew``, so edge 0 is the hottest. ``skew=0``
    recovers the uniform assignment."""
    w = (1.0 / jnp.arange(1, n_edges + 1, dtype=jnp.float32)) ** skew
    ce = jax.random.choice(key, n_edges, (cells,), p=w / w.sum())
    return Topology(ce.astype(jnp.int32),
                    edge_capacities(n_edges, capacity_tiers),
                    jnp.float32(cloud_servers))


def hot_edge_topology(cells: int, n_edges: int, hot_fraction: float = 0.5,
                      capacity_tiers=(1.0,),
                      cloud_servers: float = np.inf) -> Topology:
    """Deterministic hot edge (benchmark scenario): the first
    ``round(cells * hot_fraction)`` cells all share edge 0, the rest are
    spread round-robin over the remaining edges (over all edges when
    ``n_edges == 1``)."""
    n_hot = int(round(cells * hot_fraction))
    rest = np.arange(cells - n_hot)
    cold = 1 + rest % (n_edges - 1) if n_edges > 1 else rest % n_edges
    ce = np.concatenate([np.zeros(n_hot, np.int32), cold.astype(np.int32)])
    return Topology(jnp.asarray(ce),
                    edge_capacities(n_edges, capacity_tiers),
                    jnp.float32(cloud_servers))


def step_edge_failures(key, topo: Topology, p_fail: float) -> Topology:
    """One edge-failure scenario event: with probability ``p_fail`` a
    uniformly drawn edge fails and each of its cells is rerouted to a
    uniformly drawn *other* edge (a permanent reassignment — the fleet
    does not fail back). Pure and jit/scan-safe; a single-edge topology
    has nowhere to reroute and is returned unchanged."""
    if topo.n_edges <= 1:
        return topo
    k_ev, k_edge, k_re = jax.random.split(key, 3)
    fail = jax.random.bernoulli(k_ev, p_fail)
    edge = jax.random.randint(k_edge, (), 0, topo.n_edges)
    new = jax.random.randint(k_re, topo.cell_edge.shape, 0,
                             topo.n_edges - 1)
    new = (new + (new >= edge)).astype(jnp.int32)   # skip the failed edge
    ce = jnp.where(fail & (topo.cell_edge == edge), new, topo.cell_edge)
    return Topology(ce, topo.edge_capacity, topo.cloud_servers)


# ---------------------------------------------------------------------------
# shared contention
# ---------------------------------------------------------------------------


def _segment_totals(values, segments, n_segments: int, xp):
    """Per-segment sums, generic over numpy/jax.numpy."""
    if xp is np:
        return np.bincount(np.asarray(segments), weights=np.asarray(values),
                           minlength=n_segments)
    return jax.ops.segment_sum(values, segments, num_segments=n_segments)


def cloud_load_multiplier(n_cloud_total, cloud_servers, xp=jnp):
    """M/M/c-style queueing inflation of cloud latency under fleet-wide
    load: utilization ``rho = n_cloud_total / cloud_servers`` maps to
    ``1 / (1 - rho)`` clipped to ``[1, CLOUD_QUEUE_MAX]`` (the mean
    number-in-system inflation of an M/M/1 queue, saturating instead of
    diverging as rho -> 1). ``cloud_servers = inf`` gives exactly 1.0 —
    the isolated-cell reduction."""
    rho = n_cloud_total / cloud_servers
    m = 1.0 / xp.maximum(1.0 - rho, 1.0 / CLOUD_QUEUE_MAX)
    return xp.clip(m, 1.0, CLOUD_QUEUE_MAX)


def shared_contention(per_user, topo: Topology, active=None, xp=jnp):
    """Topology-aware contention terms for a ``(cells, N)`` decision.

    Edge job counts are summed across ALL cells assigned to the same
    edge (one segment-sum over ``topo.cell_edge``) and divided by that
    edge's capacity tier; the per-cell cloud counts keep the paper's
    processor-sharing semantics while their fleet-wide total drives the
    cloud queueing multiplier.

    Returns ``(n_edge_eff (cells,), n_cloud (cells,), cloud_mult ())``,
    shaped to feed the ``counts`` / ``cloud_mult`` seam of
    ``dynamics.response_times``. Under ``identity_topology`` the
    effective counts equal the isolated per-cell counts bit-exactly and
    the multiplier is exactly 1.0.
    """
    per_user = xp.asarray(per_user)
    at_edge = per_user == dynamics.A_EDGE
    at_cloud = per_user == dynamics.A_CLOUD
    if active is not None:
        active = xp.asarray(active)
        at_edge = at_edge & active
        at_cloud = at_cloud & active
    e_cnt = at_edge.sum(-1)
    c_cnt = at_cloud.sum(-1)
    edge_tot = _segment_totals(e_cnt, topo.cell_edge, topo.n_edges, xp)
    cap = xp.asarray(topo.edge_capacity)
    n_e_eff = edge_tot[topo.cell_edge] / cap[topo.cell_edge]
    mult = cloud_load_multiplier(c_cnt.sum(), topo.cloud_servers, xp=xp)
    return n_e_eff, c_cnt, mult


def topology_response_times(per_user, end_b, edge_b, topo: Topology,
                            active=None, calib=None, xp=jnp):
    """Per-user response times (ms) under shared edge/cloud contention —
    the topology-aware analogue of ``dynamics.response_times`` for a
    ``(cells, N)`` fleet decision."""
    n_e, n_c, mult = shared_contention(per_user, topo, active=active, xp=xp)
    return dynamics.response_times(per_user, end_b, edge_b,
                                   counts=(n_e, n_c), active=active,
                                   cloud_mult=mult, calib=calib, xp=xp)


def topology_expected_response(per_user, end_b, edge_b, topo: Topology,
                               active=None, calib=None, xp=jnp):
    """((cells,) mean ms, (cells,) mean accuracy) under shared
    contention — the topology-aware ``dynamics.expected_response``."""
    n_e, n_c, mult = shared_contention(per_user, topo, active=active, xp=xp)
    return dynamics.expected_response(per_user, end_b, edge_b,
                                      active=active, counts=(n_e, n_c),
                                      cloud_mult=mult, calib=calib, xp=xp)


@jax.jit
def fleet_topology_expected_response(per_user, end_b, edge_b,
                                     topo: Topology, active=None,
                                     calib=None):
    """Jitted fleet entry point: one call evaluates every cell of the
    fleet under shared edge/cloud contention."""
    return topology_expected_response(per_user, end_b, edge_b, topo,
                                      active=active, calib=calib, xp=jnp)


def edge_utilization(per_user, topo: Topology, active=None, xp=jnp):
    """(n_edges,) edge jobs per unit of capacity under ``per_user`` —
    the load report ``FleetOrchestrator.route`` attaches to a routing
    decision (1.0 = one job per a1.large-equivalent of capacity)."""
    per_user = xp.asarray(per_user)
    at_edge = per_user == dynamics.A_EDGE
    if active is not None:
        at_edge = at_edge & xp.asarray(active)
    edge_tot = _segment_totals(at_edge.sum(-1), topo.cell_edge,
                               topo.n_edges, xp)
    return edge_tot / xp.asarray(topo.edge_capacity)
