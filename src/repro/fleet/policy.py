"""Fleet-scale shared-policy DQN: one network, pooled experience.

``population.FleetQLearning`` gives every cell its own dense Q-table —
nothing is shared, the table caps out around 10^3 states x actions, and
a cell can only ever learn from its own history. This module is the
other end of the design space (ROADMAP "Fleet-scale DQN"): ONE factored
Q-network (``core.networks.make_factored_q``, the VDN-style per-user
decomposition of ``core.dqn``'s ``form='factored'``) trained on the
pooled experience of the whole fleet.

Three pieces make it fleet-shaped:

* **Featurized state** (``encode_fleet_state``): instead of dense table
  indices, each cell is a vector of per-user request bits, per-user
  membership (the cell-size mask), per-user end-link quality, the edge
  backhaul state, and the previous step's normalized job counts. One
  network therefore serves heterogeneous cell sizes and link patterns it
  never trained on — generalization the per-cell table cannot do.
* **On-device replay** (``fleet.replay.FleetReplay``): every fleet step
  pushes ``cells`` transitions and samples one mini-batch without
  leaving the device, so act + env + TD-update + replay stay inside a
  single ``lax.scan`` with zero host sync (buffers donated like the
  fleet Q-table).
* **Constraint-aware greedy head**: the sum decomposition cannot
  represent the QoS constraint (paper Eq. 4) — a mean-accuracy cliff
  shared across users — so, exactly like ``core.dqn``'s constraint
  greedy, the head enumerates per-user top-k combinations and filters
  them by the *known* Table-4 accuracy ladder, vectorized over the whole
  fleet: ``(cells, topk^N)`` candidates in one jitted pass.

Two design choices measurably unlock cross-size generalization (each
was worth ~15-75% held-out regret in ablation; ``net='cell'`` keeps the
monolithic baseline for comparison):

* **Sum-scaled regression target.** The env reward is the *mean*
  response over active users (paper Eq. 4), so fitting it directly
  forces per-user values onto a 1/n scale that varies with cell size —
  a size-2 cell's values don't transfer to a size-1 cell. The factored
  sum instead regresses on ``n_active * reward`` (the summed response):
  per-user values become size-invariant estimates of each user's own
  -ms contribution, and the per-cell argmax/ranking is unchanged
  (positive per-state scaling). When a QoS goal is set, the regression
  target stays the un-floored delay term: the constraint cliff is not
  representable by a sum of per-user values (it would just corrupt the
  ranking — observed as a ~20% held-out regret plateau), and
  feasibility is enforced exactly by the greedy head instead, which is
  precisely how ``core.dqn``'s constraint-greedy divides the labor. The
  reported ``info["reward"]`` remains the paper's floored reward.
* **Weight-shared per-user encoder** (``net='shared'``, default): one
  MLP maps each user's local view (own request bit, membership, link
  state, plus cell aggregates: edge link, active fraction, job counts,
  weak-link fraction) to that user's action values, vmapped over the
  user axis. The head is permutation-equivariant and size-invariant by
  construction — a fleet trained on 2-3-user cells routes 1-user cells
  it never saw at the brute-force optimum, where the monolithic
  ``net='cell'`` trunk (``core.networks.make_factored_q`` over the flat
  state) overfits the member-pattern bits it trained on.

``FleetDQN`` mirrors ``FleetQLearning``'s API (``step`` / ``run`` /
``train`` / ``greedy_decisions`` / ``policy_decisions``) so
``FleetOrchestrator`` and ``train_against_oracle`` accept either agent.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.networks import make_factored_q, mlp_apply, mlp_init
from repro.core.spaces import (N_PER_USER_ACTIONS, SpaceSpec,
                               allowed_per_user)
from repro.fleet import dynamics
from repro.fleet.population import (FleetTrainResult, adopt_mesh,
                                    check_pad_width, default_actions,
                                    fleet_bruteforce, fleet_metrics,
                                    nominal_expected_response,
                                    place_metrics, resolve_source,
                                    simulate_responses,
                                    train_against_oracle)
from repro.fleet.replay import (replay_init, replay_push, replay_sample,
                                replay_size)
from repro.kernels import ops
from repro.fleet.scenarios import FleetConfig, FleetScenario
from repro.training.optimizer import (apply_updates, constant_lr_adamw,
                                      init_opt_state)


def state_dim(users: int) -> int:
    """Feature width of ``encode_fleet_state``: 3 per-user blocks
    (active, member, end link) + edge link + 2 counts + cell size + 3
    topology features (own-edge shared load, own-edge capacity, fleet
    cloud utilization)."""
    return 3 * users + 7


def _topo_features(counts, scen: FleetScenario):
    """The three (cells, 1) topology features — own-edge shared load,
    own-edge capacity tier, fleet cloud utilization — shared by
    ``encode_fleet_state`` (flat layout) and ``fused_head_features``
    (direct per-user blocks) so the two paths cannot drift."""
    inv = 1.0 / scen.users
    counts_f = counts.astype(jnp.float32)
    if scen.topo is None:
        edge_load = counts_f[:, :1] * inv          # own jobs == shared jobs
        cap = jnp.ones((scen.cells, 1), jnp.float32)
        util = jnp.zeros((scen.cells, 1), jnp.float32)
    else:
        topo = scen.topo
        tot = jax.ops.segment_sum(counts[:, 0], topo.cell_edge,
                                  num_segments=topo.n_edges)
        cap_cell = topo.edge_capacity[topo.cell_edge]
        edge_load = (tot[topo.cell_edge] / cap_cell)[:, None] * inv
        cap = cap_cell[:, None]
        util = jnp.broadcast_to(counts_f[:, 1].sum() / topo.cloud_servers,
                                (scen.cells, 1))
    return edge_load.astype(jnp.float32), cap, util


def encode_fleet_state(counts, scen: FleetScenario) -> jnp.ndarray:
    """(cells, state_dim) feature encoding of the fleet state.

    Layout (N = scen.users):
      [0:N)    per-user request bits (active this step)
      [N:2N)   per-user membership bits (the cell-size mask)
      [2N:3N)  per-user end-link state (0 Regular, 1 Weak)
      [3N]     edge backhaul link state
      [3N+1,2] previous step's (edge, cloud) job counts / N
      [3N+3]   cell size / N
      [3N+4]   own edge's SHARED load: last-step edge jobs summed over
               every cell on this cell's edge, / (N * capacity) — the
               neighbor-pressure signal (== [3N+1] for isolated fleets)
      [3N+5]   own edge's capacity tier (1.0 for isolated fleets)
      [3N+6]   fleet-wide cloud utilization, last-step cloud jobs /
               cloud_servers (0.0 for isolated / unbounded clouds)

    The loss slices the request bits back out of stored states to mask
    per-user terms, so the layout above is load-bearing — keep the
    active block first.
    """
    users = scen.users
    inv = 1.0 / users
    counts_f = counts.astype(jnp.float32)
    edge_load, cap, util = _topo_features(counts, scen)
    return jnp.concatenate([
        scen.active.astype(jnp.float32),
        scen.member.astype(jnp.float32),
        scen.end_b.astype(jnp.float32),
        scen.edge_b[:, None].astype(jnp.float32),
        counts_f * inv,
        scen.member.sum(-1, keepdims=True).astype(jnp.float32) * inv,
        edge_load.astype(jnp.float32),
        cap,
        util,
    ], axis=-1)


#: per-user input width of the shared encoder: [own request bit, own
#: membership, own end-link, edge link, active fraction, edge jobs /N,
#: cloud jobs /N, weak-link fraction among active users, own-edge shared
#: load, own-edge capacity, fleet cloud utilization]
N_USER_FEATURES = 11


def make_shared_per_user_q(users: int, allowed):
    """Weight-shared per-user Q head (``net='shared'``).

    Rebuilds each user's local feature row from the flat
    ``encode_fleet_state`` vector and applies ONE shared MLP
    (``N_USER_FEATURES -> ... -> N_PER_USER_ACTIONS``) to every user —
    permutation-equivariant, so per-user values transfer across cell
    sizes and user orderings the fleet never trained on."""
    allowed = jnp.asarray(allowed)

    def per_user_q(params, s):
        n = users
        act, mem, end = s[:, :n], s[:, n:2 * n], s[:, 2 * n:3 * n]
        cell = s[:, 3 * n:3 * n + 3]               # edge_b, n_e/N, n_c/N
        topo_f = s[:, 3 * n + 4:3 * n + 7]         # shared load, cap, util
        n_act = act.sum(-1, keepdims=True)
        weak = (end * act).sum(-1, keepdims=True) / jnp.maximum(n_act, 1.0)
        agg = jnp.concatenate([cell[:, :1], n_act / n, cell[:, 1:], weak,
                               topo_f], -1)        # (B, 8)
        f = jnp.concatenate(
            [act[..., None], mem[..., None], end[..., None],
             jnp.broadcast_to(agg[:, None, :], (s.shape[0], n, 8))], -1)
        q = mlp_apply(params, f.reshape(-1, N_USER_FEATURES))
        return jnp.where(allowed[None], q.reshape(s.shape[0], n, -1), -1e30)

    return per_user_q


def fused_head_features(counts, scen: FleetScenario):
    """The fused head's inputs — per-user ``(active, member, end_b)``
    blocks plus the (cells, 8) cell-aggregate rows — assembled directly
    from the scenario, skipping the flat ``encode_fleet_state`` vector
    that ``make_shared_per_user_q`` would only re-slice apart. The
    arithmetic is the same op sequence, so the resulting feature rows
    (and the head's Q values) are bit-identical to the legacy path."""
    act = scen.active.astype(jnp.float32)
    end = scen.end_b.astype(jnp.float32)
    inv = 1.0 / scen.users
    counts_f = counts.astype(jnp.float32)
    edge_load, cap, util = _topo_features(counts, scen)
    n_act = act.sum(-1, keepdims=True)
    weak = (end * act).sum(-1, keepdims=True) / jnp.maximum(n_act, 1.0)
    # n_act / users (not * inv): the exact float op the legacy head
    # applies, so the rows stay bit-identical
    agg = jnp.concatenate(
        [scen.edge_b[:, None].astype(jnp.float32), n_act / scen.users,
         counts_f * inv, weak, edge_load, cap, util], -1)  # (cells, 8)
    return act, scen.member.astype(jnp.float32), end, agg


class HoldoutEval(NamedTuple):
    """Result of ``holdout_reward_ratio``: all rewards are negative
    (-expected ms; QoS-infeasible cells floored at -MAX_RESPONSE_MS), so
    ``ratio`` = optimal/achieved reward is 1.0 at the per-cell
    brute-force optimum and < 1 under regret (an untrained policy scores
    ~0.35)."""
    ratio: float              # fraction-of-optimal expected reward
    achieved: np.ndarray      # (cells,) the policy's expected rewards
    optimal: np.ndarray       # (cells,) brute-force expected rewards
    feasible: np.ndarray      # (cells,) bool, greedy meets the QoS goal


def holdout_reward_ratio(agent, scen: FleetScenario,
                         threshold: Optional[float] = None) -> HoldoutEval:
    """Score ``agent``'s cold-start greedy decisions on a (held-out)
    ``scen`` against the per-cell brute-force oracle over the agent's
    candidate set — THE cross-cell generalization metric, shared by the
    acceptance test, ``benchmarks/bench_fleet_dqn.py``, and the
    quickstart example so the floor/feasibility convention can't drift."""
    th = agent.accuracy_threshold if threshold is None else threshold
    expected = getattr(agent, "expected", None)       # FleetPolicy protocol
    g_ms, g_acc = (expected(scen) if expected is not None
                   else agent.greedy_expected(scen=scen))
    feas = np.asarray(dynamics.feasible(g_acc, th))
    opt_ms = np.asarray(fleet_bruteforce(scen, agent.pu_table, th)[0])
    achieved = np.where(feas, -g_ms, -dynamics.MAX_RESPONSE_MS)
    return HoldoutEval(float((-opt_ms).mean() / achieved.mean()),
                       achieved, -opt_ms, feas)


@dataclasses.dataclass
class FleetDQNConfig:
    lr: float = 1e-3                  # paper Table 7
    gamma: float = 0.1
    eps_start: float = 1.0
    eps_decay: float = 2e-3           # multiplicative, per fleet step
    eps_min: float = 0.02
    replay_capacity: int = 65536      # pooled transitions (rows)
    batch_size: int = 256
    hidden: int = 128                 # paper §5.4's widest rung
    noise: float = 0.02
    accuracy_threshold: float = 0.0   # QoS goal (paper Eq. 4)
    topk: int = 5                     # constraint head's per-user top-k
    net: str = "shared"               # 'shared' | 'cell' (see module doc)


class FleetDQN:
    """Shared-policy factored DQN over a fleet of cells.

    One ``step()`` = one environment step for EVERY cell plus one
    mini-batch update from the pooled replay, all inside a single jitted
    call; ``run(n)`` amortizes n of those into one ``lax.scan``.

    ``actions``: optional joint candidate set. Unlike the tabular agent
    the factored head never enumerates joint actions, so by default the
    policy spans the full 10^N space (per-user mask all-allowed) while
    the *oracle* used by ``train()`` still scores against
    ``default_actions`` (full space for N<=3, the SOTA-restricted set
    above — a lower bound on the true optimum there). Passing ``actions``
    restricts both to that candidate set.
    """

    def __init__(self, scen, fleet_cfg: Optional[FleetConfig] = None,
                 cfg: Optional[FleetDQNConfig] = None,
                 actions: Optional[np.ndarray] = None, seed: int = 0,
                 reset_key=None, mesh=None, metrics: bool = True,
                 n_windows: int = 0, window_len: int = 1,
                 impl: str = "pallas"):
        """``scen`` is a ``repro.fleet.api.ScenarioSource`` (reset with
        ``reset_key``, default ``PRNGKey(seed)``) — or, equivalently, a
        ``FleetScenario`` plus its ``FleetConfig`` (wrapped into a
        ``SyntheticSource`` pinned to that scenario).

        ``mesh`` (``repro.fleet.shard.fleet_mesh``; default: the
        source's own mesh, if any) is data-parallel training: params
        and optimizer state REPLICATE across devices, the scenario
        stream shards along the fleet axis, the replay ring splits its
        slot blocks across devices (see ``shard.shard_replay`` — push/
        sample reshard inside the scan), and the mini-batch loss mean
        becomes the partitioner's cross-device gradient reduction.

        ``metrics`` (default on) rides a ``repro.obs`` accumulator in
        the scan carry — per-step reward / response time / loss /
        replay occupancy / epsilon with zero host syncs; read it via
        ``metrics_summary``. Recording consumes no RNG and never feeds
        back into training, so trajectories are bit-identical with it
        on or off — including with ``n_windows > 0``, which adds a
        per-window ring (``window_len`` steps per slot) to every
        stream so ``metrics_summary()`` carries the learning curve.

        ``impl`` selects the encode/act head implementation:
        ``"pallas"`` (default) is the fused featurize + constraint-aware
        greedy head (``kernels.dqn_head``) — per-user feature rows
        assembled directly from the scenario, the shared MLP, the
        allowed-action mask, and the top-k accuracy-ladder filter in one
        fused pass (the compiled Pallas kernel on TPU, the
        bit-equivalent fused-jnp formulation elsewhere; see
        ``kernels.ops.resolve_rl_impl``). ``"xla"`` keeps the legacy
        head; ``"pallas_interpret"`` forces the real kernel in
        interpret mode (parity tests). The fused head exists only for
        the weight-shared ``net='shared'`` encoder — ``net='cell'``
        agents fall back to the legacy head regardless of ``impl``."""
        self.cfg = cfg or FleetDQNConfig()
        scen, self.source = resolve_source(scen, fleet_cfg, seed, reset_key)
        self.fleet_cfg = getattr(self.source, "cfg", None)
        self.mesh, scen = adopt_mesh(mesh, self.source, scen)
        self.spec = SpaceSpec(scen.users)
        users = scen.users
        if actions is None:
            self.allowed = np.ones((users, N_PER_USER_ACTIONS), bool)
            oracle = default_actions(self.spec)
        else:
            oracle = np.asarray(actions)
            self.allowed = allowed_per_user(self.spec, oracle)
        self.pu_table = jnp.asarray(self.spec.decode_actions_batch(oracle))
        self.state_dim = state_dim(users)
        key = jax.random.PRNGKey(seed)
        k_net, self.key = jax.random.split(key)
        h = self.cfg.hidden
        if self.cfg.net == "shared":
            self.params = mlp_init(
                k_net, [N_USER_FEATURES, h, h, N_PER_USER_ACTIONS])
            self._per_user_q = make_shared_per_user_q(users, self.allowed)
        elif self.cfg.net == "cell":
            self.params = mlp_init(
                k_net, [self.state_dim, h, h, users * N_PER_USER_ACTIONS])
            self._per_user_q = make_factored_q(users, self.allowed)
        else:
            raise ValueError(f"unknown net form {self.cfg.net!r} "
                             "(expected 'shared' or 'cell')")
        self.impl = impl
        resolved = ops.resolve_rl_impl(impl, self.mesh)
        if self.cfg.net != "shared":
            resolved = "xla"        # fused head is shared-encoder only
        self._op_impl = resolved
        self._op_kwargs = (None if resolved == "xla"
                           else ops.rl_op_kwargs(resolved))
        self.opt = init_opt_state(self.params)
        self.buffer = replay_init(self.cfg.replay_capacity, self.state_dim,
                                  action_shape=(users,))
        self.scen = scen
        self.counts = jnp.zeros((scen.cells, 2), jnp.int32)
        self.metrics = fleet_metrics(scen.cells, "dqn",
                                     n_windows=n_windows,
                                     window_len=window_len) if metrics \
            else None
        if self.mesh is not None:
            from repro.fleet import shard
            self.params = shard.replicate(self.params, self.mesh)
            self.opt = shard.replicate(self.opt, self.mesh)
            self.buffer = shard.shard_replay(self.buffer, self.mesh)
            self.counts = shard.shard_array(self.counts, self.mesh)
            self.metrics = place_metrics(self.metrics, self.mesh)
        self.eps = self.cfg.eps_start
        self.steps = 0
        # one greedy/act/step closure each, threaded through the jitted
        # entry points so step() and run()'s scan body cannot diverge;
        # donate params/opt/replay (and the metrics accumulator riding
        # with them) so the scan updates them in place
        greedy = self._make_greedy()
        step = self._make_step(self._make_act(greedy))
        don = (0, 1, 2) if self.metrics is None else (0, 1, 2, 3)
        self._step = jax.jit(step, donate_argnums=don)
        self._run = jax.jit(self._make_run(step), static_argnums=(8,),
                            donate_argnums=don)
        self._greedy = jax.jit(greedy)

    @property
    def accuracy_threshold(self) -> float:
        return self.cfg.accuracy_threshold

    # ---------------------------------------------------------- policy ----
    def _make_greedy(self):
        """Vectorized greedy head: (params, counts, scen) -> ((cells, N)
        per-user decisions, (cells,) joint action ids). With a QoS goal
        set, enumerates per-user top-k combos and filters by the known
        accuracy table (constraint-aware, like ``core.dqn``)."""
        if self._op_impl != "xla":
            return self._make_fused_greedy()
        users = self.spec.n_users
        per_user_q = self._per_user_q
        threshold = self.cfg.accuracy_threshold
        k = min(self.cfg.topk, N_PER_USER_ACTIONS)
        powers = jnp.asarray(
            [N_PER_USER_ACTIONS ** (users - 1 - u) for u in range(users)],
            jnp.int32)
        # static (k^N, N) table of per-user top-k index combinations
        combos = jnp.asarray(
            list(itertools.product(range(k), repeat=users)), jnp.int32)
        uidx = jnp.broadcast_to(jnp.arange(users), combos.shape)

        def constrained(q, member):
            vals, idx = jax.lax.top_k(q, k)                # (cells, N, k)
            cand = idx[:, uidx, combos]                    # (cells, K, N)
            cvals = vals[:, uidx, combos]
            acc = dynamics.accuracies(cand, xp=jnp)
            m = member[:, None, :]
            nm = jnp.maximum(member.sum(-1), 1)[:, None]
            macc = jnp.where(member.any(-1)[:, None],
                             (acc * m).sum(-1) / nm, 100.0)
            score = (cvals * m).sum(-1)                    # (cells, K)
            # a user with fewer than k allowed actions gets top-k rows
            # padded with -1e30-masked DISALLOWED ids — their scores are
            # finite, so they must be culled here or the feasibility
            # filter can prefer an action outside the candidate set
            invalid = ((cvals < -1e29) & m).any(-1)
            score = jnp.where(dynamics.feasible(macc, threshold, xp=jnp)
                              & ~invalid, score, -jnp.inf)
            j = score.argmax(-1)
            best = jnp.take_along_axis(cand, j[:, None, None], 1)[:, 0]
            # no feasible combo in the top-k set: plain factored argmax
            return jnp.where(jnp.isfinite(
                jnp.take_along_axis(score, j[:, None], 1))[:, 0][:, None],
                best, q.argmax(-1))

        def greedy(params, counts, scen):
            q = per_user_q(params, encode_fleet_state(counts, scen))
            dec = (constrained(q, scen.member) if threshold
                   else q.argmax(-1)).astype(jnp.int32)
            return dec, (dec * powers[None, :]).sum(-1)

        return greedy

    def _make_fused_greedy(self):
        """The fused encode/act head: one ``kernels.ops.dqn_head`` call
        replaces encode_fleet_state -> per_user_q -> top-k constraint
        filter. Same (dec, joint id) contract as the legacy greedy."""
        users = self.spec.n_users
        threshold = float(self.cfg.accuracy_threshold)
        k = min(self.cfg.topk, N_PER_USER_ACTIONS)
        powers = jnp.asarray(
            [N_PER_USER_ACTIONS ** (users - 1 - u) for u in range(users)],
            jnp.int32)
        allowed = jnp.asarray(self.allowed)
        acc_table = jnp.asarray(
            dynamics.accuracies(np.arange(N_PER_USER_ACTIONS)),
            jnp.float32)
        op_kwargs = self._op_kwargs

        def greedy(params, counts, scen):
            act, mem, end, agg = fused_head_features(counts, scen)
            dec, _ = ops.dqn_head(act, mem, end, agg, params, allowed,
                                  acc_table, threshold=threshold, topk=k,
                                  **op_kwargs)
            return dec, (dec * powers[None, :]).sum(-1)

        return greedy

    def _make_act(self, greedy):
        """eps-greedy over the factored head: per-user exploration draws
        a uniform allowed action, greedy uses the (constraint-aware)
        head."""
        users = self.spec.n_users
        # padded per-user allowed-id table for uniform exploration draws
        n_allowed = self.allowed.sum(-1)
        ids = np.zeros((users, n_allowed.max()), np.int32)
        for u in range(users):
            ids[u, :n_allowed[u]] = np.flatnonzero(self.allowed[u])
        ids, n_allowed = jnp.asarray(ids), jnp.asarray(n_allowed)

        def act(params, counts, scen, eps, key):
            k_exp, k_rand = jax.random.split(key)
            dec, _ = greedy(params, counts, scen)
            shape = (scen.cells, users)
            j = (jax.random.uniform(k_rand, shape)
                 * n_allowed[None, :]).astype(jnp.int32)
            rand = ids[jnp.arange(users)[None, :], j]
            explore = jax.random.uniform(k_exp, shape) < eps
            return jnp.where(explore, rand, dec)

        return act

    # ------------------------------------------------------------ train ---
    def _make_train_step(self):
        cfg = self.cfg
        users = self.spec.n_users
        per_user_q = self._per_user_q
        opt_cfg = constant_lr_adamw(cfg.lr)

        def loss_fn(params, s, a, r, s2):
            # per-user terms masked by the request bits stored in the
            # state (inactive users' actions had no effect)
            act_m, act2_m = s[:, :users], s2[:, :users]
            q = per_user_q(params, s)                      # (B, N, NA)
            qa = (jnp.take_along_axis(q, a[..., None], 2)[..., 0]
                  * act_m).sum(-1)
            q2 = (per_user_q(params, s2).max(-1) * act2_m).sum(-1)
            target = r + cfg.gamma * jax.lax.stop_gradient(q2)
            return jnp.mean((qa - target) ** 2)

        def train_step(params, opt, s, a, r, s2):
            loss, grads = jax.value_and_grad(loss_fn)(params, s, a, r, s2)
            params, opt, _ = apply_updates(params, grads, opt, opt_cfg)
            return params, opt, loss

        return train_step

    def _make_step(self, act):
        cfg = self.cfg
        advance = self.source.step          # jit-pure ScenarioSource step
        train_step = self._make_train_step()

        def step(params, opt, buf, mets, counts, scen, eps, key):
            k_act, k_noise, k_scen, k_samp = jax.random.split(key, 4)
            s = encode_fleet_state(counts, scen)
            a = act(params, counts, scen, eps, k_act)       # (cells, N)
            mean_ms, acc, counts2 = simulate_responses(k_noise, scen, a,
                                                       cfg.noise)
            # regression target: summed (not mean) response, no floor —
            # size-invariant per-user values; see module docstring
            r_train = -(mean_ms * scen.active.sum(-1)) / 1000.0
            scen2, _ = advance(k_scen, scen)
            s2 = encode_fleet_state(counts2, scen2)
            buf = replay_push(buf, s, a, r_train, s2)
            bs, ba, br, bs2 = replay_sample(k_samp, buf, cfg.batch_size)
            params, opt, loss = train_step(params, opt, bs, ba, br, bs2)
            # reported reward stays the env's floored Eq.-4 reward
            r = dynamics.reward(mean_ms, acc, cfg.accuracy_threshold,
                                xp=jnp)
            if mets is not None:       # trace-time constant, no host sync
                fill = (replay_size(buf).astype(jnp.float32)
                        / buf.capacity)
                mets = mets.update({"reward": r, "mean_ms": mean_ms,
                                    "loss": loss, "replay_fill": fill,
                                    "epsilon": eps})
            info = {"mean_ms": mean_ms, "mean_acc": acc, "reward": r,
                    "loss": loss}
            return params, opt, buf, mets, counts2, scen2, info

        return step

    def _make_run(self, step):
        """n fleet steps (act + env + replay push + mini-batch update) in
        ONE jitted lax.scan call — no host sync inside the scan."""
        decay, eps_min = self.cfg.eps_decay, self.cfg.eps_min

        def run(params, opt, buf, mets, counts, scen, eps, key, n):
            def body(carry, _):
                params, opt, buf, mets, counts, scen, eps, key = carry
                key, k = jax.random.split(key)
                params, opt, buf, mets, counts, scen, info = step(
                    params, opt, buf, mets, counts, scen, eps, k)
                eps = jnp.maximum(eps_min, eps * (1.0 - decay))
                return (params, opt, buf, mets, counts, scen, eps, key), (
                    info["mean_ms"].mean(), info["mean_acc"].mean(),
                    info["loss"])
            carry, traces = jax.lax.scan(
                body, (params, opt, buf, mets, counts, scen, eps, key),
                None, length=n)
            return carry, traces

        return run

    # -------------------------------------------------------- public API --
    def step(self):
        """Advance every cell by one step + one pooled-replay update."""
        self.key, k = jax.random.split(self.key)
        (self.params, self.opt, self.buffer, self.metrics, self.counts,
         self.scen, info) = self._step(self.params, self.opt, self.buffer,
                                       self.metrics, self.counts,
                                       self.scen, self.eps, k)
        self.eps = max(self.cfg.eps_min,
                       self.eps * (1.0 - self.cfg.eps_decay))
        self.steps += 1
        return info

    def run(self, n: int):
        """Advance every cell by ``n`` steps inside one jitted scan.
        Returns per-step fleet-mean (ms, accuracy) traces of shape (n,)."""
        self.key, k = jax.random.split(self.key)
        carry, (ms, acc, _loss) = self._run(
            self.params, self.opt, self.buffer, self.metrics, self.counts,
            self.scen, self.eps, k, n)
        (self.params, self.opt, self.buffer, self.metrics, self.counts,
         self.scen, eps, _) = carry
        self.eps = float(eps)
        self.steps += n
        return np.asarray(ms), np.asarray(acc)

    def metrics_summary(self):
        """Host-side summary of the in-scan telemetry (``None`` when the
        agent was built with ``metrics=False``)."""
        return None if self.metrics is None else self.metrics.summary()

    def _check_width(self, scen: FleetScenario) -> None:
        """The feature layout (and the 'cell' net's input width) is tied
        to the trained padded width: a wider scen would silently misread
        every feature block, a narrower one crashes cryptically — catch
        both up front through the protocol-shared guard. Smaller CELLS
        are fine (the membership mask); only the padding width is
        pinned."""
        check_pad_width(self.spec.n_users, scen, "FleetDQN")

    def policy_decisions(self, counts, scen):
        """(cells, N) per-user decisions + (cells,) joint action ids from
        one vectorized greedy pass (the FleetOrchestrator entry point)."""
        self._check_width(scen)
        return self._greedy(self.params, counts, scen)

    def greedy_decisions(self, scen: Optional[FleetScenario] = None,
                         counts=None) -> jnp.ndarray:
        """(cells, N) per-user decisions at each cell's current state —
        or, given a (possibly held-out) ``scen``, cold-start decisions
        for cells the policy has never trained on."""
        if scen is None:
            scen = self.scen
            if counts is None:
                counts = self.counts
        self._check_width(scen)
        if counts is None:
            counts = jnp.zeros((scen.cells, 2), jnp.int32)
        return self._greedy(self.params, counts, scen)[0]

    def greedy_expected(self, scen: Optional[FleetScenario] = None,
                        counts=None):
        """Noise-free (mean ms, mean acc) of each cell's greedy decision;
        pass a held-out ``scen`` to score cross-cell generalization."""
        eval_scen = scen if scen is not None else self.scen
        per_user = self.greedy_decisions(scen=scen, counts=counts)
        ms, acc = nominal_expected_response(eval_scen, per_user)
        return np.asarray(ms), np.asarray(acc)

    # ------------------------------------------------ FleetPolicy protocol
    def decisions(self, counts, scen: FleetScenario):
        """``api.FleetPolicy`` surface (alias of ``policy_decisions``)."""
        return self.policy_decisions(counts, scen)

    def expected(self, scen: Optional[FleetScenario] = None, counts=None):
        """``api.FleetPolicy`` surface (alias of ``greedy_expected``)."""
        return self.greedy_expected(scen=scen, counts=counts)

    def train(self, max_steps: int, check_every: int = 200,
              tol: float = 0.01, patience: int = 3) -> FleetTrainResult:
        """Train the shared policy; per-cell convergence is scored
        against ``fleet_bruteforce`` over this agent's candidate set
        (see ``population.train_against_oracle``)."""
        return train_against_oracle(self, max_steps, check_every=check_every,
                                    tol=tol, patience=patience)
