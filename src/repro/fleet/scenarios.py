"""Scenario generation for fleets of end-edge-cloud cells.

The paper evaluates four hand-written network patterns over one cell
(Table 5: EXP-A..D). A production orchestrator trains and evaluates over
*fleets*: thousands of cells whose link states, request arrivals, and
user populations all vary over time. This module provides that layer as
pure, seedable, jit-compatible generators over ``(cells, users)`` arrays:

* **Markov-modulated links** — each end-node / edge backhaul link is a
  two-state (Regular/Weak) Markov chain (`init_links` / `step_links`),
  generalizing the static R/W patterns of Table 5.
* **Poisson arrivals + diurnal load** — per-user request indicators
  drawn from a Poisson process whose rate follows a day-night curve
  (`poisson_active`, `diurnal_rate`).
* **User churn** — users join/leave a cell as a Markov chain on an
  active mask (`step_churn`).
* **Heterogeneous cell sizes** — per-cell user counts drawn in
  ``[min_users, max_users]``, realized as a padded active mask
  (`heterogeneous_sizes`).
* **Multi-edge-cell topologies** — cells share edge servers and queue
  at a common cloud (``fleet.topology``): `FleetConfig.n_edges` turns
  on a generated assignment (random or Zipf-skewed, with capacity
  tiers and an M/M/c cloud queue), and `p_edge_fail` adds edge-failure
  rerouting as a per-step scenario event.

`FleetScenario` composes all of the above behind `init_fleet` /
`step_fleet`; `table5_fleet` replicates any paper scenario across a
fleet for parity testing against the scalar environment.

These generators are one implementation of the front door's
`repro.fleet.api.ScenarioSource` seam (`SyntheticSource` wraps them
bit-exactly); recorded request traces are the other
(`api.TraceSource`, whose timestamp binning lives here as
`arrivals_from_timestamps`).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.fleet.dynamics import EXPERIMENTS, Calibration
from repro.fleet.topology import (Topology, hot_edge_topology,
                                  random_topology, skewed_topology,
                                  step_edge_failures)

# ---------------------------------------------------------------------------
# link-state dynamics (Markov-modulated Regular/Weak, generalizes Table 5)
# ---------------------------------------------------------------------------


def init_links(key, shape, p_weak: float = 0.3):
    """Initial link states: 1 (Weak) w.p. ``p_weak``, else 0 (Regular)."""
    return jax.random.bernoulli(key, p_weak, shape).astype(jnp.int32)


def step_links(key, b, p_r2w: float = 0.05, p_w2r: float = 0.15):
    """One Markov transition per link: Regular->Weak w.p. ``p_r2w``,
    Weak->Regular w.p. ``p_w2r``. Stationary weak fraction is
    ``p_r2w / (p_r2w + p_w2r)``."""
    flip = jax.random.bernoulli(
        key, jnp.where(b == 0, p_r2w, p_w2r), b.shape)
    return jnp.where(flip, 1 - b, b).astype(jnp.int32)


# ---------------------------------------------------------------------------
# workload dynamics (arrivals, diurnal curves, churn, heterogeneity)
# ---------------------------------------------------------------------------


def diurnal_rate(t, period: int = 1440, base: float = 1.0,
                 amplitude: float = 0.4, phase: float = 0.0):
    """Request-rate multiplier following a day-night sinusoid; ``t`` is the
    step index (array ok), ``period`` the steps per simulated day. The
    multiplier averages ``base`` (default 1, so a composed
    ``arrival_rate`` keeps its long-run mean) and is clamped at 0 when
    ``amplitude > base``."""
    m = base + amplitude * jnp.sin(2 * jnp.pi * (t / period + phase))
    return jnp.maximum(m, 0.0)


def poisson_active(key, shape, rate):
    """Per-user request indicator for one step: True iff the user issued
    >=1 request, i.e. w.p. ``1 - exp(-rate)`` (Poisson thinning)."""
    p = 1.0 - jnp.exp(-jnp.asarray(rate))
    return jax.random.bernoulli(key, p, shape)


def arrivals_from_timestamps(times, cells_idx, users_idx, horizon: int,
                             cells: int, users: int,
                             step_duration: float = 1.0) -> np.ndarray:
    """Bin recorded request timestamps into per-step activity masks.

    Event e (``times[e]`` seconds, issued by ``(cells_idx[e],
    users_idx[e])``) lands in fleet step ``floor(times[e] /
    step_duration)``; events outside ``[0, horizon)`` are dropped.
    Returns a ``(horizon, cells, users)`` bool array — True iff the
    user issued >= 1 request that step (the recorded-trace analogue of
    ``poisson_active``). Host-side numpy: traces are preprocessed once
    at load, not inside jitted steps."""
    out = np.zeros((horizon, cells, users), bool)
    if len(np.asarray(times)) == 0:
        return out
    t = np.floor(np.asarray(times, np.float64)
                 / float(step_duration)).astype(np.int64)
    keep = (t >= 0) & (t < horizon)
    out[t[keep], np.asarray(cells_idx)[keep], np.asarray(users_idx)[keep]] \
        = True
    return out


def step_churn(key, member, p_join: float = 0.02, p_leave: float = 0.02):
    """Users join/leave the cell as a two-state Markov chain on the
    membership mask."""
    flip = jax.random.bernoulli(
        key, jnp.where(member, p_leave, p_join), member.shape)
    return jnp.where(flip, ~member, member)


def heterogeneous_sizes(key, cells: int, max_users: int, min_users: int = 1,
                        width: Optional[int] = None):
    """Per-cell user counts in [min_users, max_users] and the matching
    padded (cells, width) membership mask (width defaults to max_users)."""
    sizes = jax.random.randint(key, (cells,), min_users, max_users + 1)
    member = jnp.arange(width or max_users)[None, :] < sizes[:, None]
    return sizes, member


# ---------------------------------------------------------------------------
# composition
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Knobs for a generated fleet. All dynamics are optional: with
    ``p_r2w = p_w2r = 0`` links are static, with ``arrival_rate = None``
    every member user is active each step, with ``p_join = p_leave = 0``
    membership is fixed, and ``min_users = max_users`` makes cells
    homogeneous (the paper's setting is cells=1, users<=5, all static)."""
    cells: int
    users: int = 5
    # links
    p_weak0: float = 0.3
    p_r2w: float = 0.0
    p_w2r: float = 0.0
    # workload
    arrival_rate: Optional[float] = None       # mean requests/user/step
    diurnal_period: int = 0                    # 0 -> flat rate
    diurnal_amplitude: float = 0.4
    # population
    p_join: float = 0.0
    p_leave: float = 0.0
    min_users: int = 5
    max_users: int = 5
    # topology (None -> isolated cells, the paper's 1-cell-per-edge view)
    n_edges: Optional[int] = None
    assignment: str = "random"            # 'random' | 'skewed' | 'hot'
    skew: float = 1.5                     # Zipf exponent for 'skewed'
    hot_fraction: float = 0.5             # edge-0 share for 'hot'
    capacity_tiers: Tuple[float, ...] = (1.0,)
    cloud_servers: float = float("inf")   # M/M/c queue size; inf = off
    p_edge_fail: float = 0.0              # per-step edge-failure prob.
    # sharding: cap the random assignment's locality to the device
    # blocks of an n_shards-way fleet mesh (repro.fleet.shard) so the
    # per-edge aggregation never crosses devices; None = device count
    shard_local: bool = False
    n_shards: Optional[int] = None


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class FleetScenario:
    """Array-of-structs network/workload state for a whole fleet.

    end_b  : (cells, users) int32   per-end-node link state (0 R, 1 W)
    edge_b : (cells,)       int32   edge backhaul link state
    member : (cells, users) bool    user belongs to the cell (churn/size)
    active : (cells, users) bool    member AND issued a request this step
    t      : ()             int32   step counter (drives diurnal curve)
    topo   : Topology | None        shared edge/cloud infrastructure;
                                    None = isolated cells (the paper)
    calib  : Calibration | None     sim-to-real latency-model corrections
                                    (``repro.fleet.calibrate``); None =
                                    the uncalibrated paper model
    """
    end_b: jnp.ndarray
    edge_b: jnp.ndarray
    member: jnp.ndarray
    active: jnp.ndarray
    t: jnp.ndarray
    topo: Optional[Topology] = None
    calib: Optional[Calibration] = None

    def tree_flatten(self):
        return ((self.end_b, self.edge_b, self.member, self.active, self.t,
                 self.topo, self.calib), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def cells(self) -> int:
        return self.end_b.shape[0]

    @property
    def users(self) -> int:
        return self.end_b.shape[1]


def make_topology(key, cfg: FleetConfig) -> Optional[Topology]:
    """Generate the ``Topology`` a ``FleetConfig`` describes (None when
    ``n_edges`` is unset — isolated cells)."""
    if cfg.n_edges is None:
        return None
    kw = dict(capacity_tiers=tuple(cfg.capacity_tiers),
              cloud_servers=cfg.cloud_servers)
    if cfg.shard_local and cfg.assignment != "random":
        raise ValueError(
            f"shard_local topologies are generated by the 'random' "
            f"assignment, not {cfg.assignment!r} (skewed/hot edges "
            "deliberately concentrate cells across blocks)")
    if cfg.shard_local and cfg.p_edge_fail:
        raise ValueError(
            "shard_local=True cannot be combined with p_edge_fail: "
            "step_edge_failures reroutes a failed edge's cells to ANY "
            "other edge, which breaks the shard-locality invariant "
            "local_contention relies on (and under jit the violation "
            "cannot be detected) — use the all-to-all path for fleets "
            "with edge failures")
    if cfg.assignment == "random":
        return random_topology(key, cfg.cells, cfg.n_edges,
                               shard_local=cfg.shard_local,
                               n_shards=cfg.n_shards, **kw)
    if cfg.assignment == "skewed":
        return skewed_topology(key, cfg.cells, cfg.n_edges, skew=cfg.skew,
                               **kw)
    if cfg.assignment == "hot":
        return hot_edge_topology(cfg.cells, cfg.n_edges,
                                 hot_fraction=cfg.hot_fraction, **kw)
    raise ValueError(f"unknown assignment {cfg.assignment!r} "
                     "(expected 'random', 'skewed', or 'hot')")


def with_topology(s: FleetScenario, topo: Optional[Topology]) -> \
        FleetScenario:
    """A copy of ``s`` with ``topo`` attached (or detached with None) —
    the bridge from the Table-5 builders to shared-infrastructure
    fleets."""
    return dataclasses.replace(s, topo=topo)


def init_fleet(key, cfg: FleetConfig) -> FleetScenario:
    """Seedable initial fleet state for ``cfg``."""
    # extra keys only when configured, so pre-topology configs keep
    # their exact random streams
    if cfg.n_edges is not None:
        k_end, k_edge, k_size, k_arr, k_topo = jax.random.split(key, 5)
        topo = make_topology(k_topo, cfg)
    else:
        k_end, k_edge, k_size, k_arr = jax.random.split(key, 4)
        topo = None
    end_b = init_links(k_end, (cfg.cells, cfg.users), cfg.p_weak0)
    edge_b = init_links(k_edge, (cfg.cells,), cfg.p_weak0)
    hi = min(cfg.max_users, cfg.users)
    lo = min(cfg.min_users, hi)          # a cap below min_users wins
    if lo >= cfg.users:
        member = jnp.ones((cfg.cells, cfg.users), bool)
    else:
        _, member = heterogeneous_sizes(k_size, cfg.cells, hi,
                                        min_users=lo, width=cfg.users)
    active = member & _arrivals(k_arr, cfg, member.shape, jnp.int32(0))
    return FleetScenario(end_b, edge_b, member, active, jnp.int32(0), topo)


def _arrivals(key, cfg: FleetConfig, shape, t):
    if cfg.arrival_rate is None:
        return jnp.ones(shape, bool)
    rate = cfg.arrival_rate
    if cfg.diurnal_period:
        rate = rate * diurnal_rate(t, cfg.diurnal_period,
                                   amplitude=cfg.diurnal_amplitude)
    return poisson_active(key, shape, rate)


def step_fleet(key, s: FleetScenario, cfg: FleetConfig) -> FleetScenario:
    """Advance every cell's exogenous state by one step (pure; jit/scan
    friendly — ``FleetScenario`` is a registered pytree). With
    ``cfg.p_edge_fail`` and an attached topology, each step may fail one
    edge and reroute its cells (``topology.step_edge_failures``)."""
    topo = s.topo
    if cfg.p_edge_fail and s.topo is not None:
        k_end, k_edge, k_churn, k_arr, k_fail = jax.random.split(key, 5)
        topo = step_edge_failures(k_fail, topo, cfg.p_edge_fail)
    else:
        k_end, k_edge, k_churn, k_arr = jax.random.split(key, 4)
    end_b, edge_b = s.end_b, s.edge_b
    if cfg.p_r2w or cfg.p_w2r:
        end_b = step_links(k_end, end_b, cfg.p_r2w, cfg.p_w2r)
        edge_b = step_links(k_edge, edge_b, cfg.p_r2w, cfg.p_w2r)
    member = s.member
    if cfg.p_join or cfg.p_leave:
        member = step_churn(k_churn, member, cfg.p_join, cfg.p_leave)
    t = s.t + 1
    active = member & _arrivals(k_arr, cfg, member.shape, t)
    return FleetScenario(end_b, edge_b, member, active, t, topo, s.calib)


def table5_fleet(name: str, cells: int, users: int = 5) -> FleetScenario:
    """Replicate a paper Table-5 scenario (EXP-A..D) across ``cells``
    identical cells — the bridge between the fleet simulator and the
    paper's single-cell testbed."""
    sc = EXPERIMENTS[name]
    if users > len(sc.end_b):
        raise ValueError("scenario must cover all users")
    end_b = jnp.tile(jnp.asarray(sc.end_b[:users], jnp.int32)[None, :],
                     (cells, 1))
    edge_b = jnp.full((cells,), sc.edge_b, jnp.int32)
    member = jnp.ones((cells, users), bool)
    return FleetScenario(end_b, edge_b, member, member,
                         jnp.int32(0))


def mixed_table5_fleet(key, cells: int, users: int = 5,
                       min_users: Optional[int] = None,
                       max_users: Optional[int] = None) -> FleetScenario:
    """A fleet whose cells are drawn uniformly from the four Table-5
    scenarios — the smallest interesting heterogeneous fleet.

    ``min_users``/``max_users`` additionally draw per-cell sizes in that
    range (padded to ``users``), e.g. to train a shared policy on sizes
    {2, 3} and hold out size-1 cells it never saw."""
    names = list(EXPERIMENTS)
    if users > min(len(EXPERIMENTS[n].end_b) for n in names):
        raise ValueError("scenario must cover all users")
    k_pick, k_size = jax.random.split(key)
    pick = np.asarray(jax.random.randint(k_pick, (cells,), 0, len(names)))
    end_b = np.stack([EXPERIMENTS[names[i]].end_b[:users] for i in pick])
    edge_b = np.asarray([EXPERIMENTS[names[i]].edge_b for i in pick])
    if min_users is None and max_users is None:
        member = jnp.ones((cells, users), bool)
    else:
        hi = min(max_users if max_users is not None else users, users)
        lo = min(min_users if min_users is not None else 1, hi)
        _, member = heterogeneous_sizes(k_size, cells, hi, min_users=lo,
                                        width=users)
    return FleetScenario(jnp.asarray(end_b, jnp.int32),
                         jnp.asarray(edge_b, jnp.int32), member, member,
                         jnp.int32(0))
