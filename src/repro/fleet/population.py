"""Population-scale RL training: thousands of independent cells per step.

The paper trains one tabular agent against one cell (≤5 users) with a
Python-loop environment. This module scales that to fleets: a dense
per-cell Q-table of shape ``(cells, states, actions)`` updated for every
cell in a single ``jax.jit`` call per host step, over the shared
``fleet.dynamics`` kernel and a ``fleet.scenarios.FleetScenario``.

State space. The scalar env's observation is fully determined by the
previous step's (edge jobs, cloud jobs) counts plus the link states, so
the fleet agent indexes its Q-table by
``(n_edge, n_cloud[, packed link bits])`` — ``(N+1)^2`` states for
static-link fleets (the paper's setting), times ``2^(N+1)`` when
``track_links`` is on for Markov-modulated fleets. This is exactly the
set of states the scalar agent's lazy dict ever materializes.

Action space. A candidate set of joint actions (default: the full
``10^N`` space for ``N <= 3``, the SOTA-restricted ``3^N`` offloading
set above) shared by all cells; its decoded ``(K, N)`` table lives on
device so greedy routing for the whole fleet is one argmax + one gather.

``fleet_bruteforce`` evaluates every candidate action for every cell in
chunks (the vectorized analogue of ``core.bruteforce``), and
``FleetQLearning.train`` reports per-cell convergence against it, the
fleet analogue of ``core.orchestrator.train_agent``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.spaces import SpaceSpec, restricted_actions
from repro.fleet import dynamics, topology
from repro.fleet.scenarios import FleetConfig, FleetScenario
from repro.kernels import ops
from repro.kernels.ref import first_argmax_ref
from repro.obs.metrics import MetricDef, MetricsAccumulator


def fleet_metrics(cells: int, kind: str = "tabular", n_windows: int = 0,
                  window_len: int = 1) -> MetricsAccumulator:
    """The standard in-scan telemetry pack of the fleet agents.

    Per-cell signals use ``lanes=cells`` so every accumulator update is
    elementwise along the fleet axis — the mechanism that keeps sharded
    training bit-identical to single-device (see ``repro.obs.metrics``).
    Histogram ranges come from the dynamics invariants: rewards live in
    ``[-MAX_RESPONSE_MS/1000, 0]`` and response times in
    ``[0, MAX_RESPONSE_MS]``; out-of-range values clip into edge bins
    without corrupting the exact moments (and bump the explicit
    underflow/overflow counters).

    ``n_windows > 0`` gives every stream a ``(n_windows, lanes)``
    per-window ring (``window_len`` steps per slot), so ``summary()``
    reports the learning curve — reward/td_abs/loss per window — not
    just whole-run aggregates. The ring update is the same elementwise
    op class, so the sharding bit-identity is unchanged.
    """
    r_floor = -dynamics.MAX_RESPONSE_MS / 1000.0
    w = dict(n_windows=n_windows, window_len=window_len)
    defs = {
        "reward": MetricDef(lo=r_floor, hi=0.0, lanes=cells, **w),
        "mean_ms": MetricDef(lo=0.0, hi=dynamics.MAX_RESPONSE_MS,
                             lanes=cells, **w),
        "epsilon": MetricDef(lo=0.0, hi=1.0, **w),
    }
    if kind == "tabular":
        defs["td_abs"] = MetricDef(lo=0.0, hi=-r_floor, lanes=cells, **w)
    elif kind == "dqn":
        defs["loss"] = MetricDef(lo=0.0, hi=25.0, **w)
        defs["replay_fill"] = MetricDef(lo=0.0, hi=1.0, **w)
    else:
        raise ValueError(f"unknown metrics kind {kind!r}")
    return MetricsAccumulator.create(defs)


def place_metrics(mets, mesh):
    """Shard an agent's accumulator like its other carries: per-cell
    lanes along the fleet axis (axis 1 of the windowed rings),
    histograms/counters/scalars replicated."""
    if mets is None or mesh is None:
        return mets
    from repro.fleet import shard
    return mets.place(lambda x, axis=0: shard.shard_array(x, mesh,
                                                          axis=axis),
                      lambda x: shard.replicate(x, mesh))


def check_pad_width(n_users: int, scen: FleetScenario, who: str) -> None:
    """THE pad-width guard of the FleetPolicy protocol, shared by every
    policy (both agents, the oracle, the static baselines): a scenario
    padded to a different user width than the policy was built for —
    e.g. one produced by a ``TraceSource`` recorded at another width —
    must raise the same clear error everywhere instead of silently
    misreading feature blocks or state indices."""
    if scen.users != n_users:
        raise ValueError(
            f"{who} routes fleets padded to {n_users} users; got a "
            f"{scen.users}-wide scenario — regenerate it with "
            f"users={n_users} (smaller cells are expressed via the "
            "membership mask, not a narrower pad)")


def resolve_source(scen, fleet_cfg, seed: int, reset_key=None):
    """Normalize an agent's scenario arguments onto the ScenarioSource
    seam: a source resets into its initial scenario; the legacy
    ``(FleetScenario, FleetConfig)`` pair wraps bit-exactly into a
    ``SyntheticSource`` pinned to that scenario. Returns
    ``(scen0, source)``."""
    from repro.fleet.api import SyntheticSource, is_source, \
        require_scenario_state
    if is_source(scen):
        source = scen
        require_scenario_state(source)
        key = reset_key if reset_key is not None else \
            jax.random.PRNGKey(seed)
        scen0, _ = source.reset(key)
        return scen0, source
    if fleet_cfg is None:
        raise TypeError(
            "pass a ScenarioSource (repro.fleet.api), or a FleetScenario "
            "together with its FleetConfig")
    return scen, SyntheticSource(fleet_cfg, scen=scen)


def adopt_mesh(mesh, source, scen):
    """THE mesh-adoption step of both agent constructors: resolve the
    fleet mesh (an explicit argument wins, else the source's own),
    attach it to the source so the jitted scenario stream keeps the
    layout, and place the initial scenario. Returns ``(mesh, scen)``
    (``(None, scen)`` when no mesh is in play)."""
    mesh = mesh if mesh is not None else getattr(source, "mesh", None)
    if mesh is None:
        return None, scen
    from repro.fleet import shard
    attach = getattr(source, "attach_mesh", None)
    if attach is not None:
        attach(mesh)
    return mesh, shard.shard_scenario(scen, mesh)


def simulate_responses(key, scen: FleetScenario, per_user, noise: float):
    """Noisy fleet-wide response simulation: (cells,) mean ms and mean
    accuracy over each cell's active users, plus next-step job counts.
    The jittable analogue of ``EndEdgeCloudEnv.response_times`` +
    ``accuracies`` for every cell at once.

    With an attached ``scen.topo`` the responses couple across cells
    (shared edges, cloud queueing) via ``topology_expected_response``;
    the returned ``counts`` stay per-cell own-job counts either way (the
    observation both agents index/encode — aggregation over the
    assignment happens inside the dynamics each step)."""
    if scen.topo is None:
        mean_ms, acc = dynamics.expected_response(
            per_user, scen.end_b, scen.edge_b, active=scen.active,
            calib=scen.calib, xp=jnp)
    else:
        mean_ms, acc = topology.topology_expected_response(
            per_user, scen.end_b, scen.edge_b, scen.topo,
            active=scen.active, calib=scen.calib, xp=jnp)
    n_act = jnp.maximum(scen.active.sum(-1), 1)
    if noise:
        # one per-cell draw on the mean instead of the scalar env's N
        # per-user draws (~5x less RNG); the 1/sqrt(n) scaling matches the
        # variance of averaging n independent multipliers when per-user
        # times are equal, and approximates it otherwise
        mult = jnp.clip(1.0 + (noise / jnp.sqrt(n_act))
                        * jax.random.normal(key, mean_ms.shape), 0.8, 1.2)
        mean_ms = mean_ms * mult
    counts = jnp.stack(
        [((per_user == dynamics.A_EDGE) & scen.active).sum(-1),
         ((per_user == dynamics.A_CLOUD) & scen.active).sum(-1)],
        axis=-1).astype(jnp.int32)
    return mean_ms, acc, counts


def nominal_expected_response(scen: FleetScenario, per_user):
    """Noise-free (cells,) mean ms / mean accuracy of ``per_user`` under
    nominal load (all member users requesting), shared- or
    isolated-contention depending on ``scen.topo`` — the ONE evaluation
    behind both agents' ``greedy_expected``, the oracles, and the
    benchmarks, so the two contention regimes can't drift apart."""
    if scen.topo is None:
        return dynamics.fleet_expected_response(
            per_user, scen.end_b, scen.edge_b, scen.member,
            calib=scen.calib)
    return topology.fleet_topology_expected_response(
        per_user, scen.end_b, scen.edge_b, scen.topo, scen.member,
        calib=scen.calib)


def make_fleet_env_step(source, threshold: float = 0.0,
                        noise: float = 0.02):
    """Pure per-step fleet environment transition — the fleet analogue of
    ``EndEdgeCloudEnv.step`` with the decision supplied externally.

    Takes any ``repro.fleet.api.ScenarioSource`` (``SyntheticSource``,
    ``TraceSource``, ...). Returns ``env_step(key, scen, per_user) ->
    (scen2, counts2, mean_ms, mean_acc, reward)``; wrap in ``jax.jit`` /
    ``lax.scan`` to step every cell of the fleet per call.

    The PR-4 ``make_fleet_env_step(FleetConfig)`` deprecation shim has
    been removed — wrap the config in a ``SyntheticSource`` (results
    are bit-identical; same generators, same key usage).
    """
    from repro.fleet.api import make_env_step
    if isinstance(source, FleetConfig):
        raise TypeError(
            "make_fleet_env_step(FleetConfig) was removed; wrap the "
            "config: make_fleet_env_step(repro.fleet.api."
            "SyntheticSource(cfg)) — bit-identical results")
    return make_env_step(source, threshold=threshold, noise=noise)


def default_actions(spec: SpaceSpec) -> np.ndarray:
    """Full joint space for small N, SOTA-restricted offloading set above
    (keeps the dense per-cell table ~tens of MB at N=5)."""
    if spec.n_users <= 3:
        return spec.all_actions()
    return restricted_actions(spec)


@dataclasses.dataclass
class FleetQConfig:
    alpha: float = 0.9               # paper Table 7
    gamma: float = 0.1
    eps_start: float = 1.0
    eps_decay: float = 1e-3          # multiplicative, per fleet step
    eps_min: float = 0.01
    noise: float = 0.02
    accuracy_threshold: float = 0.0
    track_links: bool = False        # index Q by link bits (Markov fleets)


class FleetQLearning:
    """Batched epsilon-greedy tabular Q-learning over a fleet of cells.

    One ``step()`` = one environment step for EVERY cell: eps-greedy
    action selection, noisy response simulation, exogenous scenario
    transition, and TD update, all inside a single jitted call.
    """

    def __init__(self, scen, fleet_cfg: Optional[FleetConfig] = None,
                 cfg: Optional[FleetQConfig] = None,
                 actions: Optional[np.ndarray] = None, seed: int = 0,
                 reset_key=None, mesh=None, metrics: bool = True,
                 n_windows: int = 0, window_len: int = 1,
                 impl: str = "pallas"):
        """``scen`` is a ``repro.fleet.api.ScenarioSource`` (reset with
        ``reset_key``, default ``PRNGKey(seed)``) — or, equivalently, a
        ``FleetScenario`` plus its ``FleetConfig`` (wrapped into a
        ``SyntheticSource`` pinned to that scenario).

        ``mesh`` (``repro.fleet.shard.fleet_mesh``; default: the
        source's own mesh, if any) shards the per-cell Q-table, job
        counts, and scenario along the fleet axis — the TD update is
        per-cell, so training never leaves the shard, bit-identical to
        the single-device path.

        ``metrics`` (default on) rides a ``repro.obs`` accumulator in
        the scan carry — per-step reward / response time / |TD| /
        epsilon with zero host syncs; read it via ``metrics_summary``.
        Recording consumes no RNG and never feeds back into training,
        so trajectories are bit-identical with it on or off —
        including with ``n_windows > 0``, which adds a per-window ring
        (``window_len`` steps per slot) to every stream so
        ``metrics_summary()`` carries the learning curve.

        ``impl`` selects the hot-path implementation: ``"pallas"``
        (default) is the fused act+update pair — one Q-row gather
        shared by the TD max and the next step's greedy, which the scan
        then carries instead of re-gathering (the compiled Pallas
        kernel on TPU, the bit-equivalent fused-jnp formulation
        elsewhere; see ``kernels.ops.resolve_rl_impl``). ``"xla"`` is
        the legacy unfused step (separate gather/argmax/scatter HLOs),
        kept as the reference and the ``rl_unfused_*`` benchmark
        baseline. ``"pallas_interpret"`` forces the real kernel in
        interpret mode (parity tests; far too slow for training)."""
        self.cfg = cfg or FleetQConfig()
        scen, self.source = resolve_source(scen, fleet_cfg, seed, reset_key)
        self.fleet_cfg = getattr(self.source, "cfg", None)
        self.mesh, scen = adopt_mesh(mesh, self.source, scen)
        self.impl = impl
        self._op_impl = ops.resolve_rl_impl(impl, self.mesh)
        self._op_kwargs = (None if self._op_impl == "xla"
                           else ops.rl_op_kwargs(self._op_impl))
        self.spec = SpaceSpec(scen.users)
        self.actions = np.asarray(actions if actions is not None
                                  else default_actions(self.spec))
        self.pu_table = jnp.asarray(
            self.spec.decode_actions_batch(self.actions))      # (K, N)
        self.n_actions = len(self.actions)
        users = scen.users
        self._count_states = (users + 1) ** 2
        self._link_states = 2 ** (users + 1) if self.cfg.track_links else 1
        self.n_states = self._count_states * self._link_states
        self.q = jnp.zeros((scen.cells, self.n_states, self.n_actions),
                           jnp.float32)
        self.scen = scen
        self.counts = jnp.zeros((scen.cells, 2), jnp.int32)
        self.metrics = fleet_metrics(scen.cells, "tabular",
                                     n_windows=n_windows,
                                     window_len=window_len) if metrics \
            else None
        if self.mesh is not None:
            from repro.fleet import shard
            self.q = shard.shard_array(self.q, self.mesh)
            self.counts = shard.shard_array(self.counts, self.mesh)
            self.metrics = place_metrics(self.metrics, self.mesh)
        self.eps = self.cfg.eps_start
        self.key = jax.random.PRNGKey(seed)
        self.steps = 0
        # donate the Q-table (and the metrics accumulator riding with it):
        # the scatter-add then runs in place instead of copying the whole
        # (cells, S, K) buffer every step (~30 ms at 36 MB)
        don = (0,) if self.metrics is None else (0, 1)
        self._step = jax.jit(self._make_step(), donate_argnums=don)
        self._run = jax.jit(self._make_run(), static_argnums=(6,),
                            donate_argnums=don)
        self._greedy = jax.jit(self._make_greedy())

    # ------------------------------------------------------------------
    def _state_index(self, counts, scen: FleetScenario):
        users = scen.users
        s = counts[:, 0] * (users + 1) + counts[:, 1]
        if self.cfg.track_links:
            weights = 2 ** jnp.arange(users)
            packed = (scen.end_b * weights[None, :]).sum(-1) * 2 + scen.edge_b
            s = s * self._link_states + packed
        return s

    def _explore(self, greedy, eps, k_exp):
        """Shared eps-greedy action draw: one uniform drives both the
        explore decision and, conditioned on u < eps, the (still
        uniform) random action u/eps — identical RNG consumption on the
        fused and unfused paths, so trajectories match across impls."""
        n_actions = self.n_actions
        u = jax.random.uniform(k_exp, greedy.shape)
        rand = jnp.minimum((u / jnp.maximum(eps, 1e-9)
                            * n_actions).astype(jnp.int32),
                           n_actions - 1)
        return jnp.where(u < eps, rand, greedy)

    def _make_fused_core(self):
        """env step + fused TD update from a precomputed ``(s, greedy)``
        pair — the body shared by the fused single-step and the fused
        scan (which carries ``greedy2`` instead of re-gathering the
        ``s2`` Q-row next step). Splits the key exactly like the legacy
        step, so fused and unfused trajectories use identical RNG."""
        cfg, pu = self.cfg, self.pu_table
        advance = self.source.step
        op_kwargs = self._op_kwargs

        def core(q, mets, counts, scen, eps, key, s, greedy):
            k_exp, k_noise, k_scen = jax.random.split(key, 3)
            a = self._explore(greedy, eps, k_exp)              # (cells,)
            per_user = pu[a]                                   # (cells, N)
            mean_ms, acc, counts2 = simulate_responses(k_noise, scen,
                                                       per_user, cfg.noise)
            r = dynamics.reward(mean_ms, acc, cfg.accuracy_threshold,
                                xp=jnp)
            scen2, _ = advance(k_scen, scen)
            s2 = self._state_index(counts2, scen2)
            q, greedy2, td = ops.fused_tabular_update(
                q, s, a, r, s2, alpha=cfg.alpha, gamma=cfg.gamma,
                **op_kwargs)
            if mets is not None:   # trace-time constant, no host sync
                mets = mets.update({"reward": r, "mean_ms": mean_ms,
                                    "td_abs": jnp.abs(td), "epsilon": eps})
            info = {"mean_ms": mean_ms, "mean_acc": acc, "reward": r}
            return q, mets, counts2, scen2, greedy2, info

        return core

    def _make_step(self):
        if self._op_impl != "xla":
            core = self._make_fused_core()

            def step(q, mets, counts, scen, eps, key):
                s = self._state_index(counts, scen)
                greedy = first_argmax_ref(q[jnp.arange(q.shape[0]), s])
                q, mets, counts2, scen2, _, info = core(
                    q, mets, counts, scen, eps, key, s, greedy)
                return q, mets, counts2, scen2, info

            return step
        cfg, pu = self.cfg, self.pu_table
        advance = self.source.step          # jit-pure ScenarioSource step
        n_actions = self.n_actions

        def step(q, mets, counts, scen, eps, key):
            cells = jnp.arange(q.shape[0])
            k_exp, k_noise, k_scen = jax.random.split(key, 3)
            s = self._state_index(counts, scen)
            q_s = q[cells, s]                                  # (cells, K)
            greedy = q_s.argmax(-1)
            # one uniform drives both the explore decision and, conditioned
            # on u < eps, the (still uniform) random action u/eps
            u = jax.random.uniform(k_exp, greedy.shape)
            rand = jnp.minimum((u / jnp.maximum(eps, 1e-9)
                                * n_actions).astype(jnp.int32),
                               n_actions - 1)
            a = jnp.where(u < eps, rand, greedy)               # (cells,)
            per_user = pu[a]                                   # (cells, N)
            # simulate every cell's response under its own conditions
            mean_ms, acc, counts2 = simulate_responses(k_noise, scen,
                                                       per_user, cfg.noise)
            r = dynamics.reward(mean_ms, acc, cfg.accuracy_threshold,
                                xp=jnp)
            # exogenous transition + TD update against the next state
            scen2, _ = advance(k_scen, scen)
            s2 = self._state_index(counts2, scen2)
            td = r + cfg.gamma * q[cells, s2].max(-1) - q[cells, s, a]
            q = q.at[cells, s, a].add(cfg.alpha * td)
            if mets is not None:       # trace-time constant, no host sync
                mets = mets.update({"reward": r, "mean_ms": mean_ms,
                                    "td_abs": jnp.abs(td), "epsilon": eps})
            info = {"mean_ms": mean_ms, "mean_acc": acc, "reward": r}
            return q, mets, counts2, scen2, info

        return step

    def _make_run(self):
        """n environment steps for the whole fleet in ONE jitted lax.scan
        call (amortizes dispatch; donation keeps the table in place).
        The fused path carries each step's ``greedy2`` through the scan
        — the act-side Q-row gather+argmax happens once, in the fused
        update of the PREVIOUS step, instead of once per step."""
        decay, eps_min = self.cfg.eps_decay, self.cfg.eps_min
        if self._op_impl != "xla":
            core = self._make_fused_core()

            def run(q, mets, counts, scen, eps, key, n):
                def body(carry, _):
                    q, mets, counts, scen, greedy, eps, key = carry
                    key, k = jax.random.split(key)
                    s = self._state_index(counts, scen)
                    q, mets, counts, scen, greedy, info = core(
                        q, mets, counts, scen, eps, k, s, greedy)
                    eps = jnp.maximum(eps_min, eps * (1.0 - decay))
                    return ((q, mets, counts, scen, greedy, eps, key),
                            (info["mean_ms"].mean(),
                             info["mean_acc"].mean()))
                s0 = self._state_index(counts, scen)
                greedy0 = first_argmax_ref(q[jnp.arange(q.shape[0]), s0])
                carry, (ms, acc) = jax.lax.scan(
                    body, (q, mets, counts, scen, greedy0, eps, key),
                    None, length=n)
                q, mets, counts, scen, _, eps, key = carry
                return (q, mets, counts, scen, eps, key), ms, acc

            return run
        step = self._make_step()

        def run(q, mets, counts, scen, eps, key, n):
            def body(carry, _):
                q, mets, counts, scen, eps, key = carry
                key, k = jax.random.split(key)
                q, mets, counts, scen, info = step(q, mets, counts, scen,
                                                   eps, k)
                eps = jnp.maximum(eps_min, eps * (1.0 - decay))
                return ((q, mets, counts, scen, eps, key),
                        (info["mean_ms"].mean(), info["mean_acc"].mean()))
            carry, (ms, acc) = jax.lax.scan(
                body, (q, mets, counts, scen, eps, key), None, length=n)
            return carry, ms, acc

        return run

    def step(self):
        """Advance every cell by one environment step (one jitted call)."""
        self.key, k = jax.random.split(self.key)
        self.q, self.metrics, self.counts, self.scen, info = self._step(
            self.q, self.metrics, self.counts, self.scen, self.eps, k)
        self.eps = max(self.cfg.eps_min,
                       self.eps * (1.0 - self.cfg.eps_decay))
        self.steps += 1
        return info

    def run(self, n: int):
        """Advance every cell by ``n`` steps inside one jitted scan.
        Returns per-step fleet-mean (ms, accuracy) traces of shape (n,)."""
        self.key, k = jax.random.split(self.key)
        (self.q, self.metrics, self.counts, self.scen, eps, _), ms, acc = \
            self._run(self.q, self.metrics, self.counts, self.scen,
                      self.eps, k, n)
        self.eps = float(eps)
        self.steps += n
        return np.asarray(ms), np.asarray(acc)

    def metrics_summary(self):
        """Host-side summary of the in-scan telemetry (``None`` when the
        agent was built with ``metrics=False``)."""
        return None if self.metrics is None else self.metrics.summary()

    # ------------------------------------------------------------------
    def _make_greedy(self):
        """One vectorized greedy pass: (cells, N) decisions + (cells,)
        action ids — shared by training checks and FleetOrchestrator."""
        pu = self.pu_table

        def greedy(q, counts, scen):
            s = self._state_index(counts, scen)
            # first_argmax_ref == jnp.argmax (first-index tie-break),
            # ~2x faster on CPU XLA; shared with the fused hot path
            a = first_argmax_ref(q[jnp.arange(q.shape[0]), s])
            return pu[a], a

        return greedy

    def greedy_decisions(self) -> jnp.ndarray:
        """(cells, N) per-user decisions from one vectorized greedy pass
        at each cell's current state."""
        return self._greedy(self.q, self.counts, self.scen)[0]

    @property
    def accuracy_threshold(self) -> float:
        return self.cfg.accuracy_threshold

    def policy_decisions(self, counts, scen):
        """(cells, N) per-user decisions + (cells,) action ids from one
        vectorized greedy pass over the batched Q-table (the
        FleetOrchestrator entry point, shared with ``FleetDQN``).

        Each cell's table is tied to the fleet it trained on, so unlike
        the shared-policy DQN this agent cannot serve a held-out fleet —
        ``scen`` may vary link/membership state but must have this
        agent's cells."""
        check_pad_width(self.spec.n_users, scen, "FleetQLearning")
        if scen.cells != self.q.shape[0]:
            raise ValueError(
                f"FleetQLearning holds one Q-table per trained cell "
                f"({self.q.shape[0]}); it cannot route a {scen.cells}-cell "
                "scenario — use the shared-policy fleet.policy.FleetDQN "
                "for held-out fleets")
        return self._greedy(self.q, counts, scen)

    def train(self, max_steps: int, check_every: int = 200,
              tol: float = 0.01, patience: int = 3) -> "FleetTrainResult":
        """Train all cells; per-cell convergence = greedy expected response
        within ``tol`` of that cell's brute-force optimum for ``patience``
        consecutive checks (fleet analogue of ``train_agent``)."""
        return train_against_oracle(self, max_steps, check_every=check_every,
                                    tol=tol, patience=patience)

    def greedy_expected(self, scen: Optional[FleetScenario] = None,
                        counts=None):
        """Noise-free (mean ms, mean acc) of each cell's greedy decision.
        Accepts ``scen``/``counts`` for API parity with ``FleetDQN`` (so
        ``holdout_reward_ratio`` takes either agent), but the per-cell
        tables only serve this agent's own fleet — a genuinely held-out
        scenario raises via ``policy_decisions``."""
        eval_scen = scen if scen is not None else self.scen
        if counts is None:
            counts = (self.counts if scen is None else
                      jnp.zeros((eval_scen.cells, 2), jnp.int32))
        per_user = self.policy_decisions(counts, eval_scen)[0]
        ms, acc = nominal_expected_response(eval_scen, per_user)
        return np.asarray(ms), np.asarray(acc)

    # ------------------------------------------------ FleetPolicy protocol
    def decisions(self, counts, scen: FleetScenario):
        """``api.FleetPolicy`` surface (alias of ``policy_decisions``)."""
        return self.policy_decisions(counts, scen)

    def expected(self, scen: Optional[FleetScenario] = None, counts=None):
        """``api.FleetPolicy`` surface (alias of ``greedy_expected``)."""
        return self.greedy_expected(scen=scen, counts=counts)


def train_against_oracle(agent, max_steps: int, check_every: int = 200,
                         tol: float = 0.01,
                         patience: int = 3) -> "FleetTrainResult":
    """THE fleet training loop, shared by ``FleetQLearning`` and
    ``fleet.policy.FleetDQN`` (anything with ``run`` /
    ``greedy_expected`` / ``scen`` / ``pu_table`` / ``fleet_cfg`` /
    ``accuracy_threshold``): per-cell convergence = greedy expected
    response within ``tol`` of that cell's brute-force optimum for
    ``patience`` consecutive checks (fleet analogue of ``train_agent``).

    For dynamic fleets (Markov links / churn / trace replay) the
    scenario — and so the optimum — moves between checks; the oracle is
    then recomputed per check, and "converged" means tracking the
    current optimum. Whether the fleet is dynamic comes from the
    agent's ``ScenarioSource`` (``source.dynamic``); agents built
    outside the source seam fall back to their ``fleet_cfg``."""
    threshold = agent.accuracy_threshold
    source = getattr(agent, "source", None)
    if source is not None:
        dynamic = bool(source.dynamic)
    else:
        fc = agent.fleet_cfg
        dynamic = bool(fc.p_r2w or fc.p_w2r or fc.p_join or fc.p_leave
                       or fc.p_edge_fail)
    opt_ms = None                        # dynamic: computed per check instead
    if not dynamic:
        opt_ms = np.asarray(fleet_bruteforce(
            agent.scen, agent.pu_table, threshold)[0])
    cells = agent.scen.cells
    converged_at = np.full(cells, -1, np.int64)
    streak = np.zeros(cells, np.int64)
    t0 = time.perf_counter()
    history = []
    for step in range(check_every, max_steps + 1, check_every):
        agent.run(check_every)
        if dynamic:
            opt_ms = np.asarray(fleet_bruteforce(
                agent.scen, agent.pu_table, threshold)[0])
        g_ms, g_acc = agent.greedy_expected()
        ok = np.asarray(dynamics.feasible(g_acc, threshold)
                        & (g_ms <= opt_ms * (1 + tol)))
        streak = np.where(ok, streak + 1, 0)
        newly = (streak >= patience) & (converged_at < 0)
        converged_at[newly] = step - (patience - 1) * check_every
        frac = float((converged_at >= 0).mean())
        history.append({"step": step, "frac_converged": frac,
                        "median_greedy_ms": float(np.median(g_ms))})
        if frac >= 1.0:
            break
    else:
        if max_steps < check_every:          # loop never ran
            g_ms, g_acc = agent.greedy_expected()
    if opt_ms is None:                       # dynamic fleet, loop never ran
        opt_ms = np.asarray(fleet_bruteforce(
            agent.scen, agent.pu_table, threshold)[0])
    from repro.obs.report import run_manifest
    wall = time.perf_counter() - t0
    return FleetTrainResult(
        converged_at=converged_at, steps=agent.steps,
        frac_converged=float((converged_at >= 0).mean()),
        optimal_ms=np.asarray(opt_ms), greedy_ms=np.asarray(g_ms),
        greedy_acc=np.asarray(g_acc), history=history,
        wall_seconds=wall,
        manifest=run_manifest(config=agent.cfg,
                              mesh=getattr(agent, "mesh", None),
                              wall_seconds=wall, steps=agent.steps))


@dataclasses.dataclass
class FleetTrainResult:
    converged_at: np.ndarray         # (cells,) step index, -1 = not yet
    steps: int
    frac_converged: float
    optimal_ms: np.ndarray           # (cells,)
    greedy_ms: np.ndarray            # (cells,)
    greedy_acc: np.ndarray           # (cells,)
    history: list
    wall_seconds: float
    #: provenance stamp (repro.obs.report.run_manifest) for this run
    manifest: Optional[dict] = None

    @property
    def cells_per_second(self) -> float:
        """Converged cells per wall-clock second of training."""
        n = int((self.converged_at >= 0).sum())
        return n / max(self.wall_seconds, 1e-9)


# ---------------------------------------------------------------------------
def fleet_bruteforce(scen: FleetScenario, pu_table: jnp.ndarray,
                     threshold: float = 0.0, chunk: int = 4096):
    """Per-cell optimum over the candidate action table under nominal
    load (all member users requesting). Returns ((cells,) best ms,
    (cells,) best index).

    Isolated fleets get the exact chunked brute force; with an attached
    ``scen.topo`` the per-cell argmax is no longer exact (cells couple
    through shared edges and the cloud queue), so this dispatches to the
    coordinate-descent ``topology_bruteforce`` — same return contract,
    so ``train_against_oracle`` / ``holdout_reward_ratio`` work
    unchanged on either fleet kind.
    """
    if scen.topo is not None:
        ms, idx, _, _ = topology_bruteforce(scen, pu_table, threshold,
                                            chunk=chunk)
        return ms, idx
    return _isolated_bruteforce(scen, pu_table, threshold, chunk)


def _isolated_bruteforce(scen: FleetScenario, pu_table: jnp.ndarray,
                         threshold: float = 0.0, chunk: int = 4096):
    """The exact per-cell brute force for uncoupled cells: evaluates all
    K candidates for all cells, chunked over K to bound the
    ``cells x chunk x N`` intermediate."""
    member = scen.member
    best_ms = jnp.full((scen.cells,), jnp.inf)
    best_idx = jnp.zeros((scen.cells,), jnp.int32)
    for lo in range(0, pu_table.shape[0], chunk):
        pu = pu_table[lo:lo + chunk]                           # (k, N)
        ms, acc = dynamics.fleet_actions_expected_response(
            pu, scen.end_b, scen.edge_b, member,
            calib=scen.calib)                                  # (cells, k)
        ms = jnp.where(dynamics.feasible(acc, threshold, xp=jnp), ms,
                       jnp.inf)
        i = ms.argmin(-1)
        m = jnp.take_along_axis(ms, i[:, None], -1)[:, 0]
        better = m < best_ms
        best_idx = jnp.where(better, i + lo, best_idx).astype(jnp.int32)
        best_ms = jnp.where(better, m, best_ms)
    if bool(jnp.isinf(best_ms).any()):
        raise ValueError("no feasible action for threshold %.2f in %d cells"
                         % (threshold, int(jnp.isinf(best_ms).sum())))
    return best_ms, best_idx


#: minimum per-cell improvement (ms) for a best-response switch — a
#: strict-improvement margin so equal-cost candidates can't cycle
BEST_RESPONSE_TOL = 1e-6


@jax.jit
def _best_response_round(idx, pu_table, end_b, edge_b, member, feas,
                         cand_e, cand_c, cell_edge, edge_capacity,
                         cloud_servers, calib=None):
    """One Gauss-Seidel sweep: each cell in turn picks its best feasible
    candidate given every OTHER cell's current decision, with running
    per-edge / cloud totals updated in place (O(1) per cell instead of a
    fleet-wide re-aggregation). ``feas`` / ``cand_e`` / ``cand_c`` are
    the (cells, K) round-invariant tables precomputed by
    ``topology_bruteforce`` — recomputing them here would redo a
    cells x K x N reduce on every sweep."""
    n_edges = edge_capacity.shape[0]
    cells = idx.shape[0]
    rows = jnp.arange(cells)
    e_cnt = cand_e[rows, idx]
    c_cnt = cand_c[rows, idx]
    edge_tot = jax.ops.segment_sum(e_cnt, cell_edge, num_segments=n_edges)
    cloud_tot = c_cnt.sum()

    def body(i, carry):
        idx, e_cnt, c_cnt, edge_tot, cloud_tot = carry
        e_i = cell_edge[i]
        n_e_k = (edge_tot[e_i] - e_cnt[i] + cand_e[i]) / edge_capacity[e_i]
        tot_c_k = cloud_tot - c_cnt[i] + cand_c[i]
        mult_k = topology.cloud_load_multiplier(tot_c_k, cloud_servers,
                                                xp=jnp)
        ms_k, _ = dynamics.expected_response(
            pu_table, end_b[i][None, :], edge_b[i],
            active=member[i][None, :], counts=(n_e_k, cand_c[i]),
            cloud_mult=mult_k[:, None], calib=calib, xp=jnp)  # (K,)
        score = jnp.where(feas[i], ms_k, jnp.inf)
        j = score.argmin()
        cur = idx[i]
        new = jnp.where(score[j] < score[cur] - BEST_RESPONSE_TOL, j,
                        cur).astype(idx.dtype)
        edge_tot = edge_tot.at[e_i].add(cand_e[i, new] - e_cnt[i])
        cloud_tot = cloud_tot + cand_c[i, new] - c_cnt[i]
        return (idx.at[i].set(new), e_cnt.at[i].set(cand_e[i, new]),
                c_cnt.at[i].set(cand_c[i, new]), edge_tot, cloud_tot)

    idx, _, _, _, _ = jax.lax.fori_loop(
        0, cells, body, (idx, e_cnt, c_cnt, edge_tot, cloud_tot))
    return idx


def topology_bruteforce(scen: FleetScenario, pu_table: jnp.ndarray,
                        threshold: float = 0.0, max_rounds: int = 50,
                        chunk: int = 4096):
    """Coupled-fleet oracle: coordinate descent by best response.

    Once cells share an edge or queue at the cloud, the per-cell argmax
    of ``_isolated_bruteforce`` is no longer exact — one cell's best
    decision depends on its neighbors'. Starting from the isolated
    optimum, this sweeps the fleet in Gauss-Seidel rounds (each cell
    best-responds to every other cell's current decision; feasibility
    depends only on a cell's own action, so the filter is exact) until a
    full round changes nothing — a pure equilibrium of the resulting
    congestion game, the standard orchestration target for this
    coupling — or ``max_rounds`` sweeps.

    Returns ``((cells,) ms, (cells,) index, converged, rounds)`` where
    ``ms`` is each cell's nominal-load expected response under shared
    contention and ``converged`` reports the fixed-point check (False
    means a best-response cycle was cut off at ``max_rounds`` and the
    result is the last sweep, still feasible but possibly unstable).
    Without an attached topology this is exactly the isolated oracle
    (converged in 0 rounds).
    """
    if scen.topo is None:
        ms, idx = _isolated_bruteforce(scen, pu_table, threshold, chunk)
        return ms, idx, True, 0
    # isolated optimum as the starting point (also raises on an
    # infeasible threshold — feasibility is contention-independent)
    _, idx = _isolated_bruteforce(scen, pu_table, threshold, chunk)
    # round-invariant (cells, K) tables, built chunked over K so the
    # cells x chunk x N intermediate stays as bounded as the isolated
    # oracle's: the feasibility filter and the per-candidate edge/cloud
    # offload counts under nominal (member) load
    member = np.asarray(scen.member)
    cells_n, K = member.shape[0], pu_table.shape[0]
    nm = np.maximum(member.sum(-1), 1)[:, None]
    any_m = member.any(-1)[:, None]
    pu_np = np.asarray(pu_table)
    feas_np = np.empty((cells_n, K), bool)
    cand_e_np = np.empty((cells_n, K), np.int32)
    cand_c_np = np.empty((cells_n, K), np.int32)
    for lo in range(0, K, chunk):
        pu = pu_np[lo:lo + chunk]                            # (k, N)
        acc = dynamics.accuracies(pu)
        macc = np.where(any_m,
                        (acc[None] * member[:, None, :]).sum(-1) / nm,
                        100.0)
        feas_np[:, lo:lo + chunk] = dynamics.feasible(macc, threshold)
        cand_e_np[:, lo:lo + chunk] = ((pu[None] == dynamics.A_EDGE)
                                       & member[:, None, :]).sum(-1)
        cand_c_np[:, lo:lo + chunk] = ((pu[None] == dynamics.A_CLOUD)
                                       & member[:, None, :]).sum(-1)
    feas = jnp.asarray(feas_np)
    cand_e, cand_c = jnp.asarray(cand_e_np), jnp.asarray(cand_c_np)
    topo = scen.topo
    converged, rounds = False, 0
    for rounds in range(1, max_rounds + 1):
        new_idx = _best_response_round(
            idx, pu_table, scen.end_b, scen.edge_b, scen.member, feas,
            cand_e, cand_c, topo.cell_edge, topo.edge_capacity,
            topo.cloud_servers, calib=scen.calib)
        if bool((new_idx == idx).all()):
            converged = True
            break
        idx = new_idx
    ms, _ = topology.fleet_topology_expected_response(
        pu_table[idx], scen.end_b, scen.edge_b, topo, scen.member,
        calib=scen.calib)
    return ms, idx, converged, rounds


