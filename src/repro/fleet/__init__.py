"""repro.fleet — vectorized fleet simulation and population-scale RL.

See README.md in this directory for the cell/fleet abstraction and how
it maps back to the paper's single-cell testbed.

Layering: ``dynamics`` is a leaf module, deliberately free of
``repro.core`` imports, and is the only part of this package that
``core.env`` depends on. ``scenarios``/``population``/``api`` import
from core, so they are loaded lazily here (module ``__getattr__``) —
importing ``repro.core`` pulls in ``repro.fleet`` without ever
executing them, keeping the core <-> fleet dependency acyclic
regardless of which package is imported first. ``api`` is the front
door (ScenarioSource / FleetPolicy / route-to-serving); see its
docstring and README.md.
"""
from repro.fleet import dynamics
from repro.fleet.dynamics import (Calibration, accuracies,
                                  calibrated_response_times,
                                  cell_response_times, expected_response,
                                  feasible,
                                  fleet_actions_expected_response,
                                  fleet_expected_response,
                                  response_components, response_times,
                                  reward, t_comp_device, user_tier)

_SCENARIOS = ("FleetConfig", "FleetScenario", "arrivals_from_timestamps",
              "diurnal_rate", "heterogeneous_sizes", "init_fleet",
              "init_links", "make_topology", "mixed_table5_fleet",
              "poisson_active", "step_churn", "step_fleet", "step_links",
              "table5_fleet", "with_topology")
_POPULATION = ("FleetQConfig", "FleetQLearning", "FleetTrainResult",
               "check_pad_width", "default_actions", "fleet_bruteforce",
               "fleet_metrics", "make_fleet_env_step",
               "nominal_expected_response", "place_metrics",
               "resolve_source", "simulate_responses",
               "topology_bruteforce", "train_against_oracle")
_API = ("FleetOrchestrator", "FleetPolicy", "FleetTrace", "OraclePolicy",
        "RouteResult", "ScenarioSource", "ServedRequest", "StatelessPolicy",
        "StaticPolicy", "SyntheticSource", "TraceSource", "load_trace",
        "make_env_step", "record_trace", "save_trace")
_TOPOLOGY = ("Topology", "cloud_load_multiplier", "edge_capacities",
             "edge_utilization", "fleet_topology_expected_response",
             "hot_edge_topology", "identity_topology", "is_shard_local",
             "random_topology", "shard_blocks", "shared_contention",
             "skewed_topology", "step_edge_failures",
             "topology_expected_response", "topology_response_times")
_REPLAY = ("FleetReplay", "replay_init", "replay_push", "replay_sample",
           "replay_size")
_SHARD = ("FLEET_AXIS", "check_shard_local", "constrain_array",
          "constrain_scenario", "fleet_mesh", "fleet_spec",
          "local_contention", "local_expected_response", "replicate",
          "shard_array", "shard_replay", "shard_scenario",
          "shard_topology")
_POLICY = ("FleetDQN", "FleetDQNConfig", "HoldoutEval",
           "encode_fleet_state", "holdout_reward_ratio")
_CALIBRATE = ("CalibratedDynamics", "CalibrationFit", "apply_calibration",
              "calibrate_serving", "calibration_report", "fit_calibration")

__all__ = [
    "dynamics", "Calibration", "accuracies", "calibrated_response_times",
    "cell_response_times", "expected_response", "feasible",
    "fleet_actions_expected_response", "fleet_expected_response",
    "response_components", "response_times", "reward", "t_comp_device",
    "user_tier",
    *_SCENARIOS, *_POPULATION, *_API, *_REPLAY, *_POLICY, *_TOPOLOGY,
    *_SHARD, *_CALIBRATE,
]


def __getattr__(name):
    import importlib
    if name in _SCENARIOS or name == "scenarios":
        mod = importlib.import_module("repro.fleet.scenarios")
    elif name in _API or name == "api":
        mod = importlib.import_module("repro.fleet.api")
    elif name in _POPULATION or name == "population":
        mod = importlib.import_module("repro.fleet.population")
    elif name in _REPLAY or name == "replay":
        mod = importlib.import_module("repro.fleet.replay")
    elif name in _POLICY or name == "policy":
        mod = importlib.import_module("repro.fleet.policy")
    elif name in _TOPOLOGY or name == "topology":
        mod = importlib.import_module("repro.fleet.topology")
    elif name in _SHARD or name == "shard":
        mod = importlib.import_module("repro.fleet.shard")
    elif name in _CALIBRATE or name == "calibrate":
        mod = importlib.import_module("repro.fleet.calibrate")
    else:
        raise AttributeError(
            f"module 'repro.fleet' has no attribute {name!r}")
    return (mod if name in ("scenarios", "population", "api", "replay",
                            "policy", "topology", "shard", "calibrate")
            else getattr(mod, name))
