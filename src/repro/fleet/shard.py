"""Device-sharded fleet execution: the cell population over a 1-D mesh.

Everything fleet-shaped so far lives on one device: the ``(cells,
states, actions)`` Q-table, the ``FleetScenario`` arrays, the pooled
replay rows, and the topology segment-sums. The ROADMAP's north star is
millions of users, which means the *fleet axis itself* must span
devices. This module is that layer — MaxText-style logical-axis
data-parallelism (``repro.distributed.sharding``'s ``cells`` / ``edges``
rules) over a 1-D ``('fleet',)`` mesh:

* **Placement** — ``fleet_mesh()`` builds the mesh; ``shard_scenario``
  / ``shard_array`` / ``shard_replay`` place fleet state with
  ``jax.sharding.NamedSharding`` (cells axis split into contiguous
  per-device blocks, everything else replicated), and the
  ``constrain_*`` twins re-assert the layout inside jitted steps. Every
  fleet computation is already pure and jitted, so XLA's SPMD
  partitioner runs each cell's dynamics, TD update, and scenario
  transition on the device that owns the cell — bit-identically to the
  single-device path (asserted in ``tests/test_fleet_shard.py``):
  per-cell work is elementwise along the fleet axis, and the only
  cross-cell reductions (topology job totals) are integer sums, which
  are associative exactly.
* **Cross-shard topologies** — once cells sharing an edge live on
  different devices, the per-edge segment-sum becomes a cross-device
  reduction. Two shipped answers, benchmarked against each other in
  ``benchmarks/bench_fleet_sharded.py``:
  (a) the **locality-capped generator**
  (``topology.random_topology(..., shard_local=True)``) keeps every
  edge's cells inside one device block, so ``local_contention`` — a
  ``shard_map`` over the fleet axis — aggregates entirely on-device
  (the one cross-device term left is a scalar ``psum`` for the cloud
  queue), and
  (b) the **all-to-all path**: any assignment through the unchanged
  ``topology.shared_contention`` under GSPMD, which turns the
  segment-sum into the compiler's cross-device reduction.
* **Training** — ``FleetQLearning(..., mesh=)`` shards the Q-table and
  scenario along cells (the update is per-cell, so it never leaves the
  shard); ``FleetDQN(..., mesh=)`` replicates params and optimizer
  state, shards the scenario stream along cells and the replay ring by
  slot blocks (``shard_replay``), and the mini-batch loss mean becomes
  the partitioner's cross-device grad reduction — standard
  replicate-the-policy / shard-the-population data parallelism.
* **Fused RL ops under the mesh** — the agents' default
  ``impl='pallas'`` hot path (ISSUE-10) gates itself here: GSPMD
  cannot partition a ``pallas_call``, so
  ``kernels.ops.resolve_rl_impl`` resolves ``'pallas'`` to the fused
  *jnp* formulation whenever a mesh is attached. That formulation is
  per-cell elementwise plus reduces along the (replicated) action
  axis — the same op classes as the legacy step — so sharded fused
  training stays bit-identical to single-device fused AND to the
  legacy unfused path (``tests/test_fleet_shard.py::
  test_fused_impl_sharded_training_bit_parity``). Running the compiled
  kernel per shard via ``shard_map`` is the open follow-up; it needs a
  TPU mesh to be worth wiring.

CPU-testable: ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
forces an 8-device host platform (no accelerator needed); with a
single device every helper degenerates to a no-op placement, and with
``mesh=None`` they are exact identities.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import sharding
from repro.fleet import dynamics, topology
from repro.fleet.scenarios import FleetScenario
from repro.fleet.topology import Topology, shard_blocks

__all__ = [
    "FLEET_AXIS", "fleet_mesh", "fleet_spec", "shard_array",
    "constrain_array", "replicate", "shard_topology", "shard_scenario",
    "constrain_scenario", "shard_replay", "local_contention",
    "local_expected_response", "check_shard_local",
]

#: the one mesh axis of fleet data parallelism (see
#: ``distributed.sharding.RULES['cells'/'edges']``)
FLEET_AXIS = "fleet"


def fleet_mesh(n_devices: Optional[int] = None, devices=None) -> Mesh:
    """A 1-D ``('fleet',)`` mesh over ``devices`` (default: all local
    devices, optionally capped at ``n_devices``)."""
    devices = list(devices if devices is not None else jax.devices())
    if n_devices is not None:
        devices = devices[:n_devices]
    return Mesh(np.asarray(devices), (FLEET_AXIS,))


def fleet_spec(mesh: Mesh, shape, axis: int = 0,
               logical: str = "cells") -> P:
    """`PartitionSpec` sharding dimension ``axis`` of ``shape`` along
    the fleet axis, through the logical-axis rule table (so a dimension
    the mesh does not divide falls back to replication instead of
    erroring, exactly like the model shardings)."""
    axes = (None,) * axis + (logical,) + (None,) * (len(shape) - axis - 1)
    return sharding.spec_for(shape, axes, mesh)


def shard_array(x, mesh: Optional[Mesh], axis: int = 0,
                logical: str = "cells"):
    """Place ``x`` with dimension ``axis`` split over the fleet axis
    (identity when ``mesh`` is None)."""
    if mesh is None:
        return x
    x = jnp.asarray(x)
    return jax.device_put(x, NamedSharding(mesh, fleet_spec(mesh, x.shape,
                                                            axis, logical)))


def constrain_array(x, mesh: Optional[Mesh], axis: int = 0,
                    logical: str = "cells"):
    """`with_sharding_constraint` twin of ``shard_array`` — safe both
    inside jit (a layout constraint for the partitioner) and eagerly (a
    commit). Values are never changed, only placement."""
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, fleet_spec(mesh, x.shape, axis, logical)))


def replicate(tree, mesh: Optional[Mesh]):
    """Replicate every leaf of ``tree`` across the mesh (the placement
    for DQN params / optimizer state; identity when ``mesh`` is None)."""
    if mesh is None:
        return tree
    s = NamedSharding(mesh, P())
    return jax.tree_util.tree_map(lambda x: jax.device_put(x, s), tree)


def _map_topology(topo: Optional[Topology], mesh: Optional[Mesh], place):
    if topo is None or mesh is None:
        return topo
    # capacities replicate: the all-to-all path indexes them by
    # arbitrary cell_edge values; shard-local aggregation re-blocks
    # them itself (``local_contention``)
    return Topology(
        place(topo.cell_edge, mesh, 0, "cells"),
        replicate(topo.edge_capacity, mesh),
        replicate(topo.cloud_servers, mesh))


def shard_topology(topo: Optional[Topology],
                   mesh: Optional[Mesh]) -> Optional[Topology]:
    """``cell_edge`` rides with its cells; capacities and the cloud
    queue size replicate."""
    return _map_topology(topo, mesh, shard_array)


def _constrain_replicated(tree, mesh: Optional[Mesh]):
    """Jit-safe twin of ``replicate``: constrain every leaf to the
    fully-replicated layout (identity when ``mesh`` is None)."""
    if mesh is None:
        return tree
    s = NamedSharding(mesh, P())
    return jax.tree_util.tree_map(
        lambda x: jax.lax.with_sharding_constraint(x, s), tree)


def _map_scenario(s: FleetScenario, mesh: Optional[Mesh], place,
                  place_topo, place_rep) -> FleetScenario:
    if mesh is None:
        return s
    # calib is tier-indexed (3,) metadata, not per-cell: replicate it
    return FleetScenario(
        place(s.end_b, mesh), place(s.edge_b, mesh), place(s.member, mesh),
        place(s.active, mesh), s.t, place_topo(s.topo, mesh),
        None if s.calib is None else place_rep(s.calib, mesh))


def shard_scenario(s: FleetScenario,
                   mesh: Optional[Mesh]) -> FleetScenario:
    """Place a ``FleetScenario`` with every per-cell leaf split along
    the fleet axis (``t``, topology metadata, and any calibration
    replicated)."""
    return _map_scenario(s, mesh, shard_array, shard_topology, replicate)


def constrain_scenario(s: FleetScenario,
                       mesh: Optional[Mesh]) -> FleetScenario:
    """Jit-safe sharding constraint over a whole scenario — what the
    sources' ``step`` applies so the layout survives ``lax.scan``."""
    return _map_scenario(
        s, mesh, constrain_array,
        lambda t, m: _map_topology(t, m, constrain_array),
        _constrain_replicated)


def shard_replay(buf, mesh: Optional[Mesh]):
    """Distribute a ``FleetReplay``'s transition rows across the mesh
    (``ptr``/``full`` replicate).

    The split is along the ring's SLOT axis — contiguous blocks of
    buffer capacity per device — not along cells: the ring is
    slot-major, so a step's ``(cells, ...)`` push lands in one slot
    window and uniform sampling gathers from all devices; the
    partitioner inserts the resharding collectives inside the training
    scan. That trades some per-step communication for an evenly split
    buffer footprint (the capacity no longer has to fit one device).
    Values are bit-identical either way; a cell-major ring that keeps
    pushes device-local is the noted follow-up."""
    if mesh is None:
        return buf
    return dataclasses.replace(
        buf,
        s=shard_array(buf.s, mesh), a=shard_array(buf.a, mesh),
        r=shard_array(buf.r, mesh), s2=shard_array(buf.s2, mesh),
        ptr=replicate(buf.ptr, mesh), full=replicate(buf.full, mesh))


# ---------------------------------------------------------------------------
# shard-local topology aggregation
# ---------------------------------------------------------------------------


def check_shard_local(topo: Topology, mesh: Mesh) -> None:
    """Raise unless ``topo`` satisfies the shard-locality invariant for
    ``mesh``. Skipped under tracing, where values are abstract — which
    is why anything that can SILENTLY break the invariant mid-run is
    rejected up front instead (``FleetConfig`` refuses
    ``shard_local=True`` together with ``p_edge_fail``, whose reroutes
    cross device blocks)."""
    if isinstance(topo.cell_edge, jax.core.Tracer):
        return
    n = mesh.shape[FLEET_AXIS]
    if not topology.is_shard_local(topo, n):
        raise ValueError(
            f"topology is not shard-local over {n} devices: at least one "
            "edge's cells span device blocks — generate it with "
            "random_topology(..., shard_local=True) or use the all-to-all "
            "path (topology.shared_contention) instead")


def local_contention(per_user, topo: Topology, mesh: Mesh, active=None):
    """Shard-local twin of ``topology.shared_contention``: per-edge job
    totals aggregated entirely on the device owning the edge.

    Requires a shard-local topology (every edge's cells inside one
    contiguous device block — ``random_topology(..., shard_local=True)``
    over ``mesh``'s device count). Under ``shard_map`` each device
    segment-sums only its own block of cells into its own block of
    edges with LOCAL edge ids; the sole cross-device term is the scalar
    ``psum`` of the fleet-wide cloud count. Returns the same
    ``(n_edge_eff, n_cloud, cloud_mult)`` seam tuple, bit-identical to
    the global path (integer totals; asserted in
    ``tests/test_fleet_shard.py``).
    """
    check_shard_local(topo, mesh)
    n_shards = mesh.shape[FLEET_AXIS]
    _, epb = shard_blocks(topo.cells, topo.n_edges, n_shards)
    if active is None:
        active = jnp.ones(jnp.asarray(per_user).shape, bool)
    # per-edge capacities enter block-sharded through the 'edges'
    # logical-axis rule (shard_blocks guarantees divisibility, so this
    # always resolves to a real fleet split, never the fallback)
    cap_spec = fleet_spec(mesh, topo.edge_capacity.shape, 0, "edges")

    def block(pu, act, ce, cap, cloud_servers):
        at_edge = (pu == dynamics.A_EDGE) & act
        at_cloud = (pu == dynamics.A_CLOUD) & act
        e_cnt = at_edge.sum(-1)
        c_cnt = at_cloud.sum(-1)
        local = ce % epb                   # block-aligned global -> local id
        edge_tot = jax.ops.segment_sum(e_cnt, local, num_segments=epb)
        n_e_eff = edge_tot[local] / cap[local]
        tot_cloud = jax.lax.psum(c_cnt.sum(), FLEET_AXIS)
        mult = topology.cloud_load_multiplier(tot_cloud, cloud_servers,
                                              xp=jnp)
        return n_e_eff, c_cnt, mult

    f = shard_map(
        block, mesh=mesh,
        in_specs=(P(FLEET_AXIS), P(FLEET_AXIS), P(FLEET_AXIS),
                  cap_spec, P()),
        out_specs=(P(FLEET_AXIS), P(FLEET_AXIS), P()))
    return f(jnp.asarray(per_user), jnp.asarray(active),
             topo.cell_edge, topo.edge_capacity,
             jnp.asarray(topo.cloud_servers))


def local_expected_response(per_user, end_b, edge_b, topo: Topology,
                            mesh: Mesh, active=None):
    """Shard-local twin of ``topology.topology_expected_response``:
    the same ``counts`` / ``cloud_mult`` seam into
    ``dynamics.expected_response``, with the edge aggregation kept
    on-device by ``local_contention``."""
    n_e, n_c, mult = local_contention(per_user, topo, mesh, active=active)
    return dynamics.expected_response(per_user, end_b, edge_b,
                                      active=active, counts=(n_e, n_c),
                                      cloud_mult=mult, xp=jnp)
