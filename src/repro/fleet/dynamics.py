"""Single source of truth for the calibrated end-edge-cloud latency /
accuracy model (paper §3, §5; DESIGN.md §5), array-shaped.

Everything here is a *pure function of arrays* with no environment state:

  response_times(per_user, end_b, edge_b)    (..., N) -> (..., N) ms
  accuracies(per_user)                       (..., N) -> (..., N) top-5 %
  expected_response(per_user, end_b, edge_b) (..., N) -> ((...,), (...,))

All functions take an ``xp`` module parameter (``numpy`` by default,
``jax.numpy`` for jitted fleet execution) and broadcast over arbitrary
leading batch dimensions, so the same kernel backs

* the scalar ``EndEdgeCloudEnv.response_times`` (shape ``(N,)``),
* the oracle's ``expected_response_batch`` (shape ``(K, N)``), and
* the fleet simulator's ``(cells, N)`` batch under ``jax.jit``/``vmap``
  (see ``cell_response_times`` / ``fleet_expected_response``).

The scalar and batched paths in the seed's ``env.py`` had drifted on how
the edge memory-busy penalty was applied (an additive correction term in
the scalar path vs a multiplicative factor in the batch path); this
kernel applies the penalty multiplicatively to the edge compute term in
both, which is what the two drifting forms both reduce to.

Calibrated anchors (see env.py module docstring for the full table):
d0 local 459 ms, cloud@1 ~364 ms, edge-only@5 ~1195 ms, all-d7 72 ms.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.edge_ladder import MOBILENET_TABLE4

# Per-user action ids, mirroring repro.core.spaces. Kept as literals here
# so this module never imports repro.core (core.env wraps this kernel, and
# a core import from here would close an import cycle); a parity test
# pins them to the canonical values in spaces.py.
A_EDGE, A_CLOUD = 8, 9

# ---- model ladder metadata (paper Table 4) --------------------------------
MACS = np.array([m for _, m, _, _, _ in MOBILENET_TABLE4], np.float64)
IS_INT8 = np.array([dt == "int8" for _, _, dt, _, _ in MOBILENET_TABLE4])
TOP5 = np.array([t5 for _, _, _, _, t5 in MOBILENET_TABLE4], np.float64)
TOP1 = np.array([t1 for _, _, _, t1, _ in MOBILENET_TABLE4], np.float64)

# ---- calibrated constants (ms) --------------------------------------------
# _fit: device fp32 affine from (d0=459, 85%-row d2=158.4) -> a=50.8 b=0.7175
#       device int8 affine from (Min row d7=50.7, 89%-row d4=223) -> a=37.3 b=0.326
A_FP32, B_FP32 = 50.8, 0.7175          # ms, ms/MMAC
A_INT8, B_INT8 = 37.3, 0.326
TIER_SPEED = {"S": 1.0, "E": 2.0, "C": 4.0}   # vCPUs 1/2/4 (Table 6)
TIER_CORES = {"E": 2.0, "C": 4.0}
T_ORCH = {0: 21.4, 1: 141.0}           # B regular/weak (Table 12 totals)
T_UP_EDGE = {0: 120.0, 1: 280.0}       # image upload device->edge
T_HOP_CLOUD = {0: 108.0, 1: 230.0}     # edge->cloud hop
EDGE_LINK_CAP = 1.3
CLOUD_LINK_CAP = 2.4
MEM_BUSY_PENALTY = 1.15
EDGE_MEM_BUSY_AT = 2                   # > jobs at edge -> memory pressure
CLOUD_MEM_BUSY_AT = 3
MAX_RESPONSE_MS = 2500.0               # reward floor (constraint violation)

# array forms of the B-indexed constants, for vectorized indexing
T_ORCH_MS = np.array([T_ORCH[0], T_ORCH[1]], np.float64)
T_UP_EDGE_MS = np.array([T_UP_EDGE[0], T_UP_EDGE[1]], np.float64)
T_HOP_CLOUD_MS = np.array([T_HOP_CLOUD[0], T_HOP_CLOUD[1]], np.float64)


@dataclasses.dataclass
class Scenario:
    """Network-condition scenario (paper Table 5): 0=Regular, 1=Weak."""
    name: str
    end_b: Tuple[int, ...]            # per end-node
    edge_b: int

    @staticmethod
    def from_string(name: str, pattern: str):
        """pattern like 'RWRWR|W' (5 end-nodes | edge)."""
        ends, edge = pattern.split("|")
        conv = {"R": 0, "W": 1}
        return Scenario(name, tuple(conv[c] for c in ends), conv[edge])


# paper Table 5
EXPERIMENTS = {
    "EXP-A": Scenario.from_string("EXP-A", "RRRRR|R"),
    "EXP-B": Scenario.from_string("EXP-B", "RWRWR|W"),
    "EXP-C": Scenario.from_string("EXP-C", "WWWRR|R"),
    "EXP-D": Scenario.from_string("EXP-D", "WWWWW|W"),
}


def t_comp_device(model_id, xp=np):
    """Compute time (ms) of model d_i on the end device (affine in MACs)."""
    m = xp.asarray(model_id)
    macs = xp.asarray(MACS)[m]
    int8 = xp.asarray(IS_INT8)[m]
    return xp.where(int8, A_INT8 + B_INT8 * macs, A_FP32 + B_FP32 * macs)


def response_times(per_user, end_b, edge_b, *, counts=None, active=None,
                   cloud_mult=None, calib=None, xp=np):
    """Per-user response time (ms), noise-free.

    per_user : (..., N) int  per-user action ids (0..7 local, 8 edge, 9 cloud)
    end_b    : (..., N) int  per-end-node link state (0 Regular, 1 Weak)
    edge_b   : (...,)   int  edge backhaul link state
    counts   : optional (n_edge, n_cloud) override of contention counts —
               the seam ``fleet.topology`` feeds shared (cross-cell,
               capacity-scaled) contention through; may be fractional
    active   : optional (..., N) bool; inactive users produce 0 ms and do
               not contribute to edge/cloud contention
    cloud_mult : optional queueing multiplier on the cloud-side terms
               (the edge->cloud hop and cloud compute, not the device
               upload), broadcastable against ``(..., N)`` — see
               ``fleet.topology.cloud_load_multiplier``
    calib    : optional ``Calibration`` — routes through the calibrated
               component path (``calibrated_response_times``); ``None``
               keeps the uncalibrated code path bit-identical

    Broadcasts over leading batch dims; ``xp`` selects numpy vs jax.numpy.
    """
    if calib is not None:
        return calibrated_response_times(
            per_user, end_b, edge_b, calib, counts=counts, active=active,
            cloud_mult=cloud_mult, xp=xp)
    per_user = xp.asarray(per_user)
    end_b = xp.asarray(end_b)
    edge_b = xp.asarray(edge_b)
    local = per_user < A_EDGE
    at_edge = per_user == A_EDGE
    at_cloud = per_user == A_CLOUD
    if active is not None:
        active = xp.asarray(active)
        at_edge = at_edge & active
        at_cloud = at_cloud & active
        local = local & active
    if counts is None:
        n_e = at_edge.sum(-1)[..., None]
        n_c = at_cloud.sum(-1)[..., None]
    else:
        n_e = xp.asarray(counts[0])[..., None]
        n_c = xp.asarray(counts[1])[..., None]

    t = xp.asarray(T_ORCH_MS)[end_b]
    # local compute: chosen model at device speed
    t = t + xp.where(local, t_comp_device(xp.where(local, per_user, 0), xp),
                     0.0)
    # edge: upload (shared link) + d0 at edge speed (processor sharing),
    # memory-busy penalty on the compute term
    up_e = xp.asarray(T_UP_EDGE_MS)[end_b]
    comp_e = t_comp_device(0, xp) / TIER_SPEED["E"]
    cpu_e = xp.maximum(1.0, n_e / TIER_CORES["E"])
    link_e = xp.maximum(1.0, n_e / EDGE_LINK_CAP)
    mem_e = xp.where(n_e > EDGE_MEM_BUSY_AT, MEM_BUSY_PENALTY, 1.0)
    t_e = up_e * link_e + comp_e * cpu_e * mem_e
    t = t + xp.where(at_edge, t_e, 0.0)
    # cloud: upload + edge->cloud hop (shared) + d0 at cloud speed
    comp_c = t_comp_device(0, xp) / TIER_SPEED["C"]
    cpu_c = xp.maximum(1.0, n_c / TIER_CORES["C"])
    link_c = xp.maximum(1.0, n_c / CLOUD_LINK_CAP)
    mem_c = xp.where(n_c > CLOUD_MEM_BUSY_AT, MEM_BUSY_PENALTY, 1.0)
    hop_c = xp.asarray(T_HOP_CLOUD_MS)[edge_b][..., None] * link_c
    comp_term = comp_c * cpu_c * mem_c
    if cloud_mult is not None:
        hop_c = hop_c * cloud_mult
        comp_term = comp_term * cloud_mult
    t_c = up_e * link_c + hop_c + comp_term
    t = t + xp.where(at_cloud, t_c, 0.0)
    if active is not None:
        t = xp.where(active, t, 0.0)
    return t


def accuracies(per_user, xp=np):
    """Per-user top-5 accuracy (%): offloaded users run d0."""
    per_user = xp.asarray(per_user)
    return xp.asarray(TOP5)[xp.where(per_user < A_EDGE, per_user, 0)]


# ---------------------------------------------------------------------------
# sim-to-real calibration seam (repro.fleet.calibrate fits these)
# ---------------------------------------------------------------------------

#: Tier order used by Calibration arrays: index 0=S (end device), 1=E, 2=C.
CALIB_TIERS = ("S", "E", "C")


class Calibration(NamedTuple):
    """Per-tier sim-to-real corrections to the latency model.

    compute_scale : (3,) multiplier on the tier's *compute* component
                    (S/E/C order) — fitted so model compute tracks the
                    measured engine wall from ``gap_breakdown()``
    hop_offset_ms : (3,) additive offset (ms) on the tier's
                    *communication* component — absorbs per-hop constants
                    the affine model misses (may be negative)

    A NamedTuple of arrays is automatically a jax pytree, so a
    Calibration rides inside ``FleetScenario`` through jit/scan/shard
    unchanged. ``identity()`` is a no-op calibration (scale 1, offset 0).
    """
    compute_scale: np.ndarray
    hop_offset_ms: np.ndarray

    @staticmethod
    def identity(xp=np):
        return Calibration(xp.ones(3, xp.float64 if xp is np else None),
                           xp.zeros(3, xp.float64 if xp is np else None))


def user_tier(per_user, xp=np):
    """(..., N) action ids -> (..., N) tier index into CALIB_TIERS."""
    per_user = xp.asarray(per_user)
    return xp.where(per_user == A_EDGE, 1,
                    xp.where(per_user == A_CLOUD, 2, 0))


def response_components(per_user, end_b, edge_b, *, counts=None, active=None,
                        cloud_mult=None, xp=np):
    """Split ``response_times`` into (communication, compute) components.

    Same signature/broadcasting as ``response_times``; returns a
    ``(comm_ms, comp_ms)`` pair with ``comm + comp ≈ response_times``
    (allclose — the split re-associates the float sums). comm carries
    orchestration + upload/hop link terms; comp carries the device/edge/
    cloud model-execution terms (with processor-sharing, memory-penalty
    and ``cloud_mult`` factors on the compute term). This is the
    decomposition ``fleet.calibrate`` fits against the measured engine
    wall isolated by ``RouteResult.gap_breakdown()``.
    """
    per_user = xp.asarray(per_user)
    end_b = xp.asarray(end_b)
    edge_b = xp.asarray(edge_b)
    local = per_user < A_EDGE
    at_edge = per_user == A_EDGE
    at_cloud = per_user == A_CLOUD
    if active is not None:
        active = xp.asarray(active)
        at_edge = at_edge & active
        at_cloud = at_cloud & active
        local = local & active
    if counts is None:
        n_e = at_edge.sum(-1)[..., None]
        n_c = at_cloud.sum(-1)[..., None]
    else:
        n_e = xp.asarray(counts[0])[..., None]
        n_c = xp.asarray(counts[1])[..., None]

    comm = xp.asarray(T_ORCH_MS)[end_b]
    comp = xp.where(local,
                    t_comp_device(xp.where(local, per_user, 0), xp), 0.0)
    up_e = xp.asarray(T_UP_EDGE_MS)[end_b]
    comp_e = t_comp_device(0, xp) / TIER_SPEED["E"]
    cpu_e = xp.maximum(1.0, n_e / TIER_CORES["E"])
    link_e = xp.maximum(1.0, n_e / EDGE_LINK_CAP)
    mem_e = xp.where(n_e > EDGE_MEM_BUSY_AT, MEM_BUSY_PENALTY, 1.0)
    comm = comm + xp.where(at_edge, up_e * link_e, 0.0)
    comp = comp + xp.where(at_edge, comp_e * cpu_e * mem_e, 0.0)
    comp_c = t_comp_device(0, xp) / TIER_SPEED["C"]
    cpu_c = xp.maximum(1.0, n_c / TIER_CORES["C"])
    link_c = xp.maximum(1.0, n_c / CLOUD_LINK_CAP)
    mem_c = xp.where(n_c > CLOUD_MEM_BUSY_AT, MEM_BUSY_PENALTY, 1.0)
    hop_c = xp.asarray(T_HOP_CLOUD_MS)[edge_b][..., None] * link_c
    comp_term = comp_c * cpu_c * mem_c
    if cloud_mult is not None:
        hop_c = hop_c * cloud_mult
        comp_term = comp_term * cloud_mult
    comm = comm + xp.where(at_cloud, up_e * link_c + hop_c, 0.0)
    comp = comp + xp.where(at_cloud, comp_term, 0.0)
    if active is not None:
        comm = xp.where(active, comm, 0.0)
        comp = xp.where(active, comp, 0.0)
    return comm, comp


def calibrated_response_times(per_user, end_b, edge_b, calib, *, counts=None,
                              active=None, cloud_mult=None, xp=np):
    """Calibrated per-user response (ms):
    ``max(comm + hop_offset[tier] + compute_scale[tier] * comp, 0)``,
    inactive users masked to 0 as in ``response_times``."""
    comm, comp = response_components(per_user, end_b, edge_b, counts=counts,
                                     active=active, cloud_mult=cloud_mult,
                                     xp=xp)
    tier = user_tier(per_user, xp=xp)
    scale = xp.asarray(calib.compute_scale)[tier]
    off = xp.asarray(calib.hop_offset_ms)[tier]
    t = xp.maximum(comm + off + scale * comp, 0.0)
    if active is not None:
        t = xp.where(xp.asarray(active), t, 0.0)
    return t


def expected_response(per_user, end_b, edge_b, *, active=None, counts=None,
                      cloud_mult=None, calib=None, xp=np):
    """(mean response ms, mean top-5 accuracy) over the (last) user axis.

    With an ``active`` mask, means are over active users only. A cell
    with zero active users served nothing: it reports 0 ms and a
    vacuously-satisfying 100% accuracy, so it can never earn the
    constraint-violation reward floor for being idle. ``counts`` /
    ``cloud_mult`` / ``calib`` pass through to ``response_times`` (the
    ``fleet.topology`` shared-contention and sim-to-real calibration
    seams).
    """
    t = response_times(per_user, end_b, edge_b, active=active, counts=counts,
                       cloud_mult=cloud_mult, calib=calib, xp=xp)
    acc = accuracies(per_user, xp=xp)
    if active is None:
        return t.mean(-1), acc.mean(-1)
    n = xp.maximum(active.sum(-1), 1)
    mean_acc = xp.where(active, acc, 0.0).sum(-1) / n
    mean_acc = xp.where(active.any(-1), mean_acc, 100.0)
    return t.sum(-1) / n, mean_acc


def feasible(mean_acc, threshold, xp=np):
    """THE accuracy-constraint predicate (paper Eq. 4), shared by the
    scalar env, the oracles, and the fleet kernel so no two paths can
    disagree on feasibility. Absolute 1e-9 slack absorbs float roundoff;
    Table-4 accuracy means are spaced >= 0.02 apart, so no real decision
    lands inside the slack."""
    return xp.asarray(mean_acc) >= xp.asarray(threshold) - 1e-9


def reward(mean_ms, mean_acc, threshold, xp=np):
    """Paper Eq. 4: -mean response if the accuracy constraint holds,
    else the -MAX_RESPONSE_MS floor; scaled to ~[-2.5, 0]."""
    return xp.where(feasible(mean_acc, threshold, xp=xp),
                    -mean_ms, -MAX_RESPONSE_MS) / 1000.0


# ---------------------------------------------------------------------------
# jitted fleet entry points: one call steps every cell in the fleet.
# ---------------------------------------------------------------------------
def _cell_response(per_user, end_b, edge_b):
    return response_times(per_user, end_b, edge_b, xp=jnp)


#: (cells, N) actions + (cells, N) link states + (cells,) edge states
#: -> (cells, N) response ms, one jitted vmapped call for the whole fleet.
cell_response_times = jax.jit(jax.vmap(_cell_response))


@jax.jit
def fleet_expected_response(per_user, end_b, edge_b, active=None, calib=None):
    """(cells, N) batch -> ((cells,) mean ms, (cells,) mean accuracy).
    ``calib=None`` keeps the uncalibrated path; a ``Calibration`` pytree
    retraces once onto the calibrated component path."""
    return expected_response(per_user, end_b, edge_b, active=active,
                             calib=calib, xp=jnp)


@jax.jit
def fleet_actions_expected_response(per_user_k, end_b, edge_b, member=None,
                                    calib=None):
    """Evaluate K candidate joint actions for every cell at once (the
    inner kernel of ``population.fleet_bruteforce``).

    per_user_k : (K, N) decoded candidate actions (shared across cells)
    end_b      : (cells, N), edge_b: (cells,)
    member     : optional (cells, N) membership mask
    Returns ((cells, K) mean ms, mean accuracy) — accuracy is (1, K)
    without ``member`` (it depends only on the action), (cells, K) with.
    """
    active = None if member is None else member[:, None, :]
    return expected_response(per_user_k[None, :, :], end_b[:, None, :],
                             edge_b[:, None], active=active, calib=calib,
                             xp=jnp)
