"""The fleet front door: one seam from scenario data to real serving.

The paper's orchestrator is a single online loop — observe the network
and workload, pick a (tier, model-variant) per user, dispatch to a real
serving tier — but PRs 1-3 grew three ad-hoc entry styles: hand-built
``FleetScenario``s, two agents with divergent call signatures, and a
``FleetOrchestrator.route`` that stopped at the latency model. This
module is the redesigned API that every remaining ROADMAP item plugs
into:

* **`ScenarioSource`** — ``reset(key) -> (FleetScenario, state)`` /
  ``step(key, state) -> (FleetScenario, state)``, the one seam that
  feeds training, evaluation, and serving. `SyntheticSource` wraps the
  ``FleetConfig`` generators (bit-exactly: it delegates to
  ``init_fleet`` / ``step_fleet`` with the same keys, so every parity
  test keeps pinning the kernel). `TraceSource` replays a recorded
  `FleetTrace` — per-cell arrival timestamps, link-quality series, and
  an optional cells-per-edge deployment map that becomes
  ``Topology.cell_edge`` + capacity tiers — the evaluation style of
  DeepEdge (arXiv:2110.01863) and the delay-aware DRL offloading work
  of Ale et al. Both agents, ``make_fleet_env_step``, and
  ``train_against_oracle`` accept either.
* **`FleetPolicy`** — ``decisions(counts, scen)`` / ``expected(scen,
  counts)``, one surface over ``FleetQLearning``, ``FleetDQN``, the
  brute-force/best-response oracles (`OraclePolicy`), and the paper's
  fixed strategies (`StaticPolicy`), so the orchestrator, the
  benchmarks, and ``holdout_reward_ratio`` stop special-casing agents.
* **`FleetOrchestrator.route(..., dispatch=engines)`** — the serving
  bridge: routed (tier, variant) decisions drain into per-tier
  ``ServingEngine``s via ``RequestBatcher``, and the measured
  wall-times come back NEXT TO the latency model's predictions
  (`RouteResult`), the paper's Table-8 predicted-vs-measured
  methodology at fleet scale.

Every seam takes a ``mesh=`` knob (``repro.fleet.shard.fleet_mesh``):
sources place the scenario stream with the cell axis sharded across
devices, agents shard their per-cell state (or replicate the shared
policy) to match, and the orchestrator routes sharded fleets — the
single-device path is bit-identical (see ``fleet.shard``).

The PR-4 deprecation shims (``population.FleetOrchestrator``,
``make_fleet_env_step(FleetConfig)``) have been removed; see the
migration table in ``src/repro/fleet/README.md``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Protocol, Tuple, Union, \
    runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.fleet import dynamics, topology
from repro.obs import timeline
from repro.obs.metrics import MetricDef, MetricsAccumulator
from repro.obs.spans import span as _span
from repro.fleet.population import (check_pad_width, default_actions,
                                    fleet_bruteforce,
                                    nominal_expected_response)
from repro.fleet.scenarios import (FleetConfig, FleetScenario,
                                   arrivals_from_timestamps, init_fleet,
                                   step_fleet)
from repro.core.spaces import SpaceSpec

__all__ = [
    "ScenarioSource", "SyntheticSource", "TraceSource", "FleetTrace",
    "load_trace", "save_trace", "record_trace", "FleetPolicy",
    "OraclePolicy", "StatelessPolicy", "StaticPolicy", "FleetOrchestrator",
    "RouteResult", "ServedRequest", "make_env_step",
]


# ---------------------------------------------------------------------------
# ScenarioSource — the scenario seam
# ---------------------------------------------------------------------------


@runtime_checkable
class ScenarioSource(Protocol):
    """Anything that can produce a stream of ``FleetScenario``s.

    ``reset(key)`` yields the initial scenario plus an opaque source
    state; ``step(key, state)`` advances it. Both must be pure and
    jit/scan-safe. The built-in sources set ``state_is_scenario = True``
    (their state IS the scenario pytree), which is what the agents'
    jitted training loops require — they carry only the scenario.
    """

    cells: int
    users: int
    state_is_scenario: bool

    @property
    def dynamic(self) -> bool:
        """Does the scenario stream move between steps? (Drives the
        per-check oracle recompute in ``train_against_oracle``.)"""
        ...

    def reset(self, key) -> Tuple[FleetScenario, object]: ...

    def step(self, key, state) -> Tuple[FleetScenario, object]: ...


def is_source(obj) -> bool:
    """Duck-typed ScenarioSource check (a ``FleetScenario`` is not one)."""
    return callable(getattr(obj, "reset", None)) and \
        callable(getattr(obj, "step", None))


def require_scenario_state(source) -> None:
    """The jitted training loops carry only the scenario; reject sources
    whose step state is something richer, up front and clearly."""
    if not getattr(source, "state_is_scenario", False):
        raise TypeError(
            f"{type(source).__name__} must set state_is_scenario=True "
            "(its step state must BE the scenario) to drive a jitted "
            "fleet training loop; both built-in sources qualify")


class SyntheticSource:
    """`ScenarioSource` over the ``FleetConfig`` generators.

    ``reset`` is ``init_fleet(key, cfg)`` and ``step`` is
    ``step_fleet(key, scen, cfg)`` — same functions, same key usage, so
    the generated random streams are bit-exactly the pre-redesign ones
    (pinned by ``tests/test_fleet_api.py``). Pass ``scen`` to pin an
    explicitly built initial fleet (e.g. ``mixed_table5_fleet``);
    ``reset`` then returns it as-is, which is exactly how the agents'
    legacy ``(scen, FleetConfig)`` constructors behaved.

    With a ``mesh`` (``fleet.shard.fleet_mesh``) the stream is placed
    with the cell axis sharded: ``reset`` device-puts the initial
    scenario and ``step`` re-asserts the layout, so a jitted training
    scan keeps every cell's state on the device that owns it. Sharding
    never changes values — only placement.
    """

    state_is_scenario = True

    def __init__(self, cfg: FleetConfig,
                 scen: Optional[FleetScenario] = None, mesh=None):
        self.cfg = cfg
        self._scen0 = scen
        self.mesh = mesh

    def attach_mesh(self, mesh) -> None:
        """Adopt the agent's fleet mesh (no-op when None)."""
        if mesh is not None:
            self.mesh = mesh

    @property
    def cells(self) -> int:
        return self.cfg.cells if self._scen0 is None else self._scen0.cells

    @property
    def users(self) -> int:
        return self.cfg.users if self._scen0 is None else self._scen0.users

    @property
    def dynamic(self) -> bool:
        c = self.cfg
        return bool(c.p_r2w or c.p_w2r or c.p_join or c.p_leave
                    or c.p_edge_fail)

    def reset(self, key):
        scen = self._scen0 if self._scen0 is not None \
            else init_fleet(key, self.cfg)
        if self.mesh is not None:
            from repro.fleet import shard
            scen = shard.shard_scenario(scen, self.mesh)
        return scen, scen

    def step(self, key, state):
        scen = step_fleet(key, state, self.cfg)
        if self.mesh is not None:
            from repro.fleet import shard
            scen = shard.constrain_scenario(scen, self.mesh)
        return scen, scen


# ---------------------------------------------------------------------------
# recorded traces
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FleetTrace:
    """A recorded fleet workload: link-quality series + arrival events.

    end_b        : (T, cells, N) int   per-user end-link series (0 R, 1 W)
    edge_b       : (T, cells)    int   edge backhaul series
    arrival_time : (E,) float  request timestamps (seconds)
    arrival_cell : (E,) int    issuing cell of each request
    arrival_user : (E,) int    issuing user (slot in the cell's pad)
    step_duration: ()  float   seconds binned into one fleet step
    member       : optional (T, cells, N) or (cells, N) bool membership
                   (None = every slot is a member)
    cell_edge    : optional (cells,) deployment map — which edge PoP
                   serves each cell (becomes ``Topology.cell_edge``)
    edge_capacity: optional (n_edges,) capacity tiers for the PoPs
    cloud_servers: ()  float   M/M/c cloud queue size (inf = off)
    """
    end_b: np.ndarray
    edge_b: np.ndarray
    arrival_time: np.ndarray
    arrival_cell: np.ndarray
    arrival_user: np.ndarray
    step_duration: float = 1.0
    member: Optional[np.ndarray] = None
    cell_edge: Optional[np.ndarray] = None
    edge_capacity: Optional[np.ndarray] = None
    cloud_servers: float = float("inf")

    @property
    def horizon(self) -> int:
        return self.end_b.shape[0]

    @property
    def cells(self) -> int:
        return self.end_b.shape[1]

    @property
    def users(self) -> int:
        return self.end_b.shape[2]

    def member_frames(self) -> np.ndarray:
        """(T, cells, N) membership mask (broadcast if recorded static)."""
        if self.member is None:
            return np.ones(self.end_b.shape, bool)
        m = np.asarray(self.member, bool)
        if m.ndim == 2:
            m = np.broadcast_to(m[None], self.end_b.shape)
        return m

    def active_frames(self) -> np.ndarray:
        """(T, cells, N) request mask: membership AND >=1 arrival event
        binned into that step (``floor(arrival_time / step_duration)``)."""
        arr = arrivals_from_timestamps(
            self.arrival_time, self.arrival_cell, self.arrival_user,
            self.horizon, self.cells, self.users, self.step_duration)
        return self.member_frames() & arr

    def topology(self) -> Optional[topology.Topology]:
        """The recorded deployment map as a ``Topology`` (None if the
        trace has no ``cell_edge``)."""
        if self.cell_edge is None:
            return None
        cap = self.edge_capacity if self.edge_capacity is not None else \
            np.ones(int(np.max(self.cell_edge)) + 1, np.float32)
        return topology.Topology(
            jnp.asarray(self.cell_edge, jnp.int32),
            jnp.asarray(cap, jnp.float32),
            jnp.float32(self.cloud_servers))

    def validate(self) -> "FleetTrace":
        T, cells, users = self.end_b.shape
        if self.edge_b.shape != (T, cells):
            raise ValueError(f"edge_b shape {self.edge_b.shape} != "
                             f"{(T, cells)}")
        e = len(self.arrival_time)
        if len(self.arrival_cell) != e or len(self.arrival_user) != e:
            raise ValueError("arrival_time/cell/user lengths differ")
        if e:
            ac = np.asarray(self.arrival_cell)
            au = np.asarray(self.arrival_user)
            if ac.min() < 0 or ac.max() >= cells:
                raise ValueError(
                    f"arrival_cell out of range [0, {cells}): "
                    f"[{ac.min()}, {ac.max()}] — a negative index would "
                    "silently attribute events to the wrong cell")
            if au.min() < 0 or au.max() >= users:
                raise ValueError(f"arrival_user out of range [0, {users}): "
                                 f"[{au.min()}, {au.max()}]")
        if self.member is not None and \
                np.asarray(self.member).shape not in ((T, cells, users),
                                                      (cells, users)):
            raise ValueError(f"member shape {np.asarray(self.member).shape}"
                             f" fits neither {(T, cells, users)} nor "
                             f"{(cells, users)}")
        if self.cell_edge is not None:
            ce = np.asarray(self.cell_edge)
            if ce.shape != (cells,):
                raise ValueError(f"cell_edge shape {ce.shape} != {(cells,)}")
            n_edges = int(ce.max()) + 1 if len(ce) else 0
            if self.edge_capacity is not None and \
                    len(self.edge_capacity) < n_edges:
                raise ValueError("edge_capacity shorter than the deployment "
                                 "map's edge count")
        return self


_TRACE_OPTIONAL = ("member", "cell_edge", "edge_capacity")


def save_trace(path, trace: FleetTrace) -> None:
    """Write a ``FleetTrace`` as an ``.npz`` (the recorded-trace format
    ``load_trace`` / ``TraceSource`` read)."""
    trace.validate()
    arrays = dict(end_b=trace.end_b, edge_b=trace.edge_b,
                  arrival_time=trace.arrival_time,
                  arrival_cell=trace.arrival_cell,
                  arrival_user=trace.arrival_user,
                  step_duration=np.float64(trace.step_duration),
                  cloud_servers=np.float64(trace.cloud_servers))
    for name in _TRACE_OPTIONAL:
        v = getattr(trace, name)
        if v is not None:
            arrays[name] = np.asarray(v)
    np.savez(path, **arrays)


def load_trace(path) -> FleetTrace:
    """Read a trace ``.npz`` written by ``save_trace`` (round-trips all
    arrays bit-exactly)."""
    with np.load(path) as z:
        kw = {name: z[name] for name in _TRACE_OPTIONAL if name in z.files}
        return FleetTrace(end_b=z["end_b"], edge_b=z["edge_b"],
                          arrival_time=z["arrival_time"],
                          arrival_cell=z["arrival_cell"],
                          arrival_user=z["arrival_user"],
                          step_duration=float(z["step_duration"]),
                          cloud_servers=float(z["cloud_servers"]),
                          **kw).validate()


class TraceSource:
    """`ScenarioSource` that replays a recorded `FleetTrace`.

    Frames live on device; ``step`` is a pure gather of frame
    ``t % horizon`` (the trace wraps), so a ``TraceSource`` drives the
    same jitted ``lax.scan`` training loops as ``SyntheticSource`` —
    and ``make_fleet_env_step`` / ``train_against_oracle`` / both
    agents take it directly. The recorded deployment map (if any) rides
    on ``FleetScenario.topo``, so shared-edge contention and the
    coupled oracle apply automatically.
    """

    state_is_scenario = True

    def __init__(self, trace: FleetTrace, mesh=None):
        trace.validate()
        self.trace = trace
        self._end_b = jnp.asarray(trace.end_b, jnp.int32)
        self._edge_b = jnp.asarray(trace.edge_b, jnp.int32)
        self._member = jnp.asarray(trace.member_frames())
        self._active = jnp.asarray(trace.active_frames())
        self._topo = trace.topology()
        self.mesh = None
        self.attach_mesh(mesh)

    def attach_mesh(self, mesh) -> None:
        """Re-place the on-device frames with the CELL axis (dim 1 of
        the ``(T, cells, ...)`` stacks) sharded over ``mesh`` — each
        device then holds only its own cells' history, and the per-step
        frame gather is device-local (no-op when ``mesh`` is None)."""
        if mesh is None:
            return
        from repro.fleet import shard
        self.mesh = mesh
        self._end_b = shard.shard_array(self._end_b, mesh, axis=1)
        self._edge_b = shard.shard_array(self._edge_b, mesh, axis=1)
        self._member = shard.shard_array(self._member, mesh, axis=1)
        self._active = shard.shard_array(self._active, mesh, axis=1)
        self._topo = shard.shard_topology(self._topo, mesh)

    @classmethod
    def load(cls, path, mesh=None) -> "TraceSource":
        return cls(load_trace(path), mesh=mesh)

    @property
    def cells(self) -> int:
        return self.trace.cells

    @property
    def users(self) -> int:
        return self.trace.users

    @property
    def horizon(self) -> int:
        return self.trace.horizon

    @property
    def dynamic(self) -> bool:
        return self.trace.horizon > 1

    def _frame(self, t) -> FleetScenario:
        i = jnp.mod(t, self.horizon)
        scen = FleetScenario(self._end_b[i], self._edge_b[i],
                             self._member[i], self._active[i],
                             jnp.int32(t), self._topo)
        if self.mesh is not None:
            from repro.fleet import shard
            scen = shard.constrain_scenario(scen, self.mesh)
        return scen

    def reset(self, key):
        scen = self._frame(jnp.int32(0))
        return scen, scen

    def step(self, key, state):
        scen = self._frame(state.t + 1)
        return scen, scen


def record_trace(source, key, steps: int,
                 step_duration: float = 1.0) -> FleetTrace:
    """Run any `ScenarioSource` for ``steps`` steps and record the
    stream as a `FleetTrace` — synthetic fleets become replayable
    traces (``TraceSource(record_trace(src, key, n))`` replays the
    exact scenario frames). Arrival events are emitted mid-bin
    (``(t + 0.5) * step_duration``) so the timestamp binning
    round-trips exactly. The FIRST frame's topology is recorded as the
    deployment map (a mid-trace edge failure is not representable in
    the static map)."""
    end_b, edge_b, member, active = [], [], [], []
    key, k = jax.random.split(key)
    scen, state = source.reset(k)
    topo = scen.topo
    for _ in range(steps):
        end_b.append(np.asarray(scen.end_b))
        edge_b.append(np.asarray(scen.edge_b))
        member.append(np.asarray(scen.member))
        active.append(np.asarray(scen.active))
        key, k = jax.random.split(key)
        scen, state = source.step(k, state)
    t_idx, c_idx, u_idx = np.nonzero(np.stack(active))
    return FleetTrace(
        end_b=np.stack(end_b).astype(np.int32),
        edge_b=np.stack(edge_b).astype(np.int32),
        arrival_time=(t_idx + 0.5) * step_duration,
        arrival_cell=c_idx.astype(np.int32),
        arrival_user=u_idx.astype(np.int32),
        step_duration=step_duration,
        member=np.stack(member),
        **_deployment_fields(topo),
    )


def _deployment_fields(topo) -> dict:
    if topo is None:
        return {}
    return dict(cell_edge=np.asarray(topo.cell_edge, np.int32),
                edge_capacity=np.asarray(topo.edge_capacity, np.float32),
                cloud_servers=float(topo.cloud_servers))


def make_env_step(source, threshold: float = 0.0, noise: float = 0.02):
    """Pure per-step fleet environment transition over any
    `ScenarioSource` — returns ``env_step(key, scen, per_user) ->
    (scen2, counts, mean_ms, mean_acc, reward)``, jit/scan friendly.
    ``population.make_fleet_env_step`` forwards here."""
    from repro.fleet.population import simulate_responses
    require_scenario_state(source)

    def env_step(key, scen, per_user):
        k_noise, k_scen = jax.random.split(key)
        mean_ms, acc, counts = simulate_responses(k_noise, scen, per_user,
                                                  noise)
        r = dynamics.reward(mean_ms, acc, threshold, xp=jnp)
        scen2, _ = source.step(k_scen, scen)
        return scen2, counts, mean_ms, acc, r

    return env_step


# ---------------------------------------------------------------------------
# FleetPolicy — one policy surface
# ---------------------------------------------------------------------------


@runtime_checkable
class FleetPolicy(Protocol):
    """One decision surface over every routable thing: the tabular
    fleet agent, the shared-policy DQN, the brute-force/best-response
    oracles, and the static baselines. ``decisions`` returns
    ``((cells, N) per-user action ids, (cells,) joint ids)``;
    ``expected`` the noise-free ``((cells,) mean ms, mean acc)`` of the
    policy's greedy decision under nominal load."""

    @property
    def accuracy_threshold(self) -> float: ...

    def decisions(self, counts, scen: FleetScenario): ...

    def expected(self, scen: Optional[FleetScenario] = None, counts=None): ...


class StatelessPolicy:
    """Shared base of the policies that carry no learned state: the
    candidate action table (which doubles as the oracle set
    ``holdout_reward_ratio`` scores against), the QoS threshold, the
    protocol pad-width guard, and the ``decisions``-derived half of the
    `FleetPolicy` surface. Subclasses implement ``decisions``."""

    def __init__(self, users: int, actions: Optional[np.ndarray] = None,
                 threshold: float = 0.0):
        self.spec = SpaceSpec(users)
        acts = np.asarray(actions) if actions is not None else \
            default_actions(self.spec)
        self.pu_table = jnp.asarray(self.spec.decode_actions_batch(acts))
        self._threshold = float(threshold)

    @property
    def accuracy_threshold(self) -> float:
        return self._threshold

    def _check(self, scen: FleetScenario) -> None:
        check_pad_width(self.spec.n_users, scen, type(self).__name__)

    def _ids(self, dec) -> jnp.ndarray:
        return jnp.asarray(self.spec.encode_actions_batch(np.asarray(dec)))

    def decisions(self, counts, scen: FleetScenario):
        raise NotImplementedError

    def policy_decisions(self, counts, scen: FleetScenario):
        """FleetOrchestrator's legacy entry point, same contract."""
        return self.decisions(counts, scen)

    def expected(self, scen: Optional[FleetScenario] = None, counts=None):
        if scen is None:
            raise ValueError(f"{type(self).__name__} has no attached "
                             "scenario; pass scen=")
        per_user = self.decisions(counts, scen)[0]
        ms, acc = nominal_expected_response(scen, per_user)
        return np.asarray(ms), np.asarray(acc)


class OraclePolicy(StatelessPolicy):
    """The per-cell brute force — or, with an attached topology, the
    coupled best-response oracle — behind the `FleetPolicy` protocol.
    Stateless w.r.t. job counts (it optimizes the nominal-load expected
    response over the candidate set), so ``counts`` is ignored."""

    def decisions(self, counts, scen: FleetScenario):
        self._check(scen)
        _, idx = fleet_bruteforce(scen, self.pu_table, self._threshold)
        return self.pu_table[idx], self._ids(self.pu_table[idx])


class StaticPolicy(StatelessPolicy):
    """The paper's fixed strategies (§6.1) as a `FleetPolicy`: every
    user runs ``'device'`` (local d0), ``'edge'``, or ``'cloud'`` — or
    any explicit per-user action id."""

    STRATEGIES = {"device": 0, "edge": dynamics.A_EDGE,
                  "cloud": dynamics.A_CLOUD}

    def __init__(self, users: int, strategy: Union[str, int] = "edge",
                 threshold: float = 0.0):
        super().__init__(users, threshold=threshold)
        self.action = (self.STRATEGIES[strategy]
                       if isinstance(strategy, str) else int(strategy))

    def decisions(self, counts, scen: FleetScenario):
        self._check(scen)
        dec = jnp.full((scen.cells, scen.users), self.action, jnp.int32)
        return dec, self._ids(dec)


# ---------------------------------------------------------------------------
# route-to-serving
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ServedRequest:
    """One request dispatched through the serving bridge."""
    cell: int
    user: int
    action: int                 # routed per-user action id (0..9)
    tier: str                   # 'S' | 'E' | 'C'
    variant: str                # model variant actually served (e.g. 'd2')
    predicted_ms: float         # latency model's per-user prediction
    measured_ms: float          # engine batch wall-clock (ms)
    queue_ms: float = 0.0       # submit -> batch-drain wait (ms)
    deadline_ms: float = float("inf")   # SLO stamped at submit
    # scored at drain by ServingEngine.serve: e2e <= deadline_ms
    deadline_met: Optional[bool] = None

    @property
    def e2e_ms(self) -> float:
        """Measured end-to-end latency: queueing + engine compute —
        what the SLO deadline is scored against."""
        return self.queue_ms + self.measured_ms


@dataclasses.dataclass
class RouteResult:
    """A routing decision plus its real-serving outcome (paper Table 8:
    predicted vs measured response, here at fleet scale)."""
    decisions: jnp.ndarray      # (cells, N) per-user action ids
    ids: jnp.ndarray            # (cells,) joint action ids
    served: List[ServedRequest]
    batches: int                # engine batches drained
    edge_util: Optional[jnp.ndarray] = None
    #: dispatch wall-time decomposition from ``FleetOrchestrator.
    #: _dispatch`` (None when nothing was dispatched)
    timings: Optional[dict] = None
    #: utilization fraction above which an edge counts as hot
    hot_edge_util: float = 1.0
    #: device-side latency accumulator fed the measured per-request
    #: e2e stream during dispatch (histogram source of ``slo()``'s
    #: quantiles; None when nothing was dispatched)
    lat_acc: Optional[MetricsAccumulator] = None
    #: async-bridge outcome (``ServingBridge.stats()`` + per-shed
    #: request detail); None on the synchronous dispatch path
    bridge: Optional[dict] = None

    @property
    def predicted_ms(self) -> np.ndarray:
        return np.asarray([r.predicted_ms for r in self.served])

    @property
    def measured_ms(self) -> np.ndarray:
        return np.asarray([r.measured_ms for r in self.served])

    @property
    def gap_x(self) -> float:
        """measured / predicted mean-latency ratio (1.0 = the latency
        model predicts real serving perfectly; the paper's Table-8 gap)."""
        p = self.predicted_ms
        return float(self.measured_ms.mean() / max(p.mean(), 1e-9)) \
            if len(p) else float("nan")

    @property
    def hot_edges(self) -> Optional[List[int]]:
        """Edges whose utilization (jobs/capacity, ``edge_utilization``)
        is at or above ``hot_edge_util`` — the threshold signal on top
        of the raw ``edge_util`` vector. None without edge_util."""
        if self.edge_util is None:
            return None
        util = np.asarray(self.edge_util)
        return [int(i) for i in np.nonzero(util >= self.hot_edge_util)[0]]

    def gap_breakdown(self) -> Optional[dict]:
        """Decompose the Table-8 ``gap_x`` (None without a dispatch).

        Two exact decompositions, both asserted end-to-end in the test
        suite:

        * per request: ``queueing + compute == e2e`` (ms and, divided
          by the predicted mean, in gap units — ``compute`` alone is
          the legacy ``gap_x``);
        * dispatch wall: ``batching + compute + dispatch == total``,
          where ``batching`` is the prompt-build/submit loop,
          ``compute`` the raw host wall of the engine calls, and
          ``dispatch`` the residual drain overhead.

        Per (tier, variant): request/batch counts, queueing delay,
        raw vs compute_scale-emulated engine wall, and the tier's own
        gap_x — which tier's latency model is off, not just that one is.
        """
        if self.timings is None or not self.served:
            return None
        t = self.timings
        p = float(self.predicted_ms.mean())
        m = float(self.measured_ms.mean())
        q = float(np.mean([r.queue_ms for r in self.served]))
        denom = max(p, 1e-9)
        per = {}
        for key, tv in t["per_tier_variant"].items():
            rs = [r for r in self.served
                  if f"{r.tier}/{r.variant}" == key]
            pm = float(np.mean([r.predicted_ms for r in rs]))
            mm = float(np.mean([r.measured_ms for r in rs]))
            per[key] = dict(tv, predicted_mean_ms=pm, measured_mean_ms=mm,
                            gap_x=mm / max(pm, 1e-9))
        return {
            "gap_x": self.gap_x,
            "per_request_ms": {"predicted": p, "queueing": q,
                               "compute": m, "e2e": q + m},
            "gap_components_x": {"queueing": q / denom,
                                 "compute": m / denom,
                                 "e2e": (q + m) / denom},
            "wall_ms": {"total": t["wall_ms"],
                        "batching": t["batching_ms"],
                        "compute": t["compute_ms"],
                        "dispatch": t["dispatch_ms"]},
            "per_tier_variant": per,
        }

    def slo(self) -> Optional[dict]:
        """Deadline attainment + latency quantiles (None w/o dispatch).

        Every request carried a ``deadline_ms`` stamped at submit and
        was scored at drain (``ServingEngine.serve``); this reduces the
        stamps into the ISSUE-8 report:

        * **measured vs predicted attainment** — overall and per
          (tier, variant), each an exact complement split, so
          ``attained + violated == dispatched`` at every granularity
          (the identity ``tools/obs_smoke.py`` gates). ``predicted``
          scores the latency model's per-user prediction against the
          same deadline; ``attainment_gap`` = predicted − measured
          quantifies how far the ~2.4x ``trace_serving_gap_x`` makes
          the model overstate deliverable SLO.
        * **quantiles from two sources that must agree**: ``exact_ms``
          — host-exact order statistics over the per-request measured
          e2e latencies (the same values emitted as ``request.e2e``
          spans, so ``SpanRecorder.durations_ms`` reproduces them) —
          and ``hist_ms`` — histogram-derived from the device-side
          ``lat_acc`` accumulator, within one ``bin_width`` unless
          ``clipped`` flags out-of-range tails.
        """
        if not self.served:
            return None
        deadline = float(max(r.deadline_ms for r in self.served))
        e2e = np.asarray([r.e2e_ms for r in self.served])
        meas_att = sum(bool(r.deadline_met) for r in self.served)
        pred_att, pred_vio = timeline.attainment(
            [r.predicted_ms for r in self.served], deadline)
        n = len(self.served)
        per = {}
        for r in self.served:
            key = f"{r.tier}/{r.variant}"
            tv = per.setdefault(key, {
                "dispatched": 0, "measured_attained": 0,
                "measured_violated": 0, "predicted_attained": 0,
                "predicted_violated": 0})
            tv["dispatched"] += 1
            tv["measured_attained" if r.deadline_met
               else "measured_violated"] += 1
            tv["predicted_attained" if r.predicted_ms <= r.deadline_ms
               else "predicted_violated"] += 1
        for tv in per.values():
            tv["attainment_measured"] = \
                tv["measured_attained"] / tv["dispatched"]
            tv["attainment_predicted"] = \
                tv["predicted_attained"] / tv["dispatched"]
        quantiles = {
            "exact_ms": timeline.exact_quantiles(e2e),
            "predicted_exact_ms": timeline.exact_quantiles(
                self.predicted_ms),
        }
        if self.lat_acc is not None:
            quantiles["hist_ms"] = self.lat_acc.quantiles("e2e_ms",
                                                          warn=False)
        meas_frac = meas_att / n
        pred_frac = pred_att / n
        return {
            "deadline_ms": deadline,
            "requests": n,
            "measured": {"attained": meas_att, "violated": n - meas_att,
                         "attainment": meas_frac},
            "predicted": {"attained": pred_att, "violated": pred_vio,
                          "attainment": pred_frac},
            "attainment_gap": pred_frac - meas_frac,
            "per_tier_variant": per,
            "quantiles": quantiles,
        }

    def summary(self) -> dict:
        s = {"requests": len(self.served), "batches": self.batches,
             "predicted_mean_ms": float(self.predicted_ms.mean())
             if self.served else None,
             "measured_mean_ms": float(self.measured_ms.mean())
             if self.served else None,
             "gap_x": self.gap_x}
        if self.edge_util is not None:
            s["hot_edges"] = self.hot_edges
            s["hot_edge_util"] = self.hot_edge_util
        breakdown = self.gap_breakdown()
        if breakdown is not None:
            s["gap_breakdown"] = breakdown
        slo = self.slo()
        if slo is not None:
            s["slo"] = slo
        if self.bridge is not None:
            s["bridge"] = self.bridge
        return s


def _tier_variant(a: int, local_variants) -> Tuple[str, str]:
    """Map a per-user action id to the serving (tier, variant): 0..7 run
    locally on the nearest available device-tier variant (ladder gaps
    snap, as in examples/serve_orchestrated.py), 8/9 offload to the
    edge/cloud d0 (the paper's setting)."""
    if a == dynamics.A_EDGE:
        return "E", "d0"
    if a == dynamics.A_CLOUD:
        return "C", "d0"
    if not local_variants:
        raise KeyError("no device-tier ('S') engines were provided for a "
                       f"local decision d{a}")
    v = min(local_variants, key=lambda x: abs(x - a))
    return "S", f"d{v}"


class FleetOrchestrator:
    """Runtime front door for a fleet: one vectorized greedy pass routes
    every cell, and — given serving engines — dispatches the routed
    requests to real batched inference.

    Accepts any `FleetPolicy` (either fleet agent, `OraclePolicy`,
    `StaticPolicy`, or legacy agents exposing only
    ``policy_decisions``). ``route()`` keeps the pre-redesign tuple
    contract; ``route(dispatch=engines)`` returns a `RouteResult` with
    measured wall-times next to the model's predictions.

    ``mesh`` (default: the policy's own fleet mesh, if any) places the
    routed scenario and job counts with the cell axis sharded before
    the greedy pass, so a device-sharded fleet is routed where its
    cells live (``repro.fleet.shard``).
    """

    def __init__(self, policy, mesh=None):
        self.policy = policy
        self.mesh = mesh if mesh is not None else getattr(policy, "mesh",
                                                          None)

    @property
    def agent(self):
        """Pre-redesign attribute name for the routed policy."""
        return self.policy

    # ------------------------------------------------------------------
    def _predicted_per_user_ms(self, dec, scen: FleetScenario):
        """(cells, N) latency-model predictions for a routed decision
        under the current request mask (inactive users predict 0)."""
        if scen.topo is None:
            return dynamics.response_times(dec, scen.end_b, scen.edge_b,
                                           active=scen.active,
                                           calib=scen.calib, xp=jnp)
        return topology.topology_response_times(dec, scen.end_b, scen.edge_b,
                                                scen.topo, active=scen.active,
                                                calib=scen.calib, xp=jnp)

    def _dispatch(self, dec, scen: FleetScenario, engines,
                  prompts: Optional[Callable], max_new_tokens: int,
                  batch_size: int, prompt_len: int, seed: int, spans=None,
                  deadline_ms: float = float("inf")):
        from repro.serving import Request, RequestBatcher
        t0 = time.perf_counter()
        dec_np = np.asarray(dec)
        active = np.asarray(scen.active)
        pred = np.asarray(self._predicted_per_user_ms(dec, scen))
        local = sorted(int(v[1:]) for v in engines.get("S", {}))
        any_tier = next(iter(engines.values()), {})
        any_eng = next(iter(any_tier.values()), None)
        if any_eng is None:
            raise ValueError("dispatch= needs a non-empty "
                             "{tier: {variant: ServingEngine}} dict "
                             "(see repro.launch.serve.build_engines)")
        vocab = int(any_eng.model.cfg.vocab_size)
        rng = np.random.default_rng(seed)
        batchers, meta = {}, {}
        with _span(spans, "dispatch.batch_build"):
            for rid, (c, u) in enumerate(zip(*np.nonzero(active))):
                a = int(dec_np[c, u])
                tier, variant = _tier_variant(a, local)
                if tier not in engines or variant not in engines[tier]:
                    raise KeyError(
                        f"no engine for tier {tier!r} variant {variant!r}; "
                        "build_engines(...) must cover the routed decisions")
                p = (np.asarray(prompts(int(c), int(u)), np.int32)
                     if prompts is not None
                     else rng.integers(0, vocab,
                                       prompt_len).astype(np.int32))
                meta[rid] = (int(c), int(u), a, tier, variant)
                batchers.setdefault((tier, variant),
                                    RequestBatcher(batch_size)).submit(
                    Request(rid, p, max_new_tokens=max_new_tokens,
                            user=int(u), deadline_ms=deadline_ms))
        t_build = time.perf_counter()
        served, batches, compute_s = [], 0, 0.0
        slo_attained = slo_violated = 0
        per_tv = {}
        for (tier, variant), batcher in batchers.items():
            eng = engines[tier][variant]
            key = f"{tier}/{variant}"
            tv = per_tv.setdefault(key, {"requests": 0, "batches": 0,
                                         "compute_ms": 0.0,
                                         "emulated_ms": 0.0,
                                         "queue_ms": []})
            with _span(spans, f"dispatch.drain.{key}",
                       queued=len(batcher.queue)):
                while True:
                    done = eng.serve(batcher, spans=spans)
                    if not done:
                        break
                    batches += 1
                    tv["batches"] += 1
                    # serve_time is per BATCH (every request in `done`
                    # carries the same stamp): count it once
                    compute_s += done[0].serve_time
                    tv["compute_ms"] += done[0].serve_time * 1e3
                    tv["emulated_ms"] += done[0].response_time * 1e3
                    for r in done:
                        c, u, a, t_, v_ = meta[r.rid]
                        q_ms = float(r.queue_time * 1e3)
                        tv["requests"] += 1
                        tv["queue_ms"].append(q_ms)
                        served.append(ServedRequest(
                            c, u, a, t_, v_, float(pred[c, u]),
                            float(r.response_time * 1e3), queue_ms=q_ms,
                            deadline_ms=r.deadline_ms,
                            deadline_met=r.deadline_met))
                        slo_attained += bool(r.deadline_met)
                        slo_violated += not r.deadline_met
                        if spans is not None:
                            # retrospective per-request e2e interval
                            # (submit -> drain + emulated compute): the
                            # host-exact quantile source — its durations
                            # reproduce ServedRequest.e2e_ms exactly
                            spans.complete(
                                "request.e2e", r.arrival_time,
                                r.queue_time + r.response_time,
                                rid=r.rid, tier=t_, variant=v_,
                                deadline_met=bool(r.deadline_met))
                    if spans is not None:
                        # running per-batch SLO attainment counter track
                        spans.counter(
                            "slo.attainment", attained=slo_attained,
                            violated=slo_violated,
                            attainment=slo_attained
                            / max(slo_attained + slo_violated, 1))
        wall_ms = (time.perf_counter() - t0) * 1e3
        batching_ms = (t_build - t0) * 1e3
        compute_ms = compute_s * 1e3
        for tv in per_tv.values():
            q = tv.pop("queue_ms")
            tv["queue_ms_mean"] = float(np.mean(q)) if q else 0.0
        # batching + compute are disjoint sub-intervals of the dispatch
        # wall on one monotonic clock, so the residual is >= 0 and the
        # three components sum to wall_ms exactly (the gap_breakdown
        # identity the acceptance test pins)
        timings = {"wall_ms": wall_ms, "batching_ms": batching_ms,
                   "compute_ms": compute_ms,
                   "dispatch_ms": wall_ms - batching_ms - compute_ms,
                   "per_tier_variant": per_tv}
        served.sort(key=lambda s: (s.cell, s.user))
        # device-side latency accumulator (built AFTER the timed wall so
        # it cannot perturb the gap_breakdown identities): the histogram
        # source RouteResult.slo() cross-checks against the host-exact
        # per-request e2e stream
        hi = 4.0 * deadline_ms if np.isfinite(deadline_ms) \
            else 4.0 * dynamics.MAX_RESPONSE_MS
        lat = MetricsAccumulator.create(
            {"e2e_ms": MetricDef(lo=0.0, hi=max(hi, 1.0), bins=64)})
        if served:
            lat = lat.update({"e2e_ms": jnp.asarray(
                [r.e2e_ms for r in served], jnp.float32)})
        return served, batches, timings, lat

    def _dispatch_bridge(self, dec, scen: FleetScenario, engines, bridge,
                         prompts: Optional[Callable], max_new_tokens: int,
                         batch_size: int, prompt_len: int, seed: int,
                         spans=None, deadline_ms: float = float("inf")):
        """Async twin of ``_dispatch``: submit every active request into
        a ``ServingBridge`` (per-(tier, variant) worker queues, see
        ``repro.serving.bridge``) and drain the fleet with the S/E/C
        engines overlapped.

        Identities preserved: per request ``queueing + compute == e2e``
        and the wall decomposition ``batching + compute + dispatch ==
        total`` still hold exactly — but ``compute_ms`` sums engine
        walls that ran CONCURRENTLY, so the residual ``dispatch_ms``
        may be negative (overlap won back); only the synchronous path
        guarantees ``dispatch >= 0``. Requests the bridge shed (bounded
        queues, exhausted deadlines, engine timeouts) are NOT in
        ``served`` — they surface with reasons in the returned bridge
        stats (``RouteResult.summary()['bridge']``), and the SLO
        identity attained + violated == dispatched holds over the
        served set.
        """
        from repro.serving import Request
        from repro.serving.bridge import BridgeConfig, ServingBridge
        t0 = time.perf_counter()
        dec_np = np.asarray(dec)
        active = np.asarray(scen.active)
        pred = np.asarray(self._predicted_per_user_ms(dec, scen))
        local = sorted(int(v[1:]) for v in engines.get("S", {}))
        any_tier = next(iter(engines.values()), {})
        any_eng = next(iter(any_tier.values()), None)
        if any_eng is None:
            raise ValueError("dispatch= needs a non-empty "
                             "{tier: {variant: ServingEngine}} dict "
                             "(see repro.launch.serve.build_engines)")
        vocab = int(any_eng.model.cfg.vocab_size)
        rng = np.random.default_rng(seed)
        if isinstance(bridge, ServingBridge):
            br, own = bridge, False
        else:
            cfg = bridge if isinstance(bridge, BridgeConfig) \
                else BridgeConfig(max_batch=batch_size)
            br, own = ServingBridge(engines, cfg, spans=spans), True
        # reused bridges accumulate across calls: slice this call's
        # results/batches off the tail for per-call accounting, and
        # offset rids so the bridge's terminal-once set (keyed by rid)
        # never mistakes this call's requests for a prior call's
        n0, b0 = len(br.results), len(br.batch_log)
        rid0 = br.submitted
        meta = {}
        with _span(spans, "dispatch.batch_build"):
            for i, (c, u) in enumerate(zip(*np.nonzero(active))):
                rid = rid0 + i
                a = int(dec_np[c, u])
                tier, variant = _tier_variant(a, local)
                if tier not in engines or variant not in engines[tier]:
                    raise KeyError(
                        f"no engine for tier {tier!r} variant {variant!r}; "
                        "build_engines(...) must cover the routed decisions")
                p = (np.asarray(prompts(int(c), int(u)), np.int32)
                     if prompts is not None
                     else rng.integers(0, vocab,
                                       prompt_len).astype(np.int32))
                meta[rid] = (int(c), int(u), a, tier, variant)
                br.submit(Request(rid, p, max_new_tokens=max_new_tokens,
                                  user=int(u), deadline_ms=deadline_ms),
                          tier, variant)
        t_build = time.perf_counter()
        br.drain()
        if own:
            br.stop()
        stats = br.stats()
        served = []
        slo_attained = slo_violated = 0
        per_tv = {}
        compute_s = 0.0
        batch_log = br.batch_log[b0:]
        for b in batch_log:
            compute_s += b["serve_time"]
            tv = per_tv.setdefault(b["key"], {"requests": 0, "batches": 0,
                                              "compute_ms": 0.0,
                                              "emulated_ms": 0.0,
                                              "queue_ms": []})
            tv["batches"] += 1
            tv["compute_ms"] += b["serve_time"] * 1e3
            tv["emulated_ms"] += b["response_time"] * 1e3
        for r, tier, variant in br.results[n0:]:
            c, u, a, _t0, _v0 = meta[r.rid]
            key = f"{tier}/{variant}"
            tv = per_tv.setdefault(key, {"requests": 0, "batches": 0,
                                         "compute_ms": 0.0,
                                         "emulated_ms": 0.0,
                                         "queue_ms": []})
            q_ms = float(r.queue_time * 1e3)
            tv["requests"] += 1
            tv["queue_ms"].append(q_ms)
            served.append(ServedRequest(
                c, u, a, tier, variant, float(pred[c, u]),
                float(r.response_time * 1e3), queue_ms=q_ms,
                deadline_ms=r.deadline_ms, deadline_met=r.deadline_met))
            slo_attained += bool(r.deadline_met)
            slo_violated += not r.deadline_met
            if spans is not None:
                spans.complete(
                    "request.e2e", r.arrival_time,
                    r.queue_time + r.response_time, rid=r.rid, tier=tier,
                    variant=variant, deadline_met=bool(r.deadline_met))
        if spans is not None and (slo_attained or slo_violated):
            spans.counter(
                "slo.attainment", attained=slo_attained,
                violated=slo_violated,
                attainment=slo_attained
                / max(slo_attained + slo_violated, 1))
        batches = len(batch_log)
        wall_ms = (time.perf_counter() - t0) * 1e3
        batching_ms = (t_build - t0) * 1e3
        compute_ms = compute_s * 1e3
        for tv in per_tv.values():
            q = tv.pop("queue_ms")
            tv["queue_ms_mean"] = float(np.mean(q)) if q else 0.0
        # the three components still sum to wall_ms exactly, but
        # compute_ms adds up engine walls that OVERLAPPED across the
        # bridge's worker threads, so the residual can be negative —
        # that is the overlap the async bridge won back
        timings = {"wall_ms": wall_ms, "batching_ms": batching_ms,
                   "compute_ms": compute_ms,
                   "dispatch_ms": wall_ms - batching_ms - compute_ms,
                   "per_tier_variant": per_tv}
        served.sort(key=lambda s: (s.cell, s.user))
        hi = 4.0 * deadline_ms if np.isfinite(deadline_ms) \
            else 4.0 * dynamics.MAX_RESPONSE_MS
        lat = MetricsAccumulator.create(
            {"e2e_ms": MetricDef(lo=0.0, hi=max(hi, 1.0), bins=64)})
        if served:
            lat = lat.update({"e2e_ms": jnp.asarray(
                [r.e2e_ms for r in served], jnp.float32)})
        # enrich shed reports with the routed (cell, user) so summary()
        # accounts for every submitted request
        for sr in stats["shed_requests"]:
            if sr["rid"] in meta:
                c, u, a, _t, _v = meta[sr["rid"]]
                sr["cell"], sr["user"], sr["action"] = c, u, a
        stats["overlap_x"] = compute_ms / max(wall_ms - batching_ms, 1e-9)
        return served, batches, timings, lat, stats

    # ------------------------------------------------------------------
    def route(self, scen: Optional[FleetScenario] = None,
              counts: Optional[jnp.ndarray] = None,
              with_edge_util: bool = False, dispatch=None,
              prompts: Optional[Callable] = None, max_new_tokens: int = 4,
              batch_size: int = 8, prompt_len: int = 12, seed: int = 0,
              spans=None, hot_edge_util: float = 1.0,
              as_result: bool = False,
              deadline_ms: Optional[float] = None, bridge=None):
        """Route the whole fleet in one greedy pass.

        Without ``dispatch`` this is the pre-redesign contract:
        ``(decisions, ids)`` — plus ``(n_edges,)`` utilization with
        ``with_edge_util=True``. A held-out ``scen`` without ``counts``
        is routed cold (zero job counts); pad-width / cell-count
        mismatches raise the shared protocol error for every policy.

        ``dispatch={tier: {variant: ServingEngine}}`` drains the routed
        decisions of every ACTIVE user into the engines through
        per-(tier, variant) ``RequestBatcher``s and returns a
        `RouteResult`: measured batch wall-times next to the latency
        model's per-user predictions (``prompts(cell, user) -> int32
        tokens`` overrides the synthetic prompts), with
        ``summary()['gap_breakdown']`` decomposing the gap into
        queueing / batching / dispatch / engine-compute components.

        Observability knobs: ``spans`` (a ``repro.obs.spans.
        SpanRecorder``) records route.decide / dispatch.* /
        engine.* spans as Chrome-trace events — plus, when
        dispatching, per-request ``request.e2e`` intervals and a
        running ``slo.attainment`` counter track; ``hot_edge_util``
        sets the utilization fraction at or above which an edge lands
        in ``RouteResult.hot_edges``; ``as_result=True`` returns a
        `RouteResult` even without a dispatch (empty ``served``), so
        callers get one return shape.

        ``deadline_ms`` is the SLO budget stamped on every dispatched
        request (end-to-end: queue + emulated compute). Default None
        = the scenario QoS target ``dynamics.MAX_RESPONSE_MS`` — the
        same bound the reward's constraint-violation penalty enforces,
        so serving SLO attainment and training QoS violations measure
        one target. ``RouteResult.slo()`` reports attainment.

        ``bridge`` switches the dispatch to the async serving bridge
        (``repro.serving.bridge``): ``True`` builds a per-call
        ``ServingBridge`` with ``max_batch=batch_size``; a
        ``BridgeConfig`` customizes admission/overflow/timeout
        behavior; an existing ``ServingBridge`` reuses its (already
        warmed, continuously running) queues. ``RouteResult.bridge``
        then carries the shed/reroute accounting; the synchronous
        one-shot drain (``bridge=None``) stays the default.
        """
        policy = self.policy
        if scen is None:
            scen = getattr(policy, "scen", None)
            if scen is None:
                raise ValueError(
                    f"{type(policy).__name__} has no attached scenario; "
                    "pass scen=")
            if counts is None:
                counts = getattr(policy, "counts", None)
        if counts is None:
            counts = jnp.zeros((scen.cells, 2), jnp.int32)
        if self.mesh is not None:
            from repro.fleet import shard
            scen = shard.shard_scenario(scen, self.mesh)
            counts = shard.shard_array(counts, self.mesh)
        decide = getattr(policy, "decisions", None) or policy.policy_decisions
        with _span(spans, "route.decide", cells=int(scen.cells)):
            dec, ids = decide(counts, scen)
            if spans is not None:
                # only when instrumenting: make the decide span cover
                # the actual device work, not just its dispatch
                jax.block_until_ready(dec)
        util = None
        if with_edge_util:
            with _span(spans, "route.edge_util"):
                topo = (scen.topo if scen.topo is not None
                        else topology.identity_topology(scen.cells))
                util = topology.edge_utilization(dec, topo,
                                                 active=scen.active)
        if dispatch is not None:
            slo_ms = dynamics.MAX_RESPONSE_MS if deadline_ms is None \
                else float(deadline_ms)
            brinfo = None
            with _span(spans, "route.dispatch"):
                if bridge is not None and bridge is not False:
                    served, batches, timings, lat, brinfo = \
                        self._dispatch_bridge(
                            dec, scen, dispatch, bridge, prompts,
                            max_new_tokens, batch_size, prompt_len, seed,
                            spans=spans, deadline_ms=slo_ms)
                else:
                    served, batches, timings, lat = self._dispatch(
                        dec, scen, dispatch, prompts, max_new_tokens,
                        batch_size, prompt_len, seed, spans=spans,
                        deadline_ms=slo_ms)
            return RouteResult(decisions=dec, ids=ids, served=served,
                               batches=batches, edge_util=util,
                               timings=timings,
                               hot_edge_util=hot_edge_util,
                               lat_acc=lat, bridge=brinfo)
        if as_result:
            return RouteResult(decisions=dec, ids=ids, served=[],
                               batches=0, edge_util=util,
                               hot_edge_util=hot_edge_util)
        if with_edge_util:
            return dec, ids, util
        return dec, ids
