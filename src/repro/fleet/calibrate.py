"""Sim-to-real calibration loop: fit the latency model to measured
serving, retrain the policy on the calibrated model, report the gap
closing.

The paper's key real-setup result (Table 8) is that the orchestrator's
*predicted* latencies track *measured* end-edge-cloud wall time. Our
latency model (``fleet.dynamics``) is calibrated to the paper's ARM/AWS
testbed, but the serving engines behind ``FleetOrchestrator.route(...,
dispatch=engines)`` are a different machine — the measured engine wall
runs ~2.4x over the model (``trace_serving_gap_x``). PR 6's
``RouteResult.gap_breakdown()`` isolates the *compute* component of
that gap per (tier, variant); this module turns the measurement into an
automated loop:

1. **fit** (`fit_calibration`) — split each served request's model
   prediction into (communication, compute) via
   ``dynamics.response_components`` under the routed decision, then
   least-squares ``measured_compute ≈ scale_tier * model_compute +
   offset_tier`` per tier (the measured compute is exactly
   ``ServedRequest.measured_ms``, the engine wall that
   ``gap_breakdown()['per_request_ms']['compute']`` aggregates).
   Rank-deficient tiers (constant model compute — every offload runs
   d0) take the minimum-norm solution; offsets may be negative.
2. **apply** (`apply_calibration` / `CalibratedDynamics`) — stamp the
   fitted ``dynamics.Calibration`` onto scenarios. The stamp rides the
   ``FleetScenario`` pytree, so ``nominal_expected_response``, the
   oracles, ``holdout_reward_ratio``, and the orchestrator's
   predictions all switch to the calibrated model with no call-site
   changes; `CalibratedDynamics` wraps any ``ScenarioSource`` the same
   way so ``FleetDQN``/``FleetQLearning`` retrain on calibrated
   dynamics unchanged.
3. **report** (`calibrate_serving` / `calibration_report`) — route the
   same fleet before and after, retrain the policy, and emit one
   artifact: fitted coefficients, before/after ``gap_x`` + SLO
   attainment, and the retrained policy's holdout reward ratio
   (rendered by ``tools/obsview.py --timeline``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Optional

import jax.numpy as jnp
import numpy as np

from repro.fleet import dynamics, topology
from repro.fleet.dynamics import CALIB_TIERS, Calibration
from repro.fleet.scenarios import FleetScenario

__all__ = [
    "CalibrationFit", "fit_calibration", "apply_calibration",
    "CalibratedDynamics", "calibrate_serving", "calibration_report",
]


class CalibrationFit(NamedTuple):
    """A fitted ``Calibration`` plus its per-tier fit diagnostics."""
    calib: Calibration
    #: tier -> {requests, compute_scale, hop_offset_ms, resid_rms_ms}
    per_tier: Dict[str, dict]

    def coefficients(self) -> dict:
        """JSON-ready per-tier coefficient block (the obsview render)."""
        scale = np.asarray(self.calib.compute_scale)
        off = np.asarray(self.calib.hop_offset_ms)
        return {t: {"compute_scale": float(scale[i]),
                    "hop_offset_ms": float(off[i]),
                    **{k: v for k, v in self.per_tier.get(t, {}).items()
                       if k in ("requests", "resid_rms_ms")}}
                for i, t in enumerate(CALIB_TIERS)}


def _model_components(dec, scen: FleetScenario):
    """(comm, comp) model components (ms, numpy) for a routed decision
    under the scenario's contention regime — uncalibrated by design:
    the fit always regresses against the BASE model."""
    if scen.topo is None:
        comm, comp = dynamics.response_components(
            dec, scen.end_b, scen.edge_b, active=scen.active, xp=jnp)
    else:
        n_e, n_c, mult = topology.shared_contention(
            dec, scen.topo, active=scen.active, xp=jnp)
        comm, comp = dynamics.response_components(
            dec, scen.end_b, scen.edge_b, counts=(n_e, n_c),
            active=scen.active, cloud_mult=mult, xp=jnp)
    return np.asarray(comm), np.asarray(comp)


def fit_calibration(result, scen: FleetScenario) -> CalibrationFit:
    """Fit per-tier (compute_scale, hop_offset_ms) from a dispatched
    ``RouteResult`` by least squares over the measured compute
    component.

    For every served request: the model splits into communication
    ``comm_i`` and compute ``comp_i`` via
    ``dynamics.response_components`` under the routed decision; the
    measurement is ``measured_ms`` (the engine wall — queueing is
    excluded, exactly as in ``gap_breakdown``'s per-request split).
    Per tier we solve ``measured_i - comm_i ~ scale * comp_i +
    offset`` so the calibrated total ``comm + offset + scale * comp``
    lands on the measurement (the offset sits on the tier's
    communication hop and may be negative — it absorbs modeled
    network time the local testbed doesn't spend). Tiers with no
    served requests keep the identity calibration.

    Two constraints keep the fitted model usable as TRAINING dynamics,
    not just a regression:

    * ``compute_scale >= 0`` — when the measured walls are
      uncorrelated with the modeled MACs (small engine batches whose
      wall is dominated by fixed dispatch cost), unconstrained least
      squares can go negative, which would INVERT the latency ladder —
      a bigger model would predict a faster response — and degrade any
      policy retrained on the calibrated dynamics. A negative solution
      is clipped to 0: the tier degrades to a constant-compute model.
    * the offset is refit to match the CLAMPED prediction's mean —
      ``calibrated_response_times`` floors each prediction at 0, so
      with a strongly negative offset (modeled network time the
      testbed doesn't spend) and per-request comm spread (weak vs
      regular links), the clamp inflates the mean above the plain
      least-squares line. ``mean(max(comm + off + scale*comp, 0))`` is
      continuous and nondecreasing in ``off``, so a bisection pins it
      to ``mean(measured)`` exactly (gap_x == 1 on the fit data by
      construction); when nothing clamps this IS the least-squares
      intercept.
    """
    dec = np.asarray(result.decisions)
    comm, comp = _model_components(dec, scen)
    rows = {t: [] for t in CALIB_TIERS}
    for r in result.served:
        tier = ("E" if r.action == dynamics.A_EDGE else
                "C" if r.action == dynamics.A_CLOUD else "S")
        rows[tier].append((float(comp[r.cell, r.user]),
                           float(comm[r.cell, r.user]),
                           float(r.measured_ms)))
    scale = np.ones(3)
    offset = np.zeros(3)
    per_tier = {}
    for i, t in enumerate(CALIB_TIERS):
        if not rows[t]:
            per_tier[t] = {"requests": 0}
            continue
        cp = np.array([c for c, _, _ in rows[t]])
        cm = np.array([c for _, c, _ in rows[t]])
        ms = np.array([m for _, _, m in rows[t]])
        a = np.stack([cp, np.ones_like(cp)], axis=1)
        sol, _res, _rank, _sv = np.linalg.lstsq(a, ms - cm, rcond=None)
        s = max(float(sol[0]), 0.0)
        offset[i] = _mean_match_offset(cm + s * cp, ms)
        scale[i] = s
        resid = np.maximum(cm + offset[i] + s * cp, 0.0) - ms
        per_tier[t] = {"requests": len(ms),
                       "compute_scale": scale[i],
                       "hop_offset_ms": offset[i],
                       "resid_rms_ms": float(np.sqrt(np.mean(resid ** 2)))}
    calib = Calibration(jnp.asarray(scale), jnp.asarray(offset))
    return CalibrationFit(calib, per_tier)


def _mean_match_offset(base: np.ndarray, measured: np.ndarray,
                       iters: int = 60) -> float:
    """The offset making ``mean(max(base + off, 0)) == mean(measured)``
    — the intercept of the clamped model. ``base`` is the fixed part
    of the prediction (``comm + scale * comp``) per request. The mean
    is continuous and nondecreasing in ``off`` (slope = clamp-active
    fraction), so bisection converges; the bracket is exact at both
    ends (all clamped vs. all above the measured mean)."""
    target = float(np.mean(measured))
    lo = -float(np.max(base))            # everything clamps -> mean 0
    hi = target                          # mean >= off + mean(base) ... >= target
    if float(np.mean(np.maximum(base + hi, 0.0))) < target:  # pragma: no cover
        hi = target + float(np.max(base))
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        if float(np.mean(np.maximum(base + mid, 0.0))) < target:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def apply_calibration(scen: FleetScenario,
                      calib: Optional[Calibration]) -> FleetScenario:
    """Stamp ``calib`` onto a scenario (None detaches — back to the
    uncalibrated paper model)."""
    return dataclasses.replace(scen, calib=calib)


class CalibratedDynamics:
    """`ScenarioSource` wrapper stamping a fitted ``Calibration`` onto
    every emitted scenario.

    Slots into ``FleetDQN`` / ``FleetQLearning`` /
    ``nominal_expected_response`` unchanged: the stamp is a pytree leaf
    of the scenario, so the wrapped source stays jit/scan-pure and the
    training loop retraces once onto the calibrated latency path."""

    state_is_scenario = True

    def __init__(self, source, calib: Calibration):
        from repro.fleet.api import require_scenario_state
        require_scenario_state(source)
        self.source = source
        self.calib = calib

    def attach_mesh(self, mesh) -> None:
        attach = getattr(self.source, "attach_mesh", None)
        if attach is not None:
            attach(mesh)

    @property
    def mesh(self):
        return getattr(self.source, "mesh", None)

    @property
    def cells(self) -> int:
        return self.source.cells

    @property
    def users(self) -> int:
        return self.source.users

    @property
    def dynamic(self) -> bool:
        return self.source.dynamic

    def _stamp(self, scen: FleetScenario) -> FleetScenario:
        return dataclasses.replace(scen, calib=self.calib)

    def reset(self, key):
        scen, _ = self.source.reset(key)
        scen = self._stamp(scen)
        return scen, scen

    def step(self, key, state):
        scen, _ = self.source.step(key, state)
        scen = self._stamp(scen)
        return scen, scen


def _route_block(result) -> dict:
    """The before/after comparison block of one dispatched route."""
    slo = result.slo() or {}
    meas = slo.get("measured", {})
    pred = slo.get("predicted", {})
    return {
        "gap_x": result.gap_x,
        "predicted_mean_ms": float(result.predicted_ms.mean())
        if result.served else None,
        "measured_mean_ms": float(result.measured_ms.mean())
        if result.served else None,
        "requests": len(result.served),
        "attainment_measured": meas.get("attainment"),
        "attainment_predicted": pred.get("attainment"),
        "attainment_gap": slo.get("attainment_gap"),
    }


def calibration_report(fit: CalibrationFit, before, after,
                       retrained: Optional[dict] = None) -> dict:
    """One JSON artifact: fitted coefficients + before/after gap and
    attainment (+ optional retrained-policy block). This is the
    ``calibration`` block ``tools/obsview.py --timeline`` renders."""
    report = {
        "coefficients": fit.coefficients(),
        "before": _route_block(before),
        "after": _route_block(after),
    }
    if retrained is not None:
        report["retrained"] = retrained
    return report


def calibrate_serving(orch, scen: FleetScenario, engines, *,
                      route_kw: Optional[dict] = None, retrain=None):
    """The full loop: route uncalibrated, fit, route calibrated,
    optionally retrain a policy on ``CalibratedDynamics``.

    orch     : a ``FleetOrchestrator`` (policy already trained)
    scen     : the fleet to dispatch (its ``calib`` is ignored — the
               'before' route always measures the base model)
    engines  : ``{tier: {variant: ServingEngine}}`` (warmed)
    route_kw : extra ``route()`` kwargs shared by both routes
    retrain  : optional callable ``retrain(calib) -> dict`` returning a
               JSON block for the report (e.g. train a ``FleetDQN`` on
               ``CalibratedDynamics`` and report its holdout ratio)

    Returns ``(report, fit, after_result)`` where ``report`` is
    ``calibration_report(...)``.
    """
    kw = dict(route_kw or {})
    base = apply_calibration(scen, None)
    before = orch.route(scen=base, dispatch=engines, **kw)
    fit = fit_calibration(before, base)
    after = orch.route(scen=apply_calibration(scen, fit.calib),
                       dispatch=engines, **kw)
    retrained = None
    if retrain is not None:
        retrained = retrain(fit.calib)
    return calibration_report(fit, before, after, retrained), fit, after
