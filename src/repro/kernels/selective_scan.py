"""Selective-scan (Mamba-1) Pallas TPU kernel.

The recurrence h_t = exp(dt_t * A) h_{t-1} + (dt_t * u_t) B_t is
embarrassingly parallel over (batch, d_inner) and sequential over time.
Grid = (B, d_inner/BD): each program owns a (BD, N) f32 state tile in
VMEM scratch and walks the time axis with a fori_loop, reading
(BD,)-slices of u/dt and (N,)-slices of B/C per step — the whole working
set (u, dt tiles of (S, BD) plus B/C (S, N)) is staged into VMEM by the
BlockSpecs, so HBM traffic is exactly one read of the inputs and one
write of y (+ final state). TPU adaptation of the CUDA kernel in the
Mamba paper: no warp shuffles — the (BD, N) tile IS the parallel unit,
mapped onto the VPU's 8x128 lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(u_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, h_out_ref,
            h_ref, *, seq: int):
    a = a_ref[...].astype(jnp.float32)                  # (BD, N)
    d = d_ref[...].astype(jnp.float32)                  # (1, BD)
    h_ref[...] = jnp.zeros_like(h_ref)

    def step(t, _):
        u_t = u_ref[0, t, :].astype(jnp.float32)        # (BD,)
        dt_t = dt_ref[0, t, :].astype(jnp.float32)      # (BD,)
        b_t = b_ref[0, t, :].astype(jnp.float32)        # (N,)
        c_t = c_ref[0, t, :].astype(jnp.float32)        # (N,)
        dA = jnp.exp(dt_t[:, None] * a)                 # (BD, N)
        dBu = (dt_t * u_t)[:, None] * b_t[None, :]
        h = h_ref[...] * dA + dBu
        h_ref[...] = h
        y = jnp.sum(h * c_t[None, :], axis=1) + u_t * d[0]
        y_ref[0, t, :] = y.astype(y_ref.dtype)
        return ()

    jax.lax.fori_loop(0, seq, step, ())
    h_out_ref[0] = h_ref[...]


def selective_scan_kernel(u, dt, A, B, C, D, *, bd: int = 256,
                          interpret: bool = True):
    """u, dt: (Bt,S,di); A: (di,N); B,C: (Bt,S,N); D: (di,).
    Returns (y: (Bt,S,di), h_last: (Bt,di,N))."""
    bt, s, di = u.shape
    n = A.shape[1]
    bd = min(bd, di)
    grid = (bt, di // bd)
    kernel = functools.partial(_kernel, seq=s)
    y, h_last = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, s, bd), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, s, bd), lambda i, j: (i, 0, j)),
            pl.BlockSpec((bd, n), lambda i, j: (j, 0)),
            pl.BlockSpec((1, s, n), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, s, n), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, bd), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, s, bd), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, bd, n), lambda i, j: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bt, s, di), u.dtype),
            jax.ShapeDtypeStruct((bt, di, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, n), jnp.float32)],
        interpret=interpret,
    )(u, dt, A, B, C, D.reshape(1, di))
    return y, h_last
