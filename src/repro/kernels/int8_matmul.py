"""Int8 x Int8 -> Int32 matmul Pallas TPU kernel (the paper's quantized
model variants d4..d7, adapted: MXU int8 path instead of ARM NEON).

Symmetric quantization: x_q (M,K) int8 with per-row scales sx (M,1),
w_q (K,N) int8 with per-column scales sw (1,N). Grid (M/BM, N/BN, K/BK)
with K innermost; the int32 accumulator tile (BM, BN) persists in VMEM
scratch across the K sweep and is rescaled to f32 once at the end —
exactly one dequant per output tile. Tiles default to 256x256x256
(int8 MXU native packing is 2x denser than bf16, so larger tiles still
fit the ~16 MB VMEM budget: 3*256*256 + 4*256*256 bytes << VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref, acc_ref):
    kk = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(kk == nk - 1)
    def _finalize():
        o_ref[...] = (acc_ref[...].astype(jnp.float32)
                      * sx_ref[...] * sw_ref[...]).astype(o_ref.dtype)


def int8_matmul_kernel(x_q, sx, w_q, sw, *, bm: int = 256, bn: int = 256,
                       bk: int = 256, out_dtype=jnp.float32,
                       interpret: bool = True):
    """x_q: (M,K) int8; sx: (M,1) f32; w_q: (K,N) int8; sw: (1,N) f32."""
    m, k = x_q.shape
    _, n = w_q.shape
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_q, w_q, sx, sw)
