"""Flash attention (prefill/train) Pallas TPU kernel.

Blocked online-softmax attention with explicit VMEM tiling:
  grid = (batch*q_heads, Sq/BQ, Skv/BK), KV innermost so the f32
  (BQ, head_dim) accumulator + (BQ, 1) running max/denominator live in
  VMEM scratch across the KV sweep. Causal and sliding-window masks skip
  whole KV blocks outside the band (pl.when), which is where the TPU win
  comes from for gemma3/hymba's 1024-token windows. GQA is handled by
  mapping each q-head program to its kv head in the BlockSpec index_map —
  no KV replication in HBM.

MXU alignment: BQ/BK default to 128 and head_dim is padded to a multiple
of 128 by the ops.py wrapper.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, bq: int, bk: int,
            seq_kv: int, q_offset: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # q positions are right-aligned against the kv sequence (q_offset =
    # seq_kv - seq_q for ragged causal / chunked prefill).
    q_start = qi * bq + q_offset
    k_start = kj * bk
    # Block-level skip: causal => k_start <= q_end; window => k_end > q_start - window
    run = jnp.asarray(True)
    if causal:
        run &= k_start <= q_start + bq - 1
    if window:
        run &= (k_start + bk - 1) > (q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)            # (BQ, hd)
        k = k_ref[0].astype(jnp.float32)            # (BK, hd)
        v = v_ref[0].astype(jnp.float32)            # (BK, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_pos < seq_kv
        if causal:
            mask &= k_pos <= q_pos
        if window:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)       # (BQ,1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + jnp.sum(p, -1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(kj == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal: bool = True, window: int = 0,
                           bq: int = 128, bk: int = 128, scale: float = 0.0,
                           seq_kv: int = 0, q_offset: int = 0,
                           interpret: bool = True):
    """q: (BH, Sq, hd); k, v: (BKV, Skv, hd) with BH = BKV * group.
    Caller (ops.py) flattens batch/head dims and pads Sq/Skv/hd; seq_kv is
    the UNPADDED kv length (mask boundary), q_offset right-aligns q."""
    bh, sq, hd = q.shape
    bkv, skv, _ = k.shape
    group = bh // bkv
    bq = min(bq, sq)
    bk = min(bk, skv)
    grid = (bh, sq // bq, skv // bk)
    scale = scale or 1.0 / math.sqrt(hd)

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk,
                               seq_kv=seq_kv or skv, q_offset=q_offset)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda h, i, j, g=group: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
