"""Jit'd public wrappers around the Pallas kernels.

Each op pads/flattens to the kernel's layout, runs the kernel (interpret
mode on CPU — the TPU target compiles the same pallas_call), and undoes
the layout. ``impl='ref'`` routes to the pure-jnp oracle instead, which
is also the path the SPMD dry-run lowers (see DESIGN.md §3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_kernel
from repro.kernels.dqn_head import dqn_head_kernel
from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.int8_matmul import int8_matmul_kernel
from repro.kernels.selective_scan import selective_scan_kernel
from repro.kernels.tabular_rl import tabular_rl_kernel

NEG_INF = -1e30


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


@functools.partial(jax.jit, static_argnames=("causal", "window", "impl",
                                             "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    impl: str = "pallas", bq: int = 128, bk: int = 128,
                    interpret: bool = True):
    """q: (B,Sq,H,hd); k,v: (B,Skv,KV,hd) -> (B,Sq,H,hd)."""
    if impl == "ref":
        return ref.attention_ref(q, k, v, causal=causal, window=window)
    b, sq, h, hd = q.shape
    skv, n_kv = k.shape[1], k.shape[2]
    g = h // n_kv
    # layout: (B*H, S, hd); pad sq/skv to block multiples, hd to 128
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * n_kv, skv, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * n_kv, skv, hd)
    qf, _ = _pad_to(qf, 1, bq)
    kf, _ = _pad_to(kf, 1, bk)
    vf, _ = _pad_to(vf, 1, bk)
    qf, hd_pad = _pad_to(qf, 2, 128)
    kf, _ = _pad_to(kf, 2, 128)
    vf, _ = _pad_to(vf, 2, 128)
    import math
    o = flash_attention_kernel(qf, kf, vf, causal=causal, window=window,
                               bq=bq, bk=bk, scale=1.0 / math.sqrt(hd),
                               seq_kv=skv, q_offset=skv - sq,
                               interpret=interpret)
    o = o[:, :sq, :hd].reshape(b, h, sq, hd).transpose(0, 2, 1, 3)
    return o


@functools.partial(jax.jit, static_argnames=("window", "impl", "bk",
                                             "interpret"))
def decode_attention(q, k_cache, v_cache, kv_pos, cur_pos, *, window: int = 0,
                     impl: str = "pallas", bk: int = 512,
                     interpret: bool = True):
    """q: (B,H,hd); caches: (B,S,KV,hd); kv_pos: (B,S) absolute slot
    positions (-1 empty); cur_pos: (B,)."""
    valid = (kv_pos >= 0) & (kv_pos <= cur_pos[:, None])
    if window:
        valid &= kv_pos > cur_pos[:, None] - window
    bias = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)
    if impl == "ref":
        return ref.decode_attention_ref(q, k_cache, v_cache, bias)
    b, h, hd = q.shape
    s = k_cache.shape[1]
    q_, hd_pad = _pad_to(q, 2, 128)
    k_, _ = _pad_to(k_cache, 3, 128)
    v_, _ = _pad_to(v_cache, 3, 128)
    k_, spad = _pad_to(k_, 1, bk)
    v_, _ = _pad_to(v_, 1, bk)
    bias_, _ = _pad_to(bias, 1, bk)
    if spad:
        bias_ = bias_.at[:, s:].set(NEG_INF)
    import math
    o = decode_attention_kernel(q_, k_, v_, bias_, bk=bk,
                                scale=1.0 / math.sqrt(hd), interpret=interpret)
    return o[:, :, :hd]


@functools.partial(jax.jit, static_argnames=("impl", "bm", "bn", "bk",
                                             "out_dtype", "interpret"))
def int8_matmul(x_q, sx, w_q, sw, *, impl: str = "pallas", bm: int = 256,
                bn: int = 256, bk: int = 256, out_dtype=jnp.float32,
                interpret: bool = True):
    if impl == "ref":
        return ref.int8_matmul_ref(x_q, sx, w_q, sw).astype(out_dtype)
    m, k = x_q.shape
    n = w_q.shape[1]
    x_, _ = _pad_to(x_q, 0, bm)
    x_, _ = _pad_to(x_, 1, bk)
    w_, _ = _pad_to(w_q, 0, bk)
    w_, _ = _pad_to(w_, 1, bn)
    sx_, _ = _pad_to(sx, 0, bm)
    sw_, _ = _pad_to(sw, 1, bn)
    o = int8_matmul_kernel(x_, sx_, w_, sw_, bm=bm, bn=bn, bk=bk,
                           out_dtype=out_dtype, interpret=interpret)
    return o[:m, :n]


def quantize(x, axis=-1):
    return ref.quantize_ref(x, axis)


@functools.partial(jax.jit, static_argnames=("impl", "bd", "interpret"))
def selective_scan(u, dt, A, B, C, D, *, impl: str = "pallas", bd: int = 256,
                   interpret: bool = True):
    """See kernels/selective_scan.py; returns (y, h_last)."""
    if impl == "ref":
        return ref.selective_scan_ref(u, dt, A, B, C, D)
    di = u.shape[2]
    bd = min(bd, di)
    pad = (-di) % bd
    u_, _ = _pad_to(u, 2, bd)
    dt_, _ = _pad_to(dt, 2, bd)
    A_ = jnp.pad(A, ((0, pad), (0, 0)))
    D_ = jnp.pad(D, (0, pad))
    y, h = selective_scan_kernel(u_, dt_, A_, B, C, D_, bd=bd,
                                 interpret=interpret)
    return y[:, :, :di], h[:, :di]


def resolve_rl_impl(impl: str, mesh=None) -> str:
    """Resolve a fleet agent's ``impl`` request to an executable path.

    ``"xla"`` is the legacy unfused step, untouched. ``"pallas"`` is
    the fused hot path and resolves by capability: the compiled kernel
    needs a TPU backend, and ``pallas_call`` cannot be partitioned by
    GSPMD, so under a device mesh (``fleet.shard``) the fused-jnp
    reference formulation runs instead — it is per-cell elementwise +
    batched gather/scatter + reduces along the unsharded action axis,
    so it stays bit-identical sharded-vs-single-device (the discipline
    ``tests/test_fleet_shard.py`` pins). On non-TPU hosts the same
    reference formulation IS the fused win: one row-gather shared by
    act and update, and the two-reduce ``first_argmax_ref``.
    ``"pallas_interpret"`` forces the real kernel in interpret mode
    (CPU CI parity runs; far too slow for production loops).
    """
    if impl in ("xla", "ref", "pallas_interpret"):
        return impl
    if impl != "pallas":
        raise ValueError(f"unknown impl {impl!r}; expected 'pallas', "
                         "'xla', 'ref', or 'pallas_interpret'")
    if mesh is not None:
        return "ref"
    if jax.default_backend() == "tpu":
        return "pallas"
    return "ref"


def rl_op_kwargs(resolved: str) -> dict:
    """kwargs for the fused ops matching a ``resolve_rl_impl`` result."""
    if resolved == "ref":
        return {"impl": "ref"}
    if resolved == "pallas":
        return {"impl": "pallas", "interpret": False}
    if resolved == "pallas_interpret":
        return {"impl": "pallas", "interpret": True}
    raise ValueError(f"no fused op path for resolved impl {resolved!r}")


@functools.partial(jax.jit, static_argnames=("alpha", "gamma", "impl",
                                             "bc", "interpret"))
def fused_tabular_update(q, s, a, r, s2, *, alpha: float, gamma: float,
                         impl: str = "ref", bc: int = 8,
                         interpret: bool = True):
    """Fused tabular act+update: q (cells,S,K) f32, s/a/s2 (cells,)
    int32, r (cells,) f32 -> (q_new, greedy2, td); see
    ``ref.fused_tabular_ref``."""
    if impl == "ref":
        return ref.fused_tabular_ref(q, s, a, r, s2, alpha=alpha,
                                     gamma=gamma)
    cells = q.shape[0]
    q_, _ = _pad_to(q, 0, bc)
    cols = [_pad_to(x[:, None], 0, bc)[0] for x in (s, a, r, s2)]
    q_new, greedy2, td = tabular_rl_kernel(
        q_, *cols, alpha=alpha, gamma=gamma, bc=bc, interpret=interpret)
    return q_new[:cells], greedy2[:cells, 0], td[:cells, 0]


@functools.partial(jax.jit, static_argnames=("threshold", "topk", "impl",
                                             "bc", "interpret"))
def dqn_head(active, member, end_b, agg, params, allowed, acc_table, *,
             threshold: float, topk: int, impl: str = "ref",
             bc: int = 128, interpret: bool = True):
    """Fused featurize + constraint-aware greedy head.

    active/member/end_b: (cells, N) f32; agg: (cells, 8) f32 cell
    aggregates; params: the 3-layer shared-net param list
    (``[{"w", "b"}] * 3``); allowed: (N, A) bool allowed-action mask;
    acc_table: (A,) f32 accuracy ladder. Returns ``(dec, q)``; see
    ``ref.dqn_head_ref``.
    """
    (w1, b1), (w2, b2), (w3, b3) = [(p["w"], p["b"].reshape(1, -1))
                                    for p in params]
    allowed_f = jnp.asarray(allowed).astype(jnp.float32)
    if impl == "ref":
        return ref.dqn_head_ref(active, member, end_b, agg, w1, b1, w2,
                                b2, w3, b3, allowed_f, acc_table,
                                threshold=threshold, topk=topk)
    cells = active.shape[0]
    act_, _ = _pad_to(active, 0, bc)
    mem_, _ = _pad_to(member, 0, bc)
    end_, _ = _pad_to(end_b, 0, bc)
    agg_, _ = _pad_to(agg, 0, bc)
    dec, q = dqn_head_kernel(act_, mem_, end_, agg_, w1, b1, w2, b2, w3,
                             b3, allowed_f, acc_table[None, :],
                             threshold=threshold, topk=topk, bc=bc,
                             interpret=interpret)
    return dec[:cells], q[:cells]
