"""Single-token (decode) attention Pallas TPU kernel.

One new query token per sequence attends to a long KV cache. The grid is
(batch, Skv/BK): each program sweeps its sequence's cache in BK-sized
VMEM tiles, carrying per-head online-softmax state — acc (H, hd) f32,
m/l (H, 1) — in VMEM scratch. Invalid slots (unwritten ring-buffer
entries, out-of-window positions) arrive pre-folded into an additive
bias row (B, Skv) computed by ops.py, so the kernel itself is
layout-agnostic (works for both linear and ring cache layouts). GQA:
the (KV*G, hd) query block is reshaped per kv-head and contracted with
(BK, hd) tiles as 2D MXU dots per kv head (static python loop — KV<=16).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, m_ref, l_ref, acc_ref, *,
            n_kv: int, bk: int, scale: float):
    kj = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                    # (H, hd)
    h, hd = q.shape
    g = h // n_kv
    bias = bias_ref[0].astype(jnp.float32)              # (BK,)
    kb = k_ref[0].astype(jnp.float32)                   # (BK, KV, hd)
    vb = v_ref[0].astype(jnp.float32)

    rows = []
    for kvh in range(n_kv):
        qh = q[kvh * g:(kvh + 1) * g]                   # (G, hd)
        kh = kb[:, kvh, :]                              # (BK, hd)
        s = jax.lax.dot_general(qh, kh, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        rows.append(s * scale + bias[None, :])
    s = jnp.concatenate(rows, axis=0)                   # (H, BK)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_prev * corr + jnp.sum(p, -1, keepdims=True)
    m_ref[...] = m_new
    outs = []
    for kvh in range(n_kv):
        ph = p[kvh * g:(kvh + 1) * g]                   # (G, BK)
        vh = vb[:, kvh, :]                              # (BK, hd)
        outs.append(jax.lax.dot_general(ph, vh, (((1,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32))
    acc_ref[...] = acc_ref[...] * corr + jnp.concatenate(outs, axis=0)

    @pl.when(kj == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def decode_attention_kernel(q, k_cache, v_cache, bias, *, bk: int = 512,
                            scale: float = 0.0, interpret: bool = True):
    """q: (B, H, hd); caches: (B, S, KV, hd); bias: (B, S) additive."""
    b, h, hd = q.shape
    s, n_kv = k_cache.shape[1], k_cache.shape[2]
    bk = min(bk, s)
    grid = (b, s // bk)
    kernel = functools.partial(_kernel, n_kv=n_kv, bk=bk,
                               scale=scale or 1.0 / math.sqrt(hd))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, h, hd), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, bk, n_kv, hd), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, bk, n_kv, hd), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, bk), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, h, hd), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k_cache, v_cache, bias)
