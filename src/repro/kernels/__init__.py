from repro.kernels.ops import (decode_attention, flash_attention,
                               int8_matmul, quantize, selective_scan)
