"""Fused featurize + constraint-aware greedy head Pallas TPU kernel.

``FleetDQN``'s act path materializes the flat ``encode_fleet_state``
vector, re-slices it back into per-user rows, runs the shared MLP, and
— with a QoS goal — gathers ``(cells, topk^N, N)`` candidate tensors to
filter the per-user top-k combinations by the accuracy ladder. This
kernel fuses the whole head per fleet block: each grid program
assembles the ``(BC * N, 11)`` per-user feature matrix directly from
the ``active``/``member``/``end_b`` blocks plus the 8-wide cell
aggregates, keeps the three MLP weight matrices resident in VMEM
across the block, masks with the allowed-action table, and resolves
the constraint head in-register — top-k as ``k`` (max, first-argmax,
mask) reduce pairs, combo scoring via compile-time-static gathers of
the ``(topk^N, N)`` combination table, accuracy lookup as a one-hot
contraction against the ladder — emitting only the ``(BC, N)`` greedy
decisions and the masked head values.

Combos with a masked (NEG_INF) member entry are culled, infeasible
combos are culled, and a cell with no feasible combo falls back to the
plain per-user argmax — bit-identical decision semantics to
``ref.dqn_head_ref`` (the PR-2 constraint-leak fix, re-pinned here).
"""
from __future__ import annotations

import functools
import itertools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _first_argmax(x, iota):
    """First-index argmax over the last axis (jnp.argmax tie-break)."""
    m = jnp.max(x, axis=-1, keepdims=True)
    k = x.shape[-1]
    return jnp.min(jnp.where(x == m, iota, k), axis=-1).astype(jnp.int32)


def _kernel(act_ref, mem_ref, end_ref, agg_ref, w1_ref, b1_ref, w2_ref,
            b2_ref, w3_ref, b3_ref, mask_ref, acc_ref, combo_ref, dec_ref,
            q_ref, *, bc: int, users: int, threshold: float, topk: int):
    n = users
    act = act_ref[...]                                    # (BC, N)
    agg = agg_ref[...]                                    # (BC, 8)
    feats = jnp.concatenate(
        [act[..., None], mem_ref[...][..., None], end_ref[...][..., None],
         jnp.broadcast_to(agg[:, None, :], (bc, n, agg.shape[-1]))], -1)
    x = feats.reshape(bc * n, feats.shape[-1])
    h = jnp.maximum(jnp.dot(x, w1_ref[...]) + b1_ref[...], 0.0)
    h = jnp.maximum(jnp.dot(h, w2_ref[...]) + b2_ref[...], 0.0)
    q = jnp.dot(h, w3_ref[...]) + b3_ref[...]
    n_act = q.shape[-1]
    q = jnp.where(mask_ref[...][None] > 0.5, q.reshape(bc, n, n_act),
                  NEG_INF)                                # (BC, N, A)
    q_ref[...] = q
    iota_a = jax.lax.broadcasted_iota(jnp.int32, (1, 1, n_act), 2)
    plain = _first_argmax(q, iota_a)                      # (BC, N)
    if not threshold:
        dec_ref[...] = plain
        return
    # --- stable top-k: k rounds of (max, first-argmax, mask-out) ------
    vals, idx, cur = [], [], q
    for _ in range(topk):
        i = _first_argmax(cur, iota_a)
        hit = iota_a == i[..., None]
        vals.append(jnp.sum(jnp.where(hit, cur, 0.0), -1)
                    + jnp.where(jnp.all(~hit, -1), NEG_INF, 0.0))
        idx.append(i)
        cur = jnp.where(hit, NEG_INF, cur)
    vals = jnp.stack(vals, -1)                            # (BC, N, k)
    idx = jnp.stack(idx, -1)
    # accuracy ladder lookup as a one-hot contraction (no gathers)
    acc = acc_ref[...]                                    # (1, A)
    onehot = idx[..., None] == jax.lax.broadcasted_iota(
        jnp.int32, (1, 1, 1, n_act), 3)                   # (BC, N, k, A)
    acc_k = jnp.sum(jnp.where(onehot, acc[None, None], 0.0), -1)
    # --- combo scoring over the (topk^N, N) table ---------------------
    # Per-user column gathers of the combos ref, expressed as one-hot
    # contractions against the candidate axis (Pallas rejects captured
    # numpy index constants, and gathers don't vectorize anyway).
    comb = combo_ref[...]                                 # (Kc, N) int32
    n_combo = comb.shape[0]
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (1, 1, topk), 2)
    mem = mem_ref[...] > 0.5
    nm = jnp.maximum(jnp.sum(mem.astype(q.dtype), -1), 1.0)[:, None]
    score = jnp.zeros((bc, n_combo), q.dtype)
    macc_sum = jnp.zeros((bc, n_combo), q.dtype)
    invalid = jnp.zeros((bc, n_combo), jnp.bool_)
    sel_idx = []
    for u in range(n):
        oh_u = comb[:, u][None, :, None] == iota_k        # (1, Kc, k)
        v_u = jnp.sum(jnp.where(oh_u, vals[:, u][:, None, :], 0.0), -1)
        a_u = jnp.sum(jnp.where(oh_u, acc_k[:, u][:, None, :], 0.0), -1)
        i_u = jnp.sum(jnp.where(oh_u, idx[:, u][:, None, :], 0), -1)
        m_u = mem[:, u:u + 1]
        score = score + jnp.where(m_u, v_u, 0.0)
        macc_sum = macc_sum + jnp.where(m_u, a_u, 0.0)
        invalid = invalid | ((v_u < -1e29) & m_u)
        sel_idx.append(i_u)                     # (BC, Kc) candidate ids
    macc = jnp.where(jnp.any(mem, -1, keepdims=True), macc_sum / nm,
                     100.0)
    feas = macc >= threshold - 1e-9             # dynamics.feasible
    score = jnp.where(feas & ~invalid, score, -jnp.inf)
    iota_c = jax.lax.broadcasted_iota(jnp.int32, (1, n_combo), 1)
    j = _first_argmax(score, iota_c)                      # (BC,)
    pick = iota_c == j[:, None]                           # (BC, Kc)
    best = jnp.stack(
        [jnp.sum(jnp.where(pick, i_u, 0), -1) for i_u in sel_idx], -1)
    has_feasible = jnp.isfinite(jnp.max(score, -1))
    dec_ref[...] = jnp.where(has_feasible[:, None], best, plain)


def dqn_head_kernel(active, member, end_b, agg, w1, b1, w2, b2, w3, b3,
                    allowed, acc_table, *, threshold: float, topk: int,
                    bc: int = 128, interpret: bool = True):
    """active/member/end_b: (cells, N) f32, cells a multiple of ``bc``;
    agg: (cells, 8) f32; w*/b*: the 3-layer shared MLP (biases shaped
    (1, width)); allowed: (N, A) f32 0/1 mask; acc_table: (1, A) f32.
    Returns ``(dec, q)``: (cells, N) int32 and (cells, N, A) f32;
    semantics of ``ref.dqn_head_ref``."""
    cells, users = active.shape
    n_act = w3.shape[1]
    grid = (cells // bc,)
    combos = jnp.asarray(
        list(itertools.product(range(topk), repeat=users)), jnp.int32)
    kernel = functools.partial(_kernel, bc=bc, users=users,
                               threshold=threshold, topk=topk)
    user_spec = pl.BlockSpec((bc, users), lambda i: (i, 0))
    full = [pl.BlockSpec(arr.shape, lambda i: (0,) * arr.ndim)
            for arr in (w1, b1, w2, b2, w3, b3, allowed, acc_table,
                        combos)]
    dec, q = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[user_spec, user_spec, user_spec,
                  pl.BlockSpec((bc, agg.shape[1]), lambda i: (i, 0)),
                  *full],
        out_specs=[user_spec,
                   pl.BlockSpec((bc, users, n_act), lambda i: (i, 0, 0))],
        out_shape=[
            jax.ShapeDtypeStruct((cells, users), jnp.int32),
            jax.ShapeDtypeStruct((cells, users, n_act), jnp.float32),
        ],
        interpret=interpret,
    )(active, member, end_b, agg, w1, b1, w2, b2, w3, b3, allowed,
      acc_table, combos)
    return dec, q
