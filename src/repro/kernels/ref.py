"""Pure-jnp oracles for every Pallas kernel (the ground truth used by
tests/test_kernels.py shape/dtype sweeps)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  bias=None):
    """Naive exact attention. q: (B,Sq,H,hd); k,v: (B,Skv,KV,hd); GQA via
    head grouping. window>0 = sliding causal window. bias: (B,Skv) additive
    (used to mask invalid cache slots)."""
    b, sq, h, hd = q.shape
    skv, n_kv = k.shape[1], k.shape[2]
    g = h // n_kv
    qg = q.reshape(b, sq, n_kv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k.astype(jnp.float32))
    s *= 1.0 / math.sqrt(hd)
    q_pos = jnp.arange(sq)[:, None] + (skv - sq)   # right-aligned
    kv_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kv_pos <= q_pos
    if window:
        mask &= kv_pos > q_pos - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    if bias is not None:
        s = s + bias[:, None, None, None, :]
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, hd).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, bias):
    """q: (B,H,hd); caches: (B,S,KV,hd); bias: (B,S) additive mask."""
    b, h, hd = q.shape
    o = attention_ref(q[:, None], k_cache, v_cache, causal=False, bias=bias)
    return o[:, 0]


def int8_matmul_ref(x_q, sx, w_q, sw):
    """x_q: (M,K) int8; sx: (M,1) f32; w_q: (K,N) int8; sw: (1,N) f32."""
    acc = jax.lax.dot_general(x_q, w_q, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * sx * sw


def quantize_ref(x, axis=-1):
    """Symmetric per-row int8 quantization -> (x_q, scale)."""
    amax = jnp.max(jnp.abs(x).astype(jnp.float32), axis=axis, keepdims=True) + 1e-8
    s = amax / 127.0
    x_q = jnp.clip(jnp.round(x.astype(jnp.float32) / s), -127, 127).astype(jnp.int8)
    return x_q, s


def selective_scan_ref(u, dt, A, B, C, D):
    """Sequential (lax.scan over time) selective-SSM oracle.

    u, dt: (Bt,S,di); A: (di,N); B,C: (Bt,S,N); D: (di,).
    Returns (y: (Bt,S,di), h_last: (Bt,di,N)); all math in f32.
    """
    uf, dtf = u.astype(jnp.float32), dt.astype(jnp.float32)
    Bf, Cf = B.astype(jnp.float32), C.astype(jnp.float32)

    def step(h, inp):
        ut, dtt, bt, ct = inp          # (Bt,di),(Bt,di),(Bt,N),(Bt,N)
        dA = jnp.exp(dtt[..., None] * A[None])           # (Bt,di,N)
        dBu = (dtt * ut)[..., None] * bt[:, None, :]
        h = h * dA + dBu
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    h0 = jnp.zeros((u.shape[0], u.shape[2], A.shape[1]), jnp.float32)
    h_last, ys = jax.lax.scan(step, h0,
                              (uf.swapaxes(0, 1), dtf.swapaxes(0, 1),
                               Bf.swapaxes(0, 1), Cf.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1) + uf * D[None, None]
    return y.astype(u.dtype), h_last
