"""Pure-jnp oracles for every Pallas kernel (the ground truth used by
tests/test_kernels.py shape/dtype sweeps)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  bias=None):
    """Naive exact attention. q: (B,Sq,H,hd); k,v: (B,Skv,KV,hd); GQA via
    head grouping. window>0 = sliding causal window. bias: (B,Skv) additive
    (used to mask invalid cache slots)."""
    b, sq, h, hd = q.shape
    skv, n_kv = k.shape[1], k.shape[2]
    g = h // n_kv
    qg = q.reshape(b, sq, n_kv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k.astype(jnp.float32))
    s *= 1.0 / math.sqrt(hd)
    q_pos = jnp.arange(sq)[:, None] + (skv - sq)   # right-aligned
    kv_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kv_pos <= q_pos
    if window:
        mask &= kv_pos > q_pos - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    if bias is not None:
        s = s + bias[:, None, None, None, :]
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, hd).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, bias):
    """q: (B,H,hd); caches: (B,S,KV,hd); bias: (B,S) additive mask."""
    b, h, hd = q.shape
    o = attention_ref(q[:, None], k_cache, v_cache, causal=False, bias=bias)
    return o[:, 0]


def int8_matmul_ref(x_q, sx, w_q, sw):
    """x_q: (M,K) int8; sx: (M,1) f32; w_q: (K,N) int8; sw: (1,N) f32."""
    acc = jax.lax.dot_general(x_q, w_q, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * sx * sw


def quantize_ref(x, axis=-1):
    """Symmetric per-row int8 quantization -> (x_q, scale)."""
    amax = jnp.max(jnp.abs(x).astype(jnp.float32), axis=axis, keepdims=True) + 1e-8
    s = amax / 127.0
    x_q = jnp.clip(jnp.round(x.astype(jnp.float32) / s), -127, 127).astype(jnp.int8)
    return x_q, s


def first_argmax_ref(x):
    """First-index argmax along the last axis via two vectorized
    reduces (max, then masked index-min) — bit-identical tie-breaking
    to ``jnp.argmax`` but ~2x faster on CPU XLA, whose native argmax
    lowers to a non-vectorized reduce. Shared by the fused RL ops."""
    k = x.shape[-1]
    m = jnp.max(x, axis=-1, keepdims=True)
    iota = jnp.arange(k, dtype=jnp.int32)
    iota = iota.reshape((1,) * (x.ndim - 1) + (k,))
    return jnp.min(jnp.where(x == m, iota, k), axis=-1).astype(jnp.int32)


def fused_tabular_ref(q, s, a, r, s2, *, alpha: float, gamma: float):
    """Fused tabular act+update oracle (one pass per fleet step).

    ``q``: (cells, S, K) f32; ``s``/``a``/``s2``: (cells,) int32;
    ``r``: (cells,) f32. Returns ``(q_new, greedy2, td)`` where

    * ``td = r + gamma * max_k q[c, s2] - q[c, s, a]`` (the TD error
      against the PRE-update table, exactly the unfused step's),
    * ``q_new = q`` with ``alpha * td`` added at ``(c, s, a)``,
    * ``greedy2 = argmax_k q_new[c, s2]`` — the next step's greedy
      action, computed on the POST-update row (when ``s2 == s`` the
      freshly written ``(s, a)`` entry participates), so the caller can
      carry it through a scan instead of re-gathering the row.
    """
    cells = jnp.arange(q.shape[0])
    k = q.shape[2]
    q_sa = q[cells, s, a]
    row2 = q[cells, s2]                                    # (cells, K)
    iota = jnp.arange(k, dtype=jnp.int32)[None, :]
    # Mask the (possibly) updated entry out of the row ONCE; both the
    # pre-update TD max and the post-update greedy then derive from TWO
    # row reduces + scalar fixups (the naive formulation needs three:
    # max(row2), then max + masked index-min over the updated row).
    same = s2 == s
    hit = same[:, None] & (iota == a[:, None])
    masked = jnp.where(hit, -jnp.inf, row2)
    m_ex = jnp.max(masked, axis=-1)                        # reduce 1
    i_ex = jnp.min(jnp.where(masked == m_ex[:, None], iota, k),
                   axis=-1).astype(jnp.int32)              # reduce 2
    # max is exact, so composing it is bit-identical to max(row2)
    m_pre = jnp.where(same, jnp.maximum(m_ex, q_sa), m_ex)
    td = r + gamma * m_pre - q_sa
    upd = alpha * td
    q_new = q.at[cells, s, a].add(upd)
    # first-index argmax of the post-update row, scalar-wise: the row
    # is `masked` plus (when s2 == s) the fresh value at column a
    v_new = q_sa + upd
    a32 = a.astype(jnp.int32)
    g_same = jnp.where(v_new > m_ex, a32,
                       jnp.where(v_new == m_ex, jnp.minimum(a32, i_ex),
                                 i_ex))
    greedy2 = jnp.where(same, g_same, i_ex)
    return q_new, greedy2, td


def _stable_topk_ref(q, k):
    """Iterative (max, first-argmax, mask) top-k: values descending,
    ties by ascending index — the ordering ``jax.lax.top_k`` produces —
    expressed as k vectorized reduce pairs so the same loop lowers
    inside the Pallas kernel. Exhausted rows re-yield ``NEG_INF``
    values (always culled by the invalid filter downstream)."""
    iota = jnp.arange(q.shape[-1], dtype=jnp.int32)
    iota = iota.reshape((1,) * (q.ndim - 1) + (-1,))
    vals, idx, cur = [], [], q
    for _ in range(k):
        i = first_argmax_ref(cur)
        vals.append(jnp.take_along_axis(cur, i[..., None], -1)[..., 0])
        idx.append(i)
        cur = jnp.where(iota == i[..., None], NEG_INF, cur)
    return jnp.stack(vals, -1), jnp.stack(idx, -1)


def dqn_head_ref(active, member, end_b, agg, w1, b1, w2, b2, w3, b3,
                 allowed, acc_table, *, threshold: float, topk: int):
    """Fused featurize + constraint-aware greedy head oracle.

    ``active``/``member``/``end_b``: (cells, N) f32 per-user blocks;
    ``agg``: (cells, 8) f32 cell aggregates (see
    ``fleet.policy.fused_head_features``); ``w*``/``b*``: the 3-layer
    shared per-user MLP; ``allowed``: (N, A) f32 allowed-action mask
    (disallowed entries become exactly NEG_INF, matching the legacy
    head's where-mask bit for bit); ``acc_table``: (A,) f32 per-action
    accuracy ladder. Returns ``(dec, q)``: (cells, N) int32 greedy
    per-user decisions and the (cells, N, A) masked head values.

    Assembles each user's ``[act, mem, end, agg...]`` feature row
    directly (never materializing the flat ``encode_fleet_state``
    vector), applies the shared MLP, masks, and — with a QoS
    ``threshold`` — scores the per-user top-k combinations against the
    accuracy ladder exactly like ``FleetDQN._make_greedy``: combos with
    a masked (NEG_INF) member entry are culled, infeasible combos are
    culled, and a cell with no feasible combo falls back to the plain
    per-user argmax.
    """
    cells, n = active.shape
    feats = jnp.concatenate(
        [active[..., None], member[..., None], end_b[..., None],
         jnp.broadcast_to(agg[:, None, :],
                          (cells, n, agg.shape[-1]))], -1)
    x = feats.reshape(cells * n, feats.shape[-1])
    h = jax.nn.relu(x @ w1 + b1)
    h = jax.nn.relu(h @ w2 + b2)
    q = jnp.where(allowed[None] > 0.5,
                  (h @ w3 + b3).reshape(cells, n, -1), NEG_INF)
    plain = first_argmax_ref(q)
    if not threshold:
        return plain, q
    import itertools
    import numpy as np
    k = topk
    # lax.top_k has the exact ordering _stable_topk_ref reproduces
    # in-kernel (descending values, ties by ascending index); on rows
    # with fewer than k finite entries the two diverge only in
    # duplicated NEG_INF candidate ids, which the invalid filter below
    # culls on both paths — decisions stay bit-identical
    vals, idx = jax.lax.top_k(q, k)                    # (cells, N, k)
    acc_k = acc_table[idx]                             # (cells, N, k)
    combos = np.asarray(list(itertools.product(range(k), repeat=n)),
                        np.int32)                      # (Kc, N) static
    mem = member > 0.5
    nm = jnp.maximum(mem.sum(-1), 1)[:, None].astype(q.dtype)
    score = jnp.zeros((cells, len(combos)), q.dtype)
    macc_sum = jnp.zeros((cells, len(combos)), q.dtype)
    invalid = jnp.zeros((cells, len(combos)), bool)
    for u in range(n):
        cu = combos[:, u]                              # static gather
        v_u, a_u = vals[:, u, cu], acc_k[:, u, cu]     # (cells, Kc)
        m_u = mem[:, u:u + 1]
        score = score + jnp.where(m_u, v_u, 0.0)
        macc_sum = macc_sum + jnp.where(m_u, a_u, 0.0)
        invalid = invalid | ((v_u < -1e29) & m_u)
    macc = jnp.where(mem.any(-1, keepdims=True), macc_sum / nm, 100.0)
    feas = macc >= threshold - 1e-9        # dynamics.feasible, inlined
    score = jnp.where(feas & ~invalid, score, -jnp.inf)
    j = first_argmax_ref(score)                        # (cells,)
    cu_j = jnp.asarray(combos)[j]                      # (cells, N)
    best = jnp.take_along_axis(idx, cu_j[..., None], 2)[..., 0]
    has_feasible = jnp.isfinite(
        jnp.take_along_axis(score, j[:, None], 1))[:, 0]
    return jnp.where(has_feasible[:, None], best, plain), q


def selective_scan_ref(u, dt, A, B, C, D):
    """Sequential (lax.scan over time) selective-SSM oracle.

    u, dt: (Bt,S,di); A: (di,N); B,C: (Bt,S,N); D: (di,).
    Returns (y: (Bt,S,di), h_last: (Bt,di,N)); all math in f32.
    """
    uf, dtf = u.astype(jnp.float32), dt.astype(jnp.float32)
    Bf, Cf = B.astype(jnp.float32), C.astype(jnp.float32)

    def step(h, inp):
        ut, dtt, bt, ct = inp          # (Bt,di),(Bt,di),(Bt,N),(Bt,N)
        dA = jnp.exp(dtt[..., None] * A[None])           # (Bt,di,N)
        dBu = (dtt * ut)[..., None] * bt[:, None, :]
        h = h * dA + dBu
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    h0 = jnp.zeros((u.shape[0], u.shape[2], A.shape[1]), jnp.float32)
    h_last, ys = jax.lax.scan(step, h0,
                              (uf.swapaxes(0, 1), dtf.swapaxes(0, 1),
                               Bf.swapaxes(0, 1), Cf.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1) + uf * D[None, None]
    return y.astype(u.dtype), h_last
