"""Fused tabular-RL act+update Pallas TPU kernel.

``FleetQLearning``'s per-cell hot path is three HLOs round-tripping the
same two Q-table rows through HBM: gather ``q[c, s2]`` for the TD max,
gather/scatter ``q[c, s, a]`` for the update, then — on the NEXT step —
gather ``q[c, s2]`` again for the greedy argmax (``s2`` is exactly the
next step's state index). This kernel fuses the act+update pair:
blocking over the fleet axis, each grid program stages a ``(BC, S, K)``
slab of the Q-table into VMEM, and for every cell in the block reads
row ``s`` and row ``s2`` ONCE, computes the TD error, writes the
updated ``(s, a)`` entry in place (``input_output_aliases`` keeps the
table buffer donated), and emits the next step's greedy action from
the post-update ``s2`` row — so the scan carries ``greedy`` instead of
re-gathering the row, and Q-table rows never leave VMEM between the
act and the update that consumed them.

Argmax is the first-index tie-break of ``jnp.argmax``, computed as a
(max, masked index-min) reduce pair — the same trick
``ref.first_argmax_ref`` uses, vectorized on the VPU lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, s_ref, a_ref, r_ref, s2_ref, q_out_ref, g_ref, td_ref,
            *, bc: int, alpha: float, gamma: float, n_actions: int):
    # q: (BC, S, K); s/a/r/s2 and g/td: (BC, 1)
    q_out_ref[...] = q_ref[...]          # no-op under aliasing; exact copy
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, n_actions), 1)

    def cell(c, _):
        s_c, a_c = s_ref[c, 0], a_ref[c, 0]
        s2_c, r_c = s2_ref[c, 0], r_ref[c, 0]
        row_s = pl.load(q_ref, (c, pl.ds(s_c, 1), slice(None)))   # (1, K)
        row_2 = pl.load(q_ref, (c, pl.ds(s2_c, 1), slice(None)))  # (1, K)
        onehot = iota == a_c
        q_sa = jnp.sum(jnp.where(onehot, row_s, 0.0))
        td = r_c + gamma * jnp.max(row_2) - q_sa
        row_s_new = row_s + jnp.where(onehot, alpha * td, 0.0)
        pl.store(q_out_ref, (c, pl.ds(s_c, 1), slice(None)), row_s_new)
        # next step's greedy on the POST-update s2 row (when s2 == s the
        # freshly written entry participates)
        row_2_new = jnp.where(s2_c == s_c, row_s_new, row_2)
        m2 = jnp.max(row_2_new)
        g = jnp.min(jnp.where(row_2_new == m2, iota, n_actions))
        g_ref[c, 0] = g.astype(jnp.int32)
        td_ref[c, 0] = td
        return _

    jax.lax.fori_loop(0, bc, cell, 0)


def tabular_rl_kernel(q, s, a, r, s2, *, alpha: float, gamma: float,
                      bc: int = 8, interpret: bool = True):
    """q: (cells, S, K) f32; s/a/r/s2: (cells, 1) int32/f32, cells a
    multiple of ``bc``. Returns ``(q_new, greedy2, td)`` with greedy2/td
    shaped (cells, 1); semantics of ``ref.fused_tabular_ref``."""
    cells, n_states, n_actions = q.shape
    grid = (cells // bc,)
    kernel = functools.partial(_kernel, bc=bc, alpha=alpha, gamma=gamma,
                               n_actions=n_actions)
    scalar_spec = pl.BlockSpec((bc, 1), lambda i: (i, 0))
    q_spec = pl.BlockSpec((bc, n_states, n_actions), lambda i: (i, 0, 0))
    q_new, greedy2, td = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[q_spec, scalar_spec, scalar_spec, scalar_spec,
                  scalar_spec],
        out_specs=[q_spec, scalar_spec, scalar_spec],
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct((cells, 1), jnp.int32),
            jax.ShapeDtypeStruct((cells, 1), jnp.float32),
        ],
        input_output_aliases={0: 0},     # update the Q slab in place
        interpret=interpret,
    )(q, s, a, r, s2)
    return q_new, greedy2, td
