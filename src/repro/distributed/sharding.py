"""Logical-axis sharding rules (MaxText-style) for the production mesh.

Mesh axes:
  'pod'   - pods (multi-pod only), extra data-parallel dim
  'data'  - within-pod data parallel / FSDP axis
  'model' - tensor/expert parallel axis
  'fleet' - 1-D fleet data-parallel axis (repro.fleet.shard): the cell
            population and per-edge arrays shard over it; absent from
            the model meshes, so the fleet rules are inert there (and
            the model rules are inert on a fleet mesh)

Logical activation/parameter axes are mapped through RULES. Every
constraint is divisibility-checked per dimension; a dim that is not
divisible by its mapped mesh axes (e.g. 25 heads over a 16-way 'model'
axis, or batch=1 decode over 'data') silently falls back to replication,
and a mesh axis is never assigned twice within one spec (first dim wins
— e.g. a KV cache shards 'data' on batch when batch is wide, else on the
cache-length dim for long-context decode). This keeps ONE rule table
valid across all 10 architectures x 4 input shapes.

The module is a process-global context (``activate_mesh``) so model code
can annotate activations without threading a mesh handle everywhere;
with no active mesh every annotation is the identity (CPU smoke tests).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axes (resolved against the active mesh; mesh axes
# absent from the mesh are dropped, so one table serves 2D and 3D meshes)
RULES = {
    "batch": ("pod", "data"),
    "fsdp": ("pod", "data"),
    "seq": (),
    "kv_seq": ("pod", "data"),     # long-context decode: shard cache length
    # decode caches: after 'batch' takes what divides, the cache-length
    # dim absorbs every remaining mesh axis (incl. 'model' when kv_heads
    # is not divisible by it) — flash-decode style sequence sharding; the
    # partial softmax is handled by GSPMD all-reduces (verified).
    "cache_len": ("pod", "data", "model"),
    "model": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "embed": (),
    "mlp": ("model",),
    "vocab": ("model",),
    "expert": ("model",),
    "d_inner": ("model",),
    # fleet logical axes (repro.fleet.shard): the cell axis of scenario
    # arrays / Q-tables / replay rows, and the edge axis of per-edge
    # arrays in shard-local topologies
    "cells": ("fleet",),
    "edges": ("fleet",),
    None: (),
}

_STATE = {"mesh": None}


def activate_mesh(mesh: Optional[Mesh]):
    _STATE["mesh"] = mesh


def current_mesh() -> Optional[Mesh]:
    return _STATE["mesh"]


def _resolve(mesh: Mesh, logical_axes):
    names = set(mesh.axis_names)
    out = []
    for ax in logical_axes:
        axes = tuple(a for a in RULES.get(ax, ()) if a in names)
        out.append(axes)
    return out


def _checked_spec(mesh: Mesh, shape, resolved) -> P:
    """Divisibility check + no-duplicate-axis guarantee (first dim wins)."""
    used = set()
    fixed = []
    resolved = list(resolved) + [()] * (len(shape) - len(resolved))
    for dim, axes in zip(shape, resolved):
        axes = tuple(a for a in axes if a not in used)
        size = math.prod(mesh.shape[a] for a in axes) if axes else 1
        if axes and dim % size == 0:
            used.update(axes)
            fixed.append(axes if len(axes) > 1 else axes[0])
        else:
            fixed.append(None)
    return P(*fixed)


def spec_for(shape, logical_axes, mesh: Optional[Mesh] = None) -> Optional[P]:
    mesh = mesh or _STATE["mesh"]
    if mesh is None:
        return None
    return _checked_spec(mesh, shape, _resolve(mesh, logical_axes))


def logical(x, *logical_axes):
    """Annotate activation x with logical axes (identity without a mesh)."""
    mesh = _STATE["mesh"]
    if mesh is None:
        return x
    spec = spec_for(x.shape, logical_axes, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def shard_moe_dispatch(x):
    """(B, E, C, D) dispatched MoE activations: experts to 'model' — the
    reshard from token layout is the expert-parallel all-to-all."""
    return logical(x, "batch", "expert", None, None)


# ---------------------------------------------------------------------------
# Parameter shardings, matched by (parent, leaf) names in the param tree.

# weight-dict parents ('w'/'w_q'/'s' leaves) -> logical axes of the 2D mat
_PARENT_RULES = {
    "embed": ("vocab", "embed"),
    "lm_head": ("fsdp", "vocab"),
    "proj_img": ("fsdp", "model"),
    "router": (None, None),
    "wq": ("fsdp", "model"),
    "wk": ("fsdp", "model"),
    "wv": ("fsdp", "model"),
    "wo": ("model", "fsdp"),
    "w_gate": ("fsdp", "mlp"),
    "w_up": ("fsdp", "mlp"),
    "w_down": ("mlp", "fsdp"),
    "in_proj": ("fsdp", "d_inner"),
    "x_proj": ("d_inner", None),
    "out_proj": ("d_inner", "fsdp"),
}
# MoE expert mats carry a leading E dim and shard experts over 'model'
# (expert parallel), so the mat dims must avoid 'model':
_MOE_PARENT_RULES = {
    "w_gate": ("expert", "fsdp", None),
    "w_up": ("expert", "fsdp", None),
    "w_down": ("expert", None, "fsdp"),
}
# direct array leaves
_LEAF_RULES = {
    "dt_w": (None, "d_inner"),
    "dt_b": ("d_inner",),
    "conv_w": (None, "d_inner"),
    "conv_b": ("d_inner",),
    "A_log": ("d_inner", None),
    "D": ("d_inner",),
}


def _path_parts(path):
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return parts


def logical_axes_for_param(path, ndim: int):
    parts = _path_parts(path)
    leaf = parts[-1]
    parent = parts[-2] if len(parts) >= 2 else ""
    in_moe = "moe" in parts
    if leaf in ("w", "w_q", "s"):
        if in_moe and parent in _MOE_PARENT_RULES:
            axes = _MOE_PARENT_RULES[parent]
        else:
            axes = _PARENT_RULES.get(parent, ())
        if leaf == "s" and axes:  # quant scales broadcast over the input dim
            head = ("expert",) if (in_moe and len(axes) == 3) else ()
            axes = head + (None,) * (ndim - len(head) - 1) + (axes[-1],)
    else:
        axes = _LEAF_RULES.get(leaf, ())
    axes = tuple(axes)
    if len(axes) < ndim:      # leading stacked-layer (or other) dims: None
        axes = (None,) * (ndim - len(axes)) + axes
    elif len(axes) > ndim:
        axes = axes[-ndim:]
    return axes


def param_shardings(params_shapes, mesh: Optional[Mesh] = None):
    """Pytree of NamedSharding matching ``params_shapes`` (arrays or
    ShapeDtypeStructs)."""
    mesh = mesh or _STATE["mesh"]
    if mesh is None:
        return jax.tree_util.tree_map(lambda _: None, params_shapes)

    def one(path, leaf):
        axes = logical_axes_for_param(path, len(leaf.shape))
        spec = _checked_spec(mesh, leaf.shape, _resolve(mesh, axes))
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_shapes)
