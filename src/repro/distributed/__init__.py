from repro.distributed.sharding import (activate_mesh, current_mesh, logical,
                                        param_shardings, shard_moe_dispatch)
