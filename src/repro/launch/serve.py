"""Serving launcher: bring up the three-tier engine set for one arch's
variant ladder and run the RL-orchestrated decode loop on synthetic
request traffic (the paper's Fig. 4 runtime, reduced scale on CPU).

  PYTHONPATH=src python -m repro.launch.serve --arch edge-ladder --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core import (EXPERIMENTS, EndEdgeCloudEnv, QLearningAgent,
                        IntelligentOrchestrator, train_agent)
from repro.models import build_model
from repro.models.variants import build_ladder
from repro.serving import Request, RequestBatcher, ServingEngine


def build_engines(cfg, variants=("d0", "d4", "d7"), max_len=64, hop_ms=None):
    """One engine per (tier, variant); tiers emulated by compute_scale.

    ``hop_ms`` (e.g. ``{"E": 25.0, "C": 50.0}``) adds a real per-batch
    network-hop sleep per tier — tier SEPARATION emulation on a single
    host (see ``ServingEngine``); default: no hops (local tiers)."""
    ladder = build_ladder(cfg)
    engines = {"S": {}, "E": {}, "C": {}}
    scales = {"S": 1.0, "E": 2.0, "C": 4.0}
    hops = dict(hop_ms or {})
    for vid in variants:
        vcfg = ladder[vid].cfg
        model = build_model(vcfg)
        params = model.init(jax.random.PRNGKey(hash(vid) % 2**31))
        for tier, sc in scales.items():
            if tier != "S" and vid != "d0":
                continue  # paper: edge/cloud always run d0
            engines[tier][vid] = ServingEngine(model, params, max_len=max_len,
                                               compute_scale=sc,
                                               hop_ms=hops.get(tier, 0.0))
    return engines


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="edge-ladder")
    ap.add_argument("--users", type=int, default=3)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--threshold", type=float, default=85.0)
    ap.add_argument("--train-steps", type=int, default=6000)
    args = ap.parse_args(argv)

    cfg = reduced(get_config(args.arch)) if args.arch != "edge-ladder" \
        else get_config(args.arch)
    env = EndEdgeCloudEnv(args.users, EXPERIMENTS["EXP-A"],
                          accuracy_threshold=args.threshold, seed=0)
    agent = QLearningAgent(env.spec, seed=0)
    print("training orchestration agent...")
    res = train_agent(agent, env, args.train_steps)
    print(f"  converged_at={res.converged_at} greedy={res.greedy_ms:.1f}ms "
          f"(optimal {res.best_ms:.1f}ms)")

    engines = build_engines(cfg)
    orch = IntelligentOrchestrator(agent, env, engines)
    state = env.reset()
    rng = np.random.default_rng(0)
    for wave in range(args.requests):
        per_user = orch.decide(state)
        prompts = [rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
                   for _ in range(args.users)]
        results = orch.dispatch(per_user, prompts)
        joint = env.spec.encode_action(per_user)
        state, _, info = env.step(joint)
        print(f"wave {wave}: decision={per_user} "
              f"env_avg={info['avg_response_ms']:.1f}ms "
              f"measured={[f'{r[2]:.0f}ms' for r in results]}")


if __name__ == "__main__":
    main()
