"""Training launcher: real steps on the available devices (CPU smoke /
TPU slice), with the same sharding rules as the dry-run.

  PYTHONPATH=src python -m repro.launch.train --arch gemma-7b --reduced \
      --steps 20 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_pytree
from repro.configs import INPUT_SHAPES, get_config, reduced
from repro.distributed import sharding
from repro.models import build_model
from repro.training import AdamWConfig, init_state, make_train_step
from repro.training.data import batches


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="2-layer smoke config (CPU)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--save", default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)
    state = init_state(model, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(2, args.steps // 10),
                          total_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, opt_cfg))

    extras = {}
    if cfg.arch_type == "vlm":
        extras["img_embeds"] = lambda b: np.random.default_rng(0).standard_normal(
            (b, cfg.n_img_tokens, cfg.d_model), dtype=np.float32)
    if cfg.is_encdec:
        extras["frames"] = lambda b: np.random.default_rng(0).standard_normal(
            (b, cfg.enc_seq, cfg.d_model), dtype=np.float32)

    t0 = time.perf_counter()
    for i, b in enumerate(batches(cfg.vocab_size, args.batch, args.seq,
                                  args.steps, extras=extras)):
        state, metrics = step_fn(state, {k: jnp.asarray(v) for k, v in b.items()})
        if i % max(1, args.steps // 10) == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss {float(metrics['loss']):8.4f} "
                  f"gnorm {float(metrics['grad_norm']):8.3f} "
                  f"lr {float(metrics['lr']):.2e}", flush=True)
    dt = time.perf_counter() - t0
    print(f"done: {args.steps} steps in {dt:.1f}s "
          f"({args.steps * args.batch * args.seq / dt:.0f} tok/s)")
    if args.save:
        save_pytree(args.save, state["params"])
        print("saved", args.save)


if __name__ == "__main__":
    main()
