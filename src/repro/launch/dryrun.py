"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
against 512 placeholder host devices; capture memory/cost/collective
analysis for the roofline (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] --out results/
"""
# The first two lines MUST run before any other import (jax locks the
# device count on first init):
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.distributed import sharding
from repro.launch.mesh import (HBM_BW, ICI_BW_PER_LINK, PEAK_BF16_FLOPS,
                               make_production_mesh)
from repro.models import build_model
from repro.training import AdamWConfig, init_opt_state, make_train_step

# skip list (DESIGN.md §4): pure full-attention archs have no sub-quadratic
# path for 524k decode.
LONG_CTX_OK = {"gemma3-4b", "hymba-1.5b", "falcon-mamba-7b"}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[^\]]*\]))\S*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)\b")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_BYTES = {"f32": 4, "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "s8": 1,
          "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f64": 8, "s16": 2,
          "u16": 2}
# effective wire multiplier per collective (ring algorithms)
_WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(spec: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(spec):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _BYTES.get(dt, 4)
    return total


def collective_bytes(hlo_text: str):
    """Per-device wire bytes by collective kind, parsed from the
    post-SPMD optimized HLO (shapes there are already per-device)."""
    out = {}
    for m in _COLL_RE.finditer(hlo_text):
        spec, kind = m.group(1), m.group(2)
        out[kind] = out.get(kind, 0.0) + _shape_bytes(spec) * _WIRE_FACTOR[kind]
    return out


# ---------------------------------------------------------------------------


def build_lowerable(arch: str, shape_name: str, *, unroll: bool):
    """Returns (fn, kwargs_specs, in_shardings, out_shardings, meta).

    unroll=True unrolls the layer scans for exact cost_analysis FLOP/byte
    counts (XLA counts a scan body once, not x trip-count); unroll=False
    keeps the runtime lax.scan program whose memory_analysis reflects the
    deployed executable."""
    from repro.models import transformer as _T
    _T.UNROLL_SEGMENTS = unroll
    cfg = get_config(arch)
    model = build_model(cfg)
    shape = INPUT_SHAPES[shape_name]
    mesh = sharding.current_mesh()

    if shape.kind == "train":
        opt_cfg = AdamWConfig()
        step = make_train_step(model, opt_cfg, remat=True)
        params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        state_shapes = {"params": params_shapes,
                        "opt": jax.eval_shape(init_opt_state, params_shapes)}
        batch = model.input_specs(shape)
        state_sh = _state_shardings(state_shapes, mesh)
        batch_sh = _batch_shardings(batch, mesh)
        fn = step
        args = (state_shapes, batch)
        in_sh = (state_sh, batch_sh)
        out_sh = (state_sh, None)
        n_tok = shape.global_batch * shape.seq_len
        model_flops = 6.0 * cfg.active_param_count() * n_tok
    elif shape.kind == "prefill":
        def fn(params, batch):
            logits, cache = model.prefill(params, batch)
            return logits, cache
        params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        batch = model.input_specs(shape)
        in_sh = (sharding.param_shardings(params_shapes, mesh),
                 _batch_shardings(batch, mesh))
        out_sh = None
        args = (params_shapes, batch)
        n_tok = shape.global_batch * shape.seq_len
        model_flops = 2.0 * cfg.active_param_count() * n_tok
    else:  # decode
        def fn(params, batch):
            return model.decode(params, batch["cache"], batch["tokens"])
        params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        batch = model.input_specs(shape)
        in_sh = (sharding.param_shardings(params_shapes, mesh),
                 _batch_shardings(batch, mesh))
        out_sh = None
        args = (params_shapes, batch)
        n_tok = shape.global_batch  # one token per sequence
        model_flops = 2.0 * cfg.active_param_count() * n_tok

    meta = {"arch": arch, "shape": shape_name, "kind": shape.kind,
            "params": cfg.param_count(), "active_params": cfg.active_param_count(),
            "tokens": n_tok, "model_flops": model_flops}
    return fn, args, in_sh, out_sh, meta


def _state_shardings(state_shapes, mesh):
    p_sh = sharding.param_shardings(state_shapes["params"], mesh)
    m_sh = sharding.param_shardings(state_shapes["opt"]["m"], mesh)
    v_sh = sharding.param_shardings(state_shapes["opt"]["v"], mesh)
    from jax.sharding import NamedSharding, PartitionSpec as P
    step_sh = NamedSharding(mesh, P())
    return {"params": p_sh, "opt": {"m": m_sh, "v": v_sh, "step": step_sh}}


def _batch_shardings(batch, mesh):
    from jax.sharding import NamedSharding

    def one(path, leaf):
        name = str(getattr(path[-1], "key", ""))
        nd = len(leaf.shape)
        if name in ("tokens",):
            axes = ("batch",) + (None,) * (nd - 1)
        elif name in ("img_embeds", "frames"):
            axes = ("batch", None, "embed")
        elif name in ("k", "v", "ck", "cv", "k_s", "v_s"):
            # §Perf iteration: head-shard the cache when kv_heads divides
            # the 'model' axis (TP attention, no softmax collectives);
            # otherwise shard the cache LENGTH over 'model' (flash-decode
            # style) instead of replicating — 4.8x memory-term win for
            # kv=8 archs (internlm2/yi/dbrx) on the 16-wide axis.
            kv_heads = leaf.shape[3] if nd >= 4 else leaf.shape[-1]
            divisible = kv_heads % mesh.shape.get("model", 1) == 0
            seq_ax = "kv_seq" if divisible else "cache_len"
            axes = (None, "batch", seq_ax, "kv_heads", None)[:nd]
        elif name == "conv":
            axes = (None, "batch", None, "d_inner")
        elif name == "h":
            axes = (None, "batch", "d_inner", None)
        else:  # pos etc.
            axes = (None,) * nd
        spec = sharding.spec_for(leaf.shape, axes, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, batch)


def _lower_compile(arch, shape_name, unroll):
    from repro.tuning import FLAGS
    fn, args, in_sh, out_sh, meta = build_lowerable(arch, shape_name,
                                                    unroll=unroll)
    donate = ()
    if meta["kind"] == "decode" and FLAGS["donate_cache"]:
        donate = (1,)
    jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                  donate_argnums=donate)
    lowered = jfn.lower(*args)
    return lowered.compile(), meta


def run_one(arch: str, shape_name: str, multi_pod: bool, *,
            save_hlo_dir=None, verbose=True, costs: bool = True):
    """costs=False (multi-pod pass): only prove lower+compile+fits with
    the runtime scanned program; the single-pod roofline pass adds the
    unrolled compile for exact per-op accounting."""
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    sharding.activate_mesh(mesh)
    try:
        with mesh:
            # pass 1 (runtime program, scanned): memory truth
            compiled_scan, meta = _lower_compile(arch, shape_name, False)
            # pass 2 (unrolled): exact per-op cost/collective accounting
            compiled = (_lower_compile(arch, shape_name, True)[0]
                        if costs else compiled_scan)
        ca = compiled.cost_analysis() or {}
        ma = compiled_scan.memory_analysis()
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        n_dev = mesh.size
        flops_dev = float(ca.get("flops", 0.0))
        bytes_dev = float(ca.get("bytes accessed", 0.0))
        coll_dev = float(sum(coll.values()))
        res = dict(meta)
        res.update(
            mesh="2x16x16" if multi_pod else "16x16",
            n_devices=n_dev,
            ok=True,
            seconds=round(time.time() - t0, 1),
            flops_per_device=flops_dev,
            bytes_per_device=bytes_dev,
            collective_bytes_per_device=coll_dev,
            collectives={k: int(v) for k, v in coll.items()},
            compute_s=flops_dev / PEAK_BF16_FLOPS,
            memory_s=bytes_dev / HBM_BW,
            collective_s=coll_dev / ICI_BW_PER_LINK,
            model_flops_per_device=meta["model_flops"] / n_dev,
            useful_flops_ratio=(meta["model_flops"] / n_dev) / max(flops_dev, 1.0),
            arg_bytes_per_device=getattr(ma, "argument_size_in_bytes", None),
            temp_bytes_per_device=getattr(ma, "temp_size_in_bytes", None),
            out_bytes_per_device=getattr(ma, "output_size_in_bytes", None),
        )
        terms = {"compute": res["compute_s"], "memory": res["memory_s"],
                 "collective": res["collective_s"]}
        res["dominant"] = max(terms, key=terms.get)
        if save_hlo_dir:
            os.makedirs(save_hlo_dir, exist_ok=True)
            tag = f"{arch}_{shape_name}_{res['mesh']}".replace("/", "-")
            with open(os.path.join(save_hlo_dir, tag + ".hlo"), "w") as f:
                f.write(hlo)
        if verbose:
            print(f"[OK] {arch:22s} {shape_name:12s} {res['mesh']:7s} "
                  f"compute={res['compute_s']*1e3:9.2f}ms "
                  f"memory={res['memory_s']*1e3:9.2f}ms "
                  f"coll={res['collective_s']*1e3:9.2f}ms "
                  f"dom={res['dominant']:10s} "
                  f"useful={res['useful_flops_ratio']:.2f} "
                  f"temp={(res['temp_bytes_per_device'] or 0)/2**30:.2f}GiB "
                  f"({res['seconds']}s)", flush=True)
        return res
    except Exception as e:  # noqa
        if verbose:
            print(f"[FAIL] {arch} {shape_name} multi_pod={multi_pod}: {e}",
                  flush=True)
            traceback.print_exc()
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16", "ok": False,
                "error": str(e)[:2000]}
    finally:
        sharding.activate_mesh(None)


def pairs(include_long_skips=False):
    for arch in ARCH_IDS:
        for shape in INPUT_SHAPES:
            if shape == "long_500k" and arch not in LONG_CTX_OK:
                if include_long_skips:
                    yield arch, shape, "skip"
                continue
            yield arch, shape, "run"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None, help="append jsonl results here")
    ap.add_argument("--skip-multi-pod-costs", action="store_true",
                    default=True)
    ap.add_argument("--tune", default=None,
                    help="comma k=v tuning flags (repro.tuning.FLAGS)")
    ap.add_argument("--hlo-dir", default=None)
    args = ap.parse_args()

    if args.tune:
        from repro.tuning import FLAGS
        for kv in args.tune.split(","):
            k, v = kv.split("=")
            cur = FLAGS[k]
            if isinstance(cur, bool):
                FLAGS[k] = v in ("1", "True", "true")
            elif isinstance(cur, int):
                FLAGS[k] = int(v)
            elif isinstance(cur, float):
                FLAGS[k] = float(v)
            else:
                FLAGS[k] = v
        print("tuning:", {k: v for k, v in FLAGS.items()})
    todo = []
    if args.all:
        for arch, shape, status in pairs():
            if status == "run":
                todo.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    for arch, shape in todo:
        for mp in meshes:
            res = run_one(arch, shape, mp, save_hlo_dir=args.hlo_dir,
                          costs=not (mp and args.skip_multi_pod_costs))
            results.append(res)
            if args.out:
                os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                with open(args.out, "a") as f:
                    f.write(json.dumps(res) + "\n")
    n_ok = sum(r.get("ok") for r in results)
    print(f"\n{n_ok}/{len(results)} lowered+compiled OK")
    return 0 if n_ok == len(results) else 1


if __name__ == "__main__":
    raise SystemExit(main())
