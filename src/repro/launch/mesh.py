"""Production mesh builders (DESIGN.md §7, system-prompt contract).

Functions — NOT module-level constants — so importing this module never
touches jax device state. The dry-run process sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax
import to make 512 placeholder host devices available.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod (v5e); multi_pod adds a 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_tier_mesh(tier: str):
    """End-edge-cloud tiers as submesh sizes (DESIGN.md §2): the
    orchestrator's 'device' is a single chip, 'edge' an 8-chip slice,
    'cloud' the full single-pod mesh. Used by launch/serve.py; on the
    CPU container these all collapse to available devices."""
    n = len(jax.devices())
    shapes = {"S": (1, 1), "E": (1, min(8, n)), "C": (1, n)}
    shape = shapes[tier]
    return jax.make_mesh(shape, ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


# TPU v5e hardware constants for the roofline (per chip)
PEAK_BF16_FLOPS = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW_PER_LINK = 50e9            # B/s per link
