"""Substrate tests: serving engine, batcher, data pipeline, checkpoint,
quantized variants, orchestrator integration."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_pytree, save_pytree
from repro.configs import get_config, reduced
from repro.models import build_model
from repro.serving import Request, RequestBatcher, ServingEngine
from repro.training.data import SyntheticLM, batches


def test_batcher_padding_and_order():
    b = RequestBatcher(batch_size=3, buckets=(16, 32))
    for i in range(5):
        b.submit(Request(rid=i, prompt=np.arange(5 + i, dtype=np.int32)))
    reqs, toks, lens = b.next_batch()
    assert len(reqs) == 3 and toks.shape == (3, 16)
    assert [r.rid for r in reqs] == [0, 1, 2]
    assert list(lens) == [5, 6, 7]
    assert (toks[0, 5:] == 0).all()
    reqs2, toks2, _ = b.next_batch()
    assert len(reqs2) == 2
    # empty drain is an empty batch, not an error (the bridge's worker
    # loop and the sync drain both rely on this)
    reqs3, toks3, lens3 = b.next_batch()
    assert reqs3 == [] and toks3.shape == (0, 16) and lens3.shape == (0,)


def test_batcher_pack_splits_oversize():
    b = RequestBatcher(batch_size=3, buckets=(16, 32))
    reqs = [Request(rid=i, prompt=np.arange(4 + i, dtype=np.int32))
            for i in range(7)]
    packed = b.pack(reqs)
    # 7 requests at batch_size=3 -> 3+3+1, never truncated
    assert [len(br) for br, _, _ in packed] == [3, 3, 1]
    assert [r.rid for br, _, _ in packed for r in br] == list(range(7))
    for br, toks, lens in packed:
        assert toks.shape[0] == len(br) and toks.shape[1] in (16, 32)
        assert list(lens) == [len(r.prompt) for r in br]
    assert b.pack([]) == []


def test_serving_engine_generates():
    cfg = reduced(get_config("gemma-7b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, max_len=64)
    toks = np.arange(20, dtype=np.int32)[None].repeat(2, 0) % cfg.vocab_size
    out, wall = eng.generate(toks, max_new_tokens=4)
    assert out.shape == (2, 4) and wall > 0
    assert (out >= 0).all() and (out < cfg.vocab_size).all()


def test_serving_engine_network_hop():
    """hop_ms emulates the hop to a physically separate tier: a real
    per-batch sleep counted in both the raw batch wall (serve_time) and
    the measured response_time the calibration fit consumes."""
    import time

    cfg = reduced(get_config("gemma-7b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    base = ServingEngine(model, params, max_len=64)
    hop = ServingEngine(model, params, max_len=64, hop_ms=80.0)
    toks = np.arange(8, dtype=np.int32)[None] % cfg.vocab_size

    def serve(eng):
        reqs = [Request(rid=0, prompt=toks[0], max_new_tokens=1,
                        arrival_time=time.perf_counter())]
        t0 = time.perf_counter()
        done = eng.serve_batch(reqs, toks)
        return done[0], time.perf_counter() - t0

    serve(base), serve(hop)                    # compile once
    r0, _w0 = serve(base)
    r1, w1 = serve(hop)
    assert w1 >= 0.08                          # the hop actually elapses
    assert r1.serve_time >= 0.08               # ...inside the batch wall
    # measured response = comm + compute (not tier-speed-scaled)
    assert r1.response_time >= r0.response_time + 0.08 - 0.005


def test_synthetic_lm_learnable_and_deterministic():
    src1 = SyntheticLM(64, seed=3)
    src2 = SyntheticLM(64, seed=3)
    a, b = src1.sample(4, 32), src2.sample(4, 32)
    assert (a == b).all()
    assert a.min() >= 0 and a.max() < 64
    # markov structure: transition matrix rows are a proper distribution
    np.testing.assert_allclose(src1.probs.sum(1), 1.0, atol=1e-6)


def test_checkpoint_roundtrip_mixed_dtypes(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.int32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16) * 1.5,
                  "d": [jnp.zeros((2,), jnp.float32),
                        jnp.full((3,), 7.0, jnp.float32)]}}
    path = str(tmp_path / "ck")
    save_pytree(path, tree)
    back = load_pytree(path, tree)
    for x, y in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_int8_variant_forward_close_to_fp():
    """The int8 ladder variant (d4) approximates its fp twin (d0)."""
    cfg = dataclasses.replace(reduced(get_config("gemma-7b")), dtype="float32")
    cfg8 = dataclasses.replace(cfg, quant="int8")
    m, m8 = build_model(cfg), build_model(cfg8)
    p = m.init(jax.random.PRNGKey(0))

    # quantize the SAME weights for the int8 twin
    def quantize_tree(t):
        if isinstance(t, dict) and "w" in t and t["w"].ndim == 2 \
                and t["w"].shape[0] > 8:
            w = t["w"].astype(jnp.float32)
            s = jnp.max(jnp.abs(w), 0, keepdims=True) / 127.0 + 1e-8
            wq = jnp.clip(jnp.round(w / s), -127, 127).astype(jnp.int8)
            return {"w_q": wq, "s": s}
        if isinstance(t, dict):
            return {k: quantize_tree(v) for k, v in t.items()}
        if isinstance(t, list):
            return [quantize_tree(v) for v in t]
        return t

    p8 = quantize_tree(p)
    # embed stays fp (matches init_linear quant rules: embeds not quantized)
    p8["embed"] = p["embed"]
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    l1, _ = m.prefill(p, {"tokens": toks})
    l8, _ = m8.prefill(p8, {"tokens": toks})
    p1 = jax.nn.softmax(l1[:, -1, : cfg.vocab_size], -1)
    p2 = jax.nn.softmax(l8[:, -1, : cfg.vocab_size], -1)
    tv = float(0.5 * jnp.abs(p1 - p2).sum(-1).max())
    assert tv < 0.25, tv    # int8 PTQ keeps the output distribution close


def test_orchestrator_end_to_end_tiny():
    """Agent decision -> engine dispatch -> real latencies (paper Fig. 4)."""
    from repro.core import (EXPERIMENTS, EndEdgeCloudEnv, QLearningAgent,
                            IntelligentOrchestrator, train_agent)
    from repro.launch.serve import build_engines
    cfg = get_config("edge-ladder")
    env = EndEdgeCloudEnv(2, EXPERIMENTS["EXP-A"], accuracy_threshold=0.0,
                          seed=0)
    agent = QLearningAgent(env.spec, seed=0)
    train_agent(agent, env, 4000)
    engines = build_engines(cfg, variants=("d0", "d7"), max_len=32)
    orch = IntelligentOrchestrator(agent, env, engines)
    per_user = orch.decide(env.reset())
    assert len(per_user) == 2
    # Min threshold -> cheapest local model
    assert per_user == (7, 7)
    prompts = [np.arange(8, dtype=np.int32) for _ in range(2)]
    results = orch.dispatch(per_user, prompts)
    assert all(r[0] == "d7" and r[1] == "S" and r[2] > 0 for r in results)


def test_int8_kv_cache_decode_close():
    """Beyond-paper: int8 KV cache decode tracks the bf16 cache decode."""
    import repro.tuning as tuning
    from repro.models import build_model as _bm
    cfg = dataclasses.replace(reduced(get_config("gemma-7b")), dtype="float32")
    m = _bm(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 40), 0,
                              cfg.vocab_size)
    _, cache = m.prefill(params, {"tokens": toks}, max_len=48)
    nxt = toks[:, -1:]
    ref_logits, _ = m.decode(params, cache, nxt)
    # quantize the prefilled cache into the int8 layout
    segs8 = []
    for c in cache["segments"]:
        amax = jnp.max(jnp.abs(c["k"].astype(jnp.float32)), -1) + 1e-8
        ks = amax / 127.0
        amaxv = jnp.max(jnp.abs(c["v"].astype(jnp.float32)), -1) + 1e-8
        vs = amaxv / 127.0
        segs8.append({
            "k": jnp.clip(jnp.round(c["k"].astype(jnp.float32) / ks[..., None]),
                          -127, 127).astype(jnp.int8),
            "v": jnp.clip(jnp.round(c["v"].astype(jnp.float32) / vs[..., None]),
                          -127, 127).astype(jnp.int8),
            "k_s": ks, "v_s": vs})
    cache8 = {"pos": cache["pos"], "segments": segs8}
    q_logits, _ = m.decode(params, cache8, nxt)
    p1 = jax.nn.softmax(ref_logits[:, -1, : cfg.vocab_size], -1)
    p2 = jax.nn.softmax(q_logits[:, -1, : cfg.vocab_size], -1)
    tv = float(0.5 * jnp.abs(p1 - p2).sum(-1).max())
    assert tv < 0.1, tv
