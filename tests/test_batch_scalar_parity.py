"""Exhaustive batch/scalar parity (ISSUE-1 satellite): the deduplicated
latency kernel must agree with itself along every entry path, for every
joint action at small N and for all four Table-5 scenarios."""
import itertools

import numpy as np
import pytest

from repro.core import EXPERIMENTS, EndEdgeCloudEnv
from repro.core.spaces import N_PER_USER_ACTIONS, SpaceSpec


@pytest.mark.parametrize("n", [1, 2, 3])
def test_decode_actions_batch_roundtrips_encode_action(n):
    """decode_actions_batch o encode_action == id over the FULL space."""
    spec = SpaceSpec(n)
    all_per_user = np.array(list(itertools.product(
        range(N_PER_USER_ACTIONS), repeat=n)), np.int64)
    encoded = np.array([spec.encode_action(pu) for pu in all_per_user])
    np.testing.assert_array_equal(encoded, spec.all_actions())
    decoded = spec.decode_actions_batch(encoded)
    np.testing.assert_array_equal(decoded, all_per_user)
    # scalar decode agrees with the batch decode
    for a in (0, 1, spec.n_joint_actions // 2, spec.n_joint_actions - 1):
        assert tuple(decoded[a]) == spec.decode_action(int(a))


@pytest.mark.parametrize("name", list(EXPERIMENTS))
@pytest.mark.parametrize("n", [1, 2, 3])
def test_expected_response_batch_matches_scalar_exhaustively(n, name):
    """expected_response_batch == per-action expected_response for EVERY
    joint action (10^n of them), on all four Table-5 scenarios."""
    env = EndEdgeCloudEnv(n, EXPERIMENTS[name], noise=0)
    acts = env.spec.all_actions()
    ms, acc = env.expected_response_batch(acts)
    for a in acts:
        m1, a1 = env.expected_response(int(a))
        assert abs(m1 - ms[a]) < 1e-9, (name, n, a)
        assert abs(a1 - acc[a]) < 1e-12, (name, n, a)


def test_feasibility_predicate_shared_across_paths():
    """env.step, bruteforce_optimal, and the fleet reward must all use
    dynamics.feasible — same slack rule, no scalar/batch disagreement."""
    from repro.core import bruteforce_optimal
    from repro.fleet import dynamics
    th = 85.7405                       # contrived: inside isclose's old slack
    assert not bool(dynamics.feasible(85.74, th))
    env = EndEdgeCloudEnv(1, EXPERIMENTS["EXP-A"], accuracy_threshold=th,
                          noise=0)
    for a in env.spec.all_actions():
        _, acc = env.expected_response(int(a))
        _, r, info = env.step(int(a))
        assert info["violated"] == (not bool(dynamics.feasible(acc, th)))
        assert (r == -2.5) == info["violated"]
    a, ms, acc, _ = bruteforce_optimal(env, th)
    assert bool(dynamics.feasible(acc, th))


def test_edge_memory_penalty_consistent_across_paths():
    """The historical drift point: the edge memory-busy penalty at >2 edge
    jobs must be identical in the scalar and batch paths."""
    env = EndEdgeCloudEnv(3, EXPERIMENTS["EXP-A"], noise=0)
    a = env.spec.encode_action([8, 8, 8])          # 3 edge jobs -> busy
    ms_scalar, _ = env.expected_response(a)
    ms_batch, _ = env.expected_response_batch(np.array([a]))
    assert abs(ms_scalar - float(ms_batch[0])) < 1e-9


# ----------------------------------------------- counts-override seam -----
# fleet.topology feeds shared (cross-cell) contention through the
# ``counts`` kwarg of dynamics.response_times, so the seam itself gets
# the same exhaustive treatment as the default path.

@pytest.mark.parametrize("name", ["EXP-A", "EXP-D"])
@pytest.mark.parametrize("n", [1, 2, 3])
def test_counts_override_matches_internal_counts_exhaustively(n, name):
    """Passing the internally computed (n_edge, n_cloud) through the
    counts override must reproduce counts=None BIT-exactly for every
    joint action — the identity that makes the 1:1 topology reduction
    exact."""
    from repro.fleet import dynamics
    env = EndEdgeCloudEnv(n, EXPERIMENTS[name], noise=0)
    end_b = np.asarray(env.scenario.end_b[:n])
    for a in env.spec.all_actions():
        pu = np.asarray(env.spec.decode_action(int(a)))
        n_e = int((pu == 8).sum())
        n_c = int((pu == 9).sum())
        t0 = dynamics.response_times(pu, end_b, env.scenario.edge_b)
        t1 = dynamics.response_times(pu, end_b, env.scenario.edge_b,
                                     counts=(n_e, n_c))
        np.testing.assert_array_equal(t0, t1)
        # fractional counts (capacity-scaled loads) are accepted too
        t2 = dynamics.response_times(pu, end_b, env.scenario.edge_b,
                                     counts=(float(n_e), float(n_c)))
        np.testing.assert_array_equal(t0, t2)


def test_counts_override_inflates_only_offloaded_users():
    """Extra background contention slows edge/cloud users and leaves
    local users untouched (the cross-cell coupling direction)."""
    from repro.fleet import dynamics
    env = EndEdgeCloudEnv(3, EXPERIMENTS["EXP-A"], noise=0)
    pu = np.array([0, 8, 9])
    end_b = np.asarray(env.scenario.end_b[:3])
    base = dynamics.response_times(pu, end_b, env.scenario.edge_b,
                                   counts=(1, 1))
    loaded = dynamics.response_times(pu, end_b, env.scenario.edge_b,
                                     counts=(5, 6))
    assert loaded[0] == base[0]                # local user unaffected
    assert loaded[1] > base[1]                 # edge user slower
    assert loaded[2] > base[2]                 # cloud user slower
    # the scalar env exposes the same seam
    t_env = env.response_times(pu, noisy=False, counts=(5, 6))
    np.testing.assert_allclose(t_env, loaded)


def test_cloud_mult_scales_only_cloud_side_terms():
    """cloud_mult=1 is a bit-exact no-op; cloud_mult>1 inflates only the
    cloud hop + compute (not the device upload, not edge/local users)."""
    from repro.fleet import dynamics
    env = EndEdgeCloudEnv(3, EXPERIMENTS["EXP-B"], noise=0)
    pu = np.array([2, 8, 9])
    end_b = np.asarray(env.scenario.end_b[:3])
    base = dynamics.response_times(pu, end_b, env.scenario.edge_b)
    noop = dynamics.response_times(pu, end_b, env.scenario.edge_b,
                                   cloud_mult=1.0)
    np.testing.assert_array_equal(base, noop)
    slow = dynamics.response_times(pu, end_b, env.scenario.edge_b,
                                   cloud_mult=2.0)
    np.testing.assert_array_equal(slow[:2], base[:2])
    assert base[2] < slow[2] < 2 * base[2]     # upload term not doubled
