"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import EXPERIMENTS, EndEdgeCloudEnv
from repro.core.spaces import (A_CLOUD, A_EDGE, N_PER_USER_ACTIONS, SpaceSpec)
from repro.kernels import ref

MAX_EXAMPLES = 50


# ------------------------------------------------------------- spaces -----
@given(st.integers(1, 5), st.data())
@settings(max_examples=MAX_EXAMPLES, deadline=None)
def test_action_encode_decode_roundtrip(n, data):
    spec = SpaceSpec(n)
    per = tuple(data.draw(st.integers(0, N_PER_USER_ACTIONS - 1))
                for _ in range(n))
    assert spec.decode_action(spec.encode_action(per)) == per


@given(st.integers(1, 4), st.data())
@settings(max_examples=MAX_EXAMPLES, deadline=None)
def test_decode_batch_matches_scalar(n, data):
    spec = SpaceSpec(n)
    acts = np.asarray(data.draw(st.lists(
        st.integers(0, spec.n_joint_actions - 1), min_size=1, max_size=20)))
    batch = spec.decode_actions_batch(acts)
    for i, a in enumerate(acts):
        assert tuple(batch[i]) == spec.decode_action(int(a))


# ---------------------------------------------------------------- env -----
@given(st.integers(1, 5), st.data())
@settings(max_examples=MAX_EXAMPLES, deadline=None)
def test_response_time_positive_and_acc_in_range(n, data):
    env = EndEdgeCloudEnv(n, EXPERIMENTS["EXP-B"], noise=0)
    a = data.draw(st.integers(0, env.spec.n_joint_actions - 1))
    ms, acc = env.expected_response(a)
    assert ms > 0
    assert 72.8 - 1e-9 <= acc <= 89.9 + 1e-9


@given(st.integers(2, 5), st.data())
@settings(max_examples=MAX_EXAMPLES, deadline=None)
def test_contention_monotone(n, data):
    """More users on the same remote tier never lowers anyone's latency."""
    env = EndEdgeCloudEnv(n, EXPERIMENTS["EXP-A"], noise=0)
    tier = data.draw(st.sampled_from([A_EDGE, A_CLOUD]))
    k = data.draw(st.integers(1, n - 1))
    few = [tier] * k + [0] * (n - k)
    more = [tier] * (k + 1) + [0] * (n - k - 1)
    t_few = env.response_times(few, noisy=False)
    t_more = env.response_times(more, noisy=False)
    assert t_more[0] >= t_few[0] - 1e-9


@given(st.integers(1, 5), st.data())
@settings(max_examples=MAX_EXAMPLES, deadline=None)
def test_response_monotone_in_contention_counts(n, data):
    """dynamics.response_times is monotonically non-decreasing in the
    edge/cloud contention counts fed through the override seam (the
    property fleet.topology's shared, capacity-scaled loads rely on:
    more neighbors can never make anyone faster)."""
    from repro.fleet import dynamics
    env = EXPERIMENTS[data.draw(st.sampled_from(["EXP-A", "EXP-D"]))]
    pu = np.asarray([data.draw(st.integers(0, N_PER_USER_ACTIONS - 1))
                     for _ in range(n)])
    end_b = np.asarray(env.end_b[:n])
    n_e = data.draw(st.floats(0.0, 10.0))
    n_c = data.draw(st.floats(0.0, 10.0))
    d_e = data.draw(st.floats(0.0, 10.0))
    d_c = data.draw(st.floats(0.0, 10.0))
    t0 = dynamics.response_times(pu, end_b, env.edge_b,
                                 counts=(n_e, n_c))
    t1 = dynamics.response_times(pu, end_b, env.edge_b,
                                 counts=(n_e + d_e, n_c + d_c))
    assert (t1 >= t0 - 1e-9).all()


@given(st.data())
@settings(max_examples=MAX_EXAMPLES, deadline=None)
def test_device_compute_monotone_in_macs(data):
    """t_comp_device is non-decreasing in a model's MACs within each
    dtype family (the affine fit has positive slope, so a bigger model
    can never run faster on the same hardware)."""
    from repro.fleet import dynamics
    fam = data.draw(st.sampled_from([[0, 1, 2, 3], [4, 5, 6, 7]]))
    i = data.draw(st.sampled_from(fam))
    j = data.draw(st.sampled_from(fam))
    if dynamics.MACS[i] < dynamics.MACS[j]:
        i, j = j, i                      # i is the bigger model
    assert float(dynamics.t_comp_device(i)) >= \
        float(dynamics.t_comp_device(j)) - 1e-9


@given(st.data())
@settings(max_examples=MAX_EXAMPLES, deadline=None)
def test_weak_network_never_faster(data):
    """Same decision under EXP-D (all weak) >= EXP-A (all regular)."""
    n = data.draw(st.integers(1, 5))
    env_a = EndEdgeCloudEnv(n, EXPERIMENTS["EXP-A"], noise=0)
    env_d = EndEdgeCloudEnv(n, EXPERIMENTS["EXP-D"], noise=0)
    a = data.draw(st.integers(0, env_a.spec.n_joint_actions - 1))
    assert env_d.expected_response(a)[0] >= env_a.expected_response(a)[0] - 1e-9


@given(st.data())
@settings(max_examples=MAX_EXAMPLES, deadline=None)
def test_model_ladder_latency_accuracy_tradeoff(data):
    """Within a dtype family, higher-accuracy local models cost more."""
    env = EndEdgeCloudEnv(1, EXPERIMENTS["EXP-A"], noise=0)
    fam = data.draw(st.sampled_from([[0, 1, 2, 3], [4, 5, 6, 7]]))
    i = data.draw(st.integers(0, 2))
    hi, lo = fam[i], fam[i + 1]          # hi accuracy vs next step down
    ms_hi, acc_hi = env.expected_response(env.spec.encode_action([hi]))
    ms_lo, acc_lo = env.expected_response(env.spec.encode_action([lo]))
    assert acc_hi > acc_lo and ms_hi > ms_lo


# ------------------------------------------------------------ kernels -----
@given(st.integers(1, 3), st.integers(1, 4), st.sampled_from([16, 32, 64]),
       st.integers(2, 6))
@settings(max_examples=20, deadline=None)
def test_attention_rows_sum_to_one_property(b, kv, hd, blocks):
    """Flash attention output is a convex combination of V rows: with
    constant V == c, output == c regardless of masking pattern."""
    from repro.kernels import ops
    h = kv * 2
    s = blocks * 16
    key = jax.random.PRNGKey(b * 100 + kv)
    q = jax.random.normal(key, (b, s, h, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kv, hd))
    v = jnp.ones((b, s, kv, hd)) * 3.5
    out = ops.flash_attention(q, k, v, causal=True, bq=16, bk=16)
    np.testing.assert_allclose(np.asarray(out), 3.5, atol=1e-4)


@given(st.integers(1, 3), st.integers(8, 64), st.integers(8, 48))
@settings(max_examples=20, deadline=None)
def test_quantize_roundtrip_bound(b, m, k):
    """|dequant(quant(x)) - x| <= scale/2 elementwise."""
    x = jax.random.normal(jax.random.PRNGKey(b), (m, k))
    xq, s = ref.quantize_ref(x)
    err = jnp.abs(xq.astype(jnp.float32) * s - x)
    assert float(jnp.max(err - s / 2)) < 1e-6


# ------------------------------------------------------------ replay ------
@given(st.integers(1, 64), st.integers(1, 200))
@settings(max_examples=MAX_EXAMPLES, deadline=None)
def test_replay_fifo_len(cap, n_push):
    from repro.core.replay import ReplayBuffer
    rb = ReplayBuffer(cap, 4)
    for i in range(n_push):
        rb.push(np.full(4, i, np.float32), i, float(i), np.zeros(4))
    assert len(rb) == min(cap, n_push)
    if n_push >= cap:      # oldest overwritten: all stored ids in window
        lo = n_push - cap
        assert rb.a.min() >= lo


# ------------------------------------------------------- sharding rules ---
@given(st.data())
@settings(max_examples=MAX_EXAMPLES, deadline=None)
def test_sharding_spec_always_valid(data):
    """spec_for never assigns an axis twice and always divides the dims."""
    import math
    from jax.sharding import PartitionSpec
    from repro.distributed import sharding as sh

    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}

    ndim = data.draw(st.integers(1, 5))
    shape = tuple(data.draw(st.sampled_from([1, 2, 3, 16, 25, 128, 256, 4096]))
                  for _ in range(ndim))
    axes = tuple(data.draw(st.sampled_from(
        ["batch", "fsdp", "model", "kv_seq", "vocab", "expert", None]))
        for _ in range(ndim))
    spec = sh._checked_spec(FakeMesh, shape, sh._resolve(FakeMesh, axes))
    used = []
    for dim, entry in zip(shape, tuple(spec)):
        if entry is None:
            continue
        entry_t = entry if isinstance(entry, tuple) else (entry,)
        size = math.prod(FakeMesh.shape[a] for a in entry_t)
        assert dim % size == 0
        used += list(entry_t)
    assert len(used) == len(set(used))


# --------------------------------------------------------- optimizer ------
@given(st.floats(1e-5, 1e-2), st.integers(1, 5))
@settings(max_examples=20, deadline=None)
def test_adamw_descends_quadratic(lr, seed):
    from repro.training.optimizer import (AdamWConfig, apply_updates,
                                          init_opt_state)
    key = jax.random.PRNGKey(seed)
    target = jax.random.normal(key, (8,))
    params = {"w": jnp.zeros((8,))}
    opt = init_opt_state(params)
    cfg = AdamWConfig(lr=lr, warmup_steps=0, total_steps=1000,
                      weight_decay=0.0, grad_clip=0.0)
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    l0 = float(loss(params))
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, opt, _ = apply_updates(params, g, opt, cfg)
    assert float(loss(params)) < l0
