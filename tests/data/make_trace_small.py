"""Generator for the golden trace fixture ``tests/data/trace_small.npz``.

A small but fully-featured recorded trace: 6 cells x 3 users over 12
steps, with a Markov-ish link-quality series, Poisson arrival
timestamps (plus a guaranteed t=0 request per cell so frame 0 always
has traffic), a partially-filled membership mask, and a 2-PoP
deployment map with mixed capacity tiers and a finite cloud queue.

Regenerate (bit-identical — everything flows from one seeded
``default_rng``) with:

  PYTHONPATH=src python tests/data/make_trace_small.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..",
                                "src"))

import numpy as np

from repro.fleet.api import FleetTrace, save_trace

CELLS, USERS, HORIZON = 6, 3, 12
STEP_DURATION = 0.5                      # seconds binned into one step
PATH = os.path.join(os.path.dirname(__file__), "trace_small.npz")


def build_trace(seed: int = 7) -> FleetTrace:
    rng = np.random.default_rng(seed)
    # link-quality series: start biased-Regular, flip sparsely per step
    end_b = np.zeros((HORIZON, CELLS, USERS), np.int32)
    edge_b = np.zeros((HORIZON, CELLS), np.int32)
    end_b[0] = rng.random((CELLS, USERS)) < 0.3
    edge_b[0] = rng.random(CELLS) < 0.3
    for t in range(1, HORIZON):
        end_b[t] = np.where(rng.random((CELLS, USERS)) < 0.15,
                            1 - end_b[t - 1], end_b[t - 1])
        edge_b[t] = np.where(rng.random(CELLS) < 0.15,
                             1 - edge_b[t - 1], edge_b[t - 1])
    # membership: cells have 2-3 of the 3 padded slots (prefix mask)
    sizes = rng.integers(2, USERS + 1, CELLS)
    member = np.arange(USERS)[None, :] < sizes[:, None]
    # Poisson arrival timestamps per (cell, member user), rate ~2/s,
    # plus one t=0 event for user 0 of every cell
    times, ev_cell, ev_user = [], [], []
    for c in range(CELLS):
        times.append(0.0)
        ev_cell.append(c)
        ev_user.append(0)
        for u in range(int(sizes[c])):
            t = rng.exponential(0.5)
            while t < HORIZON * STEP_DURATION:
                times.append(t)
                ev_cell.append(c)
                ev_user.append(u)
                t += rng.exponential(0.5)
    order = np.argsort(np.asarray(times), kind="stable")
    return FleetTrace(
        end_b=end_b, edge_b=edge_b,
        arrival_time=np.asarray(times, np.float64)[order],
        arrival_cell=np.asarray(ev_cell, np.int32)[order],
        arrival_user=np.asarray(ev_user, np.int32)[order],
        step_duration=STEP_DURATION,
        member=member,
        # deployment map: cells 0-3 share hot PoP 0 (double capacity),
        # cells 4-5 sit on PoP 1; the cloud queues at 6 concurrent jobs
        cell_edge=np.asarray([0, 0, 0, 0, 1, 1], np.int32),
        edge_capacity=np.asarray([2.0, 1.0], np.float32),
        cloud_servers=6.0,
    ).validate()


if __name__ == "__main__":
    save_trace(PATH, build_trace())
    print(f"wrote {PATH}")
