"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned family (2 layers, d_model<=512, <=4 experts) runs one forward /
train step on CPU; output shapes + no NaNs asserted. Full configs are
exercised only by the dry-run."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import build_model
from repro.training import AdamWConfig, init_state, make_train_step


def _batch(cfg, b=2, s=24, key=jax.random.PRNGKey(7)):
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.arch_type == "vlm":
        batch["img_embeds"] = jax.random.normal(
            key, (b, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            key, (b, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_config_invariants(arch):
    cfg = reduced(get_config(arch))
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    assert cfg.arch_type == get_config(arch).arch_type


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_decode(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, cache = model.prefill(params, batch, max_len=32)
    b = batch["tokens"].shape[0]
    assert logits.shape == (b, 1, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    tok = jnp.argmax(logits[:, -1:, : cfg.vocab_size], -1).astype(jnp.int32)
    logits2, cache2 = model.decode(params, cache, tok)
    assert logits2.shape == (b, 1, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits2.astype(jnp.float32)).any())
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    state = init_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3, warmup_steps=1,
                                                      total_steps=10)))
    state, metrics = step(state, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    before = jax.tree_util.tree_leaves(init_state(model, jax.random.PRNGKey(0))["params"])
    after = jax.tree_util.tree_leaves(state["params"])
    changed = any(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                        - b.astype(jnp.float32)))) > 0
                  for a, b in zip(after, before))
    assert changed


@pytest.mark.parametrize("arch", ["gemma-7b", "gemma3-4b", "falcon-mamba-7b",
                                  "hymba-1.5b", "dbrx-132b", "whisper-medium"])
def test_decode_matches_full_forward(arch):
    """Cache correctness: decode(t | prefill(t[:-1])) == prefill(t)."""
    cfg = dataclasses.replace(reduced(get_config(arch)), dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 100          # > reduced sliding window (64): ring buffer path
    batch = _batch(cfg, b, s, jax.random.PRNGKey(1))
    if cfg.arch_type == "vlm":
        batch["img_embeds"] = batch["img_embeds"].astype(jnp.float32)
    if cfg.is_encdec:
        batch["frames"] = batch["frames"].astype(jnp.float32)
    full_logits, _ = model.prefill(params, batch, max_len=s + 4)
    b2 = dict(batch)
    b2["tokens"] = batch["tokens"][:, :-1]
    _, cache = model.prefill(params, b2, max_len=s + 4)
    dec_logits, _ = model.decode(params, cache, batch["tokens"][:, -1:])
    err = float(jnp.max(jnp.abs(full_logits - dec_logits)))
    rel = err / (float(jnp.max(jnp.abs(full_logits))) + 1e-9)
    assert rel < 2e-3, (arch, rel)


def test_unrolled_segments_match_scan():
    """Dry-run unroll mode is numerically identical to the runtime scan."""
    from repro.models import transformer as T
    cfg = dataclasses.replace(reduced(get_config("gemma3-4b")), dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, 2, 32)
    loss1, _ = model.loss(params, batch)
    T.UNROLL_SEGMENTS = True
    try:
        loss2, _ = model.loss(params, batch)
    finally:
        T.UNROLL_SEGMENTS = False
    np.testing.assert_allclose(float(loss1), float(loss2), rtol=1e-5)


def test_moe_dispatch_matches_dense_reference():
    """Capacity-based scatter dispatch == dense all-experts oracle when
    capacity is not binding."""
    from repro.models import moe as MOE
    cfg = dataclasses.replace(reduced(get_config("dbrx-132b")),
                              dtype="float32")
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=8.0))   # no drops
    p = MOE.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, aux = MOE.moe_block(p, x, cfg)
    y_ref = MOE.moe_block_dense_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-4, rtol=1e-3)
    assert float(aux["dropped_frac"]) == 0.0


def test_variant_ladder_macs_monotone():
    """d0..d3 (and d4..d7) shrink monotonically in MACs like Table 4."""
    from repro.models.variants import build_ladder
    ladder = build_ladder(get_config("gemma-7b"))
    fp = [ladder[f"d{i}"].million_macs for i in range(4)]
    i8 = [ladder[f"d{i}"].million_macs for i in range(4, 8)]
    assert fp == sorted(fp, reverse=True)
    assert i8 == sorted(i8, reverse=True)
    assert ladder["d0"].top5 == 89.9 and ladder["d7"].top5 == 72.8
