"""Paper-core tests: environment calibration, brute-force oracle, agent
convergence to the optimum (claim C1), speedup vs SOTA (C2), fixed
strategies (C3), and transfer learning (C5)."""
import numpy as np
import pytest

from repro.core import (EXPERIMENTS, THRESHOLDS, DQNAgent, DQNConfig,
                        EndEdgeCloudEnv, QLearningAgent, QLearningConfig,
                        bruteforce_complexity, bruteforce_optimal,
                        fixed_strategy_response, make_sota_agent,
                        train_agent, transfer_experiment)
from repro.core.spaces import SpaceSpec, restricted_actions


# ---------------------------------------------------------------- env ------
def test_env_calibration_anchors():
    env = EndEdgeCloudEnv(5, EXPERIMENTS["EXP-A"], noise=0)
    enc = env.spec.encode_action
    # paper Fig.5 / Tables 8-9 anchors (ms), tolerance 10%
    anchors = [
        (enc([7] * 5), 72.08),           # Min threshold row
        (enc([8] * 5), 1140.0),          # edge-only @5
        (enc([9] * 5), 665.0),           # cloud-only @5
    ]
    for a, want in anchors:
        got, _ = env.expected_response(a)
        assert abs(got - want) / want < 0.10, (a, got, want)
    env1 = EndEdgeCloudEnv(1, EXPERIMENTS["EXP-A"], noise=0)
    got, _ = env1.expected_response(env1.spec.encode_action([9]))
    assert abs(got - 363.47) < 15


def test_env_scalar_batch_consistency():
    env = EndEdgeCloudEnv(3, EXPERIMENTS["EXP-B"], noise=0)
    acts = np.random.default_rng(0).integers(0, env.spec.n_joint_actions, 64)
    ms, acc = env.expected_response_batch(acts)
    for i, a in enumerate(acts):
        m1, a1 = env.expected_response(int(a))
        assert abs(m1 - ms[i]) < 1e-6 and abs(a1 - acc[i]) < 1e-9


def test_reward_structure():
    """Eq. 4: constraint violation -> minimum reward."""
    env = EndEdgeCloudEnv(2, EXPERIMENTS["EXP-A"], accuracy_threshold=89.0,
                          seed=0, noise=0)
    _, r_ok, info = env.step(env.spec.encode_action([0, 0]))     # d0 = 89.9
    assert not info["violated"] and r_ok > -2.5
    _, r_bad, info = env.step(env.spec.encode_action([7, 7]))    # 72.8 < 89
    assert info["violated"] and r_bad == -2.5


def test_bruteforce_structure_matches_paper_table9():
    env = EndEdgeCloudEnv(5, EXPERIMENTS["EXP-A"], noise=0)
    # Min -> all d7 local; 89% -> 4x d4 local + one d0 offload (Table 9)
    a, ms, acc, _ = bruteforce_optimal(env, THRESHOLDS["Min"])
    assert env.spec.decode_action(a) == (7,) * 5
    a, ms, acc, _ = bruteforce_optimal(env, THRESHOLDS["89%"])
    per = env.spec.decode_action(a)
    assert sorted(per)[:4] == [4, 4, 4, 4] and per[4] >= 8 or \
        sum(p == 4 for p in per) == 4
    assert abs(acc - 89.1) < 0.05
    assert abs(ms - 269.8) / 269.8 < 0.05


def test_bruteforce_complexity_eq6():
    assert abs(bruteforce_complexity(5) - 4.2e12) / 4.2e12 < 0.05


def test_speedup_claim_c2():
    """~35% speedup vs SOTA at <0.9% accuracy loss (paper abstract)."""
    env = EndEdgeCloudEnv(5, EXPERIMENTS["EXP-A"], noise=0)
    _, sota_ms, sota_acc, _ = bruteforce_optimal(
        env, 0.0, restricted_actions(env.spec))
    _, ours_ms, ours_acc, _ = bruteforce_optimal(env, THRESHOLDS["89%"])
    speedup = 1 - ours_ms / sota_ms
    assert 0.25 < speedup < 0.45, speedup
    assert sota_acc - ours_acc < 0.9


def test_fixed_strategies_ordering_c3():
    """Fig. 5: device-only flat; edge worst at 5 users; cloud between."""
    for n in (1, 3, 5):
        env = EndEdgeCloudEnv(n, EXPERIMENTS["EXP-A"], noise=0)
        dev, _ = fixed_strategy_response(env, "device")
        edge, _ = fixed_strategy_response(env, "edge")
        cloud, _ = fixed_strategy_response(env, "cloud")
        if n == 1:
            assert cloud < edge < dev
        if n == 5:
            assert dev < cloud < edge


# ------------------------------------------------------------- agents -----
def test_qlearning_converges_to_optimal_c1():
    env = EndEdgeCloudEnv(2, EXPERIMENTS["EXP-A"], accuracy_threshold=89.0,
                          seed=1)
    agent = QLearningAgent(env.spec, seed=1)
    res = train_agent(agent, env, max_steps=30000, check_every=200)
    assert res.converged_at is not None
    assert res.prediction_accuracy == 1.0


def test_dqn_paper_form_converges():
    env = EndEdgeCloudEnv(2, EXPERIMENTS["EXP-A"], accuracy_threshold=0.0,
                          seed=3)
    agent = DQNAgent(env.spec, DQNConfig(form="paper"), seed=3)
    res = train_agent(agent, env, max_steps=8000, check_every=500)
    assert res.converged_at is not None
    assert res.prediction_accuracy == 1.0


def test_sota_baseline_is_limited_to_d0():
    spec = SpaceSpec(3)
    acts = restricted_actions(spec)
    assert len(acts) == 27
    pu = spec.decode_actions_batch(acts)
    assert set(np.unique(pu)) <= {0, 8, 9}


def test_transfer_learning_c5():
    def make_agent():
        return QLearningAgent(SpaceSpec(2), QLearningConfig(eps_decay=1e-2),
                              seed=5)

    def make_env(th):
        return EndEdgeCloudEnv(2, EXPERIMENTS["EXP-A"],
                               accuracy_threshold=th, seed=5)

    scratch, warm = transfer_experiment(make_agent, make_env,
                                        source_threshold=0.0,
                                        target_threshold=85.0,
                                        max_steps=30000, check_every=100)
    assert warm.converged_at is not None and scratch.converged_at is not None
    assert warm.converged_at <= scratch.converged_at
