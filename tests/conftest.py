import os
import sys

# smoke tests / benches see the single real CPU device; ONLY the dry-run
# (launch/dryrun.py, run as its own process) forces 512 host devices.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
