"""Dry-run utility tests: HLO collective parsing + roofline arithmetic
(no 512-device mesh needed — pure text processing)."""
import importlib
import sys
import types

import pytest


def _dryrun():
    # import without triggering the XLA_FLAGS device-count override side
    # effects twice (idempotent: appends to XLA_FLAGS only)
    import repro.launch.dryrun as d
    return d


def test_collective_bytes_parsing():
    d = _dryrun()
    hlo = """
  %ag = bf16[4,1024]{1,0} all-gather(bf16[1,1024]{1,0} %p), dims={0}
  %ar = f32[128]{0} all-reduce(f32[128]{0} %x), to_apply=%sum
  %rs = f32[32]{0} reduce-scatter(f32[128]{0} %y), dimensions={0}
  %a2a = (s8[16,64]{1,0}, s8[16,64]{1,0}) all-to-all(s8[16,64] %a, s8[16,64] %b)
  %cp = bf16[8,8]{1,0} collective-permute(bf16[8,8]{1,0} %z)
  %dot = f32[8,8]{1,0} dot(f32[8,8] %l, f32[8,8] %r)
"""
    out = d.collective_bytes(hlo)
    assert out["all-gather"] == 4 * 1024 * 2
    assert out["all-reduce"] == 128 * 4 * 2.0        # ring 2x
    assert out["reduce-scatter"] == 32 * 4
    assert out["all-to-all"] == 2 * 16 * 64 * 1
    assert out["collective-permute"] == 8 * 8 * 2
    assert "dot" not in out


def test_shape_bytes_tuple_and_scalar():
    d = _dryrun()
    assert d._shape_bytes("f32[128]") == 512
    assert d._shape_bytes("(bf16[2,2], s8[4])") == 8 + 4
    assert d._shape_bytes("pred[]") == 1    # scalar: empty dims


def test_long_ctx_skip_list_matches_design():
    d = _dryrun()
    runs = {(a, s) for a, s, st in d.pairs(include_long_skips=True)
            if st == "run" and s == "long_500k"}
    assert runs == {("gemma3-4b", "long_500k"), ("hymba-1.5b", "long_500k"),
                    ("falcon-mamba-7b", "long_500k")}
    skips = {a for a, s, st in d.pairs(include_long_skips=True)
             if st == "skip"}
    assert len(skips) == 7


def test_full_matrix_is_40_pairs():
    d = _dryrun()
    allp = list(d.pairs(include_long_skips=True))
    assert len(allp) == 40
