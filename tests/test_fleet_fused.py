"""End-to-end training equivalence across the RL impl seam (ISSUE-10).

The fused hot path (``impl='pallas'`` and friends) must train the SAME
agent as the legacy unfused step: both agents consume RNG identically
(see ``FleetQLearning._explore``), so tabular trajectories are
bit-identical and DQN trajectories match to reduction-order tolerance.
Runs entirely on CPU — ``'pallas'`` resolves to the fused-jnp
formulation here, and ``'pallas_interpret'`` forces the real kernel
through the Pallas interpreter on a tiny fleet.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fleet import (FleetConfig, FleetDQN, FleetDQNConfig,
                         FleetQConfig, FleetQLearning, SyntheticSource)


def _source(cells=32, users=2, seed=0):
    return SyntheticSource(FleetConfig(cells=cells, users=users,
                                       arrival_rate=1.0, p_r2w=0.05,
                                       p_w2r=0.1))


def _tabular(impl, cells=32, **kw):
    return FleetQLearning(_source(cells), cfg=FleetQConfig(), seed=3,
                          impl=impl, **kw)


def test_tabular_fused_training_bit_identical_to_xla():
    """40 scanned steps: Q-table, counts, and greedy decisions from the
    fused path are bit-identical to the legacy unfused step."""
    a, b = _tabular("xla"), _tabular("pallas")
    assert b._op_impl != "xla"       # the seam actually switched paths
    a.run(40)
    b.run(40)
    np.testing.assert_array_equal(np.asarray(a.q), np.asarray(b.q))
    np.testing.assert_array_equal(np.asarray(a.counts),
                                  np.asarray(b.counts))
    np.testing.assert_array_equal(np.asarray(a.greedy_decisions()),
                                  np.asarray(b.greedy_decisions()))
    sa, sb = a.metrics_summary(), b.metrics_summary()
    assert sa["reward"]["count"] == sb["reward"]["count"]
    assert sa["reward"]["mean"] == pytest.approx(sb["reward"]["mean"],
                                                 rel=1e-6)


def test_tabular_stepwise_bit_identical_across_impls():
    """The single-step path (which re-gathers greedy instead of carrying
    it) is also bit-identical across the seam. Stepwise and scanned
    runs differ from EACH OTHER on either impl (host-float vs in-carry
    f32 epsilon decay, a pre-existing property) — the seam guarantee is
    within each mode."""
    a, b = _tabular("xla", cells=8), _tabular("pallas", cells=8)
    for _ in range(10):
        a.step()
        b.step()
    np.testing.assert_array_equal(np.asarray(a.q), np.asarray(b.q))
    np.testing.assert_array_equal(np.asarray(a.counts),
                                  np.asarray(b.counts))


def test_tabular_interpret_kernel_training_matches_xla():
    """The real Pallas kernel (interpret mode, tiny fleet): identical
    trajectories up to the kernel's fma-contraction ulp."""
    a = _tabular("xla", cells=4)
    b = _tabular("pallas_interpret", cells=4)
    a.run(12)
    b.run(12)
    np.testing.assert_allclose(np.asarray(a.q), np.asarray(b.q),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(a.counts),
                                  np.asarray(b.counts))


def test_tabular_unknown_impl_raises():
    with pytest.raises(ValueError, match="unknown impl"):
        _tabular("cuda")


def _dqn(impl, threshold=85.0, cells=16):
    return FleetDQN(_source(cells), seed=5, impl=impl,
                    cfg=FleetDQNConfig(replay_capacity=512, batch_size=32,
                                       hidden=32,
                                       accuracy_threshold=threshold))


@pytest.mark.parametrize("threshold", [0.0, 85.0])
def test_dqn_fused_training_matches_xla(threshold):
    """30 steps of replay-driven training: fused head vs legacy encode +
    masked argmax. At threshold 0 the paths are bit-identical; with the
    constraint head active the combo scoring reduces in a different
    order, so params match to float tolerance — decisions exactly."""
    a, b = _dqn("xla", threshold), _dqn("pallas", threshold)
    assert b._op_impl != "xla"
    a.run(30)
    b.run(30)
    for pa, pb in zip(a.params, b.params):
        np.testing.assert_allclose(np.asarray(pa["w"]),
                                   np.asarray(pb["w"]),
                                   atol=1e-4, rtol=1e-4)
    np.testing.assert_array_equal(np.asarray(a.counts),
                                  np.asarray(b.counts))
    np.testing.assert_array_equal(np.asarray(a.greedy_decisions()),
                                  np.asarray(b.greedy_decisions()))


def test_dqn_cell_net_falls_back_to_legacy():
    """The fused head only covers the shared per-user net; a 'cell' net
    agent silently keeps the legacy path (impl seam resolves to xla)."""
    agent = FleetDQN(_source(8), seed=1,
                     cfg=FleetDQNConfig(replay_capacity=256, batch_size=16,
                                        hidden=16, net="cell"))
    assert agent._op_impl == "xla"
    agent.run(5)                     # still trains


def test_dqn_fused_greedy_respects_constraint_feasibility():
    """Fused greedy decisions at an active QoS goal stay feasible
    whenever the legacy head's are (same accuracy ladder)."""
    from repro.fleet import dynamics
    a, b = _dqn("xla", 85.0), _dqn("pallas", 85.0)
    a.run(20)
    b.run(20)
    da = np.asarray(a.greedy_decisions())
    db = np.asarray(b.greedy_decisions())
    np.testing.assert_array_equal(da, db)
    member = np.asarray(a.scen.member)
    acc = dynamics.accuracies(db)
    nm = np.maximum(member.sum(-1), 1)
    macc = np.where(member.any(-1),
                    (acc * member).sum(-1) / nm, 100.0)
    feas_frac = dynamics.feasible(macc, 85.0).mean()
    assert feas_frac == dynamics.feasible(
        np.where(member.any(-1),
                 (dynamics.accuracies(da) * member).sum(-1) / nm,
                 100.0), 85.0).mean()
