"""repro.fleet.api — the redesigned fleet front door (ISSUE-4).

Covers: SyntheticSource bit-exactness against the pre-redesign
generator streams, the recorded-trace format (golden fixture
round-trip), TraceSource replay into the jitted training loops, the
FleetPolicy protocol (agents + oracle + static baselines behind one
surface), the shared pad-width protocol error, the removed PR-4 shims,
and the end-to-end acceptance path: train on a trace, route through
FleetOrchestrator, dispatch to a real ServingEngine with measured
wall-time next to the model's prediction."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fleet import (FleetConfig, FleetOrchestrator, FleetQConfig,
                         FleetQLearning, dynamics, fleet_bruteforce,
                         holdout_reward_ratio, init_fleet,
                         make_fleet_env_step, mixed_table5_fleet,
                         nominal_expected_response, step_fleet)
from repro.fleet.api import (FleetTrace, OraclePolicy, RouteResult,
                             ScenarioSource, StaticPolicy, SyntheticSource,
                             TraceSource, load_trace, make_env_step,
                             record_trace, save_trace)

DATA = os.path.join(os.path.dirname(__file__), "data")
FIXTURE = os.path.join(DATA, "trace_small.npz")


def _assert_scen_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a.end_b), np.asarray(b.end_b))
    np.testing.assert_array_equal(np.asarray(a.edge_b), np.asarray(b.edge_b))
    np.testing.assert_array_equal(np.asarray(a.member), np.asarray(b.member))
    np.testing.assert_array_equal(np.asarray(a.active), np.asarray(b.active))


# ----------------------------------------------------- SyntheticSource ----
def test_synthetic_source_reproduces_generator_streams_bit_exactly():
    """Acceptance: SyntheticSource.reset/step ARE init_fleet/step_fleet
    under the same keys — the pre-redesign random streams, bit for bit,
    over a fully dynamic config."""
    cfg = FleetConfig(cells=24, users=4, p_r2w=0.1, p_w2r=0.2,
                      arrival_rate=0.9, diurnal_period=50, p_join=0.05,
                      p_leave=0.05, min_users=1, max_users=4, n_edges=3,
                      p_edge_fail=0.2, cloud_servers=8.0)
    src = SyntheticSource(cfg)
    assert isinstance(src, ScenarioSource) and src.dynamic
    key = jax.random.PRNGKey(11)
    old = init_fleet(key, cfg)
    new, state = src.reset(key)
    _assert_scen_equal(old, new)
    for i in range(5):
        k = jax.random.PRNGKey(100 + i)
        old = step_fleet(k, old, cfg)
        new, state = src.step(k, state)
        _assert_scen_equal(old, new)
        np.testing.assert_array_equal(np.asarray(old.topo.cell_edge),
                                      np.asarray(new.topo.cell_edge))


def test_synthetic_source_pins_an_explicit_scenario():
    """SyntheticSource(cfg, scen=...) resets to exactly that scenario —
    the legacy (scen, FleetConfig) agent constructor, as a source."""
    scen = mixed_table5_fleet(jax.random.PRNGKey(2), 8, 2)
    src = SyntheticSource(FleetConfig(cells=8, users=2), scen=scen)
    got, _ = src.reset(jax.random.PRNGKey(999))   # key must not matter
    assert got is scen
    assert src.cells == 8 and src.users == 2 and not src.dynamic


# ------------------------------------------------------ trace format ------
def _load_generator():
    import importlib.util
    spec = importlib.util.spec_from_file_location(
        "make_trace_small", os.path.join(DATA, "make_trace_small.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_golden_trace_fixture_matches_generator():
    """The committed fixture is exactly what the generator script
    produces (regenerating it is always safe)."""
    want = _load_generator().build_trace()
    got = load_trace(FIXTURE)
    for f in ("end_b", "edge_b", "arrival_time", "arrival_cell",
              "arrival_user", "member", "cell_edge", "edge_capacity"):
        np.testing.assert_array_equal(getattr(got, f), getattr(want, f), f)
    assert got.step_duration == want.step_duration
    assert got.cloud_servers == want.cloud_servers


def test_trace_save_load_roundtrip(tmp_path):
    tr = load_trace(FIXTURE)
    p = tmp_path / "t.npz"
    save_trace(p, tr)
    back = load_trace(p)
    np.testing.assert_array_equal(back.end_b, tr.end_b)
    np.testing.assert_array_equal(back.arrival_time, tr.arrival_time)
    np.testing.assert_array_equal(back.cell_edge, tr.cell_edge)
    assert back.step_duration == tr.step_duration
    # optional fields stay optional
    bare = FleetTrace(end_b=tr.end_b, edge_b=tr.edge_b,
                      arrival_time=tr.arrival_time,
                      arrival_cell=tr.arrival_cell,
                      arrival_user=tr.arrival_user)
    save_trace(tmp_path / "b.npz", bare)
    back2 = load_trace(tmp_path / "b.npz")
    assert back2.member is None and back2.cell_edge is None
    assert np.isinf(back2.cloud_servers)


def test_trace_validate_rejects_inconsistent_shapes():
    tr = load_trace(FIXTURE)
    with pytest.raises(ValueError, match="edge_b shape"):
        FleetTrace(end_b=tr.end_b, edge_b=tr.edge_b[:, :3],
                   arrival_time=tr.arrival_time,
                   arrival_cell=tr.arrival_cell,
                   arrival_user=tr.arrival_user).validate()
    with pytest.raises(ValueError, match="cell_edge shape"):
        FleetTrace(end_b=tr.end_b, edge_b=tr.edge_b,
                   arrival_time=tr.arrival_time,
                   arrival_cell=tr.arrival_cell,
                   arrival_user=tr.arrival_user,
                   cell_edge=np.zeros(2, np.int32)).validate()
    # out-of-range events must fail loudly: a negative cell index would
    # silently wrap to the LAST cell and train on wrong data
    for cell, user in ((-1, 0), (tr.cells, 0), (0, -1), (0, tr.users)):
        with pytest.raises(ValueError, match="out of range"):
            FleetTrace(end_b=tr.end_b, edge_b=tr.edge_b,
                       arrival_time=np.asarray([0.0]),
                       arrival_cell=np.asarray([cell], np.int32),
                       arrival_user=np.asarray([user], np.int32)).validate()


def test_trace_source_stream_matches_recorded_arrays_exactly():
    """Satellite: write trace -> TraceSource -> the FleetScenario stream
    equals the recorded arrays exactly, including the wrap past the
    horizon and the deployment map on ``scen.topo``."""
    tr = load_trace(FIXTURE)
    src = TraceSource(tr)
    active = tr.active_frames()
    member = tr.member_frames()
    scen, state = src.reset(jax.random.PRNGKey(0))
    for t in range(2 * tr.horizon):                      # includes wrap
        f = t % tr.horizon
        np.testing.assert_array_equal(np.asarray(scen.end_b), tr.end_b[f])
        np.testing.assert_array_equal(np.asarray(scen.edge_b), tr.edge_b[f])
        np.testing.assert_array_equal(np.asarray(scen.member), member[f])
        np.testing.assert_array_equal(np.asarray(scen.active), active[f])
        assert int(scen.t) == t
        np.testing.assert_array_equal(np.asarray(scen.topo.cell_edge),
                                      tr.cell_edge)
        np.testing.assert_array_equal(np.asarray(scen.topo.edge_capacity),
                                      tr.edge_capacity)
        scen, state = src.step(jax.random.PRNGKey(t), state)


def test_record_trace_replays_a_synthetic_stream():
    """record_trace captures any source's stream; TraceSource replays
    the exact frames (synthetic fleets become shareable traces)."""
    cfg = FleetConfig(cells=6, users=2, p_r2w=0.2, p_w2r=0.2,
                      arrival_rate=1.0, p_join=0.05, p_leave=0.05)
    src = SyntheticSource(cfg)
    key = jax.random.PRNGKey(5)
    tr = record_trace(src, key, 7)
    assert tr.horizon == 7 and tr.cells == 6 and tr.users == 2
    # replay == the recorded frames
    rep = TraceSource(tr)
    scen, state = rep.reset(jax.random.PRNGKey(0))
    for t in range(7):
        np.testing.assert_array_equal(np.asarray(scen.end_b), tr.end_b[t])
        np.testing.assert_array_equal(np.asarray(scen.active),
                                      tr.active_frames()[t])
        scen, state = rep.step(jax.random.PRNGKey(0), state)


def test_trace_source_env_step_runs_under_jit_scan():
    """A TraceSource slots straight into make_fleet_env_step (the new,
    un-deprecated source path) and steps inside one jitted lax.scan."""
    src = TraceSource.load(FIXTURE)
    env_step = make_fleet_env_step(src, threshold=85.0, noise=0.0)
    scen, _ = src.reset(jax.random.PRNGKey(0))
    pu = jnp.zeros((src.cells, src.users), jnp.int32)

    def body(carry, k):
        scen, _ = carry
        scen2, counts, ms, acc, r = env_step(k, scen, pu)
        return (scen2, counts), (ms, r)

    keys = jax.random.split(jax.random.PRNGKey(1), 2 * src.horizon)
    (scen_f, _), (ms, r) = jax.lax.scan(
        body, (scen, jnp.zeros((src.cells, 2), jnp.int32)), keys)
    assert int(scen_f.t) == 2 * src.horizon
    assert np.isfinite(np.asarray(ms)).all()
    # frames repeat after one horizon: deterministic replay, noise-free
    np.testing.assert_allclose(np.asarray(ms)[0], np.asarray(ms)[src.horizon],
                               rtol=1e-6)


# --------------------------------------------------- FleetPolicy protocol -
def test_oracle_policy_routes_at_the_bruteforce_optimum():
    scen = mixed_table5_fleet(jax.random.PRNGKey(3), 12, 2)
    pol = OraclePolicy(2, threshold=85.0)
    dec, ids = FleetOrchestrator(pol).route(scen=scen)
    _, want_idx = fleet_bruteforce(scen, pol.pu_table, 85.0)
    np.testing.assert_array_equal(np.asarray(dec),
                                  np.asarray(pol.pu_table[want_idx]))
    ms, acc = pol.expected(scen)
    want_ms, want_acc = nominal_expected_response(scen, dec)
    np.testing.assert_allclose(ms, np.asarray(want_ms), rtol=1e-6)
    # the oracle scores 100% of itself through the shared metric
    ev = holdout_reward_ratio(pol, scen, 85.0)
    assert ev.ratio == pytest.approx(1.0, abs=1e-6)


def test_static_policy_is_the_papers_fixed_strategy():
    scen = mixed_table5_fleet(jax.random.PRNGKey(4), 8, 3)
    for strategy, aid in (("device", 0), ("edge", 8), ("cloud", 9)):
        dec, ids = FleetOrchestrator(StaticPolicy(3, strategy)).route(
            scen=scen)
        assert (np.asarray(dec) == aid).all()
        spec_ids = [int(str(aid) * 3)] * 8       # base-10 joint encoding
        assert np.asarray(ids).tolist() == spec_ids
    ms, acc = StaticPolicy(3, "cloud").expected(scen)
    assert ms.shape == (8,) and (ms > 0).all()
    # every stateless policy carries the oracle candidate table, so the
    # shared generalization metric takes it too (regression: used to
    # AttributeError on pu_table)
    ev = holdout_reward_ratio(StaticPolicy(3, "device"), scen, 0.0)
    assert 0.0 < ev.ratio <= 1.0 + 1e-6


def test_shared_pad_width_error_for_every_policy():
    """Satellite: a TraceSource-produced scenario padded to a different
    width raises the SAME protocol error for the tabular agent, the
    DQN, and the stateless policies (pre-redesign, only FleetDQN
    checked)."""
    from repro.fleet import FleetDQN
    trace_scen, _ = TraceSource.load(FIXTURE).reset(jax.random.PRNGKey(0))
    assert trace_scen.users == 3
    scen2 = mixed_table5_fleet(jax.random.PRNGKey(5), 6, 2)
    tab = FleetQLearning(scen2, FleetConfig(cells=6, users=2), seed=0)
    dqn = FleetDQN(scen2, FleetConfig(cells=6, users=2), seed=0)
    pat = r"routes fleets padded to 2 users; got a 3-wide"
    for policy in (tab, dqn, OraclePolicy(2), StaticPolicy(2)):
        with pytest.raises(ValueError, match=pat):
            FleetOrchestrator(policy).route(scen=trace_scen)


# ------------------------------------------------------- agents x source --
def test_both_agents_train_from_a_trace_source():
    src = TraceSource.load(FIXTURE)
    from repro.fleet import FleetDQN
    tab = FleetQLearning(src, cfg=FleetQConfig(eps_decay=5e-3), seed=0)
    assert tab.source is src and tab.fleet_cfg is None
    tab.run(3 * src.horizon)
    assert int(tab.scen.t) == 3 * src.horizon
    dqn = FleetDQN(src, seed=0)
    ms, acc = dqn.run(src.horizon)
    assert np.isfinite(ms).all()
    # the shared convergence loop treats a multi-frame trace as dynamic
    res = tab.train(max_steps=200, check_every=100)
    assert 0.0 <= res.frac_converged <= 1.0


def test_agent_requires_config_or_source():
    scen = mixed_table5_fleet(jax.random.PRNGKey(0), 4, 2)
    with pytest.raises(TypeError, match="ScenarioSource"):
        FleetQLearning(scen)                     # scenario without config


# ------------------------------------------------ removed PR-4 shims ------
def test_pr4_deprecation_shims_are_gone():
    """Satellite: the one-release shims were removed — the old
    population import path no longer exists, and the raw-FleetConfig
    env-step form fails with a clear pointer to SyntheticSource."""
    import repro.fleet.population as population
    assert not hasattr(population, "FleetOrchestrator")
    with pytest.raises(TypeError, match="SyntheticSource"):
        make_fleet_env_step(FleetConfig(cells=4, users=2))


def test_legacy_agent_ctor_equals_source_ctor():
    """(scen, FleetConfig) and SyntheticSource(cfg, scen=scen) are the
    same agent: identical training streams under the same seed."""
    cfg = FleetConfig(cells=8, users=2, arrival_rate=1.0)
    scen = mixed_table5_fleet(jax.random.PRNGKey(7), 8, 2)
    a = FleetQLearning(scen, cfg, seed=3)
    b = FleetQLearning(SyntheticSource(cfg, scen=scen), seed=3)
    a.run(25)
    b.run(25)
    np.testing.assert_array_equal(np.asarray(a.q), np.asarray(b.q))
    _assert_scen_equal(a.scen, b.scen)


# ------------------------------------------- ISSUE-4 acceptance: serving --
def test_trace_train_route_dispatch_end_to_end():
    """Acceptance: train on a TraceSource, route through
    FleetOrchestrator, and dispatch at least one batch to a REAL
    ServingEngine — measured wall-time reported next to the latency
    model's prediction (paper Table-8 methodology)."""
    from repro.configs import get_config
    from repro.launch.serve import build_engines
    src = TraceSource.load(FIXTURE)
    agent = FleetQLearning(src, cfg=FleetQConfig(eps_decay=5e-3,
                                                 accuracy_threshold=85.0),
                           seed=0)
    agent.run(4 * src.horizon)
    engines = build_engines(get_config("edge-ladder"), variants=("d0",),
                            max_len=48)
    res = FleetOrchestrator(agent).route(dispatch=engines,
                                         max_new_tokens=2, batch_size=4,
                                         prompt_len=8)
    assert isinstance(res, RouteResult)
    n_active = int(np.asarray(agent.scen.active).sum())
    assert len(res.served) == n_active and res.batches >= 1
    for r in res.served:
        assert r.tier in ("S", "E", "C") and r.variant == "d0"
        assert r.measured_ms > 0.0
        assert np.isfinite(r.predicted_ms) and r.predicted_ms > 0.0
    # predictions ARE the latency model's per-user times for the routed
    # decision (the fixture carries a deployment map -> topology path)
    from repro.fleet import topology
    want = np.asarray(topology.topology_response_times(
        res.decisions, agent.scen.end_b, agent.scen.edge_b, agent.scen.topo,
        active=agent.scen.active, xp=jnp))
    for r in res.served:
        assert r.predicted_ms == pytest.approx(want[r.cell, r.user])
    s = res.summary()
    assert s["requests"] == n_active and np.isfinite(s["gap_x"])
    assert s["measured_mean_ms"] > 0 and s["predicted_mean_ms"] > 0


# ------------------------------------------- ISSUE-9 acceptance: bridge --
def test_route_bridge_end_to_end_real_engines():
    """Acceptance: route(bridge=True) dispatches the same fleet through
    the async bridge against REAL engines — conservation identities
    hold (submitted == admitted + shed; served + shed == submitted;
    attained + violated == dispatched; per-request queueing + compute
    == e2e) and the bridge outcome surfaces in summary()."""
    from repro.configs import get_config
    from repro.launch.serve import build_engines
    from repro.obs.spans import SpanRecorder, validate_chrome_trace
    src = TraceSource.load(FIXTURE)
    agent = FleetQLearning(src, cfg=FleetQConfig(eps_decay=5e-3), seed=0)
    agent.run(2 * src.horizon)
    engines = build_engines(get_config("edge-ladder"), variants=("d0",),
                            max_len=48)
    orch = FleetOrchestrator(agent)
    kw = dict(dispatch=engines, max_new_tokens=2, batch_size=4,
              prompt_len=8)
    sync = orch.route(**kw)                       # warm + sync reference
    spans = SpanRecorder()
    res = orch.route(bridge=True, spans=spans, **kw)
    n_active = int(np.asarray(agent.scen.active).sum())
    assert len(res.served) == n_active
    # same request set as the sync drain, bridge path attributed
    assert ({(r.cell, r.user) for r in res.served}
            == {(r.cell, r.user) for r in sync.served})
    st = res.bridge
    assert st is not None and res.summary()["bridge"] is st
    assert st["submitted"] == n_active
    assert st["submitted"] == st["admitted"] + st["shed"]["overflow"] \
        + st["shed"]["deadline"]
    assert st["served"] + st["shed"]["total"] == st["submitted"]
    # per-request conservation + timing-wall identity
    for r in res.served:
        assert r.queue_ms + r.measured_ms == pytest.approx(r.e2e_ms)
    t = res.timings
    assert t["batching_ms"] + t["compute_ms"] + t["dispatch_ms"] \
        == pytest.approx(t["wall_ms"])
    slo = res.slo()
    assert slo["measured"]["attained"] + slo["measured"]["violated"] \
        == slo["requests"] == n_active
    # bridge spans land in a valid Chrome trace
    names = {e["name"] for e in spans.events}
    assert any(n.startswith("bridge.batch.") for n in names)
    assert "request.e2e" in names
    validate_chrome_trace(spans.chrome_trace())
