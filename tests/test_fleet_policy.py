"""repro.fleet.policy / repro.fleet.replay: on-device ring replay
semantics, the shared-policy fleet DQN's API parity with the tabular
agent, and the ISSUE-2 acceptance criterion — >= 95% of the brute-force
expected reward on held-out cells, including cell sizes absent from
training."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fleet import (FleetConfig, FleetDQN, FleetDQNConfig,
                         FleetOrchestrator, dynamics, encode_fleet_state,
                         holdout_reward_ratio, mixed_table5_fleet,
                         replay_init, replay_push, replay_sample,
                         replay_size, table5_fleet)
from repro.fleet.policy import state_dim


# ------------------------------------------------------------- replay -----
def test_replay_ring_wraps_and_overwrites_oldest():
    buf = replay_init(4, 2)
    push = jax.jit(replay_push)
    for i in range(3):            # 6 rows through a capacity-4 ring
        s = jnp.full((2, 2), float(i))
        buf = push(buf, s, jnp.full((2,), i, jnp.int32),
                   jnp.full((2,), float(i)), s + 0.5)
    assert bool(buf.full) and int(buf.ptr) == 2 and len(buf) == 4
    # slots 0..1 hold the newest batch (i=2), slots 2..3 the previous
    rows = np.asarray(buf.r)
    assert rows.tolist() == [2.0, 2.0, 1.0, 1.0]


def test_replay_sample_only_from_filled_prefix():
    buf = replay_init(64, 3)
    s = jnp.asarray([[1.0, 2.0, 3.0], [4.0, 5.0, 6.0]])
    buf = replay_push(buf, s, jnp.zeros((2,), jnp.int32),
                      jnp.asarray([7.0, 8.0]), s)
    assert int(replay_size(buf)) == 2
    bs, _, br, _ = replay_sample(jax.random.PRNGKey(0), buf, 32)
    assert bs.shape == (32, 3)
    assert set(np.asarray(br).tolist()) <= {7.0, 8.0}


def test_replay_push_larger_than_capacity_raises():
    buf = replay_init(4, 2)
    with pytest.raises(ValueError, match="self-overwrite"):
        replay_push(buf, jnp.zeros((5, 2)), jnp.zeros((5,), jnp.int32),
                    jnp.zeros((5,)), jnp.zeros((5, 2)))


def test_replay_is_a_pytree():
    buf = replay_init(8, 2, action_shape=(3,))
    leaves, treedef = jax.tree_util.tree_flatten(buf)
    assert len(leaves) == 6
    buf2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert buf2.capacity == 8 and buf2.a.shape == (8, 3)


# ----------------------------------------------------------- features -----
def test_encode_fleet_state_layout():
    scen = table5_fleet("EXP-B", cells=4, users=5)      # RWRWR | W
    counts = jnp.asarray([[2, 1]] * 4, jnp.int32)
    s = np.asarray(encode_fleet_state(counts, scen))
    assert s.shape == (4, state_dim(5))
    assert (s[:, :5] == 1.0).all() and (s[:, 5:10] == 1.0).all()
    assert s[0, 10:15].tolist() == [0, 1, 0, 1, 0]      # end links
    assert s[0, 15] == 1.0                              # weak edge backhaul
    np.testing.assert_allclose(s[0, 16:18], [0.4, 0.2])  # counts / N
    assert s[0, 18] == 1.0                              # size / N


# ----------------------------------------------------------- FleetDQN -----
def test_fleet_dqn_mirrors_tabular_api():
    scen = mixed_table5_fleet(jax.random.PRNGKey(2), 32, 2)
    agent = FleetDQN(scen, FleetConfig(cells=32, users=2), seed=1)
    info = agent.step()
    assert np.asarray(info["mean_ms"]).shape == (32,)
    assert np.isfinite(float(info["loss"]))
    ms, acc = agent.run(5)
    assert ms.shape == (5,) and acc.shape == (5,) and agent.steps == 6
    dec = agent.greedy_decisions()
    assert dec.shape == (32, 2)
    assert set(np.unique(np.asarray(dec))) <= set(range(10))


def test_fleet_dqn_orchestrator_and_joint_ids():
    scen = mixed_table5_fleet(jax.random.PRNGKey(3), 16, 3)
    agent = FleetDQN(scen, FleetConfig(cells=16, users=3), seed=2)
    agent.step()
    dec, ids = FleetOrchestrator(agent).route()
    np.testing.assert_array_equal(np.asarray(dec),
                                  np.asarray(agent.greedy_decisions()))
    # joint ids are the base-10 encoding of the per-user decisions
    want = [agent.spec.encode_action(list(row)) for row in np.asarray(dec)]
    assert np.asarray(ids).tolist() == want


def test_fleet_dqn_rejects_unknown_net_form():
    scen = mixed_table5_fleet(jax.random.PRNGKey(0), 4, 2)
    with pytest.raises(ValueError, match="net form"):
        FleetDQN(scen, FleetConfig(cells=4, users=2),
                 FleetDQNConfig(net="transformer"))


def test_fleet_dqn_cell_form_trains():
    scen = mixed_table5_fleet(jax.random.PRNGKey(4), 16, 2)
    agent = FleetDQN(scen, FleetConfig(cells=16, users=2),
                     FleetDQNConfig(net="cell"), seed=0)
    agent.run(3)
    assert agent.greedy_decisions().shape == (16, 2)


def test_fleet_dqn_train_returns_fleet_result():
    """train() goes through the shared train_against_oracle loop."""
    scen = mixed_table5_fleet(jax.random.PRNGKey(5), 16, 2)
    agent = FleetDQN(scen, FleetConfig(cells=16, users=2),
                     FleetDQNConfig(eps_decay=5e-3), seed=0)
    res = agent.train(max_steps=400, check_every=200)
    assert res.optimal_ms.shape == (16,) and res.greedy_ms.shape == (16,)
    assert 0.0 <= res.frac_converged <= 1.0 and res.steps == agent.steps


def test_fleet_dqn_rejects_mismatched_pad_width():
    """The feature layout is pinned to the trained padded width: a
    wider held-out scen must raise, not silently misread every block
    (smaller cells go through the membership mask instead)."""
    scen = mixed_table5_fleet(jax.random.PRNGKey(7), 8, 3)
    agent = FleetDQN(scen, FleetConfig(cells=8, users=3), seed=0)
    wide = mixed_table5_fleet(jax.random.PRNGKey(8), 8, 5)
    with pytest.raises(ValueError, match="padded to 3"):
        agent.greedy_decisions(scen=wide)
    with pytest.raises(ValueError, match="padded to 3"):
        FleetOrchestrator(agent).route(scen=wide)


def test_holdout_reward_ratio_takes_either_agent():
    """The shared generalization metric works on the tabular agent for
    its OWN fleet (API parity), and a genuinely held-out fleet raises
    the clear per-cell-tables-don't-transfer error."""
    from repro.fleet import FleetQLearning
    scen = mixed_table5_fleet(jax.random.PRNGKey(10), 16, 2)
    tab = FleetQLearning(scen, FleetConfig(cells=16, users=2), seed=0)
    tab.run(200)
    ev = holdout_reward_ratio(tab, tab.scen, 0.0)
    assert 0.0 < ev.ratio <= 1.0 + 1e-6
    with pytest.raises(ValueError, match="FleetDQN"):
        holdout_reward_ratio(
            tab, mixed_table5_fleet(jax.random.PRNGKey(11), 32, 2), 0.0)


def test_constrained_head_respects_restricted_candidate_set():
    """With fewer allowed per-user actions than topk, lax.top_k pads the
    candidate combos with -1e30-masked DISALLOWED ids; the constrained
    head must never emit one (regression: their finite scores used to
    slip past the feasibility filter)."""
    users = 2
    # low-accuracy local models only (TOP5[3]=74.2, TOP5[7]=72.8): no
    # candidate action can meet the 85% goal, while the DISALLOWED
    # models/tiers the top-k rows are padded with all can — the exact
    # setup where the old head escaped the candidate set
    actions = np.asarray([33, 37, 73, 77])
    scen = mixed_table5_fleet(jax.random.PRNGKey(6), 64, users)
    agent = FleetDQN(scen, FleetConfig(cells=64, users=users),
                     FleetDQNConfig(accuracy_threshold=85.0, topk=5),
                     actions=actions, seed=3)
    assert agent.allowed.sum(-1).min() < agent.cfg.topk  # padding occurs
    for _ in range(3):
        agent.step()
    dec = np.asarray(agent.greedy_decisions())
    for u in range(users):
        assert agent.allowed[u, dec[:, u]].all(), \
            f"user {u} got a decision outside the candidate set"


# ------------------------------------------------- ISSUE-2 acceptance -----
def test_fleet_dqn_generalizes_to_held_out_cells_and_sizes():
    """One shared policy, trained on a mixed Table-5 fleet of 2-3-user
    cells under a QoS goal, reaches >= 95% of the brute-force expected
    reward on a HELD-OUT fleet — including 1-user cells, a size absent
    from training."""
    cells, users, th = 256, 3, 85.0
    train_scen = mixed_table5_fleet(jax.random.PRNGKey(0), cells, users,
                                    min_users=2, max_users=3)
    # Poisson arrivals vary the active subset during training, so the
    # policy also sees sparse cells while membership stays 2-3 users
    fc = FleetConfig(cells=cells, users=users, arrival_rate=1.2)
    agent = FleetDQN(train_scen, fc,
                     FleetDQNConfig(accuracy_threshold=th), seed=0)
    agent.run(1000)

    hold = mixed_table5_fleet(jax.random.PRNGKey(99), 128, users,
                              min_users=1, max_users=3)
    sizes = np.asarray(hold.member).sum(1)
    assert (sizes == 1).any(), "holdout must contain the unseen size"
    ev = holdout_reward_ratio(agent, hold, th)
    assert ev.ratio >= 0.95, (ev.ratio, ev.feasible.mean())
    # the unseen cell size specifically is also served near-optimally
    ratio_unseen = (ev.optimal[sizes == 1].mean()
                    / ev.achieved[sizes == 1].mean())
    assert ratio_unseen >= 0.95, ratio_unseen
