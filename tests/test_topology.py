"""repro.fleet.topology: shared edge servers, cross-cell contention,
cloud queueing, the coupled best-response oracle, and the ISSUE-3
acceptance criteria — bit-exact 1:1 reduction to the isolated-cell
path, and topology-aware routing beating topology-blind routing on a
hot edge."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.spaces import A_CLOUD, A_EDGE, SpaceSpec
from repro.fleet import (FleetConfig, FleetDQN, FleetDQNConfig,
                         FleetOrchestrator, FleetQConfig, FleetQLearning,
                         Topology, cloud_load_multiplier, dynamics,
                         edge_utilization, fleet_bruteforce,
                         fleet_topology_expected_response,
                         hot_edge_topology, identity_topology, init_fleet,
                         make_fleet_env_step, make_topology,
                         mixed_table5_fleet, random_topology,
                         simulate_responses, skewed_topology, SyntheticSource,
                         step_edge_failures, step_fleet, table5_fleet,
                         topology_bruteforce, topology_expected_response,
                         topology_response_times, with_topology)
from repro.fleet.topology import CLOUD_QUEUE_MAX


def _rand_fleet(key, cells, users):
    rng = np.random.default_rng(key)
    pu = jnp.asarray(rng.integers(0, 10, (cells, users)), jnp.int32)
    end_b = jnp.asarray(rng.integers(0, 2, (cells, users)), jnp.int32)
    edge_b = jnp.asarray(rng.integers(0, 2, cells), jnp.int32)
    active = jnp.asarray(rng.random((cells, users)) < 0.8)
    return pu, end_b, edge_b, active


# ------------------------------------------------- 1:1 reduction ----------
def test_identity_topology_reduces_bit_exactly():
    """ISSUE-3 acceptance: a 1:1 assignment with unit capacities and an
    unbounded cloud queue reproduces the isolated-cell dynamics
    BIT-EXACTLY (assert_array_equal, not allclose)."""
    pu, end_b, edge_b, active = _rand_fleet(0, 32, 5)
    topo = identity_topology(32)
    iso_t = dynamics.response_times(pu, end_b, edge_b, active=active,
                                    xp=jnp)
    topo_t = topology_response_times(pu, end_b, edge_b, topo,
                                     active=active)
    np.testing.assert_array_equal(np.asarray(iso_t), np.asarray(topo_t))
    iso = dynamics.expected_response(pu, end_b, edge_b, active=active,
                                     xp=jnp)
    top = topology_expected_response(pu, end_b, edge_b, topo,
                                     active=active)
    for a, b in zip(iso, top):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_identity_topology_numpy_path_matches_jax():
    pu, end_b, edge_b, active = _rand_fleet(1, 8, 3)
    topo = identity_topology(8)
    j = topology_response_times(pu, end_b, edge_b, topo, active=active)
    n = topology_response_times(np.asarray(pu), np.asarray(end_b),
                                np.asarray(edge_b), topo,
                                active=np.asarray(active), xp=np)
    np.testing.assert_allclose(np.asarray(j), n, rtol=1e-5)


def test_simulate_responses_identity_topology_bit_exact():
    """The full env path (noise on) is also unchanged by the identity
    topology: same key -> bit-identical responses and counts."""
    scen = mixed_table5_fleet(jax.random.PRNGKey(3), 16, 3)
    scen_t = with_topology(scen, identity_topology(16))
    pu = jnp.asarray(np.random.default_rng(5).integers(0, 10, (16, 3)),
                     jnp.int32)
    k = jax.random.PRNGKey(9)
    ms_a, acc_a, cnt_a = simulate_responses(k, scen, pu, 0.02)
    ms_b, acc_b, cnt_b = simulate_responses(k, scen_t, pu, 0.02)
    np.testing.assert_array_equal(np.asarray(ms_a), np.asarray(ms_b))
    np.testing.assert_array_equal(np.asarray(acc_a), np.asarray(acc_b))
    np.testing.assert_array_equal(np.asarray(cnt_a), np.asarray(cnt_b))


# ------------------------------------------------ shared contention -------
def test_shared_edge_aggregates_counts_across_cells():
    """Two cells pinned to one edge: each sees the OTHER's edge jobs.
    The result must equal the single-cell kernel with the summed count
    passed through the counts-override seam."""
    users = 3
    scen = table5_fleet("EXP-A", cells=2, users=users)
    topo = Topology(jnp.zeros(2, jnp.int32), jnp.ones(1, jnp.float32),
                    jnp.float32(np.inf))
    pu = jnp.full((2, users), A_EDGE, jnp.int32)
    got = topology_response_times(pu, scen.end_b, scen.edge_b, topo,
                                  active=scen.member)
    want = dynamics.response_times(np.asarray(pu[0]),
                                   np.asarray(scen.end_b[0]),
                                   int(scen.edge_b[0]),
                                   counts=(2 * users, 0))
    np.testing.assert_allclose(np.asarray(got[0]), want, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(got[1]), want, rtol=1e-5)
    # and sharing is strictly slower than owning the edge
    alone = dynamics.response_times(pu, scen.end_b, scen.edge_b, xp=jnp)
    assert (np.asarray(got) > np.asarray(alone)).all()


def test_edge_capacity_tier_divides_effective_load():
    """A capacity-2 edge serving 2N jobs behaves like a unit edge
    serving N jobs."""
    users = 2
    scen = table5_fleet("EXP-A", cells=2, users=users)
    pu = jnp.full((2, users), A_EDGE, jnp.int32)
    cap2 = Topology(jnp.zeros(2, jnp.int32),
                    jnp.full((1,), 2.0, jnp.float32), jnp.float32(np.inf))
    got = topology_response_times(pu, scen.end_b, scen.edge_b, cap2,
                                  active=scen.member)
    want = dynamics.response_times(np.asarray(pu[0]),
                                   np.asarray(scen.end_b[0]),
                                   int(scen.edge_b[0]),
                                   counts=(users, 0))
    np.testing.assert_allclose(np.asarray(got[0]), want, rtol=1e-5)


def test_cloud_queue_inflates_cloud_latency_only():
    """A finite cloud queue slows cloud offloaders fleet-wide but leaves
    local and edge users untouched."""
    users = 3
    scen = table5_fleet("EXP-A", cells=8, users=users)
    pu = jnp.asarray(np.tile([0, A_EDGE, A_CLOUD], (8, 1)), jnp.int32)
    unbounded = with_topology(scen, identity_topology(8))
    queued = with_topology(
        scen, Topology(jnp.arange(8, dtype=jnp.int32),
                       jnp.ones(8, jnp.float32), jnp.float32(4.0)))
    t_u = np.asarray(topology_response_times(
        pu, scen.end_b, scen.edge_b, unbounded.topo, active=scen.member))
    t_q = np.asarray(topology_response_times(
        pu, scen.end_b, scen.edge_b, queued.topo, active=scen.member))
    # 8 cloud jobs on a 4-slot queue: rho=2 -> saturated multiplier
    np.testing.assert_array_equal(t_q[:, 0], t_u[:, 0])    # local
    np.testing.assert_array_equal(t_q[:, 1], t_u[:, 1])    # edge
    assert (t_q[:, 2] > t_u[:, 2]).all()                   # cloud


def test_cloud_load_multiplier_shape_and_saturation():
    assert float(cloud_load_multiplier(0, np.inf, xp=np)) == 1.0
    assert float(cloud_load_multiplier(1000, np.inf, xp=np)) == 1.0
    m = [float(cloud_load_multiplier(n, 8.0, xp=np)) for n in range(0, 32)]
    assert m[0] == 1.0
    assert all(b >= a for a, b in zip(m, m[1:]))           # monotone
    assert m[-1] == CLOUD_QUEUE_MAX                        # saturates
    assert float(cloud_load_multiplier(4.0, 8.0, xp=np)) == pytest.approx(2.0)


# ------------------------------------------------------ generators --------
def test_topology_generators_seedable_and_bounded():
    k = jax.random.PRNGKey(0)
    t1 = random_topology(k, 64, 8, capacity_tiers=(1.0, 2.0))
    t2 = random_topology(k, 64, 8, capacity_tiers=(1.0, 2.0))
    np.testing.assert_array_equal(np.asarray(t1.cell_edge),
                                  np.asarray(t2.cell_edge))
    assert t1.n_edges == 8 and t1.cells == 64
    ce = np.asarray(t1.cell_edge)
    assert ce.min() >= 0 and ce.max() < 8
    # capacity tiers cycle deterministically
    np.testing.assert_allclose(np.asarray(t1.edge_capacity),
                               [1.0, 2.0] * 4)


def test_skewed_topology_makes_edge_zero_hottest():
    topo = skewed_topology(jax.random.PRNGKey(1), 512, 8, skew=2.0)
    loads = np.bincount(np.asarray(topo.cell_edge), minlength=8)
    assert loads[0] == loads.max()
    assert loads[0] > 512 / 8          # clearly above uniform


def test_hot_edge_topology_deterministic_split():
    topo = hot_edge_topology(20, 4, hot_fraction=0.6)
    ce = np.asarray(topo.cell_edge)
    assert (ce[:12] == 0).all()
    assert set(ce[12:]) == {1, 2, 3}
    # single-edge degenerate case still works
    assert (np.asarray(hot_edge_topology(6, 1).cell_edge) == 0).all()


def test_make_topology_from_fleet_config():
    cfg = FleetConfig(cells=32, users=2, n_edges=4, assignment="skewed",
                      capacity_tiers=(1.0, 0.5), cloud_servers=16.0)
    topo = make_topology(jax.random.PRNGKey(0), cfg)
    assert topo.n_edges == 4 and float(topo.cloud_servers) == 16.0
    assert make_topology(jax.random.PRNGKey(0),
                         FleetConfig(cells=4, users=2)) is None
    with pytest.raises(ValueError, match="assignment"):
        make_topology(jax.random.PRNGKey(0),
                      FleetConfig(cells=4, users=2, n_edges=2,
                                  assignment="mesh"))


def test_init_fleet_attaches_topology_deterministically():
    cfg = FleetConfig(cells=16, users=3, n_edges=4, cloud_servers=32.0)
    s = init_fleet(jax.random.PRNGKey(7), cfg)
    assert s.topo is not None and s.topo.n_edges == 4
    assert float(s.topo.cloud_servers) == 32.0
    s2 = init_fleet(jax.random.PRNGKey(7), cfg)
    np.testing.assert_array_equal(np.asarray(s.topo.cell_edge),
                                  np.asarray(s2.topo.cell_edge))
    np.testing.assert_array_equal(np.asarray(s.end_b),
                                  np.asarray(s2.end_b))
    # configs without n_edges never build one (and, because the key is
    # only split 5 ways when a topology is configured, they keep the
    # exact random streams of the pre-topology code)
    assert init_fleet(jax.random.PRNGKey(7),
                      FleetConfig(cells=16, users=3)).topo is None


# -------------------------------------------------- failure events --------
def test_step_edge_failures_reroutes_off_the_failed_edge():
    topo = hot_edge_topology(32, 4, hot_fraction=0.5)
    before = np.asarray(topo.cell_edge)
    after_t = step_edge_failures(jax.random.PRNGKey(0), topo, 1.0)
    after = np.asarray(after_t.cell_edge)
    moved = before != after
    assert moved.any()
    failed = set(before[moved])
    assert len(failed) == 1                    # exactly one edge failed
    (failed,) = failed
    assert failed not in set(after)            # nobody remains on it
    assert (after[~moved] == before[~moved]).all()
    # p_fail=0 and single-edge topologies are no-ops
    same = step_edge_failures(jax.random.PRNGKey(0), topo, 0.0)
    np.testing.assert_array_equal(np.asarray(same.cell_edge), before)
    one = hot_edge_topology(8, 1)
    assert step_edge_failures(jax.random.PRNGKey(0), one, 1.0) is one


def test_step_fleet_applies_edge_failures_under_jit():
    cfg = FleetConfig(cells=32, users=2, n_edges=4, p_edge_fail=1.0)
    s = init_fleet(jax.random.PRNGKey(0), cfg)
    stepper = jax.jit(lambda k, s: step_fleet(k, s, cfg))
    s2 = stepper(jax.random.PRNGKey(1), s)
    assert (np.asarray(s2.topo.cell_edge)
            != np.asarray(s.topo.cell_edge)).any()
    # without p_edge_fail the topology rides along unchanged
    cfg0 = dataclasses.replace(cfg, p_edge_fail=0.0)
    s3 = jax.jit(lambda k, s: step_fleet(k, s, cfg0))(
        jax.random.PRNGKey(1), s)
    np.testing.assert_array_equal(np.asarray(s3.topo.cell_edge),
                                  np.asarray(s.topo.cell_edge))


# ------------------------------------------------------- oracle -----------
def test_topology_bruteforce_identity_matches_isolated_oracle():
    """Under the 1:1 identity topology the coupled oracle must terminate
    at the isolated per-cell optimum in a single sweep."""
    scen = mixed_table5_fleet(jax.random.PRNGKey(2), 16, 2)
    spec = SpaceSpec(2)
    pu = jnp.asarray(spec.decode_actions_batch(spec.all_actions()))
    iso_ms, iso_idx = fleet_bruteforce(scen, pu, 85.0)
    scen_t = with_topology(scen, identity_topology(16))
    ms, idx, converged, rounds = topology_bruteforce(scen_t, pu, 85.0)
    assert converged and rounds == 1
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(iso_idx))
    np.testing.assert_allclose(np.asarray(ms), np.asarray(iso_ms),
                               rtol=1e-6)


def test_fleet_bruteforce_dispatches_on_topology():
    scen = mixed_table5_fleet(jax.random.PRNGKey(2), 8, 2)
    spec = SpaceSpec(2)
    pu = jnp.asarray(spec.decode_actions_batch(spec.all_actions()))
    scen_t = with_topology(scen, hot_edge_topology(8, 2, cloud_servers=4.0))
    ms_t, idx_t = fleet_bruteforce(scen_t, pu, 89.0)
    want = topology_bruteforce(scen_t, pu, 89.0)
    np.testing.assert_array_equal(np.asarray(idx_t), np.asarray(want[1]))
    np.testing.assert_allclose(np.asarray(ms_t), np.asarray(want[0]))
    # infeasible thresholds still fail loudly through the dispatch
    with pytest.raises(ValueError, match="no feasible action"):
        fleet_bruteforce(scen_t, pu, 99.0)


def test_topology_aware_beats_blind_routing_on_hot_edge():
    """ISSUE-3 acceptance: under a hot-edge scenario the best-response
    (topology-aware) decisions earn strictly more expected reward than
    the isolated-optimal (topology-blind) decisions evaluated under the
    same shared contention."""
    cells, users, th = 24, 2, 89.0
    scen = mixed_table5_fleet(jax.random.PRNGKey(0), cells, users)
    topo = hot_edge_topology(cells, 4, hot_fraction=0.6, cloud_servers=8.0)
    scen_t = with_topology(scen, topo)
    spec = SpaceSpec(users)
    pu = jnp.asarray(spec.decode_actions_batch(spec.all_actions()))
    _, blind_idx = fleet_bruteforce(scen, pu, th)
    b_ms, b_acc = fleet_topology_expected_response(
        pu[blind_idx], scen.end_b, scen.edge_b, topo, scen.member)
    a_ms, a_idx, converged, _ = topology_bruteforce(scen_t, pu, th)
    _, a_acc = fleet_topology_expected_response(
        pu[a_idx], scen.end_b, scen.edge_b, topo, scen.member)
    r_blind = float(dynamics.reward(b_ms, b_acc, th, xp=jnp).mean())
    r_aware = float(dynamics.reward(a_ms, a_acc, th, xp=jnp).mean())
    assert converged
    assert r_aware > r_blind
    # every cell stays QoS-feasible while routing around the hot edge
    assert bool(np.asarray(dynamics.feasible(a_acc, th)).all())


def test_best_response_never_worse_than_blind_per_round():
    """The oracle's fixed point never has a higher fleet cost than its
    isolated-start evaluation (each accepted switch strictly improves
    the switching cell against the then-current background)."""
    scen = mixed_table5_fleet(jax.random.PRNGKey(5), 16, 2)
    topo = skewed_topology(jax.random.PRNGKey(6), 16, 3, skew=2.0,
                           cloud_servers=6.0)
    scen_t = with_topology(scen, topo)
    spec = SpaceSpec(2)
    pu = jnp.asarray(spec.decode_actions_batch(spec.all_actions()))
    _, blind_idx = fleet_bruteforce(scen, pu, 89.0)
    blind_ms, _ = fleet_topology_expected_response(
        pu[blind_idx], scen.end_b, scen.edge_b, topo, scen.member)
    ms, _, converged, _ = topology_bruteforce(scen_t, pu, 89.0)
    assert converged
    assert float(np.mean(ms)) <= float(np.mean(blind_ms)) + 1e-6


# -------------------------------------------------- agents + serving ------
def test_fleet_env_step_with_topology_in_scan():
    cfg = FleetConfig(cells=16, users=2, n_edges=4, assignment="skewed",
                      cloud_servers=8.0, p_edge_fail=0.1)
    scen = init_fleet(jax.random.PRNGKey(0), cfg)
    env_step = make_fleet_env_step(SyntheticSource(cfg), threshold=85.0)

    def run(key, scen, actions):
        def body(carry, a):
            key, scen = carry
            key, k = jax.random.split(key)
            scen2, counts, ms, acc, r = env_step(k, scen, a)
            return (key, scen2), (ms, r)
        return jax.lax.scan(body, (key, scen), actions)

    acts = jnp.asarray(np.random.default_rng(0).integers(0, 10, (10, 16, 2)),
                       jnp.int32)
    (_, scen2), (ms, r) = jax.jit(run)(jax.random.PRNGKey(1), scen, acts)
    assert np.isfinite(np.asarray(ms)).all()
    assert int(scen2.t) == 10 and scen2.topo is not None


def test_agents_train_on_topology_fleet():
    """Both agents run their jitted training loops on a shared-edge
    fleet, and train() scores them against the coupled oracle."""
    cfg = FleetConfig(cells=16, users=2, n_edges=4, assignment="skewed",
                      cloud_servers=8.0)
    scen = init_fleet(jax.random.PRNGKey(1), cfg)
    tab = FleetQLearning(scen, cfg, FleetQConfig(eps_decay=5e-3), seed=0)
    res = tab.train(max_steps=400, check_every=200)
    assert 0.0 <= res.frac_converged <= 1.0
    assert res.optimal_ms.shape == (16,)
    dqn = FleetDQN(scen, cfg, FleetDQNConfig(), seed=0)
    dqn.run(30)
    assert dqn.greedy_decisions().shape == (16, 2)


def test_orchestrator_reports_per_edge_utilization():
    cfg = FleetConfig(cells=12, users=2, n_edges=3, cloud_servers=8.0)
    scen = init_fleet(jax.random.PRNGKey(2), cfg)
    agent = FleetQLearning(scen, cfg, seed=0)
    agent.step()
    orch = FleetOrchestrator(agent)
    dec, ids, util = orch.route(with_edge_util=True)
    assert util.shape == (3,)
    want = edge_utilization(dec, agent.scen.topo, active=agent.scen.active)
    np.testing.assert_allclose(np.asarray(util), np.asarray(want))
    # isolated fleets report per-cell loads via the identity topology
    iso = mixed_table5_fleet(jax.random.PRNGKey(3), 8, 2)
    a2 = FleetQLearning(iso, FleetConfig(cells=8, users=2), seed=0)
    _, _, util2 = FleetOrchestrator(a2).route(with_edge_util=True)
    assert util2.shape == (8,)
    assert (np.asarray(util2) >= 0).all()


def test_encode_fleet_state_topology_features():
    from repro.fleet import encode_fleet_state
    from repro.fleet.policy import state_dim
    users = 2
    scen = table5_fleet("EXP-A", cells=4, users=users)
    counts = jnp.asarray([[2, 1]] * 4, jnp.int32)
    base = 3 * users
    # isolated: shared load == own load, capacity 1, cloud util 0
    s = np.asarray(encode_fleet_state(counts, scen))
    assert s.shape == (4, state_dim(users))
    np.testing.assert_allclose(s[:, base + 4], s[:, base + 1])
    np.testing.assert_allclose(s[:, base + 5], 1.0)
    np.testing.assert_allclose(s[:, base + 6], 0.0)
    # shared edge: all 4 cells on one capacity-2 edge, finite cloud
    topo = Topology(jnp.zeros(4, jnp.int32), jnp.full((1,), 2.0),
                    jnp.float32(16.0))
    s_t = np.asarray(encode_fleet_state(counts, with_topology(scen, topo)))
    # 4 cells x 2 edge jobs on one capacity-2 edge: 8 / 2.0, then / N
    np.testing.assert_allclose(s_t[:, base + 4], 8 / 2.0 / users)
    np.testing.assert_allclose(s_t[:, base + 5], 2.0)
    np.testing.assert_allclose(s_t[:, base + 6], 4 / 16.0)
    # per-user blocks are untouched by topology features
    np.testing.assert_array_equal(s_t[:, :base + 4], s[:, :base + 4])


def test_fleet_dqn_sees_neighbor_pressure():
    """The shared policy's per-edge load feature makes a cell's
    Q-values depend on its NEIGHBORS' jobs: holding cell 0's own counts
    fixed, loading the other cells on its edge must change cell 0's
    values (the whole point of threading topology into the encoder)."""
    from repro.fleet import encode_fleet_state
    users = 2
    cfg = FleetConfig(cells=8, users=users, n_edges=2)
    scen = init_fleet(jax.random.PRNGKey(4), cfg)
    dqn = FleetDQN(scen, cfg, FleetDQNConfig(), seed=1)
    dqn.run(10)
    quiet = jnp.zeros((8, 2), jnp.int32).at[0, 0].set(1)
    noisy = jnp.ones((8, 2), jnp.int32).at[0, 0].set(1).at[0, 1].set(0)
    s_q = encode_fleet_state(quiet, scen)
    s_n = encode_fleet_state(noisy, scen)
    # cell 0's own-count features are identical; only shared load moved
    np.testing.assert_array_equal(
        np.asarray(s_q[0, :3 * users + 4]),
        np.asarray(s_n[0, :3 * users + 4]))
    q_quiet = dqn._per_user_q(dqn.params, s_q)[0]
    q_noisy = dqn._per_user_q(dqn.params, s_n)[0]
    assert (np.asarray(q_quiet) != np.asarray(q_noisy)).any()
