"""Sim-to-real calibration loop (ISSUE-9): the response-components
split, the Calibration pytree seam through dynamics/scenarios/shard,
the least-squares fit closing a synthetic gap, and CalibratedDynamics
slotting into the training loops."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fleet import (CalibratedDynamics, Calibration, FleetConfig,
                         FleetDQN, FleetOrchestrator, FleetQConfig,
                         FleetQLearning, SyntheticSource, apply_calibration,
                         calibrated_response_times, dynamics,
                         fit_calibration, init_fleet, mixed_table5_fleet,
                         nominal_expected_response, response_times,
                         user_tier)
from repro.fleet.api import RouteResult, ServedRequest
from repro.fleet.calibrate import _model_components, calibration_report


def _rand_actions(key, cells, users):
    return jax.random.randint(key, (cells, users), 0, 10)


def _scen(cells=6, users=3, seed=0):
    return init_fleet(jax.random.PRNGKey(seed),
                      FleetConfig(cells=cells, users=users,
                                  arrival_rate=None))


# ------------------------------------------------ components identity ----
def test_response_components_sum_to_response_times():
    scen = _scen()
    pu = _rand_actions(jax.random.PRNGKey(1), scen.cells, 3)
    comm, comp = dynamics.response_components(
        pu, scen.end_b, scen.edge_b, active=scen.active, xp=jnp)
    want = response_times(pu, scen.end_b, scen.edge_b,
                          active=scen.active, xp=jnp)
    np.testing.assert_allclose(np.asarray(comm + comp), np.asarray(want),
                               rtol=1e-6)


def test_identity_calibration_matches_base_model():
    scen = _scen(seed=2)
    pu = _rand_actions(jax.random.PRNGKey(3), scen.cells, 3)
    base = response_times(pu, scen.end_b, scen.edge_b,
                          active=scen.active, xp=jnp)
    ident = calibrated_response_times(pu, scen.end_b, scen.edge_b,
                                      Calibration.identity(jnp),
                                      active=scen.active, xp=jnp)
    np.testing.assert_allclose(np.asarray(ident), np.asarray(base),
                               rtol=1e-6)
    # response_times(calib=None) is the untouched base path, bit-exact
    np.testing.assert_array_equal(
        np.asarray(response_times(pu, scen.end_b, scen.edge_b,
                                  active=scen.active, calib=None, xp=jnp)),
        np.asarray(base))


def test_user_tier_maps_offload_actions():
    pu = jnp.asarray([[0, dynamics.A_EDGE, dynamics.A_CLOUD, 5]])
    np.testing.assert_array_equal(np.asarray(user_tier(pu, jnp)),
                                  [[0, 1, 2, 0]])


# --------------------------------------------------- calibration seam ----
def test_scenario_pytree_carries_calibration():
    scen = _scen()
    calib = Calibration(jnp.asarray([1.5, 2.0, 0.5]),
                        jnp.asarray([3.0, -1.0, 0.0]))
    stamped = apply_calibration(scen, calib)
    leaves, treedef = jax.tree_util.tree_flatten(stamped)
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert back.calib is not None
    np.testing.assert_array_equal(np.asarray(back.calib.compute_scale),
                                  np.asarray(calib.compute_scale))
    # detaching restores the base model
    assert apply_calibration(stamped, None).calib is None
    # the stamp survives a fleet step
    from repro.fleet import step_fleet
    stepped = step_fleet(jax.random.PRNGKey(0), stamped,
                         FleetConfig(cells=scen.cells, users=3,
                                     arrival_rate=None))
    assert stepped.calib is not None


def test_calibration_changes_nominal_and_jitted_paths():
    scen = _scen(seed=4)
    pu = _rand_actions(jax.random.PRNGKey(5), scen.cells, 3)
    calib = Calibration(jnp.asarray([2.0, 2.0, 2.0]),
                        jnp.asarray([10.0, 10.0, 10.0]))
    base_ms, _ = nominal_expected_response(scen, pu)
    cal_ms, _ = nominal_expected_response(apply_calibration(scen, calib), pu)
    # scale 2 + positive offsets: every cell's expected ms strictly grows
    assert (np.asarray(cal_ms) > np.asarray(base_ms)).all()


# ----------------------------------------------------------- the fit ----
def _synthetic_result(scen, pu, scale=1.3, offset=20.0):
    """A fake RouteResult whose measurements are an exact affine map of
    the model's compute component: measured = scale*comp + offset + comm
    (so a perfect fit recovers (scale, offset) and gap_x -> 1)."""
    comm, comp = _model_components(np.asarray(pu), scen)
    act = np.asarray(scen.active)
    served = []
    for c in range(scen.cells):
        for u in range(pu.shape[1]):
            if not act[c, u]:
                continue
            a = int(np.asarray(pu)[c, u])
            tier = ("E" if a == dynamics.A_EDGE else
                    "C" if a == dynamics.A_CLOUD else "S")
            pred = comm[c, u] + comp[c, u]
            meas = comm[c, u] + scale * comp[c, u] + offset
            served.append(ServedRequest(cell=c, user=u, action=a, tier=tier,
                                        variant="d0", predicted_ms=pred,
                                        measured_ms=meas))
    return RouteResult(decisions=pu, ids=jnp.zeros((scen.cells,), jnp.int32),
                       served=served, batches=1)


def test_fit_recovers_affine_gap_and_closes_it():
    scen = _scen(cells=8, seed=6)
    pu = _rand_actions(jax.random.PRNGKey(7), scen.cells, 3)
    res = _synthetic_result(scen, pu, scale=1.3, offset=20.0)
    fit = fit_calibration(res, scen)
    coeff = fit.coefficients()
    for tier in ("S", "E", "C"):
        if coeff[tier].get("requests", 0) < 2:
            continue
        assert coeff[tier]["resid_rms_ms"] == pytest.approx(0.0, abs=1e-3)
    # local tier has spread in comp -> exact recovery of (scale, offset)
    assert coeff["S"]["compute_scale"] == pytest.approx(1.3, abs=1e-3)
    assert coeff["S"]["hop_offset_ms"] == pytest.approx(20.0, abs=1e-2)
    # the calibrated model reproduces the measurements: gap_x -> 1
    pred = calibrated_response_times(pu, scen.end_b, scen.edge_b, fit.calib,
                                     active=scen.active, xp=jnp)
    pred = np.asarray(pred)
    for r in res.served:
        assert pred[r.cell, r.user] == pytest.approx(r.measured_ms,
                                                     rel=1e-3)
    report = calibration_report(fit, res, res)
    assert set(report) == {"coefficients", "before", "after"}
    assert report["after"]["requests"] == len(res.served)


def test_fit_ignores_empty_tiers():
    scen = _scen(cells=4, seed=8)
    pu = jnp.zeros((scen.cells, 3), jnp.int32)      # everything local
    fit = fit_calibration(_synthetic_result(scen, pu), scen)
    coeff = fit.coefficients()
    for tier in ("E", "C"):
        assert coeff[tier]["requests"] == 0
        assert coeff[tier]["compute_scale"] == 1.0       # identity kept
        assert coeff[tier]["hop_offset_ms"] == 0.0


# ------------------------------------------------- CalibratedDynamics ----
def test_calibrated_dynamics_trains_policies():
    cfg = FleetConfig(cells=8, users=3, arrival_rate=None)
    calib = Calibration(jnp.asarray([1.2, 1.1, 0.9]),
                        jnp.asarray([5.0, 2.0, -1.0]))
    src = CalibratedDynamics(SyntheticSource(cfg), calib)
    assert src.cells == 8 and src.users == 3
    scen, state = src.reset(jax.random.PRNGKey(0))
    assert scen.calib is not None and state.calib is not None
    scen2, _ = src.step(jax.random.PRNGKey(1), state)
    assert scen2.calib is not None
    # both agents train a few jitted steps on the calibrated pytree
    FleetQLearning(src, cfg=FleetQConfig(), seed=0).run(8)
    FleetDQN(src, seed=0).run(8)


def test_calibrated_dynamics_requires_scenario_state():
    class _Bad:
        state_is_scenario = False
        cells, users, dynamic = 4, 3, False
    with pytest.raises(TypeError):
        CalibratedDynamics(_Bad(), Calibration.identity(jnp))
