"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (ref.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _rand(shape, dtype, key):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,sq,skv,h,kv,hd,causal,window", [
    (2, 128, 128, 8, 2, 64, True, 0),
    (1, 100, 100, 4, 4, 32, True, 48),     # ragged + sliding window
    (2, 64, 192, 6, 3, 128, False, 0),     # cross attention
    (1, 256, 256, 2, 1, 256, True, 0),     # MQA, big head
    (3, 33, 65, 5, 5, 16, True, 0),        # odd everything
])
def test_flash_attention(dtype, b, sq, skv, h, kv, hd, causal, window):
    ks = jax.random.split(KEY, 3)
    q = _rand((b, sq, h, hd), dtype, ks[0])
    k = _rand((b, skv, kv, hd), dtype, ks[1])
    v = _rand((b, skv, kv, hd), dtype, ks[2])
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              bq=32, bk=32)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kv,hd,s,window,bk", [
    (3, 8, 2, 64, 300, 64, 128),
    (1, 16, 16, 128, 1024, 0, 256),
    (2, 4, 1, 32, 96, 0, 32),
])
def test_decode_attention(dtype, b, h, kv, hd, s, window, bk):
    ks = jax.random.split(KEY, 3)
    q = _rand((b, h, hd), dtype, ks[0])
    kc = _rand((b, s, kv, hd), dtype, ks[1])
    vc = _rand((b, s, kv, hd), dtype, ks[2])
    kv_pos = jnp.tile(jnp.arange(s)[None], (b, 1))
    cur = jnp.asarray(np.random.default_rng(0).integers(1, s, b))
    out = ops.decode_attention(q, kc, vc, kv_pos, cur, window=window, bk=bk)
    valid = (kv_pos >= 0) & (kv_pos <= cur[:, None])
    if window:
        valid &= kv_pos > cur[:, None] - window
    bias = jnp.where(valid, 0.0, -1e30)
    want = ref.decode_attention_ref(q, kc, vc, bias)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("m,k,n,bm", [(100, 200, 300, 64), (128, 128, 128, 128),
                                      (17, 333, 65, 32)])
def test_int8_matmul(m, k, n, bm):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (m, k))
    w = jax.random.normal(ks[1], (k, n))
    xq, sx = ref.quantize_ref(x)
    wq, sw = ref.quantize_ref(w, axis=0)
    out = ops.int8_matmul(xq, sx, wq, sw, bm=bm, bn=64, bk=64)
    want = ref.int8_matmul_ref(xq, sx, wq, sw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-4, rtol=1e-5)


def test_int8_quant_error_bound():
    x = jax.random.normal(KEY, (64, 512))
    w = jax.random.normal(jax.random.PRNGKey(1), (512, 256))
    xq, sx = ref.quantize_ref(x)
    wq, sw = ref.quantize_ref(w, axis=0)
    approx = ops.int8_matmul(xq, sx, wq, sw)
    exact = x @ w
    rel = float(jnp.linalg.norm(approx - exact) / jnp.linalg.norm(exact))
    assert rel < 0.02, rel    # int8 symmetric quant keeps ~1% error here


@pytest.mark.parametrize("bt,s,di,n,bd", [(2, 64, 96, 16, 32),
                                          (1, 128, 64, 8, 64),
                                          (3, 37, 48, 16, 16)])
def test_selective_scan(bt, s, di, n, bd):
    ks = jax.random.split(KEY, 5)
    u = jax.random.normal(ks[0], (bt, s, di)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bt, s, di))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (di, n)) * 0.3)
    B = jax.random.normal(ks[3], (bt, s, n))
    C = jax.random.normal(ks[4], (bt, s, n))
    D = jnp.ones((di,))
    y, h = ops.selective_scan(u, dt, A, B, C, D, bd=bd)
    y2, h2 = ref.selective_scan_ref(u, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h2), atol=1e-4)


def test_assoc_scan_matches_sequential_oracle():
    """models/mamba.py's associative scan == ref.py's sequential scan."""
    from repro.models.mamba import selective_scan_ref as assoc
    ks = jax.random.split(KEY, 5)
    bt, s, di, n = 2, 50, 32, 8
    u = jax.random.normal(ks[0], (bt, s, di)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bt, s, di))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (di, n)) * 0.3)
    B = jax.random.normal(ks[3], (bt, s, n))
    C = jax.random.normal(ks[4], (bt, s, n))
    D = jnp.ones((di,))
    y1, h1 = assoc(u, dt, A, B, C, D)
    y2, h2 = ref.selective_scan_ref(u, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4)
