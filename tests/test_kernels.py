"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (ref.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _rand(shape, dtype, key):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,sq,skv,h,kv,hd,causal,window", [
    (2, 128, 128, 8, 2, 64, True, 0),
    (1, 100, 100, 4, 4, 32, True, 48),     # ragged + sliding window
    (2, 64, 192, 6, 3, 128, False, 0),     # cross attention
    (1, 256, 256, 2, 1, 256, True, 0),     # MQA, big head
    (3, 33, 65, 5, 5, 16, True, 0),        # odd everything
])
def test_flash_attention(dtype, b, sq, skv, h, kv, hd, causal, window):
    ks = jax.random.split(KEY, 3)
    q = _rand((b, sq, h, hd), dtype, ks[0])
    k = _rand((b, skv, kv, hd), dtype, ks[1])
    v = _rand((b, skv, kv, hd), dtype, ks[2])
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              bq=32, bk=32)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kv,hd,s,window,bk", [
    (3, 8, 2, 64, 300, 64, 128),
    (1, 16, 16, 128, 1024, 0, 256),
    (2, 4, 1, 32, 96, 0, 32),
])
def test_decode_attention(dtype, b, h, kv, hd, s, window, bk):
    ks = jax.random.split(KEY, 3)
    q = _rand((b, h, hd), dtype, ks[0])
    kc = _rand((b, s, kv, hd), dtype, ks[1])
    vc = _rand((b, s, kv, hd), dtype, ks[2])
    kv_pos = jnp.tile(jnp.arange(s)[None], (b, 1))
    cur = jnp.asarray(np.random.default_rng(0).integers(1, s, b))
    out = ops.decode_attention(q, kc, vc, kv_pos, cur, window=window, bk=bk)
    valid = (kv_pos >= 0) & (kv_pos <= cur[:, None])
    if window:
        valid &= kv_pos > cur[:, None] - window
    bias = jnp.where(valid, 0.0, -1e30)
    want = ref.decode_attention_ref(q, kc, vc, bias)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("m,k,n,bm", [(100, 200, 300, 64), (128, 128, 128, 128),
                                      (17, 333, 65, 32)])
def test_int8_matmul(m, k, n, bm):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (m, k))
    w = jax.random.normal(ks[1], (k, n))
    xq, sx = ref.quantize_ref(x)
    wq, sw = ref.quantize_ref(w, axis=0)
    out = ops.int8_matmul(xq, sx, wq, sw, bm=bm, bn=64, bk=64)
    want = ref.int8_matmul_ref(xq, sx, wq, sw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-4, rtol=1e-5)


def test_int8_quant_error_bound():
    x = jax.random.normal(KEY, (64, 512))
    w = jax.random.normal(jax.random.PRNGKey(1), (512, 256))
    xq, sx = ref.quantize_ref(x)
    wq, sw = ref.quantize_ref(w, axis=0)
    approx = ops.int8_matmul(xq, sx, wq, sw)
    exact = x @ w
    rel = float(jnp.linalg.norm(approx - exact) / jnp.linalg.norm(exact))
    assert rel < 0.02, rel    # int8 symmetric quant keeps ~1% error here


@pytest.mark.parametrize("bt,s,di,n,bd", [(2, 64, 96, 16, 32),
                                          (1, 128, 64, 8, 64),
                                          (3, 37, 48, 16, 16)])
def test_selective_scan(bt, s, di, n, bd):
    ks = jax.random.split(KEY, 5)
    u = jax.random.normal(ks[0], (bt, s, di)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bt, s, di))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (di, n)) * 0.3)
    B = jax.random.normal(ks[3], (bt, s, n))
    C = jax.random.normal(ks[4], (bt, s, n))
    D = jnp.ones((di,))
    y, h = ops.selective_scan(u, dt, A, B, C, D, bd=bd)
    y2, h2 = ref.selective_scan_ref(u, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h2), atol=1e-4)


def test_assoc_scan_matches_sequential_oracle():
    """models/mamba.py's associative scan == ref.py's sequential scan."""
    from repro.models.mamba import selective_scan_ref as assoc
    ks = jax.random.split(KEY, 5)
    bt, s, di, n = 2, 50, 32, 8
    u = jax.random.normal(ks[0], (bt, s, di)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bt, s, di))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (di, n)) * 0.3)
    B = jax.random.normal(ks[3], (bt, s, n))
    C = jax.random.normal(ks[4], (bt, s, n))
    D = jnp.ones((di,))
    y1, h1 = assoc(u, dt, A, B, C, D)
    y2, h2 = ref.selective_scan_ref(u, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4)


# ------------------------------------------------ fused tabular RL --------
ALPHA, GAMMA = 0.9, 0.1


def _naive_tabular(q, s, a, r, s2, alpha=ALPHA, gamma=GAMMA):
    """The legacy unfused composition (population.py's xla step +
    next-step gather/argmax), the semantic oracle for the fused op."""
    cells = jnp.arange(q.shape[0])
    td = r + gamma * q[cells, s2].max(-1) - q[cells, s, a]
    q_new = q.at[cells, s, a].add(alpha * td)
    greedy2 = q_new[cells, s2].argmax(-1).astype(jnp.int32)
    return q_new, greedy2, td


def _tabular_case(cells, states=9, k=10, seed=0, ties=False):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (cells, states, k), jnp.float32)
    if ties:                       # constant rows force argmax tie-breaks
        q = q.at[:, :, :].set(jnp.round(q * 2.0) / 2.0)
        q = q.at[0].set(1.0)
    s = jax.random.randint(ks[1], (cells,), 0, states).astype(jnp.int32)
    a = jax.random.randint(ks[2], (cells,), 0, k).astype(jnp.int32)
    s2 = jax.random.randint(ks[3], (cells,), 0, states).astype(jnp.int32)
    # half the fleet lands on s2 == s: the fused path's hard case (the
    # freshly written entry participates in the next greedy)
    s2 = jnp.where(jnp.arange(cells) % 2 == 0, s, s2)
    r = -jax.random.uniform(ks[4], (cells,), jnp.float32)
    return q, s, a, r, s2


@pytest.mark.parametrize("cells,ties", [(1, False), (13, False),
                                        (64, False), (37, True)])
def test_fused_tabular_ref_matches_naive_composition(cells, ties):
    """The 2-reduce fused formulation is BIT-identical to the legacy
    gather/max/scatter/argmax chain — q, TD error, and next greedy,
    including forced-tie rows (first-index tie-break)."""
    q, s, a, r, s2 = _tabular_case(cells, ties=ties)
    q1, g1, td1 = _naive_tabular(q, s, a, r, s2)
    q2, g2, td2 = ref.fused_tabular_ref(q, s, a, r, s2, alpha=ALPHA,
                                        gamma=GAMMA)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))
    np.testing.assert_array_equal(np.asarray(td1), np.asarray(td2))


@pytest.mark.parametrize("cells,bc", [(1, 8), (13, 8), (37, 8), (64, 16)])
def test_tabular_kernel_parity(cells, bc):
    """Pallas kernel (interpret mode; non-block-multiple shapes exercise
    the padding) vs the jnp oracle: integer leaves (greedy) and the
    untouched Q entries bit-exact; touched floats allclose (the kernel
    lowering may contract the TD fma differently)."""
    q, s, a, r, s2 = _tabular_case(cells, seed=cells)
    want_q, want_g, want_td = ref.fused_tabular_ref(
        q, s, a, r, s2, alpha=ALPHA, gamma=GAMMA)
    got_q, got_g, got_td = ops.fused_tabular_update(
        q, s, a, r, s2, alpha=ALPHA, gamma=GAMMA, impl="pallas", bc=bc,
        interpret=True)
    np.testing.assert_array_equal(np.asarray(want_g), np.asarray(got_g))
    touched = np.zeros(q.shape, bool)
    touched[np.arange(cells), np.asarray(s), np.asarray(a)] = True
    np.testing.assert_array_equal(np.asarray(got_q)[~touched],
                                  np.asarray(q)[~touched])
    np.testing.assert_allclose(np.asarray(got_q), np.asarray(want_q),
                               atol=1e-6, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(got_td), np.asarray(want_td),
                               atol=1e-5, rtol=1e-5)


def test_tabular_kernel_tie_break_first_index():
    """All-equal Q rows: the kernel's greedy must reproduce jnp.argmax's
    first-index tie-break bit-exactly through the padded dispatch."""
    q, s, a, r, s2 = _tabular_case(13, ties=True)
    q = jnp.zeros_like(q)          # every row fully tied
    _, want_g, _ = _naive_tabular(q, s, a, r, s2)
    _, got_g, _ = ops.fused_tabular_update(
        q, s, a, r, s2, alpha=ALPHA, gamma=GAMMA, impl="pallas", bc=8,
        interpret=True)
    np.testing.assert_array_equal(np.asarray(want_g), np.asarray(got_g))


def test_resolve_rl_impl_gating():
    assert ops.resolve_rl_impl("xla") == "xla"
    assert ops.resolve_rl_impl("ref") == "ref"
    assert ops.resolve_rl_impl("pallas_interpret") == "pallas_interpret"
    # GSPMD cannot partition pallas_call: a mesh forces the fused-jnp ref
    assert ops.resolve_rl_impl("pallas", mesh=object()) == "ref"
    assert ops.resolve_rl_impl("pallas") in ("pallas", "ref")
    with pytest.raises(ValueError, match="unknown impl"):
        ops.resolve_rl_impl("cuda")
    with pytest.raises(ValueError, match="no fused op path"):
        ops.rl_op_kwargs("xla")


# ------------------------------------------------- fused DQN head ---------
def _dqn_params(users, hidden=16, seed=0, n_act=10):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    dims = [11, hidden, hidden, n_act]
    return [{"w": jax.random.normal(ks[2 * i], (dims[i], dims[i + 1]),
                                    jnp.float32) * 0.3,
             "b": jax.random.normal(ks[2 * i + 1], (dims[i + 1],),
                                    jnp.float32) * 0.1}
            for i in range(3)]


def _dqn_case(cells, users, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed + 100), 4)
    mem = (jax.random.uniform(ks[0], (cells, users)) < 0.8)
    mem = mem.at[:, 0].set(True)          # never an empty cell
    act = mem & (jax.random.uniform(ks[1], (cells, users)) < 0.7)
    end_b = (jax.random.uniform(ks[2], (cells, users)) < 0.5)
    agg = jax.random.normal(ks[3], (cells, 8), jnp.float32)
    from repro.fleet import dynamics
    acc_table = jnp.asarray(dynamics.accuracies(np.arange(10)),
                            jnp.float32)
    return (act.astype(jnp.float32), mem.astype(jnp.float32),
            end_b.astype(jnp.float32), agg, acc_table)


@pytest.mark.parametrize("cells,users,threshold,bc", [
    (1, 2, 0.0, 16), (37, 3, 0.0, 16),
    (1, 2, 85.0, 16), (37, 3, 85.0, 16),
    (64, 2, 85.0, 64),
    (13, 3, 101.0, 16),       # infeasible goal: every cell falls back
])
def test_dqn_head_kernel_parity(cells, users, threshold, bc):
    """Fused head kernel vs the jnp oracle across the constraint
    regimes (off / active / infeasible-fallback), with padding."""
    act, mem, end_b, agg, acc_table = _dqn_case(cells, users, seed=cells)
    params = _dqn_params(users, seed=users)
    allowed = jnp.ones((users, 10), jnp.float32)
    kw = dict(threshold=threshold, topk=3)
    want_d, want_q = ops.dqn_head(act, mem, end_b, agg, params, allowed,
                                  acc_table, impl="ref", **kw)
    got_d, got_q = ops.dqn_head(act, mem, end_b, agg, params, allowed,
                                acc_table, impl="pallas", bc=bc,
                                interpret=True, **kw)
    np.testing.assert_array_equal(np.asarray(want_d), np.asarray(got_d))
    np.testing.assert_allclose(np.asarray(want_q), np.asarray(got_q),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("threshold", [0.0, 85.0])
def test_dqn_head_masked_rows_parity(threshold):
    """Sparse allowed-action masks — one user with fewer allowed actions
    than topk (exhausted top-k rows) and one all-masked user — keep the
    kernel bit-identical to the oracle on decisions."""
    cells, users = 29, 3
    act, mem, end_b, agg, acc_table = _dqn_case(cells, users, seed=7)
    params = _dqn_params(users, seed=3)
    allowed = np.ones((users, 10), np.float32)
    allowed[0, 2:] = 0.0          # 2 allowed < topk=3: exhausted rows
    allowed[1, :] = 0.0           # all-masked user
    allowed = jnp.asarray(allowed)
    kw = dict(threshold=threshold, topk=3)
    want_d, want_q = ops.dqn_head(act, mem, end_b, agg, params, allowed,
                                  acc_table, impl="ref", **kw)
    got_d, got_q = ops.dqn_head(act, mem, end_b, agg, params, allowed,
                                acc_table, impl="pallas", bc=16,
                                interpret=True, **kw)
    np.testing.assert_array_equal(np.asarray(want_d), np.asarray(got_d))
    np.testing.assert_allclose(np.asarray(want_q), np.asarray(got_q),
                               atol=1e-5, rtol=1e-5)


def test_dqn_head_infeasible_falls_back_to_plain_argmax():
    act, mem, end_b, agg, acc_table = _dqn_case(17, 2, seed=5)
    params = _dqn_params(2, seed=5)
    allowed = jnp.ones((2, 10), jnp.float32)
    dec, q = ops.dqn_head(act, mem, end_b, agg, params, allowed,
                          acc_table, threshold=101.0, topk=3, impl="ref")
    np.testing.assert_array_equal(np.asarray(dec),
                                  np.asarray(ref.first_argmax_ref(q)))


# ---------------------------------------------- hypothesis properties -----
def test_property_fused_tabular_preserves_untouched_entries():
    """Fused update may only write the (cell, s, a) scatter targets —
    every other Q entry must come back bit-identical."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(1, 33))
    def prop(seed, cells):
        q, s, a, r, s2 = _tabular_case(cells, seed=seed % 10_000)
        q_new, _, _ = ops.fused_tabular_update(
            q, s, a, r, s2, alpha=ALPHA, gamma=GAMMA, impl="ref")
        touched = np.zeros(q.shape, bool)
        touched[np.arange(cells), np.asarray(s), np.asarray(a)] = True
        np.testing.assert_array_equal(np.asarray(q_new)[~touched],
                                      np.asarray(q)[~touched])

    prop()


def test_property_dqn_head_respects_allowed_mask():
    """The constraint head never emits an action outside a member
    user's allowed set (when that user has any allowed action at all),
    at any threshold — the PR-2 constraint-leak invariant."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(1, 17),
           st.integers(2, 3), st.sampled_from([0.0, 85.0]))
    def prop(seed, cells, users, threshold):
        act, mem, end_b, agg, acc_table = _dqn_case(cells, users,
                                                    seed=seed % 10_000)
        params = _dqn_params(users, seed=seed % 97)
        rng = np.random.default_rng(seed)
        allowed = (rng.random((users, 10)) < 0.6)
        allowed[:, 0] = True          # every user keeps >= 1 action
        dec, _ = ops.dqn_head(act, mem, end_b, agg, params,
                              jnp.asarray(allowed, jnp.float32),
                              acc_table, threshold=threshold, topk=3,
                              impl="ref")
        dec = np.asarray(dec)
        member = np.asarray(mem) > 0.5
        assert allowed[np.arange(users)[None, :], dec][member].all()

    prop()
