"""Async serving bridge (ISSUE-9): conservation identities under
overload, deadline-aware admission, timeout/reroute fault injection,
drain-timeout flush, and the route(bridge=...) end-to-end path against
real engines."""
import time

import numpy as np
import pytest

from repro.obs.spans import SpanRecorder, validate_chrome_trace
from repro.serving import BridgeConfig, Request, ServingBridge


class StubEngine:
    """serve_batch-compatible stand-in: stamps the same fields as
    ``ServingEngine.serve_batch`` without a model. ``wall_s`` holds the
    engine busy so queues back up deterministically."""

    def __init__(self, wall_s: float = 0.0):
        self.wall_s = wall_s
        self.calls = 0

    def serve_batch(self, reqs, toks, spans=None, t_drain=None):
        self.calls += 1
        if self.wall_s:
            time.sleep(self.wall_s)
        t_drain = time.perf_counter() if t_drain is None else t_drain
        raw = max(self.wall_s, 1e-4)
        for i, r in enumerate(reqs):
            r.output = np.asarray(toks[i][:1])
            r.response_time = raw
            r.queue_time = max(0.0, t_drain - r.arrival_time)
            r.serve_time = raw
            r.deadline_met = \
                (r.queue_time + r.response_time) * 1e3 <= r.deadline_ms
        return reqs


def _req(rid, **kw):
    return Request(rid=rid, prompt=np.arange(4, dtype=np.int32),
                   max_new_tokens=1, **kw)


def _assert_conserved(st):
    assert st["submitted"] == st["admitted"] + st["shed"]["overflow"] \
        + st["shed"]["deadline"]
    assert st["served"] + st["shed"]["total"] == st["submitted"]
    assert len(st["shed_requests"]) == st["shed"]["total"]


def test_bridge_overload_sheds_and_conserves():
    """A bounded queue under overload sheds instead of growing; every
    counter balances and every shed request is itemized."""
    eng = StubEngine(wall_s=0.05)
    cfg = BridgeConfig(max_batch=2, max_wait_ms=0.0, max_queue=4,
                       drain_timeout_s=30.0)
    with ServingBridge({"S": {"d0": eng}}, cfg) as br:
        for i in range(40):
            br.submit(_req(i), "S", "d0")
        assert br.drain()
        st = br.stats()
    assert st["submitted"] == 40
    assert st["shed"]["overflow"] > 0          # overload actually shed
    assert st["served"] == st["admitted"]      # clean drain: no leftovers
    _assert_conserved(st)
    assert all(s["reason"] == "overflow" for s in st["shed_requests"])
    # served requests carry e2e stamps (queue grows as the queue backs up)
    assert eng.calls >= st["served"] / cfg.max_batch


def test_bridge_deadline_admission():
    """A request whose SLO budget is exhausted at submit is shed as
    shed_deadline (False from submit), not queued."""
    with ServingBridge({"S": {"d0": StubEngine()}}, BridgeConfig()) as br:
        late = _req(0, deadline_ms=5.0,
                    arrival_time=time.perf_counter() - 1.0)  # 1000ms ago
        assert br.submit(late, "S", "d0") is False
        assert br.submit(_req(1, deadline_ms=1e6), "S", "d0") is True
        assert br.submit(_req(2), "S", "d0") is True          # inf deadline
        assert br.drain()
        st = br.stats()
    assert st["shed"]["deadline"] == 1 and st["served"] == 2
    _assert_conserved(st)
    assert st["shed_requests"][0] == {"rid": 0, "tier": "S",
                                      "variant": "d0", "reason": "deadline"}


def test_bridge_unknown_tier_raises():
    with ServingBridge({"S": {"d0": StubEngine()}}, BridgeConfig()) as br:
        with pytest.raises(KeyError):
            br.submit(_req(0), "E", "d0")


def test_bridge_timeout_reroutes_once_then_serves():
    """Fault injection: a hung tier's batch times out; its requests are
    rerouted once to the fallback tier, served there, and every event
    lands in the span stream."""
    spans = SpanRecorder()
    hung, fast = StubEngine(wall_s=1.0), StubEngine()
    cfg = BridgeConfig(max_batch=4, max_wait_ms=0.0, engine_timeout_s=0.1)
    with ServingBridge({"S": {"d0": hung}, "E": {"d0": fast}}, cfg,
                       spans=spans) as br:
        for i in range(3):
            br.submit(_req(i), "S", "d0")
        assert br.drain()
        st = br.stats()
    assert st["timeouts"] >= 1 and st["rerouted"] == 3
    assert st["served"] == 3 and st["shed"]["total"] == 0
    _assert_conserved(st)
    # rerouted requests were served by the fallback engine
    assert fast.calls >= 1
    names = {e["name"] for e in spans.events}
    assert {"bridge.timeout", "bridge.reroute"} <= names
    validate_chrome_trace(spans.chrome_trace())


def test_bridge_timeout_sheds_without_fallback():
    """The same fault with rerouting disabled: requests shed as
    shed_timeout and the drain still completes."""
    spans = SpanRecorder()
    cfg = BridgeConfig(max_batch=4, max_wait_ms=0.0, engine_timeout_s=0.1,
                       reroute={})
    with ServingBridge({"S": {"d0": StubEngine(wall_s=1.0)}}, cfg,
                       spans=spans) as br:
        for i in range(3):
            br.submit(_req(i), "S", "d0")
        assert br.drain()
        st = br.stats()
    assert st["shed"]["timeout"] == 3 and st["served"] == 0
    _assert_conserved(st)
    assert {e["name"] for e in spans.events} >= {"bridge.timeout",
                                                "bridge.shed"}


def test_bridge_drain_timeout_flushes():
    """A drain past its budget flushes queued + in-flight requests as
    shed_drain (returns False) so the identities still balance."""
    cfg = BridgeConfig(max_batch=2, max_wait_ms=0.0, engine_timeout_s=30.0)
    with ServingBridge({"S": {"d0": StubEngine(wall_s=2.0)}}, cfg) as br:
        for i in range(6):
            br.submit(_req(i), "S", "d0")
        assert br.drain(timeout_s=0.2) is False
        st = br.stats()
    assert st["shed"]["drain"] > 0 and st["served"] == 0
    _assert_conserved(st)


def test_bridge_oversize_submit_splits_batches():
    """More queued requests than max_batch split into several engine
    calls (RequestBatcher.pack), never truncate."""
    eng = StubEngine(wall_s=0.01)
    cfg = BridgeConfig(max_batch=3, max_wait_ms=50.0, max_queue=64)
    with ServingBridge({"S": {"d0": eng}}, cfg) as br:
        for i in range(8):
            br.submit(_req(i), "S", "d0")
        assert br.drain()
        st = br.stats()
    assert st["served"] == 8
    _assert_conserved(st)
    assert all(b["requests"] <= cfg.max_batch for b in br.batch_log)
    assert sum(b["requests"] for b in br.batch_log) == 8


def test_route_bridge_reuse_per_call_accounting():
    """A ServingBridge reused across route() calls accounts each call
    separately: served/batches/compute are per call, not cumulative."""
    from types import SimpleNamespace

    import jax

    from repro.fleet import FleetConfig, init_fleet
    from repro.fleet.api import FleetOrchestrator, StaticPolicy

    scen = init_fleet(jax.random.PRNGKey(0),
                      FleetConfig(cells=4, users=3, arrival_rate=None))
    n_active = int(np.asarray(scen.active).sum())
    eng = StubEngine(wall_s=0.01)
    eng.model = SimpleNamespace(cfg=SimpleNamespace(vocab_size=32))
    engines = {"S": {"d0": eng}}
    orch = FleetOrchestrator(StaticPolicy(3, "device"))
    with ServingBridge(engines, BridgeConfig(max_batch=4)) as br:
        r1 = orch.route(scen=scen, dispatch=engines, bridge=br,
                        max_new_tokens=1, batch_size=4)
        r2 = orch.route(scen=scen, dispatch=engines, bridge=br,
                        max_new_tokens=1, batch_size=4)
    for r in (r1, r2):
        assert len(r.served) == n_active
        per = r.timings["per_tier_variant"]["S/d0"]
        assert per["requests"] == n_active
        # per-call batches cover exactly this call's requests
        assert 1 <= r.batches <= -(-n_active // 4) + 1
    # cumulative bridge stats still conserve over BOTH calls
    st = r2.bridge
    assert st["submitted"] == 2 * n_active
    assert st["served"] + st["shed"]["total"] == st["submitted"]
