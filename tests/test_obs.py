"""repro.obs (ISSUE-6 + ISSUE-8): host-sync-free fleet telemetry.

Covers: MetricsAccumulator correctness against numpy, chunked-merge
equality (exact on integer leaves and extrema, ULP-tolerant on float
sums), histogram merge == concat-then-bin, both agents carrying the
accumulator inside their jitted scans (counts, epsilon decay, the
metrics=False escape hatch, and metrics-on/off training bit-identity),
SpanRecorder + Chrome trace-event schema validation, run manifests,
hot_edges in RouteResult.summary(), the end-to-end gap_breakdown
acceptance (both exact sum identities against a real ServingEngine
batch), and tools/obsview.py via subprocess.

ISSUE-8 (time-resolved telemetry): windowed ring leaves (slot
arithmetic, wrap, windows-on/off update equality on the shared
leaves), explicit underflow/overflow counters + the clipped-quantile
UserWarning regression, the two-source quantile agreement bound
(exact order statistics vs histogram midpoints within one bin width),
SLO attainment identities end-to-end against a real ServingEngine
(attained + violated == dispatched at every granularity, request.e2e
spans reproducing the served e2e stream, the slo.attainment counter
track), and obsview --timeline rendering.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fleet import (FleetConfig, FleetDQN, FleetDQNConfig,
                        FleetOrchestrator, FleetQLearning, SyntheticSource,
                        TraceSource, fleet_metrics, mixed_table5_fleet,
                        topology, train_against_oracle, with_topology)
from repro.obs import (MetricDef, MetricsAccumulator, SpanRecorder,
                       attach_manifest, config_hash, run_manifest, span,
                       validate_chrome_trace)

DATA = os.path.join(os.path.dirname(__file__), "data")
FIXTURE = os.path.join(DATA, "trace_small.npz")
ROOT = os.path.join(os.path.dirname(__file__), "..")


# ------------------------------------------------------------ metrics -----
def _acc():
    return MetricsAccumulator.create({
        "r": MetricDef(lo=-2.0, hi=0.0, bins=8, lanes=4),
        "eps": MetricDef(lo=0.0, hi=1.0, bins=4, lanes=1),
    })


def test_metrics_moments_match_numpy():
    rng = np.random.default_rng(0)
    acc = _acc()
    samples = []
    for _ in range(7):
        x = rng.uniform(-2.5, 0.5, size=(4,)).astype(np.float32)
        samples.append(x)
        acc = acc.update({"r": jnp.asarray(x)})
    flat = np.concatenate(samples).astype(np.float64)
    s = acc.summary()["r"]
    assert s["count"] == flat.size and s["lanes"] == 4
    assert s["mean"] == pytest.approx(flat.mean(), rel=1e-6)
    assert s["std"] == pytest.approx(flat.std(), rel=1e-5)
    assert s["min"] == pytest.approx(flat.min(), rel=1e-6)
    assert s["max"] == pytest.approx(flat.max(), rel=1e-6)
    # out-of-range values clipped into edge bins, mass conserved
    assert sum(s["hist"]) == s["count"]
    assert len(s["edges"]) == 8 + 1


def test_metrics_histogram_merge_equals_concat_then_bin():
    rng = np.random.default_rng(1)
    xs = rng.uniform(-2.2, 0.2, size=(10, 4)).astype(np.float32)
    a, b = _acc(), _acc()
    for x in xs[:6]:
        a = a.update({"r": jnp.asarray(x)})
    for x in xs[6:]:
        b = b.update({"r": jnp.asarray(x)})
    merged = a.merge(b).summary()["r"]
    ref, _ = np.histogram(np.clip(xs.ravel(), -2.0, np.nextafter(0.0, -1)),
                          bins=8, range=(-2.0, 0.0))
    np.testing.assert_array_equal(merged["hist"], ref)


def test_metrics_chunked_merge_matches_single_stream():
    """merge(chunk1, chunk2) == one stream: exact on count/hist/extrema,
    reassociation-ULP close on the float sums (the CHANGES.md caveat)."""
    rng = np.random.default_rng(2)
    xs = [rng.uniform(-2.0, 0.0, size=(4,)).astype(np.float32)
          for _ in range(9)]
    one = _acc()
    for x in xs:
        one = one.update({"r": jnp.asarray(x)})
    a, b = _acc(), _acc()
    for x in xs[:4]:
        a = a.update({"r": jnp.asarray(x)})
    for x in xs[4:]:
        b = b.update({"r": jnp.asarray(x)})
    m, o = a.merge(b).data["r"], one.data["r"]
    for leaf in ("count", "hist", "mn", "mx"):
        np.testing.assert_array_equal(np.asarray(m[leaf]),
                                      np.asarray(o[leaf]))
    for leaf in ("total", "sumsq"):
        np.testing.assert_allclose(np.asarray(m[leaf]),
                                   np.asarray(o[leaf]), rtol=1e-6)


def test_metrics_jit_update_matches_eager():
    """The scan-carry usage: updates inside jit produce the same leaves
    as eager updates — including donation-friendly structure stability
    when only a subset of metrics is named."""
    x = jnp.asarray([-0.5, -1.0, -1.5, -0.25], jnp.float32)

    def once(acc):
        return acc.update({"r": x})        # 'eps' passes through

    eager = once(_acc())
    jitted = jax.jit(once)(_acc())
    for leaf in ("count", "total", "sumsq", "mn", "mx", "hist"):
        np.testing.assert_array_equal(np.asarray(eager.data["r"][leaf]),
                                      np.asarray(jitted.data["r"][leaf]))
    # untouched metric is bit-identical to the fresh one
    np.testing.assert_array_equal(np.asarray(jitted.data["eps"]["count"]),
                                  np.zeros(1, np.int32))


def test_metrics_lane_means_and_empty_summary():
    acc = _acc()
    s = acc.summary()["r"]
    assert s["count"] == 0 and s["mean"] is None and s["min"] is None
    acc = acc.update({"r": jnp.asarray([1.0, 2.0, 3.0, 4.0])})
    lm = acc.lane_means("r")
    np.testing.assert_allclose(lm, [1.0, 2.0, 3.0, 4.0])
    assert np.isnan(acc.lane_means("eps")).all()


def test_metrics_errors():
    with pytest.raises(ValueError, match="hi > lo"):
        MetricDef(lo=1.0, hi=1.0)
    with pytest.raises(ValueError, match="bins"):
        MetricDef(bins=0)
    acc = _acc()
    with pytest.raises(KeyError, match="unknown metric"):
        acc.update({"nope": jnp.zeros(4)})
    with pytest.raises(ValueError, match="lanes"):
        acc.update({"r": jnp.zeros(3)})     # 3 does not split into 4 lanes
    other = MetricsAccumulator.create({"r": MetricDef(lanes=4)})
    with pytest.raises(ValueError, match="different specs"):
        acc.merge(other)


# ----------------------------------------------- agents carry metrics -----
def test_qlearning_records_metrics_in_scan():
    src = TraceSource.load(FIXTURE)
    agent = FleetQLearning(src, seed=0)
    steps = 2 * src.horizon
    agent.run(steps)
    s = agent.metrics_summary()
    assert s["reward"]["count"] == src.cells * steps
    assert s["epsilon"]["count"] == steps            # one lane, one obs/step
    assert -2.5 <= s["reward"]["min"] <= s["reward"]["max"] <= 0.0
    # epsilon decays monotonically: max is the first value, min the last
    assert s["epsilon"]["max"] > s["epsilon"]["min"]
    assert sum(s["reward"]["hist"]) == s["reward"]["count"]
    assert agent.metrics.lane_means("reward").shape == (src.cells,)


def test_dqn_records_metrics_including_replay_fill():
    cfg = FleetConfig(cells=8, users=2, arrival_rate=1.0)
    agent = FleetDQN(SyntheticSource(cfg), cfg=FleetDQNConfig(), seed=0)
    agent.run(30)
    s = agent.metrics_summary()
    assert s["reward"]["count"] == 8 * 30
    assert s["loss"]["count"] == 30
    assert 0.0 < s["replay_fill"]["max"] <= 1.0
    assert s["replay_fill"]["min"] <= s["replay_fill"]["max"]  # fills up


def test_metrics_off_is_bit_identical_training():
    """The accumulator consumes no RNG and feeds nothing back: training
    with metrics=False is bit-identical, and metrics_summary is None."""
    src = SyntheticSource(FleetConfig(cells=8, users=2, arrival_rate=1.0))
    a = FleetQLearning(src, seed=4)
    b = FleetQLearning(src, seed=4, metrics=False)
    a.run(30)
    b.run(30)
    assert b.metrics is None and b.metrics_summary() is None
    np.testing.assert_array_equal(np.asarray(a.q), np.asarray(b.q))
    np.testing.assert_array_equal(np.asarray(a.counts),
                                  np.asarray(b.counts))
    da = FleetDQN(src, cfg=FleetDQNConfig(), seed=4)
    db = FleetDQN(src, cfg=FleetDQNConfig(), seed=4, metrics=False)
    da.run(25)
    db.run(25)
    assert db.metrics_summary() is None
    for la, lb in zip(jax.tree_util.tree_leaves(da.params),
                      jax.tree_util.tree_leaves(db.params)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_fleet_metrics_factory_shapes():
    m = fleet_metrics(16, "tabular")
    assert m.defs["reward"].lanes == 16 and "loss" not in m.defs
    m = fleet_metrics(16, "dqn")
    assert {"loss", "replay_fill"} <= set(m.defs)
    with pytest.raises(ValueError, match="kind"):
        fleet_metrics(16, "nope")


def test_train_against_oracle_attaches_manifest():
    src = TraceSource.load(FIXTURE)
    agent = FleetQLearning(src, seed=0)
    res = train_against_oracle(agent, max_steps=src.horizon,
                               check_every=src.horizon)
    m = res.manifest
    assert m["schema"] == "repro.obs/manifest-v1"
    assert m["jax_version"] == jax.__version__
    assert m["steps"] == agent.steps > 0
    assert m["wall_seconds"] == pytest.approx(res.wall_seconds)


# -------------------------------------------------------------- spans -----
def test_span_recorder_nesting_and_durations():
    rec = SpanRecorder()
    with rec.span("outer", kind="test"):
        with rec.span("inner"):
            pass
    rec.instant("marker", note="hi")
    rec.counter("queue", depth=3)
    names = [e["name"] for e in rec.events]
    assert names == ["inner", "outer", "marker", "queue"]  # close order
    outer = next(e for e in rec.events if e["name"] == "outer")
    inner = next(e for e in rec.events if e["name"] == "inner")
    assert outer["ts"] <= inner["ts"]
    assert outer["dur"] >= inner["dur"]
    assert outer["args"] == {"kind": "test"}
    assert rec.durations_ms("outer") and rec.durations_ms("nope") == []


def test_span_module_helper_none_recorder_is_noop():
    with span(None, "anything", x=1):
        pass                                         # no recorder, no-op
    rec = SpanRecorder()
    with span(rec, "real"):
        pass
    assert [e["name"] for e in rec.events] == ["real"]


def test_chrome_trace_save_validate_roundtrip(tmp_path):
    rec = SpanRecorder()
    with rec.span("a", obj=object()):                # non-json arg -> str
        pass
    path = rec.save(str(tmp_path / "t.json"), manifest=run_manifest())
    with open(path) as f:
        trace = json.load(f)
    validate_chrome_trace(trace)
    assert trace["displayTimeUnit"] == "ms"
    assert trace["otherData"]["schema"] == "repro.obs/manifest-v1"
    e = trace["traceEvents"][0]
    assert e["ph"] == "X" and e["ts"] >= 0 and e["dur"] >= 0
    assert isinstance(e["args"]["obj"], str)


def test_validate_chrome_trace_rejections():
    ok = {"traceEvents": [{"name": "x", "ph": "X", "ts": 0.0, "dur": 1.0,
                           "pid": 1, "tid": 1}]}
    validate_chrome_trace(ok)
    with pytest.raises(ValueError, match="must be a dict"):
        validate_chrome_trace([])
    with pytest.raises(ValueError, match="must be a list"):
        validate_chrome_trace({"traceEvents": {}})
    bad = {"traceEvents": [{"ph": "X", "ts": 0.0}]}
    with pytest.raises(ValueError, match="name"):
        validate_chrome_trace(bad)
    bad = {"traceEvents": [{"name": "x", "ph": "Z", "ts": 0.0,
                            "pid": 1, "tid": 1}]}
    with pytest.raises(ValueError, match="bad phase"):
        validate_chrome_trace(bad)
    bad = {"traceEvents": [{"name": "x", "ph": "X", "ts": -1.0, "dur": 1.0,
                            "pid": 1, "tid": 1}]}
    with pytest.raises(ValueError, match="ts"):
        validate_chrome_trace(bad)
    bad = {"traceEvents": [{"name": "x", "ph": "X", "ts": 0.0,
                            "pid": 1, "tid": 1}]}
    with pytest.raises(ValueError, match="dur"):
        validate_chrome_trace(bad)


# ----------------------------------------------------------- manifest -----
def test_run_manifest_keys_and_config_hash():
    m = run_manifest(config=FleetConfig(cells=4, users=2), extra_key=7)
    assert m["schema"] == "repro.obs/manifest-v1"
    assert m["backend"] == jax.default_backend()
    assert m["device_count"] == jax.device_count()
    assert m["extra_key"] == 7
    assert len(m["config_hash"]) == 16
    # hash is deterministic and config-sensitive
    assert config_hash(FleetConfig(cells=4, users=2)) == m["config_hash"]
    assert config_hash(FleetConfig(cells=5, users=2)) != m["config_hash"]
    assert config_hash({"b": 1, "a": 2}) == config_hash({"a": 2, "b": 1})


def test_attach_manifest_does_not_mutate():
    payload = {"x": 1}
    out = attach_manifest(payload, wall_seconds=1.0)
    assert "manifest" not in payload
    assert out["x"] == 1 and out["manifest"]["wall_seconds"] == 1.0


# ------------------------------------------- hot edges + gap breakdown ----
def _trained_topo_agent():
    scen = with_topology(mixed_table5_fleet(jax.random.PRNGKey(0), 12, 2),
                         topology.hot_edge_topology(12, 4))
    cfg = FleetConfig(cells=12, users=2, arrival_rate=1.5, n_edges=4)
    agent = FleetQLearning(SyntheticSource(cfg, scen=scen), seed=0)
    agent.run(40)
    return agent


def test_hot_edges_in_summary():
    """Satellite: route everything to the edge tier over a
    hot_edge_topology — half the fleet shares edge 0, so edge 0 is the
    unique utilization peak; the hot set follows the threshold."""
    from repro.fleet.api import StaticPolicy
    scen = with_topology(mixed_table5_fleet(jax.random.PRNGKey(0), 12, 2),
                         topology.hot_edge_topology(12, 4))
    orch = FleetOrchestrator(StaticPolicy(users=2, strategy="edge"))
    res = orch.route(scen=scen, with_edge_util=True, as_result=True,
                     hot_edge_util=0.5)
    util = np.asarray(res.edge_util)
    assert util.argmax() == 0                        # the hot edge
    s = res.summary()
    assert s["hot_edge_util"] == 0.5
    assert s["hot_edges"] == res.hot_edges
    assert res.hot_edges == [int(i) for i in np.nonzero(util >= 0.5)[0]]
    assert 0 in res.hot_edges
    # threshold above the peak -> empty hot set
    res2 = orch.route(scen=scen, with_edge_util=True, as_result=True,
                      hot_edge_util=float(util.max()) + 0.01)
    assert res2.summary()["hot_edges"] == []
    # a trained agent keeps the tuple contract, util values matching
    agent_orch = FleetOrchestrator(_trained_topo_agent())
    r3 = agent_orch.route(with_edge_util=True, as_result=True)
    dec, ids, util3 = agent_orch.route(with_edge_util=True)
    np.testing.assert_allclose(np.asarray(util3),
                               np.asarray(r3.edge_util))
    assert "hot_edges" not in agent_orch.route(as_result=True).summary()


def test_gap_breakdown_end_to_end_with_real_engines():
    """ISSUE-6 acceptance: gap_breakdown components sum to the measured
    wall time of a real engine batch — both identities exact."""
    from repro.launch.serve import build_engines, get_config
    src = TraceSource.load(FIXTURE)
    agent = FleetQLearning(src, seed=0)
    agent.run(src.horizon)
    engines = build_engines(get_config("edge-ladder"), variants=("d0",),
                            max_len=48)
    rec = SpanRecorder()
    res = FleetOrchestrator(agent).route(
        dispatch=engines, max_new_tokens=2, batch_size=4, prompt_len=8,
        spans=rec)
    gb = res.gap_breakdown()
    w = gb["wall_ms"]
    assert w["total"] == pytest.approx(
        w["batching"] + w["compute"] + w["dispatch"], abs=1e-6)
    assert w["dispatch"] >= 0.0
    pr = gb["per_request_ms"]
    assert pr["e2e"] == pytest.approx(pr["queueing"] + pr["compute"],
                                      abs=1e-6)
    assert gb["gap_x"] > 0.0
    assert gb["gap_components_x"]["e2e"] == pytest.approx(
        gb["gap_components_x"]["queueing"]
        + gb["gap_components_x"]["compute"], abs=1e-9)
    for tv in gb["per_tier_variant"].values():
        assert tv["gap_x"] > 0.0
    # per-request identity holds request by request, not just in the mean
    for r in res.served:
        assert r.queue_ms >= 0.0
        assert r.measured_ms >= 0.0
    # the spans cover the dispatch path
    names = {e["name"] for e in rec.events}
    assert {"route.decide", "route.dispatch", "dispatch.batch_build",
            "engine.generate", "engine.prefill",
            "engine.decode"} <= names
    assert any(n.startswith("dispatch.drain.") for n in names)
    validate_chrome_trace(rec.chrome_trace(run_manifest()))
    # summary carries the breakdown
    assert res.summary()["gap_breakdown"]["gap_x"] == gb["gap_x"]


def test_gap_breakdown_none_without_dispatch():
    orch = FleetOrchestrator(_trained_topo_agent())
    res = orch.route(as_result=True)
    assert res.gap_breakdown() is None
    assert "gap_breakdown" not in res.summary()


# ------------------------------------------------------------ obsview ----
def _run_obsview(*args):
    return subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "obsview.py"), *args],
        capture_output=True, text=True, timeout=60)


def test_obsview_show_and_diff(tmp_path):
    a = tmp_path / "a.json"
    b = tmp_path / "b.json"
    a.write_text(json.dumps(attach_manifest(
        {"x": 1.0, "nested": {"y": 2.0}}, wall_seconds=1.0)))
    b.write_text(json.dumps(attach_manifest(
        {"x": 1.5, "nested": {"y": 2.0}}, wall_seconds=2.0)))
    res = _run_obsview(str(a))
    assert res.returncode == 0, res.stderr
    assert "nested.y" in res.stdout and "jax" in res.stdout
    res = _run_obsview("--diff", str(a), str(b))
    assert res.returncode == 0, res.stderr
    assert "+50.0%" in res.stdout and "<--" in res.stdout
    assert "1 metric(s) moved" in res.stdout


# ------------------------------------------ ISSUE-8: windowed metrics -----
def test_windowed_ring_slots_wrap_and_series():
    """Slot arithmetic: updates land in (step // window_len) %
    n_windows; the ring wraps and summary()/window_series flag it."""
    acc = MetricsAccumulator.create(
        {"m": MetricDef(lo=0.0, hi=10.0, bins=4, lanes=2,
                        n_windows=3, window_len=2)})
    # 8 updates of a 3x2-step ring -> slots 0,0,1,1,2,2,0,0 (wrapped)
    for t in range(8):
        acc = acc.update({"m": jnp.asarray([float(t), float(t)])})
    d = acc.data["m"]
    np.testing.assert_array_equal(np.asarray(d["wcount"]),
                                  [[4, 4], [2, 2], [2, 2]])
    # slot 0 holds steps {0,1,6,7}: total 14, min 0, max 7 per lane
    np.testing.assert_allclose(np.asarray(d["wtotal"])[0], [14.0, 14.0])
    np.testing.assert_allclose(np.asarray(d["wmn"])[0], [0.0, 0.0])
    np.testing.assert_allclose(np.asarray(d["wmx"])[0], [7.0, 7.0])
    s = acc.summary()["m"]
    w = s["windows"]
    assert w["n_windows"] == 3 and w["window_len"] == 2
    assert w["count"] == [8, 4, 4]
    assert sum(w["count"]) == s["count"]
    assert w["wrapped"] is True
    assert w["last_slot"] == 0                       # step 7 -> slot 0
    from repro.obs import window_series
    rows = window_series(s)
    assert [r[0] for r in rows] == [0, 1, 2]
    assert rows[1] == (1, 4, pytest.approx(2.5), pytest.approx(2.0),
                       pytest.approx(3.0))
    # un-windowed stream: no windows block, empty series
    plain = _acc().summary()["r"]
    assert "windows" not in plain and window_series(plain) == []


def test_windowed_empty_slots_and_def_validation():
    acc = MetricsAccumulator.create(
        {"m": MetricDef(n_windows=4, window_len=5)})
    acc = acc.update({"m": jnp.asarray([0.5])})      # only slot 0 touched
    w = acc.summary()["m"]["windows"]
    assert w["count"] == [1, 0, 0, 0]
    assert w["mean"][1] is None and w["min"][1] is None
    assert w["wrapped"] is False and w["last_slot"] == 0
    with pytest.raises(ValueError, match="n_windows"):
        MetricDef(n_windows=-1)
    with pytest.raises(ValueError, match="n_windows"):
        MetricDef(n_windows=2, window_len=0)


def test_windowed_merge_and_chunked_step_clock():
    """Positional window merge (shard semantics) + the self-clock:
    chunked scans resume the SAME accumulator, so the step counter —
    and hence slot assignment — continues across chunks."""
    mk = lambda: MetricsAccumulator.create(  # noqa: E731
        {"m": MetricDef(lo=0.0, hi=1.0, bins=4, lanes=2,
                        n_windows=2, window_len=2)})

    @jax.jit
    def chunk(acc, xs):
        def body(c, x):
            return c.update({"m": x}), None
        acc, _ = jax.lax.scan(body, acc, xs)
        return acc

    xs = jnp.linspace(0.0, 1.0, 16).reshape(8, 2)
    whole = chunk(mk(), xs)
    split = chunk(chunk(mk(), xs[:3]), xs[3:])       # uneven chunks
    for la, lb in zip(jax.tree_util.tree_leaves(whole),
                      jax.tree_util.tree_leaves(split)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    assert int(whole.step) == 8
    # positional merge: counts add slot-by-slot, extrema min/max
    m = whole.merge(whole)
    np.testing.assert_array_equal(np.asarray(m.data["m"]["wcount"]),
                                  2 * np.asarray(whole.data["m"]["wcount"]))
    assert int(m.step) == 8                          # max, not sum


# ------------------- ISSUE-8: underflow/overflow + quantile agreement -----
def test_underflow_overflow_counts_and_quantile_warns():
    """Regression (edge-bin fix): out-of-range mass is COUNTED, not
    silently folded — and quantiles() warns when the bound is void."""
    acc = MetricsAccumulator.create(
        {"m": MetricDef(lo=0.0, hi=1.0, bins=4, lanes=1)})
    acc = acc.update({"m": jnp.asarray([-5.0, -1.0, 0.5, 2.0])})
    s = acc.summary()["m"]
    assert s["underflow"] == 2 and s["overflow"] == 1
    assert sum(s["hist"]) == s["count"] == 4         # mass still conserved
    with pytest.warns(UserWarning, match="underflow"):
        q = acc.quantiles("m")
    assert q["clipped"] and q["underflow"] == 2 and q["overflow"] == 1
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error")               # warn=False is silent
        q2 = acc.quantiles("m", warn=False)
    assert q2["p50"] == q["p50"]
    # in-range stream: no counts, no warning, clipped False
    clean = MetricsAccumulator.create(
        {"m": MetricDef(lo=0.0, hi=1.0, bins=4, lanes=1)})
    clean = clean.update({"m": jnp.asarray([0.1, 0.6])})
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        qc = clean.quantiles("m")
    assert not qc["clipped"]
    assert clean.summary()["m"]["underflow"] == 0


def test_exact_vs_hist_quantiles_within_bin_width():
    from repro.obs import timeline
    rng = np.random.default_rng(3)
    vals = rng.gamma(2.0, 200.0, size=500)           # skewed, latency-like
    acc = MetricsAccumulator.create(
        {"ms": MetricDef(lo=0.0, hi=float(vals.max()) + 1.0, bins=64)})
    for v in vals:
        acc = acc.update({"ms": jnp.asarray([v], jnp.float32)})
    exact = timeline.exact_quantiles(vals)
    hist = acc.quantiles("ms")
    assert not hist["clipped"] and hist["n"] == 500
    for k in ("p50", "p90", "p95", "p99"):
        assert abs(exact[k] - hist[k]) <= hist["bin_width"] + 1e-9, k
        # and the exact source really is an order statistic
        assert exact[k] in vals
    # empty + malformed inputs
    assert timeline.exact_quantiles([]) == {}
    assert timeline.hist_quantiles([0, 0], [0.0, 0.5, 1.0])["n"] == 0
    with pytest.raises(ValueError, match="len\\(hist\\)\\+1"):
        timeline.hist_quantiles([1, 2], [0.0, 1.0])
    att = timeline.attainment([1.0, 2.0, 3.0], 2.0)
    assert att == (2, 1) and sum(att) == 3


# --------------------------------- ISSUE-8: SLO through the bridge --------
def test_slo_end_to_end_with_real_engines():
    """ISSUE-8 acceptance: RouteResult.slo() satisfies attained +
    violated == dispatched per (tier, variant) against a REAL
    ServingEngine, the request.e2e spans reproduce the served e2e
    stream, and both quantile sources agree within one bin width."""
    from repro.launch.serve import build_engines, get_config
    src = TraceSource.load(FIXTURE)
    agent = FleetQLearning(src, seed=0)
    agent.run(src.horizon)
    engines = build_engines(get_config("edge-ladder"), variants=("d0",),
                            max_len=48)
    rec = SpanRecorder()
    res = FleetOrchestrator(agent).route(
        dispatch=engines, max_new_tokens=2, batch_size=4, prompt_len=8,
        spans=rec)
    slo = res.slo()
    n = slo["requests"]
    assert n == len(res.served) > 0
    from repro.fleet.dynamics import MAX_RESPONSE_MS
    assert slo["deadline_ms"] == MAX_RESPONSE_MS     # the QoS default
    for side in ("measured", "predicted"):
        assert slo[side]["attained"] + slo[side]["violated"] == n
        assert slo[side]["attainment"] == slo[side]["attained"] / n
    assert sum(tv["dispatched"]
               for tv in slo["per_tier_variant"].values()) == n
    for tv in slo["per_tier_variant"].values():
        assert tv["measured_attained"] + tv["measured_violated"] \
            == tv["dispatched"]
        assert tv["predicted_attained"] + tv["predicted_violated"] \
            == tv["dispatched"]
    # per-request stamps are scored, and e2e = queue + compute
    for r in res.served:
        assert r.deadline_met is not None
        assert r.deadline_met == (r.e2e_ms <= r.deadline_ms)
        assert r.e2e_ms == pytest.approx(r.queue_ms + r.measured_ms)
    # the request.e2e spans ARE the host-exact quantile source
    durs = np.sort(np.asarray(rec.durations_ms("request.e2e")))
    e2e = np.sort(np.asarray([r.e2e_ms for r in res.served]))
    assert durs.size == n
    np.testing.assert_allclose(durs, e2e, rtol=1e-6)
    from repro.obs import timeline
    assert slo["quantiles"]["exact_ms"] == timeline.exact_quantiles(e2e)
    # two-source agreement (guarded by the explicit clipped flag)
    hist = slo["quantiles"]["hist_ms"]
    if not hist["clipped"]:
        for k in ("p50", "p90", "p95", "p99"):
            assert abs(slo["quantiles"]["exact_ms"][k] - hist[k]) \
                <= hist["bin_width"] + 1e-9, k
    # the counter track rides the trace and ends at the final split
    cnt = [e for e in rec.events
           if e["ph"] == "C" and e["name"] == "slo.attainment"]
    assert cnt and cnt[-1]["args"]["attained"] == slo["measured"]["attained"]
    assert cnt[-1]["args"]["violated"] == slo["measured"]["violated"]
    validate_chrome_trace(rec.chrome_trace())
    # summary carries it
    assert res.summary()["slo"]["requests"] == n


def test_slo_deadline_override_forces_violations():
    """An impossible deadline violates every request — the identity
    holds in the all-violated regime and predicted tracks the same
    deadline."""
    from repro.launch.serve import build_engines, get_config
    src = TraceSource.load(FIXTURE)
    agent = FleetQLearning(src, seed=0)
    agent.run(4)
    engines = build_engines(get_config("edge-ladder"), variants=("d0",),
                            max_len=48)
    res = FleetOrchestrator(agent).route(
        dispatch=engines, max_new_tokens=2, batch_size=4, prompt_len=8,
        deadline_ms=1e-3)
    slo = res.slo()
    n = slo["requests"]
    assert slo["deadline_ms"] == pytest.approx(1e-3)
    assert slo["measured"] == {"attained": 0, "violated": n,
                               "attainment": 0.0}
    assert slo["predicted"]["attained"] + slo["predicted"]["violated"] == n
    assert all(r.deadline_met is False for r in res.served)
    # lat_acc sized off the deadline: everything overflows, flagged
    hist = slo["quantiles"]["hist_ms"]
    assert hist["clipped"] and hist["overflow"] == n


def test_slo_none_without_dispatch():
    orch = FleetOrchestrator(_trained_topo_agent())
    res = orch.route(as_result=True)
    assert res.slo() is None
    assert res.lat_acc is None
    assert "slo" not in res.summary()


def test_obsview_timeline(tmp_path):
    """--timeline renders windows + SLO blocks from a stamped JSON."""
    acc = MetricsAccumulator.create(
        {"reward": MetricDef(lo=-2.5, hi=0.0, bins=8, lanes=2,
                             n_windows=2, window_len=2)})
    for v in (-0.5, -1.5, -0.25, -2.0):
        acc = acc.update({"reward": jnp.asarray([v, v])})
    payload = attach_manifest({
        "training": acc.summary(),
        "slo": {
            "deadline_ms": 2500.0, "requests": 4,
            "measured": {"attained": 3, "violated": 1,
                         "attainment": 0.75},
            "predicted": {"attained": 4, "violated": 0,
                          "attainment": 1.0},
            "attainment_gap": 0.25,
            "per_tier_variant": {"E/d0": {
                "dispatched": 4, "attainment_measured": 0.75,
                "attainment_predicted": 1.0}},
            "quantiles": {"exact_ms": {"p50": 100.0, "p99": 400.0},
                          "hist_ms": {"p50": 110.0, "p99": 390.0,
                                      "bin_width": 50.0,
                                      "clipped": False}},
        }})
    p = tmp_path / "run.json"
    p.write_text(json.dumps(payload))
    res = _run_obsview("--timeline", str(p))
    assert res.returncode == 0, res.stderr
    out = res.stdout
    assert "windows  training.reward" in out and "<- last" in out
    assert "slo  slo" in out and "75.0%" in out and "+25.0%" in out
    assert "E/d0" in out and "bin_width = 50" in out
    # plain show / diff untouched by the new mode; exclusivity enforced
    bad = _run_obsview("--timeline", "--diff", str(p), str(p))
    assert bad.returncode != 0
    # a run without any time-resolved blocks says so instead of failing
    q = tmp_path / "plain.json"
    q.write_text(json.dumps(attach_manifest({"x": 1.0})))
    res2 = _run_obsview("--timeline", str(q))
    assert res2.returncode == 0, res2.stderr
    assert "no windowed metrics" in res2.stdout
