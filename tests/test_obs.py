"""repro.obs (ISSUE-6): host-sync-free fleet telemetry.

Covers: MetricsAccumulator correctness against numpy, chunked-merge
equality (exact on integer leaves and extrema, ULP-tolerant on float
sums), histogram merge == concat-then-bin, both agents carrying the
accumulator inside their jitted scans (counts, epsilon decay, the
metrics=False escape hatch, and metrics-on/off training bit-identity),
SpanRecorder + Chrome trace-event schema validation, run manifests,
hot_edges in RouteResult.summary(), the end-to-end gap_breakdown
acceptance (both exact sum identities against a real ServingEngine
batch), and tools/obsview.py via subprocess.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fleet import (FleetConfig, FleetDQN, FleetDQNConfig,
                        FleetOrchestrator, FleetQLearning, SyntheticSource,
                        TraceSource, fleet_metrics, mixed_table5_fleet,
                        topology, train_against_oracle, with_topology)
from repro.obs import (MetricDef, MetricsAccumulator, SpanRecorder,
                       attach_manifest, config_hash, run_manifest, span,
                       validate_chrome_trace)

DATA = os.path.join(os.path.dirname(__file__), "data")
FIXTURE = os.path.join(DATA, "trace_small.npz")
ROOT = os.path.join(os.path.dirname(__file__), "..")


# ------------------------------------------------------------ metrics -----
def _acc():
    return MetricsAccumulator.create({
        "r": MetricDef(lo=-2.0, hi=0.0, bins=8, lanes=4),
        "eps": MetricDef(lo=0.0, hi=1.0, bins=4, lanes=1),
    })


def test_metrics_moments_match_numpy():
    rng = np.random.default_rng(0)
    acc = _acc()
    samples = []
    for _ in range(7):
        x = rng.uniform(-2.5, 0.5, size=(4,)).astype(np.float32)
        samples.append(x)
        acc = acc.update({"r": jnp.asarray(x)})
    flat = np.concatenate(samples).astype(np.float64)
    s = acc.summary()["r"]
    assert s["count"] == flat.size and s["lanes"] == 4
    assert s["mean"] == pytest.approx(flat.mean(), rel=1e-6)
    assert s["std"] == pytest.approx(flat.std(), rel=1e-5)
    assert s["min"] == pytest.approx(flat.min(), rel=1e-6)
    assert s["max"] == pytest.approx(flat.max(), rel=1e-6)
    # out-of-range values clipped into edge bins, mass conserved
    assert sum(s["hist"]) == s["count"]
    assert len(s["edges"]) == 8 + 1


def test_metrics_histogram_merge_equals_concat_then_bin():
    rng = np.random.default_rng(1)
    xs = rng.uniform(-2.2, 0.2, size=(10, 4)).astype(np.float32)
    a, b = _acc(), _acc()
    for x in xs[:6]:
        a = a.update({"r": jnp.asarray(x)})
    for x in xs[6:]:
        b = b.update({"r": jnp.asarray(x)})
    merged = a.merge(b).summary()["r"]
    ref, _ = np.histogram(np.clip(xs.ravel(), -2.0, np.nextafter(0.0, -1)),
                          bins=8, range=(-2.0, 0.0))
    np.testing.assert_array_equal(merged["hist"], ref)


def test_metrics_chunked_merge_matches_single_stream():
    """merge(chunk1, chunk2) == one stream: exact on count/hist/extrema,
    reassociation-ULP close on the float sums (the CHANGES.md caveat)."""
    rng = np.random.default_rng(2)
    xs = [rng.uniform(-2.0, 0.0, size=(4,)).astype(np.float32)
          for _ in range(9)]
    one = _acc()
    for x in xs:
        one = one.update({"r": jnp.asarray(x)})
    a, b = _acc(), _acc()
    for x in xs[:4]:
        a = a.update({"r": jnp.asarray(x)})
    for x in xs[4:]:
        b = b.update({"r": jnp.asarray(x)})
    m, o = a.merge(b).data["r"], one.data["r"]
    for leaf in ("count", "hist", "mn", "mx"):
        np.testing.assert_array_equal(np.asarray(m[leaf]),
                                      np.asarray(o[leaf]))
    for leaf in ("total", "sumsq"):
        np.testing.assert_allclose(np.asarray(m[leaf]),
                                   np.asarray(o[leaf]), rtol=1e-6)


def test_metrics_jit_update_matches_eager():
    """The scan-carry usage: updates inside jit produce the same leaves
    as eager updates — including donation-friendly structure stability
    when only a subset of metrics is named."""
    x = jnp.asarray([-0.5, -1.0, -1.5, -0.25], jnp.float32)

    def once(acc):
        return acc.update({"r": x})        # 'eps' passes through

    eager = once(_acc())
    jitted = jax.jit(once)(_acc())
    for leaf in ("count", "total", "sumsq", "mn", "mx", "hist"):
        np.testing.assert_array_equal(np.asarray(eager.data["r"][leaf]),
                                      np.asarray(jitted.data["r"][leaf]))
    # untouched metric is bit-identical to the fresh one
    np.testing.assert_array_equal(np.asarray(jitted.data["eps"]["count"]),
                                  np.zeros(1, np.int32))


def test_metrics_lane_means_and_empty_summary():
    acc = _acc()
    s = acc.summary()["r"]
    assert s["count"] == 0 and s["mean"] is None and s["min"] is None
    acc = acc.update({"r": jnp.asarray([1.0, 2.0, 3.0, 4.0])})
    lm = acc.lane_means("r")
    np.testing.assert_allclose(lm, [1.0, 2.0, 3.0, 4.0])
    assert np.isnan(acc.lane_means("eps")).all()


def test_metrics_errors():
    with pytest.raises(ValueError, match="hi > lo"):
        MetricDef(lo=1.0, hi=1.0)
    with pytest.raises(ValueError, match="bins"):
        MetricDef(bins=0)
    acc = _acc()
    with pytest.raises(KeyError, match="unknown metric"):
        acc.update({"nope": jnp.zeros(4)})
    with pytest.raises(ValueError, match="lanes"):
        acc.update({"r": jnp.zeros(3)})     # 3 does not split into 4 lanes
    other = MetricsAccumulator.create({"r": MetricDef(lanes=4)})
    with pytest.raises(ValueError, match="different specs"):
        acc.merge(other)


# ----------------------------------------------- agents carry metrics -----
def test_qlearning_records_metrics_in_scan():
    src = TraceSource.load(FIXTURE)
    agent = FleetQLearning(src, seed=0)
    steps = 2 * src.horizon
    agent.run(steps)
    s = agent.metrics_summary()
    assert s["reward"]["count"] == src.cells * steps
    assert s["epsilon"]["count"] == steps            # one lane, one obs/step
    assert -2.5 <= s["reward"]["min"] <= s["reward"]["max"] <= 0.0
    # epsilon decays monotonically: max is the first value, min the last
    assert s["epsilon"]["max"] > s["epsilon"]["min"]
    assert sum(s["reward"]["hist"]) == s["reward"]["count"]
    assert agent.metrics.lane_means("reward").shape == (src.cells,)


def test_dqn_records_metrics_including_replay_fill():
    cfg = FleetConfig(cells=8, users=2, arrival_rate=1.0)
    agent = FleetDQN(SyntheticSource(cfg), cfg=FleetDQNConfig(), seed=0)
    agent.run(30)
    s = agent.metrics_summary()
    assert s["reward"]["count"] == 8 * 30
    assert s["loss"]["count"] == 30
    assert 0.0 < s["replay_fill"]["max"] <= 1.0
    assert s["replay_fill"]["min"] <= s["replay_fill"]["max"]  # fills up


def test_metrics_off_is_bit_identical_training():
    """The accumulator consumes no RNG and feeds nothing back: training
    with metrics=False is bit-identical, and metrics_summary is None."""
    src = SyntheticSource(FleetConfig(cells=8, users=2, arrival_rate=1.0))
    a = FleetQLearning(src, seed=4)
    b = FleetQLearning(src, seed=4, metrics=False)
    a.run(30)
    b.run(30)
    assert b.metrics is None and b.metrics_summary() is None
    np.testing.assert_array_equal(np.asarray(a.q), np.asarray(b.q))
    np.testing.assert_array_equal(np.asarray(a.counts),
                                  np.asarray(b.counts))
    da = FleetDQN(src, cfg=FleetDQNConfig(), seed=4)
    db = FleetDQN(src, cfg=FleetDQNConfig(), seed=4, metrics=False)
    da.run(25)
    db.run(25)
    assert db.metrics_summary() is None
    for la, lb in zip(jax.tree_util.tree_leaves(da.params),
                      jax.tree_util.tree_leaves(db.params)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_fleet_metrics_factory_shapes():
    m = fleet_metrics(16, "tabular")
    assert m.defs["reward"].lanes == 16 and "loss" not in m.defs
    m = fleet_metrics(16, "dqn")
    assert {"loss", "replay_fill"} <= set(m.defs)
    with pytest.raises(ValueError, match="kind"):
        fleet_metrics(16, "nope")


def test_train_against_oracle_attaches_manifest():
    src = TraceSource.load(FIXTURE)
    agent = FleetQLearning(src, seed=0)
    res = train_against_oracle(agent, max_steps=src.horizon,
                               check_every=src.horizon)
    m = res.manifest
    assert m["schema"] == "repro.obs/manifest-v1"
    assert m["jax_version"] == jax.__version__
    assert m["steps"] == agent.steps > 0
    assert m["wall_seconds"] == pytest.approx(res.wall_seconds)


# -------------------------------------------------------------- spans -----
def test_span_recorder_nesting_and_durations():
    rec = SpanRecorder()
    with rec.span("outer", kind="test"):
        with rec.span("inner"):
            pass
    rec.instant("marker", note="hi")
    rec.counter("queue", depth=3)
    names = [e["name"] for e in rec.events]
    assert names == ["inner", "outer", "marker", "queue"]  # close order
    outer = next(e for e in rec.events if e["name"] == "outer")
    inner = next(e for e in rec.events if e["name"] == "inner")
    assert outer["ts"] <= inner["ts"]
    assert outer["dur"] >= inner["dur"]
    assert outer["args"] == {"kind": "test"}
    assert rec.durations_ms("outer") and rec.durations_ms("nope") == []


def test_span_module_helper_none_recorder_is_noop():
    with span(None, "anything", x=1):
        pass                                         # no recorder, no-op
    rec = SpanRecorder()
    with span(rec, "real"):
        pass
    assert [e["name"] for e in rec.events] == ["real"]


def test_chrome_trace_save_validate_roundtrip(tmp_path):
    rec = SpanRecorder()
    with rec.span("a", obj=object()):                # non-json arg -> str
        pass
    path = rec.save(str(tmp_path / "t.json"), manifest=run_manifest())
    with open(path) as f:
        trace = json.load(f)
    validate_chrome_trace(trace)
    assert trace["displayTimeUnit"] == "ms"
    assert trace["otherData"]["schema"] == "repro.obs/manifest-v1"
    e = trace["traceEvents"][0]
    assert e["ph"] == "X" and e["ts"] >= 0 and e["dur"] >= 0
    assert isinstance(e["args"]["obj"], str)


def test_validate_chrome_trace_rejections():
    ok = {"traceEvents": [{"name": "x", "ph": "X", "ts": 0.0, "dur": 1.0,
                           "pid": 1, "tid": 1}]}
    validate_chrome_trace(ok)
    with pytest.raises(ValueError, match="must be a dict"):
        validate_chrome_trace([])
    with pytest.raises(ValueError, match="must be a list"):
        validate_chrome_trace({"traceEvents": {}})
    bad = {"traceEvents": [{"ph": "X", "ts": 0.0}]}
    with pytest.raises(ValueError, match="name"):
        validate_chrome_trace(bad)
    bad = {"traceEvents": [{"name": "x", "ph": "Z", "ts": 0.0,
                            "pid": 1, "tid": 1}]}
    with pytest.raises(ValueError, match="bad phase"):
        validate_chrome_trace(bad)
    bad = {"traceEvents": [{"name": "x", "ph": "X", "ts": -1.0, "dur": 1.0,
                            "pid": 1, "tid": 1}]}
    with pytest.raises(ValueError, match="ts"):
        validate_chrome_trace(bad)
    bad = {"traceEvents": [{"name": "x", "ph": "X", "ts": 0.0,
                            "pid": 1, "tid": 1}]}
    with pytest.raises(ValueError, match="dur"):
        validate_chrome_trace(bad)


# ----------------------------------------------------------- manifest -----
def test_run_manifest_keys_and_config_hash():
    m = run_manifest(config=FleetConfig(cells=4, users=2), extra_key=7)
    assert m["schema"] == "repro.obs/manifest-v1"
    assert m["backend"] == jax.default_backend()
    assert m["device_count"] == jax.device_count()
    assert m["extra_key"] == 7
    assert len(m["config_hash"]) == 16
    # hash is deterministic and config-sensitive
    assert config_hash(FleetConfig(cells=4, users=2)) == m["config_hash"]
    assert config_hash(FleetConfig(cells=5, users=2)) != m["config_hash"]
    assert config_hash({"b": 1, "a": 2}) == config_hash({"a": 2, "b": 1})


def test_attach_manifest_does_not_mutate():
    payload = {"x": 1}
    out = attach_manifest(payload, wall_seconds=1.0)
    assert "manifest" not in payload
    assert out["x"] == 1 and out["manifest"]["wall_seconds"] == 1.0


# ------------------------------------------- hot edges + gap breakdown ----
def _trained_topo_agent():
    scen = with_topology(mixed_table5_fleet(jax.random.PRNGKey(0), 12, 2),
                         topology.hot_edge_topology(12, 4))
    cfg = FleetConfig(cells=12, users=2, arrival_rate=1.5, n_edges=4)
    agent = FleetQLearning(SyntheticSource(cfg, scen=scen), seed=0)
    agent.run(40)
    return agent


def test_hot_edges_in_summary():
    """Satellite: route everything to the edge tier over a
    hot_edge_topology — half the fleet shares edge 0, so edge 0 is the
    unique utilization peak; the hot set follows the threshold."""
    from repro.fleet.api import StaticPolicy
    scen = with_topology(mixed_table5_fleet(jax.random.PRNGKey(0), 12, 2),
                         topology.hot_edge_topology(12, 4))
    orch = FleetOrchestrator(StaticPolicy(users=2, strategy="edge"))
    res = orch.route(scen=scen, with_edge_util=True, as_result=True,
                     hot_edge_util=0.5)
    util = np.asarray(res.edge_util)
    assert util.argmax() == 0                        # the hot edge
    s = res.summary()
    assert s["hot_edge_util"] == 0.5
    assert s["hot_edges"] == res.hot_edges
    assert res.hot_edges == [int(i) for i in np.nonzero(util >= 0.5)[0]]
    assert 0 in res.hot_edges
    # threshold above the peak -> empty hot set
    res2 = orch.route(scen=scen, with_edge_util=True, as_result=True,
                      hot_edge_util=float(util.max()) + 0.01)
    assert res2.summary()["hot_edges"] == []
    # a trained agent keeps the tuple contract, util values matching
    agent_orch = FleetOrchestrator(_trained_topo_agent())
    r3 = agent_orch.route(with_edge_util=True, as_result=True)
    dec, ids, util3 = agent_orch.route(with_edge_util=True)
    np.testing.assert_allclose(np.asarray(util3),
                               np.asarray(r3.edge_util))
    assert "hot_edges" not in agent_orch.route(as_result=True).summary()


def test_gap_breakdown_end_to_end_with_real_engines():
    """ISSUE-6 acceptance: gap_breakdown components sum to the measured
    wall time of a real engine batch — both identities exact."""
    from repro.launch.serve import build_engines, get_config
    src = TraceSource.load(FIXTURE)
    agent = FleetQLearning(src, seed=0)
    agent.run(src.horizon)
    engines = build_engines(get_config("edge-ladder"), variants=("d0",),
                            max_len=48)
    rec = SpanRecorder()
    res = FleetOrchestrator(agent).route(
        dispatch=engines, max_new_tokens=2, batch_size=4, prompt_len=8,
        spans=rec)
    gb = res.gap_breakdown()
    w = gb["wall_ms"]
    assert w["total"] == pytest.approx(
        w["batching"] + w["compute"] + w["dispatch"], abs=1e-6)
    assert w["dispatch"] >= 0.0
    pr = gb["per_request_ms"]
    assert pr["e2e"] == pytest.approx(pr["queueing"] + pr["compute"],
                                      abs=1e-6)
    assert gb["gap_x"] > 0.0
    assert gb["gap_components_x"]["e2e"] == pytest.approx(
        gb["gap_components_x"]["queueing"]
        + gb["gap_components_x"]["compute"], abs=1e-9)
    for tv in gb["per_tier_variant"].values():
        assert tv["gap_x"] > 0.0
    # per-request identity holds request by request, not just in the mean
    for r in res.served:
        assert r.queue_ms >= 0.0
        assert r.measured_ms >= 0.0
    # the spans cover the dispatch path
    names = {e["name"] for e in rec.events}
    assert {"route.decide", "route.dispatch", "dispatch.batch_build",
            "engine.generate", "engine.prefill",
            "engine.decode"} <= names
    assert any(n.startswith("dispatch.drain.") for n in names)
    validate_chrome_trace(rec.chrome_trace(run_manifest()))
    # summary carries the breakdown
    assert res.summary()["gap_breakdown"]["gap_x"] == gb["gap_x"]


def test_gap_breakdown_none_without_dispatch():
    orch = FleetOrchestrator(_trained_topo_agent())
    res = orch.route(as_result=True)
    assert res.gap_breakdown() is None
    assert "gap_breakdown" not in res.summary()


# ------------------------------------------------------------ obsview ----
def _run_obsview(*args):
    return subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "obsview.py"), *args],
        capture_output=True, text=True, timeout=60)


def test_obsview_show_and_diff(tmp_path):
    a = tmp_path / "a.json"
    b = tmp_path / "b.json"
    a.write_text(json.dumps(attach_manifest(
        {"x": 1.0, "nested": {"y": 2.0}}, wall_seconds=1.0)))
    b.write_text(json.dumps(attach_manifest(
        {"x": 1.5, "nested": {"y": 2.0}}, wall_seconds=2.0)))
    res = _run_obsview(str(a))
    assert res.returncode == 0, res.stderr
    assert "nested.y" in res.stdout and "jax" in res.stdout
    res = _run_obsview("--diff", str(a), str(b))
    assert res.returncode == 0, res.stderr
    assert "+50.0%" in res.stdout and "<--" in res.stdout
    assert "1 metric(s) moved" in res.stdout
