"""repro.fleet: kernel parity with the scalar env, scenario generators,
and population-scale training (ISSUE-1 acceptance criteria)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EXPERIMENTS, EndEdgeCloudEnv
from repro.core.spaces import A_CLOUD, A_EDGE, SpaceSpec
from repro.fleet import (FleetConfig, FleetOrchestrator, FleetQConfig,
                         FleetQLearning, dynamics, fleet_bruteforce,
                         heterogeneous_sizes, init_fleet, init_links,
                         mixed_table5_fleet, poisson_active, step_churn,
                         step_fleet, step_links, table5_fleet)


def test_action_id_constants_match_spaces():
    """dynamics keeps core-free mirror constants; pin them."""
    assert dynamics.A_EDGE == A_EDGE and dynamics.A_CLOUD == A_CLOUD


# ------------------------------------------------------------- parity -----
@pytest.mark.parametrize("name", list(EXPERIMENTS))
def test_fleet_dynamics_match_scalar_env_cell_by_cell(name):
    """Acceptance: jitted fleet dynamics == EndEdgeCloudEnv.expected_response
    for every cell, on all four Table-5 scenarios."""
    env = EndEdgeCloudEnv(5, EXPERIMENTS[name], noise=0)
    cells = 64
    acts = np.random.default_rng(7).integers(0, env.spec.n_joint_actions,
                                             cells)
    pu = env.spec.decode_actions_batch(acts)
    scen = table5_fleet(name, cells=cells, users=5)
    ms, acc = dynamics.fleet_expected_response(jnp.asarray(pu), scen.end_b,
                                               scen.edge_b)
    for i, a in enumerate(acts):
        m1, a1 = env.expected_response(int(a))
        np.testing.assert_allclose(float(ms[i]), m1, rtol=1e-4)
        np.testing.assert_allclose(float(acc[i]), a1, rtol=1e-5)


def test_fleet_1024_cells_single_jitted_step():
    """Acceptance: >=1024 independent 5-user cells step in ONE jitted call."""
    scen = mixed_table5_fleet(jax.random.PRNGKey(0), 1024, 5)
    agent = FleetQLearning(scen, FleetConfig(cells=1024, users=5))
    info = agent.step()
    ms = np.asarray(info["mean_ms"])
    assert ms.shape == (1024,) and np.isfinite(ms).all() and (ms > 0).all()
    assert agent.q.shape[0] == 1024


def test_cell_response_times_vmap_matches_numpy_kernel():
    rng = np.random.default_rng(3)
    pu = rng.integers(0, 10, (16, 5))
    end_b = rng.integers(0, 2, (16, 5))
    edge_b = rng.integers(0, 2, 16)
    got = np.asarray(dynamics.cell_response_times(
        jnp.asarray(pu), jnp.asarray(end_b), jnp.asarray(edge_b)))
    for c in range(16):
        want = dynamics.response_times(pu[c], end_b[c], edge_b[c])
        np.testing.assert_allclose(got[c], want, rtol=1e-5)


def test_active_mask_excludes_users_from_contention_and_means():
    """An inactive user neither loads the edge nor enters the mean."""
    pu = np.array([[A_EDGE, A_EDGE, A_EDGE, A_EDGE, 0]])
    end_b = np.zeros((1, 5), int)
    edge_b = np.zeros(1, int)
    full = dynamics.response_times(pu, end_b, edge_b)
    masked = dynamics.response_times(
        pu, end_b, edge_b, active=np.array([[True, True, False, False,
                                             True]]))
    # with only 2 edge jobs, contention and memory pressure drop
    assert masked[0, 0] < full[0, 0]
    assert masked[0, 2] == 0.0 and masked[0, 3] == 0.0
    ms, acc = dynamics.expected_response(
        pu, end_b, edge_b, active=np.array([[True, True, False, False,
                                             True]]))
    assert ms[0] == pytest.approx(masked[0, [0, 1, 4]].mean())


# ---------------------------------------------------------- scenarios -----
def test_table5_fleet_rejects_oversized_user_count():
    with pytest.raises(ValueError, match="cover all users"):
        table5_fleet("EXP-A", cells=4, users=6)
    with pytest.raises(ValueError, match="cover all users"):
        mixed_table5_fleet(jax.random.PRNGKey(0), cells=4, users=6)


def test_scenario_generators_seedable_and_bounded():
    key = jax.random.PRNGKey(5)
    b1 = init_links(key, (32, 5), p_weak=0.3)
    b2 = init_links(key, (32, 5), p_weak=0.3)
    assert (np.asarray(b1) == np.asarray(b2)).all()
    assert set(np.unique(np.asarray(b1))) <= {0, 1}
    stepped = step_links(jax.random.PRNGKey(6), b1, 0.5, 0.5)
    assert set(np.unique(np.asarray(stepped))) <= {0, 1}


def test_markov_links_stationary_fraction():
    """Long-run weak fraction approaches p_r2w / (p_r2w + p_w2r)."""
    key = jax.random.PRNGKey(0)
    b = init_links(key, (256, 8), p_weak=0.0)
    p_r2w, p_w2r = 0.1, 0.3
    for i in range(300):
        key, k = jax.random.split(key)
        b = step_links(k, b, p_r2w, p_w2r)
    frac = float(np.asarray(b).mean())
    assert abs(frac - p_r2w / (p_r2w + p_w2r)) < 0.05


def test_poisson_and_churn_and_sizes():
    key = jax.random.PRNGKey(9)
    act = poisson_active(key, (1000,), rate=1.0)
    frac = float(np.asarray(act).mean())
    assert abs(frac - (1 - np.exp(-1.0))) < 0.06
    member = jnp.ones((64, 5), bool)
    m2 = step_churn(key, member, p_join=0.0, p_leave=0.5)
    assert 0.2 < float(np.asarray(m2).mean()) < 0.8
    sizes, mask = heterogeneous_sizes(key, 128, 5, min_users=2)
    s = np.asarray(sizes)
    assert s.min() >= 2 and s.max() <= 5
    assert (np.asarray(mask).sum(1) == s).all()


def test_init_fleet_respects_max_users_cap():
    cfg = FleetConfig(cells=64, users=5, min_users=1, max_users=2)
    s = init_fleet(jax.random.PRNGKey(4), cfg)
    sizes = np.asarray(s.member).sum(1)
    assert s.member.shape == (64, 5)
    assert sizes.min() >= 1 and sizes.max() <= 2
    # a cap below the (default) min_users wins rather than being ignored
    capped = init_fleet(jax.random.PRNGKey(4),
                        FleetConfig(cells=16, users=5, max_users=3))
    assert (np.asarray(capped.member).sum(1) == 3).all()


def test_idle_cell_not_penalized_under_threshold():
    """A cell with zero active users served nothing — it must not earn
    the constraint-violation floor."""
    from repro.fleet import FleetConfig as FC, simulate_responses
    from repro.fleet import dynamics as dyn
    scen = table5_fleet("EXP-A", cells=1, users=2)
    idle = type(scen)(scen.end_b, scen.edge_b, scen.member,
                      jnp.zeros_like(scen.active), scen.t)
    ms, acc, counts = simulate_responses(jax.random.PRNGKey(0), idle,
                                         jnp.zeros((1, 2), jnp.int32), 0.0)
    r = dyn.reward(ms, acc, 85.0, xp=jnp)
    assert float(ms[0]) == 0.0 and float(r[0]) == 0.0
    assert (np.asarray(counts) == 0).all()


def test_churn_extremes_empty_and_refill_the_cell():
    """p_join=0 / p_leave=1 deterministically empties the membership in
    one step (and the mirror extreme refills it) — the Markov chain's
    absorbing corners, not just its stationary middle."""
    key = jax.random.PRNGKey(0)
    member = jnp.asarray(np.random.default_rng(0).random((64, 5)) < 0.5)
    gone = step_churn(key, member, p_join=0.0, p_leave=1.0)
    assert not bool(np.asarray(gone).any())
    everyone = step_churn(key, member, p_join=1.0, p_leave=0.0)
    assert bool(np.asarray(everyone).all())
    # and the empty cell stays empty under p_join=0
    still_gone = step_churn(key, gone, p_join=0.0, p_leave=1.0)
    assert not bool(np.asarray(still_gone).any())


def test_heterogeneous_sizes_degenerate_range_is_homogeneous():
    """min_users == max_users collapses the draw: every cell gets exactly
    that size, mask padded to ``width``."""
    for k in (1, 3, 5):
        sizes, mask = heterogeneous_sizes(jax.random.PRNGKey(1), 32, k,
                                          min_users=k, width=5)
        assert (np.asarray(sizes) == k).all()
        assert mask.shape == (32, 5)
        assert (np.asarray(mask).sum(1) == k).all()
        # padded mask is a prefix mask: users [0, k) present, rest absent
        assert (np.asarray(mask) == (np.arange(5)[None, :] < k)).all()


def test_step_fleet_is_deterministic_under_a_fixed_key():
    """Same key + same state -> bit-identical next state, jitted or not;
    different keys diverge (the generators are pure functions of key)."""
    cfg = FleetConfig(cells=48, users=5, p_r2w=0.1, p_w2r=0.2,
                      arrival_rate=0.9, diurnal_period=50,
                      p_join=0.05, p_leave=0.05, min_users=1, max_users=5)
    s0 = init_fleet(jax.random.PRNGKey(3), cfg)
    k = jax.random.PRNGKey(7)
    a = step_fleet(k, s0, cfg)
    b = step_fleet(k, s0, cfg)
    c = jax.jit(lambda k, s: step_fleet(k, s, cfg))(k, s0)
    for x, y in ((a, b), (a, c)):
        np.testing.assert_array_equal(np.asarray(x.end_b), np.asarray(y.end_b))
        np.testing.assert_array_equal(np.asarray(x.edge_b),
                                      np.asarray(y.edge_b))
        np.testing.assert_array_equal(np.asarray(x.member),
                                      np.asarray(y.member))
        np.testing.assert_array_equal(np.asarray(x.active),
                                      np.asarray(y.active))
    d = step_fleet(jax.random.PRNGKey(8), s0, cfg)
    assert (np.asarray(a.end_b) != np.asarray(d.end_b)).any() or \
           (np.asarray(a.active) != np.asarray(d.active)).any()
    # init_fleet is deterministic in its key too
    np.testing.assert_array_equal(
        np.asarray(init_fleet(jax.random.PRNGKey(3), cfg).member),
        np.asarray(s0.member))


def test_composed_fleet_steps_under_jit():
    cfg = FleetConfig(cells=32, users=5, p_r2w=0.05, p_w2r=0.2,
                      arrival_rate=0.8, diurnal_period=100,
                      p_join=0.02, p_leave=0.02, min_users=2, max_users=5)
    s = init_fleet(jax.random.PRNGKey(1), cfg)
    stepper = jax.jit(lambda k, s: step_fleet(k, s, cfg))
    key = jax.random.PRNGKey(2)
    for i in range(20):
        key, k = jax.random.split(key)
        s = stepper(k, s)
    assert int(s.t) == 20
    assert bool((np.asarray(s.active) <= np.asarray(s.member)).all())


# --------------------------------------------------------- population -----
def test_fleet_qlearning_converges_to_per_cell_optimum():
    """Fleet tabular Q reaches each cell's brute-force optimum — the
    population analogue of claim C1."""
    scen = mixed_table5_fleet(jax.random.PRNGKey(1), 64, 2)
    agent = FleetQLearning(scen, FleetConfig(cells=64, users=2),
                           FleetQConfig(eps_decay=2e-3,
                                        accuracy_threshold=85.0))
    res = agent.train(max_steps=8000, check_every=200)
    assert res.frac_converged >= 0.9
    # final-state check: most cells sit at their optimum (a few converged
    # cells may be perturbed by residual exploration while others finish)
    at_opt = ((res.greedy_ms <= res.optimal_ms * 1.011)
              & (res.greedy_acc >= 85.0 - 1e-6))
    assert at_opt.mean() >= 0.9


def test_train_tracks_moving_optimum_on_dynamic_fleet():
    """With Markov links the oracle moves; train() must recompute it per
    check instead of pinning the t=0 scenario."""
    cfg = FleetConfig(cells=32, users=2, p_r2w=0.05, p_w2r=0.15)
    agent = FleetQLearning(init_fleet(jax.random.PRNGKey(7), cfg), cfg,
                           FleetQConfig(track_links=True, eps_decay=5e-3))
    res = agent.train(max_steps=1000, check_every=200)
    assert 0.0 <= res.frac_converged <= 1.0
    # the recorded optimum reflects the FINAL scenario, not the initial one
    from repro.fleet import fleet_bruteforce
    final_opt = np.asarray(fleet_bruteforce(agent.scen, agent.pu_table,
                                            0.0)[0])
    np.testing.assert_allclose(res.optimal_ms, final_opt, rtol=1e-5)


def test_fleet_bruteforce_raises_when_infeasible():
    scen = table5_fleet("EXP-A", cells=4, users=2)
    spec = SpaceSpec(2)
    pu = jnp.asarray(spec.decode_actions_batch(spec.all_actions()))
    with pytest.raises(ValueError, match="no feasible action"):
        fleet_bruteforce(scen, pu, threshold=99.0)


def test_fleet_bruteforce_matches_scalar_bruteforce():
    from repro.core import bruteforce_optimal
    for name in ("EXP-A", "EXP-D"):
        env = EndEdgeCloudEnv(2, EXPERIMENTS[name], noise=0)
        scen = table5_fleet(name, cells=4, users=2)
        spec = SpaceSpec(2)
        pu = jnp.asarray(spec.decode_actions_batch(spec.all_actions()))
        best_ms, best_idx = fleet_bruteforce(scen, pu, threshold=85.0)
        a, ms, acc, _ = bruteforce_optimal(env, 85.0)
        np.testing.assert_allclose(np.asarray(best_ms), ms, rtol=1e-4)
        assert (np.asarray(best_idx) == a).all()


def test_fleet_orchestrator_single_vectorized_greedy_pass():
    scen = mixed_table5_fleet(jax.random.PRNGKey(3), 256, 3)
    agent = FleetQLearning(scen, FleetConfig(cells=256, users=3), seed=2)
    for _ in range(5):
        agent.step()
    orch = FleetOrchestrator(agent)
    dec, ids = orch.route()
    assert dec.shape == (256, 3) and ids.shape == (256,)
    # routing equals per-cell greedy over the Q-table
    np.testing.assert_array_equal(np.asarray(dec),
                                  np.asarray(agent.greedy_decisions()))
    pu = np.asarray(agent.pu_table)
    np.testing.assert_array_equal(np.asarray(dec), pu[np.asarray(ids)])


def test_tabular_agent_refuses_held_out_fleet():
    """Per-cell Q-tables don't transfer: routing a fleet with a
    different cell count must fail loudly, not gather garbage (the
    shared-policy FleetDQN is the held-out path)."""
    scen = mixed_table5_fleet(jax.random.PRNGKey(3), 16, 2)
    agent = FleetQLearning(scen, FleetConfig(cells=16, users=2), seed=0)
    agent.step()
    other = mixed_table5_fleet(jax.random.PRNGKey(4), 32, 2)
    with pytest.raises(ValueError, match="FleetDQN"):
        FleetOrchestrator(agent).route(scen=other)
