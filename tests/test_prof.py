"""repro.obs.prof (ISSUE-7): compiled-cost profiling + the regression
gate.

Covers: CostProfile flops sanity on a known matmul (compiler count
within 2x of the analytic 2mnk), determinism across recompiles,
roofline terms and backend-peak fallback, stage_costs for both fleet
agents (stage sets, fractions summing to ~1, determinism of the flop
fractions, spans recorded), scaling_sweep report schema + JSON
round-trip, tools/benchgate.py via subprocess (pass / regression /
manifest mismatch / --force / structural on the tracked baseline and
on a broken JSON), obsview --fail-on-move and --history, and the
save_json history.jsonl append.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, ROOT)  # for the benchmarks package

from repro.fleet import (FleetConfig, FleetDQN, FleetDQNConfig,
                         FleetQConfig, FleetQLearning, SyntheticSource)
from repro.obs import SpanRecorder, attach_manifest
from repro.obs.prof import (PEAKS, backend_peaks, profile_fn,
                            scaling_sweep, stage_costs)
from repro.obs.report import flatten, rel_diff


# ------------------------------------------------------- CostProfile -----
def _matmul_profile(m=64, k=128, n=32):
    a = jnp.ones((m, k), jnp.float32)
    b = jnp.ones((k, n), jnp.float32)
    return profile_fn(jnp.dot, a, b, name="mm"), 2 * m * k * n


def test_costprofile_matmul_flops_within_2x_of_analytic():
    prof, analytic = _matmul_profile()
    assert prof.name == "mm"
    assert analytic / 2 <= prof.flops <= analytic * 2
    assert prof.bytes_accessed > 0
    assert prof.arithmetic_intensity == pytest.approx(
        prof.flops / prof.bytes_accessed)
    assert prof.dominant in ("compute", "memory")


def test_costprofile_dict_is_jsonable_and_derived():
    prof, _ = _matmul_profile()
    d = prof.as_dict()
    json.dumps(d)  # must round-trip
    for key in ("flops", "bytes_accessed", "arithmetic_intensity",
                "ridge_intensity", "compute_s", "memory_s", "dominant",
                "backend", "temp_bytes"):
        assert key in d
    assert d["ridge_intensity"] == pytest.approx(
        prof.peak_flops_per_s / prof.peak_bytes_per_s)
    # dominant consistent with the roofline terms
    expect = "compute" if d["compute_s"] >= d["memory_s"] else "memory"
    assert d["dominant"] == expect


def test_costprofile_deterministic_across_recompiles():
    p1, _ = _matmul_profile()
    p2, _ = _matmul_profile()
    assert p1.flops == p2.flops
    assert p1.bytes_accessed == p2.bytes_accessed
    assert p1.temp_bytes == p2.temp_bytes


def test_backend_peaks_known_rows_and_fallback():
    assert backend_peaks("tpu").flops_per_s == pytest.approx(197e12)
    assert backend_peaks("no_such_backend") == PEAKS["cpu"]
    # default resolves to the live backend without raising
    assert backend_peaks().flops_per_s > 0


def test_profile_fn_never_executes():
    calls = []

    def f(x):
        calls.append(1)  # traced once at lower time, never executed
        return x * 2.0

    profile_fn(f, jnp.ones((4,)))
    assert len(calls) == 1  # tracing only; no second call from execution


# -------------------------------------------------------- stage_costs ----
def _source(cells=8):
    return SyntheticSource(FleetConfig(cells=cells, users=2,
                                       arrival_rate=1.0))


def test_stage_costs_dqn_stages_and_fractions():
    spans = SpanRecorder()
    agent = FleetDQN(_source(), cfg=FleetDQNConfig(replay_capacity=256,
                                                   batch_size=16))
    rep = stage_costs(agent, reps=2, spans=spans)
    assert rep["kind"] == "dqn"
    # default impl routes the act stage through the fused head
    assert set(rep["stages"]) == {"fused_encode_act", "env_step",
                                  "replay", "update"}
    for fr in ("flop_fracs", "byte_fracs", "wall_fracs"):
        assert sum(rep[fr].values()) == pytest.approx(1.0)
        assert all(v >= 0 for v in rep[fr].values())
    assert rep["dominant_stage_flops"] in rep["stages"]
    assert rep["dominant_stage_wall"] in rep["stages"]
    # wall was measured through the span recorder
    assert len(spans.durations_ms("prof.stage.update")) == 2
    json.dumps(rep)


def test_stage_costs_tabular_stages_and_fractions():
    agent = FleetQLearning(_source(), cfg=FleetQConfig())
    rep = stage_costs(agent, reps=2)
    assert rep["kind"] == "tabular"
    # default impl: TD update + next-step act fused into one stage
    assert set(rep["stages"]) == {"encode_act", "env_step",
                                  "fused_update_act"}
    assert sum(rep["flop_fracs"].values()) == pytest.approx(1.0)
    assert rep["cells"] == 8 and rep["users"] == 2
    json.dumps(rep)


def test_stage_costs_xla_impl_keeps_legacy_stage_names():
    rep = stage_costs(FleetQLearning(_source(), cfg=FleetQConfig(),
                                     impl="xla"), reps=1)
    assert set(rep["stages"]) == {"encode_act", "env_step", "update"}
    rep = stage_costs(FleetDQN(_source(),
                               cfg=FleetDQNConfig(replay_capacity=256,
                                                  batch_size=16),
                               impl="xla"), reps=1)
    assert set(rep["stages"]) == {"encode_act", "env_step", "replay",
                                  "update"}


def test_stage_flop_fractions_deterministic_across_recompiles():
    agent = FleetDQN(_source(), cfg=FleetDQNConfig(replay_capacity=256,
                                                   batch_size=16))
    r1 = stage_costs(agent, reps=1)
    r2 = stage_costs(agent, reps=1)
    assert r1["flop_fracs"] == r2["flop_fracs"]
    assert r1["byte_fracs"] == r2["byte_fracs"]


# ------------------------------------------------------ scaling_sweep ----
def test_scaling_sweep_schema_and_classification():
    rep = scaling_sweep([8, 16], users=2, steps=20, chunk=5)
    assert rep["grid"] == [8, 16]
    assert rep["devices"] == 1 and rep["sharded"] is False
    for key in ("flops_per_cell", "us_device_per_cell_step",
                "per_device_cell_steps_per_s"):
        assert set(rep[key]) == {"8", "16"}
        assert all(v > 0 for v in rep[key].values())
    assert 0 < rep["flatness"] <= 1.0
    assert rep["classification"] in ("flat", "runtime", "algorithmic")
    if rep["classification"] == "flat":
        assert rep["cliff_cells"] is None
    else:
        assert rep["cliff_cells"] in rep["grid"]
        assert str(rep["cliff_cells"]) in rep["summary"]
    json.dumps(rep)


# ---------------------------------------------------------- benchgate ----
GATE = os.path.join(ROOT, "tools", "benchgate.py")
BASELINE = os.path.join(ROOT, "results", "BENCH_fleet.json")


def _gate(*args):
    return subprocess.run([sys.executable, GATE, *args],
                          capture_output=True, text=True, timeout=60)


def _bench_payload(**overrides):
    metrics = {
        "env_steps_per_s": 1e6, "rl_steps_per_s": 4e5,
        "dqn_rl_steps_per_s": 4e4, "converged_cells_per_s": 100.0,
        "trace_env_steps_per_s": 5e5, "sharded_env_steps_per_s": 2e5,
        "dqn_holdout_reward_ratio": 1.0, "dqn_obs_overhead_x": 1.0,
        "trace_serving_gap_x": 7.0,
        "slo_attainment_measured": 0.9, "slo_attainment_predicted": 1.0,
        "p99_ms": 2000.0, "windowed_overhead_x": 1.0,
        "rl_fused_tabular_steps_per_s": 8e5,
        "rl_unfused_tabular_steps_per_s": 4e5,
        "rl_fused_tabular_speedup_x": 2.0,
        "rl_fused_dqn_steps_per_s": 9e4,
        "rl_unfused_dqn_steps_per_s": 8e4,
        "rl_fused_dqn_speedup_x": 1.15,
    }
    metrics.update(overrides)
    return attach_manifest(metrics)


def _write(path, payload):
    path.write_text(json.dumps(payload, default=str))
    return str(path)


def test_benchgate_identical_passes(tmp_path):
    p = _write(tmp_path / "base.json", _bench_payload())
    res = _gate(p, p)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "0 regression(s)" in res.stdout


def test_benchgate_regression_fails(tmp_path):
    base = _write(tmp_path / "base.json", _bench_payload())
    bad = _write(tmp_path / "bad.json", _bench_payload(
        env_steps_per_s=1e5,            # -90% throughput (tol 40%)
        dqn_holdout_reward_ratio=0.8,   # below the 0.95 floor
        trace_serving_gap_x=20.0))      # gap blew up (lower-better)
    res = _gate(base, bad)
    assert res.returncode == 1, res.stdout + res.stderr
    assert "3 regression(s)" in res.stdout
    assert "REGR" in res.stdout


def test_benchgate_degraded_slo_attainment_fails(tmp_path):
    """ISSUE-8 satellite: a copy whose SLO metrics degraded exits 1 —
    attainment gates on an absolute floor (0.50), p99 and the windowed
    overhead on lower-better bands."""
    base = _write(tmp_path / "base.json", _bench_payload())
    bad = _write(tmp_path / "bad.json", _bench_payload(
        slo_attainment_measured=0.3,    # below the 0.50 floor
        p99_ms=5000.0,                  # 2.5x the baseline tail (tol 60%)
        windowed_overhead_x=1.5))       # windows suddenly cost 50%
    res = _gate(base, bad)
    assert res.returncode == 1, res.stdout + res.stderr
    assert "3 regression(s)" in res.stdout
    for key in ("slo_attainment_measured", "p99_ms",
                "windowed_overhead_x"):
        assert key in res.stdout
    # attainment at the floor still passes even if below baseline
    ok = _write(tmp_path / "ok.json", _bench_payload(
        slo_attainment_measured=0.55))
    assert _gate(base, ok).returncode == 0


def test_benchgate_fused_speedup_floor(tmp_path):
    """ISSUE-10: a run whose fused/unfused ratio collapses below the
    absolute floor exits 1 — fused regressing to parity with the legacy
    path must fail the build even if raw throughput looks fine."""
    base = _write(tmp_path / "base.json", _bench_payload())
    bad = _write(tmp_path / "bad.json", _bench_payload(
        rl_fused_tabular_speedup_x=1.1,   # below the 1.7 floor
        rl_fused_dqn_speedup_x=0.9))      # fused slower than legacy
    res = _gate(base, bad)
    assert res.returncode == 1, res.stdout + res.stderr
    assert "2 regression(s)" in res.stdout
    assert "rl_fused_tabular_speedup_x" in res.stdout
    # at-floor still passes even below the baseline's recorded ratio
    ok = _write(tmp_path / "ok.json", _bench_payload(
        rl_fused_tabular_speedup_x=1.75, rl_fused_dqn_speedup_x=1.03))
    assert _gate(base, ok).returncode == 0


def test_benchgate_improvement_passes(tmp_path):
    base = _write(tmp_path / "base.json", _bench_payload())
    better = _write(tmp_path / "up.json", _bench_payload(
        env_steps_per_s=5e6, trace_serving_gap_x=2.0))
    res = _gate(base, better)
    assert res.returncode == 0, res.stdout + res.stderr


def test_benchgate_manifest_mismatch_refused_unless_forced(tmp_path):
    base_payload = _bench_payload()
    other = json.loads(json.dumps(base_payload, default=str))
    other["manifest"]["device_count"] = 512
    base = _write(tmp_path / "base.json", base_payload)
    new = _write(tmp_path / "new.json", other)
    res = _gate(base, new)
    assert res.returncode == 2, res.stdout + res.stderr
    assert "NOT COMPARABLE" in res.stdout
    assert "device_count" in res.stdout
    res = _gate(base, new, "--force")
    assert res.returncode == 0, res.stdout + res.stderr


def test_benchgate_tolerance_scale_widens_band(tmp_path):
    base = _write(tmp_path / "base.json", _bench_payload())
    down = _write(tmp_path / "down.json", _bench_payload(
        env_steps_per_s=5e5))  # -50%: outside tol 40%, inside 40%*2
    assert _gate(base, down).returncode == 1
    assert _gate(base, down, "--tolerance-scale", "2.0").returncode == 0


def test_benchgate_structural_on_tracked_baseline():
    res = _gate("--structural", BASELINE)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "0 structural problem(s)" in res.stdout


def test_benchgate_structural_rejects_broken_json(tmp_path):
    broken = _bench_payload()
    del broken["env_steps_per_s"]
    broken["dqn_holdout_reward_ratio"] = None
    del broken["manifest"]
    p = _write(tmp_path / "broken.json", broken)
    res = _gate("--structural", p)
    assert res.returncode == 2, res.stdout + res.stderr
    assert "no manifest" in res.stdout
    assert "env_steps_per_s" in res.stdout


# ------------------------------------------------- obsview satellites ----
OBSVIEW = os.path.join(ROOT, "tools", "obsview.py")


def _obsview(*args):
    return subprocess.run([sys.executable, OBSVIEW, *args],
                          capture_output=True, text=True, timeout=60)


def test_obsview_fail_on_move(tmp_path):
    a = _write(tmp_path / "a.json", _bench_payload())
    b = _write(tmp_path / "b.json", _bench_payload(env_steps_per_s=2e6))
    assert _obsview("--diff", a, b).returncode == 0  # informational
    res = _obsview("--diff", a, b, "--fail-on-move")
    assert res.returncode == 1, res.stdout + res.stderr
    res = _obsview("--diff", a, a, "--fail-on-move")
    assert res.returncode == 0, res.stdout + res.stderr


def test_obsview_history_renders_trajectory(tmp_path):
    hist = tmp_path / "history.jsonl"
    rows = [
        {"_name": "BENCH_fleet", "_created_utc": f"2026-08-0{i}T00:00:00",
         "_git_sha": "abc", "env_steps_per_s": 1e6 * (1 + i),
         "suites.fleet.detail": 1.0}
        for i in range(3)
    ]
    hist.write_text("\n".join(json.dumps(r) for r in rows) + "\n")
    res = _obsview("--history", str(hist))
    assert res.returncode == 0, res.stdout + res.stderr
    assert "3 run(s)" in res.stdout
    assert "->" in res.stdout and "env_steps_per_s" in res.stdout
    assert "overall" in res.stdout
    assert "suites.fleet.detail" not in res.stdout  # hidden by default
    res = _obsview("--history", str(hist), "--filter", "detail")
    assert "suites.fleet.detail" in res.stdout
    res = _obsview("--history", str(hist), "--name", "no_such_bench")
    assert res.returncode == 0 and "no rows" in res.stdout


def test_save_json_appends_history_row(tmp_path, monkeypatch):
    from benchmarks import common
    monkeypatch.setattr(common, "RESULTS_DIR", str(tmp_path))
    common.save_json("histtest", {"a": 1.5, "nested": {"b": 2}})
    common.save_json("histtest", {"a": 2.5, "nested": {"b": 2}})
    rows = [json.loads(line) for line in
            (tmp_path / "history.jsonl").read_text().splitlines()]
    assert len(rows) == 2
    assert rows[0]["_name"] == "histtest"
    assert rows[0]["a"] == 1.5 and rows[1]["a"] == 2.5
    assert rows[0]["nested.b"] == 2
    assert rows[0]["_created_utc"]
    # the main JSON is still written, manifest attached
    payload = json.loads((tmp_path / "histtest.json").read_text())
    assert payload["manifest"]["jax_version"]


# ----------------------------------------------------- shared helpers ----
def test_flatten_and_rel_diff_shared_semantics():
    flat = flatten({"a": 1, "b": {"c": 2.0}, "manifest": {"skip": 1},
                    "s": "x"})
    assert flat == {"a": 1, "b.c": 2.0, "s": "x"}
    assert rel_diff(100.0, 50.0) == pytest.approx(-0.5)
    assert rel_diff(0.0, 1.0) == pytest.approx(1.0)  # zero-base guard
