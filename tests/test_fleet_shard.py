"""repro.fleet.shard (ISSUE-5): device-sharded fleet execution.

The acceptance claims: the sharded fleet step and training are
BIT-identical (``assert_array_equal``) to the single-device path — the
comparisons below run the same jitted programs on sharded vs unsharded
inputs, which is exactly the GSPMD guarantee being claimed — the
shard-local topology generator never lets an edge span device blocks,
and the ``shard_map`` local-aggregation path matches the global
segment-sum path. At one device every helper degenerates to a no-op
placement and the tests still pin the code paths;
``test_forced_8_device_parity`` re-runs this file under a forced
8-device host platform (the CI fleet-subset step uses 2).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fleet import (FleetConfig, FleetDQN, FleetDQNConfig,
                         FleetOrchestrator, FleetQConfig, FleetQLearning,
                         SyntheticSource, TraceSource, holdout_reward_ratio,
                         init_fleet, record_trace, shard, step_fleet,
                         topology)

NDEV = jax.device_count()


def _mesh():
    return shard.fleet_mesh()


def _full_cfg(cells, users=2, shard_local=False):
    """Every scenario dynamic at once: Markov links, Poisson arrivals,
    churn, a shared-edge topology with cloud queueing and edge
    failures — the hardest case for placement to preserve."""
    return FleetConfig(cells=cells, users=users, p_r2w=0.1, p_w2r=0.2,
                       arrival_rate=1.0, p_join=0.02, p_leave=0.02,
                       n_edges=2 * NDEV, cloud_servers=8.0,
                       capacity_tiers=(1.0, 2.0), p_edge_fail=0.1,
                       shard_local=shard_local, n_shards=NDEV)


def _assert_scen_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a.end_b), np.asarray(b.end_b))
    np.testing.assert_array_equal(np.asarray(a.edge_b),
                                  np.asarray(b.edge_b))
    np.testing.assert_array_equal(np.asarray(a.member),
                                  np.asarray(b.member))
    np.testing.assert_array_equal(np.asarray(a.active),
                                  np.asarray(b.active))
    if a.topo is not None:
        np.testing.assert_array_equal(np.asarray(a.topo.cell_edge),
                                      np.asarray(b.topo.cell_edge))


# ------------------------------------------------------------ placement ---
def test_fleet_spec_shards_divisible_cells():
    mesh = _mesh()
    spec = shard.fleet_spec(mesh, (8 * NDEV, 3), axis=0)
    assert spec[0] == "fleet"
    x = shard.shard_array(jnp.zeros((8 * NDEV, 3)), mesh)
    assert x.sharding.spec[0] == "fleet"


@pytest.mark.skipif(NDEV < 2, reason="needs a real multi-device mesh")
def test_fleet_spec_indivisible_falls_back_to_replication():
    mesh = _mesh()
    spec = shard.fleet_spec(mesh, (8 * NDEV + 1, 3), axis=0)
    assert spec[0] is None          # graceful fallback, never an error


def test_helpers_are_identity_without_mesh():
    scen = init_fleet(jax.random.PRNGKey(0), _full_cfg(4 * NDEV))
    assert shard.shard_scenario(scen, None) is scen
    assert shard.constrain_array(scen.end_b, None) is scen.end_b
    assert shard.replicate(scen, None) is scen


# ------------------------------------------- bit-parity: scenario step ----
def test_step_fleet_sharded_bit_parity():
    """Same jitted step, sharded vs unsharded inputs: bit-identical
    through 5 chained steps of every scenario dynamic at once."""
    mesh = _mesh()
    cfg = _full_cfg(8 * NDEV)
    scen = init_fleet(jax.random.PRNGKey(0), cfg)
    step = jax.jit(lambda k, s: step_fleet(k, s, cfg))
    a, b = scen, shard.shard_scenario(scen, mesh)
    for i in range(5):
        k = jax.random.PRNGKey(10 + i)
        a, b = step(k, a), step(k, b)
        _assert_scen_equal(a, b)
    if NDEV > 1:
        assert b.end_b.sharding.spec[0] == "fleet"   # layout survives


# --------------------------------------- bit-parity: tabular training -----
def _trained_pair(steps=40):
    cfg = _full_cfg(8 * NDEV)
    a = FleetQLearning(SyntheticSource(cfg), cfg=FleetQConfig(), seed=3)
    b = FleetQLearning(SyntheticSource(cfg), cfg=FleetQConfig(), seed=3,
                       mesh=_mesh())
    a.run(steps)
    b.run(steps)
    return a, b


def test_qlearning_training_bit_parity():
    a, b = _trained_pair()
    np.testing.assert_array_equal(np.asarray(a.q), np.asarray(b.q))
    np.testing.assert_array_equal(np.asarray(a.counts),
                                  np.asarray(b.counts))
    _assert_scen_equal(a.scen, b.scen)
    np.testing.assert_array_equal(np.asarray(a.greedy_decisions()),
                                  np.asarray(b.greedy_decisions()))
    # the in-scan metrics accumulator (ISSUE-6) rides the same carry.
    # The accumulator itself adds no cross-lane float ops (see the
    # standalone test below for its own bit-parity), so integer leaves,
    # extrema, and histograms are exact; the float total/sumsq record
    # values like the per-cell mean_ms whose masked-mean arithmetic can
    # contract (FMA) differently under partitioning — ULP-level, the
    # same compilation-context caveat CHANGES.md documents for
    # eager-vs-jit, while the Q-table stays bit-identical above
    for name, da in a.metrics.data.items():
        db = b.metrics.data[name]
        for leaf in ("count", "hist", "mn", "mx"):
            np.testing.assert_array_equal(np.asarray(da[leaf]),
                                          np.asarray(db[leaf]))
        for leaf in ("total", "sumsq"):
            np.testing.assert_allclose(np.asarray(da[leaf]),
                                       np.asarray(db[leaf]), rtol=1e-6)
    sa, sb = a.metrics_summary(), b.metrics_summary()
    for name in sa:
        assert sa[name]["count"] == sb[name]["count"]
        assert sa[name]["hist"] == sb[name]["hist"]
        assert sa[name]["mean"] == pytest.approx(sb[name]["mean"],
                                                 rel=1e-6)
    if NDEV > 1:
        assert b.q.sharding.spec[0] == "fleet"       # donation kept layout


def test_fused_impl_sharded_training_bit_parity():
    """ISSUE-10: the fused hot path under a mesh. ``impl='pallas'``
    resolves to the fused-jnp formulation when a mesh is attached
    (GSPMD cannot partition ``pallas_call``; see
    ``kernels.ops.resolve_rl_impl``) — per-cell elementwise + reduces
    along the unsharded action axis, so a sharded fused run is
    bit-identical to the single-device fused run AND to the legacy
    unfused step."""
    from repro.kernels import ops
    cfg = _full_cfg(8 * NDEV)
    single = FleetQLearning(SyntheticSource(cfg), cfg=FleetQConfig(),
                            seed=3, impl="pallas")
    meshed = FleetQLearning(SyntheticSource(cfg), cfg=FleetQConfig(),
                            seed=3, impl="pallas", mesh=_mesh())
    legacy = FleetQLearning(SyntheticSource(cfg), cfg=FleetQConfig(),
                            seed=3, impl="xla", mesh=_mesh())
    assert ops.resolve_rl_impl("pallas", meshed.mesh) == "ref"
    assert meshed._op_impl == "ref"
    for ag in (single, meshed, legacy):
        ag.run(40)
    np.testing.assert_array_equal(np.asarray(single.q),
                                  np.asarray(meshed.q))
    np.testing.assert_array_equal(np.asarray(legacy.q),
                                  np.asarray(meshed.q))
    np.testing.assert_array_equal(np.asarray(single.counts),
                                  np.asarray(meshed.counts))
    np.testing.assert_array_equal(
        np.asarray(single.greedy_decisions()),
        np.asarray(meshed.greedy_decisions()))
    if NDEV > 1:
        assert meshed.q.sharding.spec[0] == "fleet"


def test_metrics_accumulator_sharded_update_bit_parity():
    """Standalone obs satellite: the same jitted update on a placed
    accumulator (lane leaves sharded along the fleet axis, histograms
    replicated) is bit-identical to the unplaced one — per-lane
    elementwise work plus an integer scatter, the op classes the fleet
    parity discipline allows."""
    from repro.obs import MetricDef, MetricsAccumulator
    mesh = _mesh()
    lanes = 8 * NDEV
    defs = {"r": MetricDef(lo=-2.5, hi=0.0, bins=16, lanes=lanes),
            "eps": MetricDef(lo=0.0, hi=1.0, bins=8)}
    plain = MetricsAccumulator.create(defs)
    placed = plain.place(lambda x, axis=0: shard.shard_array(x, mesh,
                                                             axis=axis),
                         lambda x: shard.replicate(x, mesh))
    if NDEV > 1:
        assert placed.data["r"]["total"].sharding.spec[0] == "fleet"
        assert placed.data["r"]["hist"].sharding.is_fully_replicated

    @jax.jit
    def roll(acc, key):
        def body(carry, k):
            x = -2.5 * jax.random.uniform(k, (lanes,))
            e = jax.random.uniform(jax.random.fold_in(k, 1), (1,))
            return carry.update({"r": x, "eps": e}), None
        acc, _ = jax.lax.scan(body, acc, jax.random.split(key, 10))
        return acc

    key = jax.random.PRNGKey(0)
    a, b = roll(plain, key), roll(placed, key)
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    if NDEV > 1:                                     # layout survived scan
        assert b.data["r"]["count"].sharding.spec[0] == "fleet"
    # and merging the two reduces exactly (integer + extrema leaves)
    m = a.merge(b).summary()["r"]
    assert m["count"] == 2 * a.summary()["r"]["count"]


def test_windowed_metrics_sharded_update_bit_parity():
    """ISSUE-8: the ``(n_windows, lanes)`` ring — integer slot index on
    the replicated window axis, elementwise along the sharded lane
    axis — is the permitted op class, so windowed leaves stay
    bit-identical under placement too."""
    from repro.obs import MetricDef, MetricsAccumulator
    mesh = _mesh()
    lanes = 8 * NDEV
    defs = {"r": MetricDef(lo=-2.5, hi=0.0, bins=16, lanes=lanes,
                           n_windows=4, window_len=3)}
    plain = MetricsAccumulator.create(defs)
    placed = plain.place(lambda x, axis=0: shard.shard_array(x, mesh,
                                                             axis=axis),
                         lambda x: shard.replicate(x, mesh))
    if NDEV > 1:
        assert placed.data["r"]["wtotal"].sharding.spec[1] == "fleet"
        assert placed.data["r"]["hist"].sharding.is_fully_replicated

    @jax.jit
    def roll(acc, key):
        def body(carry, k):
            return carry.update(
                {"r": -2.5 * jax.random.uniform(k, (lanes,))}), None
        acc, _ = jax.lax.scan(body, acc, jax.random.split(key, 10))
        return acc

    a, b = (roll(acc, jax.random.PRNGKey(4)) for acc in (plain, placed))
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    if NDEV > 1:                                     # layout survived scan
        assert b.data["r"]["wcount"].sharding.spec[1] == "fleet"


def test_windowed_training_bit_parity_and_matches_unwindowed():
    """ISSUE-8 acceptance: a windowed FleetQLearning run is (a)
    bit-identical sharded vs single-device on every leaf including the
    ring, and (b) bit-identical on the shared (un-windowed) leaves and
    the Q-table to a run with windows off — windows only ADD telemetry,
    they never perturb training."""
    cfg = _full_cfg(8 * NDEV)
    w = dict(n_windows=4, window_len=10)
    a = FleetQLearning(SyntheticSource(cfg), cfg=FleetQConfig(), seed=3,
                       **w)
    b = FleetQLearning(SyntheticSource(cfg), cfg=FleetQConfig(), seed=3,
                       mesh=_mesh(), **w)
    off = FleetQLearning(SyntheticSource(cfg), cfg=FleetQConfig(), seed=3)
    for agent in (a, b, off):
        agent.run(40)
    np.testing.assert_array_equal(np.asarray(a.q), np.asarray(b.q))
    for name, da in a.metrics.data.items():
        db = b.metrics.data[name]
        for leaf in ("count", "hist", "underflow", "overflow",
                     "wcount", "wmn", "wmx"):
            np.testing.assert_array_equal(np.asarray(da[leaf]),
                                          np.asarray(db[leaf]))
        np.testing.assert_allclose(np.asarray(da["wtotal"]),
                                   np.asarray(db["wtotal"]), rtol=1e-6)
    # (b) windows on vs off: training stream untouched
    np.testing.assert_array_equal(np.asarray(a.q), np.asarray(off.q))
    _assert_scen_equal(a.scen, off.scen)
    for name, da in a.metrics.data.items():
        do = off.metrics.data[name]
        for leaf in do:                              # shared leaves only
            np.testing.assert_array_equal(np.asarray(da[leaf]),
                                          np.asarray(do[leaf]))
    # and the ring is self-consistent: per-window counts sum to totals
    s = a.metrics_summary()["reward"]
    assert sum(s["windows"]["count"]) == s["count"]


def test_windowed_ring_sums_property():
    """Hypothesis property (ISSUE-8): for any update stream, per-window
    counts sum EXACTLY to the whole-run count (integer leaves), and the
    float window totals sum to the run total within reassociation ULPs."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    from repro.obs import MetricDef, MetricsAccumulator

    @hyp.given(st.data())
    @hyp.settings(max_examples=20, deadline=None)
    def run(data):
        lanes = data.draw(st.integers(1, 4), label="lanes")
        n_windows = data.draw(st.integers(1, 5), label="n_windows")
        window_len = data.draw(st.integers(1, 4), label="window_len")
        steps = data.draw(st.integers(0, 24), label="steps")
        vals = data.draw(st.lists(
            st.lists(st.floats(-1e3, 1e3, allow_nan=False, width=32),
                     min_size=lanes, max_size=lanes),
            min_size=steps, max_size=steps), label="vals")
        acc = MetricsAccumulator.create(
            {"m": MetricDef(lo=-10.0, hi=10.0, bins=8, lanes=lanes,
                            n_windows=n_windows, window_len=window_len)})
        for row in vals:
            acc = acc.update({"m": jnp.asarray(row, jnp.float32)})
        d = acc.data["m"]
        np.testing.assert_array_equal(
            np.asarray(d["wcount"]).sum(0), np.asarray(d["count"]))
        np.testing.assert_allclose(
            np.asarray(d["wtotal"], np.float64).sum(0),
            np.asarray(d["total"], np.float64), rtol=1e-5, atol=1e-4)
        assert int(acc.step) == steps

    run()


def test_holdout_reward_ratio_bit_parity():
    a, b = _trained_pair()
    ha = holdout_reward_ratio(a, a.scen)
    hb = holdout_reward_ratio(b, b.scen)
    assert ha.ratio == hb.ratio
    np.testing.assert_array_equal(ha.achieved, hb.achieved)
    np.testing.assert_array_equal(ha.optimal, hb.optimal)
    np.testing.assert_array_equal(ha.feasible, hb.feasible)


def test_orchestrator_routes_sharded_fleet():
    _, b = _trained_pair(steps=20)
    orch = FleetOrchestrator(b)
    assert orch.mesh is b.mesh                       # inherited knob
    dec, ids = orch.route()
    assert np.asarray(dec).shape == (8 * NDEV, 2)
    assert np.asarray(ids).shape == (8 * NDEV,)


# ------------------------------------------------ DQN data parallelism ----
def test_dqn_sharded_cold_decisions_match_and_training_runs():
    cfg = FleetConfig(cells=8 * NDEV, users=2, arrival_rate=1.0)
    a = FleetDQN(SyntheticSource(cfg), cfg=FleetDQNConfig(), seed=5)
    b = FleetDQN(SyntheticSource(cfg), cfg=FleetDQNConfig(), seed=5,
                 mesh=_mesh())
    # same seed -> identical replicated params; the cold greedy pass is
    # per-cell, so sharding the fleet cannot change any decision
    scen = init_fleet(jax.random.PRNGKey(1), cfg)
    counts = jnp.zeros((cfg.cells, 2), jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(a.policy_decisions(counts, scen)[0]),
        np.asarray(b.policy_decisions(
            shard.shard_array(counts, b.mesh),
            shard.shard_scenario(scen, b.mesh))[0]))
    b.run(30)                                        # trains sharded
    if NDEV > 1:
        assert b.buffer.s.sharding.spec[0] == "fleet"
        leaf = jax.tree_util.tree_leaves(b.params)[0]
        assert leaf.sharding.spec == jax.sharding.PartitionSpec()
    h = holdout_reward_ratio(b, b.scen)
    assert 0.0 < h.ratio <= 1.0 + 1e-6


# ---------------------------------------------- trace replay placement ----
def test_tracesource_mesh_training_bit_parity():
    base = SyntheticSource(FleetConfig(cells=4 * NDEV, users=2,
                                       arrival_rate=1.0, p_r2w=0.1,
                                       p_w2r=0.2))
    trace = record_trace(base, jax.random.PRNGKey(0), 12)
    a = FleetQLearning(TraceSource(trace), seed=7)
    b = FleetQLearning(TraceSource(trace, mesh=_mesh()), seed=7)
    assert b.mesh is not None                        # inherited from source
    a.run(24)
    b.run(24)
    np.testing.assert_array_equal(np.asarray(a.q), np.asarray(b.q))
    _assert_scen_equal(a.scen, b.scen)


def test_synthetic_source_mesh_reset_is_value_identical():
    cfg = _full_cfg(4 * NDEV)
    plain, _ = SyntheticSource(cfg).reset(jax.random.PRNGKey(2))
    placed, _ = SyntheticSource(cfg, mesh=_mesh()).reset(
        jax.random.PRNGKey(2))
    _assert_scen_equal(plain, placed)


# ------------------------------------------------- shard-local topology ---
def test_shard_local_generator_invariant():
    """Satellite: no edge spans shards when shard_local=True — for the
    generator AND through FleetConfig/init_fleet."""
    n_shards = max(NDEV, 4)
    topo = topology.random_topology(jax.random.PRNGKey(0), 8 * n_shards,
                                    2 * n_shards, shard_local=True,
                                    n_shards=n_shards)
    assert topology.is_shard_local(topo, n_shards)
    cpb, epb = topology.shard_blocks(topo.cells, topo.n_edges, n_shards)
    ce = np.asarray(topo.cell_edge)
    for e in range(topo.n_edges):                    # edge-wise statement
        owners = np.nonzero(ce == e)[0]
        assert len(np.unique(owners // cpb)) <= 1
        assert (owners // cpb == e // epb).all()
    # the unconstrained generator does cross blocks (same sizes)
    free = topology.random_topology(jax.random.PRNGKey(0), 8 * n_shards,
                                    2 * n_shards)
    assert not topology.is_shard_local(free, n_shards)


def test_shard_local_divisibility_and_assignment_errors():
    with pytest.raises(ValueError, match="divisible"):
        topology.random_topology(jax.random.PRNGKey(0), 10, 4,
                                 shard_local=True, n_shards=4)
    from repro.fleet.scenarios import make_topology
    with pytest.raises(ValueError, match="random"):
        make_topology(jax.random.PRNGKey(0),
                      FleetConfig(cells=8, users=2, n_edges=4,
                                  assignment="skewed", shard_local=True,
                                  n_shards=2))
    # edge failures reroute across device blocks — they would break the
    # locality invariant mid-run where jit cannot detect it, so the
    # combination is rejected up front
    with pytest.raises(ValueError, match="p_edge_fail"):
        make_topology(jax.random.PRNGKey(0),
                      FleetConfig(cells=8, users=2, n_edges=4,
                                  p_edge_fail=0.1, shard_local=True,
                                  n_shards=2))


def test_local_contention_matches_global_bit_exact():
    """Mode (a) vs mode (b): the shard_map local aggregation equals the
    global segment-sum path — exactly, since the per-edge totals are
    integer sums and the cloud multiplier sees the same psum'd total."""
    mesh = _mesh()
    cells, n_edges = 8 * NDEV, 2 * NDEV
    topo = topology.random_topology(jax.random.PRNGKey(1), cells, n_edges,
                                    shard_local=True, n_shards=NDEV,
                                    capacity_tiers=(1.0, 2.0),
                                    cloud_servers=16.0)
    scen = init_fleet(jax.random.PRNGKey(2),
                      FleetConfig(cells=cells, users=3, arrival_rate=1.0))
    pu = jnp.asarray(np.random.default_rng(0).integers(0, 10, (cells, 3)),
                     jnp.int32)
    ref = topology.shared_contention(pu, topo, active=scen.active)
    topo_s = shard.shard_topology(topo, mesh)
    scen_s = shard.shard_scenario(scen, mesh)
    got = shard.local_contention(shard.shard_array(pu, mesh), topo_s, mesh,
                                 active=scen_s.active)
    for r, g in zip(ref, got):
        np.testing.assert_array_equal(np.asarray(r), np.asarray(g))
    # the jitted seam agrees too (what the benchmark times)
    jit_got = jax.jit(lambda p, t, m: shard.local_contention(
        p, t, mesh, active=m))(shard.shard_array(pu, mesh), topo_s,
                               scen_s.active)
    for r, g in zip(ref, jit_got):
        np.testing.assert_array_equal(np.asarray(r), np.asarray(g))
    # and the full eager response path is bit-identical end to end
    r_ms, r_acc = topology.topology_expected_response(
        pu, scen.end_b, scen.edge_b, topo, active=scen.active)
    l_ms, l_acc = shard.local_expected_response(
        shard.shard_array(pu, mesh), scen_s.end_b, scen_s.edge_b, topo_s,
        mesh, active=scen_s.active)
    np.testing.assert_array_equal(np.asarray(r_ms), np.asarray(l_ms))
    np.testing.assert_array_equal(np.asarray(r_acc), np.asarray(l_acc))


def test_local_contention_rejects_cross_shard_topology():
    mesh = _mesh()
    if NDEV < 2:
        pytest.skip("locality is unfalsifiable on one device")
    bad = topology.hot_edge_topology(8 * NDEV, 2 * NDEV)   # spans blocks
    pu = jnp.zeros((8 * NDEV, 2), jnp.int32)
    with pytest.raises(ValueError, match="shard-local"):
        shard.local_contention(pu, shard.shard_topology(bad, mesh), mesh)


# --------------------------------------------------- forced 8 devices -----
@pytest.mark.skipif(NDEV >= 8 or os.environ.get("REPRO_SHARD_SUBPROCESS"),
                    reason="already on a multi-device host platform")
def test_forced_8_device_parity():
    """The acceptance run: this whole file under a forced 8-device CPU
    host platform (jax locks the device count at first init, so it must
    be a fresh process)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8")
    env["REPRO_SHARD_SUBPROCESS"] = "1"
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..",
                                      "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    res = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", __file__],
        env=env, capture_output=True, text=True, timeout=1200)
    assert res.returncode == 0, \
        f"8-device run failed:\n{res.stdout}\n{res.stderr}"
